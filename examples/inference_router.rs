//! Inference-mode scheduling (§9 Discussion): latency-sensitive MoE
//! serving, where per-request scheduling time matters more than steady
//! state. Simulates a bursty request stream (variable batch sizes, shifting
//! expert popularity) and compares three per-batch solvers on the same
//! placement:
//!
//! * warm LP  — the training-path scheduler (carries basis state),
//! * cold LP  — a fresh simplex per batch (no cross-request state),
//! * max-flow — the paper's proposed LP replacement (stateless, integral).
//!
//! Run: `cargo run --release --example inference_router [-- --requests 200]`

use micromoe::bench_harness::{fmt_time, Table};
use micromoe::cli::Args;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::flow::flow_schedule;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::stats::Summary;
use micromoe::topology::Topology;

fn main() {
    let args = Args::from_env();
    let requests = args.usize_or("requests", 200);
    let topo = Topology::new(8, 4, 2, 8);
    let e = 32;
    let placement = symmetric_placement(&topo, e);

    // bursty request stream: batch sizes 16..2048 tokens/GPU, popularity
    // ranking rotates every ~25 requests (session locality)
    let mut rng = Rng::new(17);
    let mut rank: Vec<usize> = (0..e).collect();
    let zipf = Zipf::new(e, 1.1);
    let mut batches = Vec::with_capacity(requests);
    for r in 0..requests {
        if r % 25 == 0 {
            rng.shuffle(&mut rank);
        }
        let per_gpu = 16 << rng.below(8); // 16..2048
        let mut lm = LoadMatrix::zeros(e, 8);
        for g in 0..8 {
            for _ in 0..per_gpu {
                lm.add(rank[zipf.sample(&mut rng)], g, 1);
            }
        }
        batches.push(lm);
    }

    let mut warm = MicroEpScheduler::new(
        placement.clone(),
        Some(topo.clone()),
        SchedulerOptions::default(),
    );
    let mut cold_opts = SchedulerOptions::default();
    cold_opts.warm_start = false;
    let mut cold = MicroEpScheduler::new(placement.clone(), Some(topo), cold_opts);

    let mut t_warm = Vec::new();
    let mut t_cold = Vec::new();
    let mut t_flow = Vec::new();
    let mut agree = 0usize;
    for lm in &batches {
        let t0 = std::time::Instant::now();
        let sw = warm.schedule(lm);
        t_warm.push(t0.elapsed().as_secs_f64());

        let t0 = std::time::Instant::now();
        let _sc = cold.schedule(lm);
        t_cold.push(t0.elapsed().as_secs_f64());

        let t0 = std::time::Instant::now();
        let sf = flow_schedule(&placement, lm);
        t_flow.push(t0.elapsed().as_secs_f64());

        if (sw.stats.lp_objective.ceil() as i64 - sf.max_load as i64).abs() <= 1 {
            agree += 1;
        }
    }

    let mut table = Table::new(
        &format!("inference scheduling latency over {requests} bursty requests"),
        &["solver", "p50", "p95", "max"],
    );
    for (name, ts) in [("warm LP", &t_warm), ("cold LP", &t_cold), ("max-flow", &t_flow)] {
        let s = Summary::of(ts);
        table.row(vec![
            name.to_string(),
            fmt_time(s.p50),
            fmt_time(s.p95),
            fmt_time(s.max),
        ]);
    }
    table.print();
    println!(
        "\noptima agreement (flow == ⌈LP⌉): {agree}/{requests}\n\
         §9: for inference, tail latency matters — compare p95/max, not p50; \
         the stateless flow solver has no warm-state dependence on the \
         previous request's shape."
    );
}
