//! Inference-mode scheduling (§9 Discussion): latency-sensitive MoE
//! serving, where per-request scheduling time matters more than steady
//! state. Simulates a bursty request stream (variable batch sizes, shifting
//! expert popularity) and compares three registered policies on the same
//! placement through the closed-loop [`ServingRunner`]:
//!
//! * warm LP  — the training-path scheduler (carries basis state),
//! * cold LP  — a fresh simplex per batch (no cross-request state),
//! * max-flow — the `least-loaded-inference` policy (stateless, integral).
//!
//! Run: `cargo run --release --example inference_router [-- --requests 200]`
//!
//! Pass `--serve` to instead drive the open-loop batching-window server
//! ([`micromoe::serving::MoeServer`]) under a configurable arrival process
//! (`--arrival poisson|bursty|diurnal`, `--window-us`, `--max-batch`, …).

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::{fmt_time, Table};
use micromoe::cli::Args;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::placement::Placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::flow::flow_schedule;
use micromoe::scheduler::LoadMatrix;
use micromoe::serving::{ArrivalGen, ServingRunner, SlaStats, TokenModel};
use micromoe::topology::Topology;
use micromoe::workload::TopicMix;

fn session(policy: &str, warm: bool, label: &str, topo: &Topology, p: &Placement) -> MoeSession {
    let opts =
        micromoe::scheduler::SchedulerOptions { warm_start: warm, ..Default::default() };
    MoeSession::builder()
        .topology(topo.clone())
        .placement(p.clone())
        .policy_name(policy)
        .options(opts)
        .label(label)
        .build()
        .expect("registered policy builds")
}

fn exact_us(sla: &SlaStats, q: f64) -> f64 {
    sla.solve.exact(q)
}

fn main() {
    let args = Args::from_env();
    let requests = args.usize_or("requests", 200);
    let topo = Topology::new(8, 4, 2, 8);
    let e = 32;
    let placement = symmetric_placement(&topo, e);

    if args.flag("serve") {
        return serve_demo(&args, &topo, e);
    }

    // bursty request stream: batch sizes 16..2048 tokens/GPU, popularity
    // ranking rotates every ~25 requests (session locality)
    let mut rng = Rng::new(17);
    let mut rank: Vec<usize> = (0..e).collect();
    let zipf = Zipf::new(e, 1.1);
    let mut batches = Vec::with_capacity(requests);
    for r in 0..requests {
        if r % 25 == 0 {
            rng.shuffle(&mut rank);
        }
        let per_gpu = 16 << rng.below(8); // 16..2048
        let mut lm = LoadMatrix::zeros(e, 8);
        for g in 0..8 {
            for _ in 0..per_gpu {
                lm.add(rank[zipf.sample(&mut rng)], g, 1);
            }
        }
        batches.push(lm);
    }

    let arms = [
        ("warm LP", session("micromoe", true, "warm LP", &topo, &placement)),
        ("cold LP", session("micromoe", false, "cold LP", &topo, &placement)),
        ("max-flow", session("least-loaded-inference", true, "max-flow", &topo, &placement)),
    ];

    let mut table = Table::new(
        &format!("inference scheduling latency over {requests} bursty requests"),
        &["solver", "p50", "p95", "max"],
    );
    let mut flow_plans = Vec::new();
    let mut warm_plans = Vec::new();
    for (name, s) in arms {
        let mut runner = ServingRunner::new(s);
        let plans = runner.run(&batches);
        let sla = runner.sla();
        table.row(vec![
            name.to_string(),
            fmt_time(exact_us(sla, 0.50) * 1e-6),
            fmt_time(exact_us(sla, 0.95) * 1e-6),
            fmt_time(sla.solve.max() * 1e-6),
        ]);
        match name {
            "warm LP" => warm_plans = plans,
            "max-flow" => flow_plans = plans,
            _ => {}
        }
    }
    table.print();

    // optimum agreement: the stateless flow router's bottleneck is the
    // integral optimum, so it never exceeds (and usually matches) warm LP's
    let mut agree = 0usize;
    for (i, lm) in batches.iter().enumerate() {
        let flow_max = *flow_plans[i].gpu_compute.iter().max().unwrap_or(&0);
        let warm_max = *warm_plans[i].gpu_compute.iter().max().unwrap_or(&0);
        assert_eq!(
            flow_max,
            flow_schedule(&placement, lm).max_load,
            "request {i}: policy deviated from the flow optimum"
        );
        assert!(flow_max <= warm_max, "request {i}: flow above a feasible LP plan");
        if flow_max == warm_max {
            agree += 1;
        }
    }
    println!(
        "\noptima agreement (flow max == warm-LP max): {agree}/{requests}\n\
         §9: for inference, tail latency matters — compare p95/max, not p50; \
         the stateless flow solver has no warm-state dependence on the \
         previous request's shape."
    );
}

/// `--serve`: the open-loop complement — a batching-window server under a
/// CLI-selected arrival process, reporting SLO accounting.
fn serve_demo(args: &Args, topo: &Topology, e: usize) {
    let n = args.usize_or("requests", 2_000);
    let process = args.arrival_process().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let cfg = args.serving_config();
    let seed = args.u64_or("seed", 17);
    let placement = symmetric_placement(topo, e);
    let session = session("least-loaded-inference", true, "max-flow serving", topo, &placement);
    let reqs = ArrivalGen::new(process, TokenModel::Fixed(32), seed).take(n);
    let mut server = session.serve(cfg, TopicMix::new(e, 1.1, 25, seed));
    let trace = server.run(&reqs);
    let sla = server.sla();
    let mut table = Table::new(
        &format!("open-loop serving: {n} requests, {} windows", trace.windows.len()),
        &["track", "p50", "p95", "p99 (P²)"],
    );
    for (name, t) in
        [("queue", &sla.queue), ("solve", &sla.solve), ("dispatch", &sla.dispatch), ("e2e", &sla.e2e)]
    {
        table.row(vec![
            name.to_string(),
            fmt_time(t.exact(0.50) * 1e-6),
            fmt_time(t.exact(0.95) * 1e-6),
            fmt_time(t.p2_p99() * 1e-6),
        ]);
    }
    table.print();
    println!(
        "served {} / shed {} / deadline misses {} (miss rate {:.2}%)",
        sla.served,
        sla.shed,
        sla.deadline_misses,
        sla.miss_rate() * 100.0
    );
}
