//! Quickstart: MicroEP through the unified session API in ~60 lines.
//!
//! Builds the paper's §7 testbed shape (DP=8, EP=4, d=2, 32 experts),
//! generates one skewed micro-batch, and steps two policies from the
//! registry over it: vanilla EP suffers the straggler, MicroEP's LP
//! schedule balances it.
//!
//! Run: `cargo run --release --example quickstart`

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::Table;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::LoadMatrix;
use micromoe::stats::imbalance_ratio;
use micromoe::topology::Topology;

fn main() {
    // 1. topology: 8-GPU DP group, EP degree 4, MicroEP merges d=2 EP groups
    let topo = Topology::new(8, 4, 2, 8);
    println!(
        "topology: DP={} EP={} d={} -> one MicroEP group of {} GPUs",
        topo.dp_degree,
        topo.ep_degree,
        topo.d,
        topo.microep_group_size()
    );

    // 2. one micro-batch of gate outputs with Zipf(1.0) skew
    let mut rng = Rng::new(7);
    let zipf = Zipf::new(32, 1.0);
    let mut loads = LoadMatrix::zeros(32, 8);
    for g in 0..8 {
        for _ in 0..8192 {
            loads.add(zipf.sample(&mut rng), g, 1);
        }
    }
    let hottest = loads.expert_loads().into_iter().max().unwrap();
    println!("micro-batch: {} tokens, hottest expert holds {hottest}", loads.total());

    // 3. two policies from the registry, one step loop: the LP scheduler
    //    (symmetric Cayley placement built for us) vs vanilla EP
    let session = |policy: &str| {
        MoeSession::builder()
            .topology(topo.clone())
            .experts(32)
            .policy_name(policy)
            .build()
            .expect("registered policy")
    };
    let mut micro = session("micromoe");
    let mut vanilla = session("vanilla-ep");

    // 4. step both on the same loads and compare per-GPU compute
    let as_f64 = |v: &[u64]| v.iter().map(|&x| x as f64).collect::<Vec<_>>();
    let mut table = Table::new(
        "per-GPU compute loads (tokens)",
        &["system", "g0", "g1", "g2", "g3", "g4", "g5", "g6", "g7", "max/avg"],
    );
    for s in [&mut vanilla, &mut micro] {
        let out = s.step(std::slice::from_ref(&loads));
        let gpu = &out.layers[0].gpu_compute;
        let mut row = vec![s.name().to_string()];
        row.extend(gpu.iter().map(|l| l.to_string()));
        row.push(format!("{:.3}", imbalance_ratio(&as_f64(gpu))));
        table.row(row);
    }
    table.print();

    let st = micro.stats();
    println!(
        "\nLP solved in {} pivots ({}) — every micro-batch gets the Eq.-3 optimum.",
        st.lp_pivots,
        micromoe::bench_harness::fmt_time(st.sched_seconds),
    );
}
