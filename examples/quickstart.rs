//! Quickstart: MicroEP in ~60 lines.
//!
//! Builds the paper's §7 testbed shape (DP=8, EP=4, d=2, 32 experts),
//! generates one skewed micro-batch, and shows what each system does with
//! it: vanilla EP suffers the straggler, MicroEP's LP schedule balances it.
//!
//! Run: `cargo run --release --example quickstart`

use micromoe::baselines::{MoeSystem, VanillaEp};
use micromoe::bench_harness::Table;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::stats::imbalance_ratio;
use micromoe::topology::Topology;

fn main() {
    // 1. topology: 8-GPU DP group, EP degree 4, MicroEP merges d=2 EP groups
    let topo = Topology::new(8, 4, 2, 8);
    println!(
        "topology: DP={} EP={} d={} -> one MicroEP group of {} GPUs",
        topo.dp_degree, topo.ep_degree, topo.d, topo.microep_group_size()
    );

    // 2. expert placement: symmetric Cayley graph (App. B)
    let placement = symmetric_placement(&topo, 32);
    println!("placement: 32 experts × {} replicas, consistent slots: {:?}", topo.d,
             placement.check_consistency().is_ok());

    // 3. one micro-batch of gate outputs with Zipf(1.0) skew
    let mut rng = Rng::new(7);
    let zipf = Zipf::new(32, 1.0);
    let mut loads = LoadMatrix::zeros(32, 8);
    for g in 0..8 {
        for _ in 0..8192 {
            loads.add(zipf.sample(&mut rng), g, 1);
        }
    }
    let hottest = loads.expert_loads().into_iter().max().unwrap();
    println!("micro-batch: {} tokens, hottest expert holds {hottest}", loads.total());

    // 4. schedule it: LP (LPP 1) + Algorithm-1 routing
    let mut sched = MicroEpScheduler::new(placement.clone(), Some(topo.clone()), SchedulerOptions::default());
    let schedule = sched.schedule(&loads);

    // 5. compare with vanilla EP
    let mut vanilla = VanillaEp::new(topo, 32);
    let plan = vanilla.plan(&loads);

    let as_f64 = |v: &[u64]| v.iter().map(|&x| x as f64).collect::<Vec<_>>();
    let mut table = Table::new(
        "per-GPU compute loads (tokens)",
        &["system", "g0", "g1", "g2", "g3", "g4", "g5", "g6", "g7", "max/avg"],
    );
    for (name, loads_v) in [
        ("Megatron-LM (EP)", plan.gpu_compute.clone()),
        ("MicroEP (LP)", schedule.gpu_loads(&placement)),
    ] {
        let mut row = vec![name.to_string()];
        row.extend(loads_v.iter().map(|l| l.to_string()));
        row.push(format!("{:.3}", imbalance_ratio(&as_f64(&loads_v))));
        table.row(row);
    }
    table.print();

    println!(
        "\nLP solved in {} pivots ({}), objective {:.0} tokens — the Eq.-3 optimum.",
        schedule.stats.lp_iterations,
        micromoe::bench_harness::fmt_time(schedule.stats.solve_ns as f64 * 1e-9),
        schedule.stats.lp_objective,
    );
}
