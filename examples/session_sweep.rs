//! Policy sweep through the unified session API: every registered policy
//! (with the `micromoe` policy expanded over its three engine modes) on a
//! 64-GPU drifting-Zipf trace, reporting balance, scheduling time, LP
//! pivots, and speculation hit rate per policy, and emitting the
//! `session_sweep.json` artifact CI uploads beside fig9/engine_pipeline.
//!
//! Run: `cargo run --release --example session_sweep`
//! Env knobs (CI smoke): `SESSION_SWEEP_STEPS` (default 12),
//! `SESSION_SWEEP_TOKENS` (tokens per GPU per step, default 1024).

use micromoe::balancer::{registered_policies, MoeSession};
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::config::PolicySpec;
use micromoe::control::ControlSpec;
use micromoe::engine::EngineMode;
use micromoe::scheduler::LoadMatrix;
use micromoe::ser::Json;
use micromoe::stats::imbalance_ratio;
use micromoe::topology::Topology;
use micromoe::workload::{DriftingWorkload, Workload};

const EXPERTS: usize = 128;
const GPUS: usize = 64;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let steps = env_usize("SESSION_SWEEP_STEPS", 12);
    let tokens = env_usize("SESSION_SWEEP_TOKENS", 1024) as u64;
    // one 64-GPU MicroEP scope: DP=64, EP=32, d=2, 8 GPUs/node
    let topo = Topology::new(GPUS, GPUS / 2, 2, 8);

    // one shared drifting-Zipf trace so every policy sees identical loads
    let mut wl = DriftingWorkload::new(EXPERTS, GPUS, tokens, 1.0, 4, 42);
    let trace: Vec<LoadMatrix> = (0..steps).map(|_| wl.next_batch()).collect();

    // every registered policy; micromoe fans out over its engine modes
    let mut arms: Vec<(String, PolicySpec)> = Vec::new();
    for &name in registered_policies() {
        if name == "micromoe" {
            for (label, engine) in [
                ("micromoe (barrier)", EngineMode::Barrier),
                ("micromoe (pipeline)", EngineMode::pipeline()),
                ("micromoe (speculative)", EngineMode::speculative()),
            ] {
                let mut spec = PolicySpec { name: name.to_string(), ..Default::default() };
                spec.options.engine = engine;
                arms.push((label.to_string(), spec));
            }
            // the two-timescale arm: barrier engine plus the slow
            // placement-control loop (replication/eviction every 4 steps,
            // migration downtime charged at h100_testbed pricing)
            let mut spec = PolicySpec { name: name.to_string(), ..Default::default() };
            spec.control = Some(ControlSpec { interval: 4, dwell: 2, ..Default::default() });
            arms.push(("micromoe (controlled)".to_string(), spec));
        } else {
            let spec = PolicySpec { name: name.to_string(), ..Default::default() };
            arms.push((name.to_string(), spec));
        }
    }

    let mut table = Table::new(
        &format!(
            "Session sweep: all registered policies ({GPUS} GPUs, {EXPERTS} experts, \
             drifting Zipf s=1.0, {steps} steps)"
        ),
        &["policy", "mean imb", "sched/step", "LP pivots", "hit rate", "rungs w/c/g/p"],
    );
    let mut json = Vec::new();
    for (label, spec) in arms {
        let mut session = MoeSession::builder()
            .topology(topo.clone())
            .experts(EXPERTS)
            .policy(spec.clone())
            .label(&label)
            .build()
            .expect("registered policy builds");
        let mut imb_acc = 0.0;
        for lm in &trace {
            let out = session.step(std::slice::from_ref(lm));
            imb_acc += imbalance_ratio(
                &out.layers[0].gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            );
        }
        let mean_imb = imb_acc / trace.len() as f64;
        let st = session.stats();
        let hit_rate = session.engine_stats().map(|e| e.hit_rate());
        // degradation-rung counts (warm/cold LP, greedy, passthrough):
        // anything right of the LP columns is a silent-fallback red flag
        // the CI sweep watches for
        let deg = st.degradation;
        table.row(vec![
            label.clone(),
            format!("{mean_imb:.3}"),
            fmt_time(st.sched_seconds_per_step()),
            st.lp_pivots.to_string(),
            hit_rate.map_or("-".to_string(), |h| format!("{:.0}%", h * 100.0)),
            format!("{}/{}/{}/{}", deg.warm_lp, deg.cold_lp, deg.greedy, deg.passthrough),
        ]);
        json.push(Json::obj(vec![
            ("policy", Json::Str(label)),
            ("spec", spec.to_json()),
            ("gpus", Json::Num(GPUS as f64)),
            ("experts", Json::Num(EXPERTS as f64)),
            ("steps", Json::Num(steps as f64)),
            ("mean_imbalance", Json::Num(mean_imb)),
            ("sched_s_per_step", Json::Num(st.sched_seconds_per_step())),
            ("lp_pivots", Json::Num(st.lp_pivots as f64)),
            ("warm_layers", Json::Num(st.warm_layers as f64)),
            ("spec_hit_rate", hit_rate.map_or(Json::Null, Json::Num)),
            ("rung_warm_lp", Json::Num(deg.warm_lp as f64)),
            ("rung_cold_lp", Json::Num(deg.cold_lp as f64)),
            ("rung_greedy", Json::Num(deg.greedy as f64)),
            ("rung_passthrough", Json::Num(deg.passthrough as f64)),
            ("lp_rate", Json::Num(deg.lp_rate())),
            ("control_decisions", Json::Num(st.control.decisions as f64)),
            ("control_downtime_s", Json::Num(st.control.downtime)),
        ]));
    }
    table.print();
    println!(
        "\nevery row is one `MoeSession::builder().policy(..)` call — new \
         scenarios are a policy registration away."
    );
    let _ = save_json("session_sweep", &Json::Arr(json));
}
