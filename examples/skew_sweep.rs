//! Load-balance capability sweep (the Fig.-7 scenario as a runnable demo):
//! all five systems across Zipf skew s ∈ {0, 0.25, …, 2.0}, reporting
//! max/avg GPU load. Every arm is a policy selected by name through the
//! `MoeSession` registry. Expect MicroMoE ≈ 1.0 everywhere with AR,
//! symmetric MicroMoE perfect until s ≈ 1, FlexMoE flat-but-imperfect,
//! SmartMoE and vanilla deteriorating with skew.
//!
//! Run: `cargo run --release --example skew_sweep [-- --batches 24]`

use micromoe::bench_harness::{fig7_policy_arms, fig7_zipf_stream, mean_imbalance, Table};
use micromoe::cli::Args;
use micromoe::topology::Topology;

fn main() {
    let args = Args::from_env();
    let batches = args.usize_or("batches", 24);
    let topo = Topology::new(8, 4, 2, 8);

    let mut table = Table::new(
        "max/avg GPU load vs Zipf skewness (DP=8, 32 experts) — Fig. 7 scenario",
        &["s", "vanilla EP", "SmartMoE", "FlexMoE", "MicroMoE(rand)", "MicroMoE(w/o AR)", "MicroMoE"],
    );

    for si in 0..=8 {
        let s = si as f64 * 0.25;
        // one shared stream per skew so every policy sees identical loads
        let stream = fig7_zipf_stream(s, batches);
        let mut arms = fig7_policy_arms(&topo, 32);
        let mut row = vec![format!("{s:.2}")];
        for session in &mut arms {
            row.push(format!("{:.3}", mean_imbalance(session, &stream, batches / 3)));
        }
        table.row(row);
    }
    table.print();
    println!("\n(1.000 = perfect balance; paper Fig. 7 shows the same ordering)");
}
