//! Load-balance capability sweep (the Fig.-7 scenario as a runnable demo):
//! all five systems across Zipf skew s ∈ {0, 0.25, …, 2.0}, reporting
//! max/avg GPU load. Expect MicroMoE ≈ 1.0 everywhere with AR, symmetric
//! MicroMoE perfect until s ≈ 1, FlexMoE flat-but-imperfect, SmartMoE and
//! vanilla deteriorating with skew.
//!
//! Run: `cargo run --release --example skew_sweep [-- --batches 24]`

use micromoe::adaptive::AdaptiveConfig;
use micromoe::baselines::{FlexMoe, MicroMoe, MoeSystem, SmartMoe, VanillaEp};
use micromoe::bench_harness::Table;
use micromoe::cli::Args;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::placement::random::random_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::stats::imbalance_ratio;
use micromoe::topology::Topology;

fn mean_imbalance(sys: &mut dyn MoeSystem, s: f64, batches: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(32, s);
    let mut acc = 0.0;
    let mut n = 0;
    for b in 0..batches {
        let mut lm = LoadMatrix::zeros(32, 8);
        for g in 0..8 {
            for _ in 0..2000 {
                lm.add(zipf.sample(&mut rng), g, 1);
            }
        }
        let plan = sys.plan(&lm);
        if b >= batches / 3 {
            acc += imbalance_ratio(&plan.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>());
            n += 1;
        }
    }
    acc / n as f64
}

fn main() {
    let args = Args::from_env();
    let batches = args.usize_or("batches", 24);
    let topo = Topology::new(8, 4, 2, 8);

    let mut table = Table::new(
        "max/avg GPU load vs Zipf skewness (DP=8, 32 experts) — Fig. 7 scenario",
        &["s", "vanilla EP", "SmartMoE", "FlexMoE", "MicroMoE(rand)", "MicroMoE(w/o AR)", "MicroMoE"],
    );

    for si in 0..=8 {
        let s = si as f64 * 0.25;
        let mut vanilla = VanillaEp::new(topo.clone(), 32);
        let mut smart = SmartMoe::new(topo.clone(), 32);
        smart.replace_every = 8;
        let mut flex = FlexMoe::new(topo.clone(), 32, 1);
        flex.adjust_every = 8;
        let mut rng = Rng::new(99);
        let mut mm_rand = MicroMoe::new(
            topo.clone(),
            random_placement(8, 32, 2, &mut rng),
            SchedulerOptions::default(),
        );
        mm_rand.name_override = Some("MicroMoE (random)");
        let mut mm_sym = MicroMoe::new(
            topo.clone(),
            symmetric_placement(&topo, 32),
            SchedulerOptions::default(),
        );
        let mut mm_full = MicroMoe::new(
            topo.clone(),
            symmetric_placement(&topo, 32),
            SchedulerOptions::default(),
        )
        .with_adaptive(
            AdaptiveConfig { check_every: 4, window: 8, slots_per_gpu: 8, ..Default::default() },
            5,
        );

        table.row(vec![
            format!("{s:.2}"),
            format!("{:.3}", mean_imbalance(&mut vanilla, s, batches, 1)),
            format!("{:.3}", mean_imbalance(&mut smart, s, batches, 1)),
            format!("{:.3}", mean_imbalance(&mut flex, s, batches, 1)),
            format!("{:.3}", mean_imbalance(&mut mm_rand, s, batches, 1)),
            format!("{:.3}", mean_imbalance(&mut mm_sym, s, batches, 1)),
            format!("{:.3}", mean_imbalance(&mut mm_full, s, batches, 1)),
        ]);
    }
    table.print();
    println!("\n(1.000 = perfect balance; paper Fig. 7 shows the same ordering)");
}
