//! Full-cluster simulation of the paper's §7.2 end-to-end experiment:
//! every Table-2 model, every system, iteration times and throughput
//! speedups vs Megatron-LM under the calibrated H100 cost model. Systems
//! are policies selected by name through the `MoeSession` registry.
//!
//! Run: `cargo run --release --example cluster_sim [-- --batches 16 --skew 1.0]`

use micromoe::bench_harness::{fig6_policy_arms, mean_layer_breakdown, Table};
use micromoe::cli::Args;
use micromoe::cluster::migration::expert_bytes;
use micromoe::cluster::sim::TrainIterationModel;
use micromoe::cluster::CostModel;
use micromoe::config::table2;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::LoadMatrix;

fn main() {
    let args = Args::from_env();
    let batches = args.usize_or("batches", 16);
    let skew = args.f64_or("skew", 1.0);

    for preset in table2() {
        let topo = preset.topology();
        let model = CostModel::h100_testbed().for_hidden_size(preset.hidden);
        let iter_model = TrainIterationModel::paper_default(
            preset.pp_degree,
            preset.layers,
            preset.num_microbatches(),
        );
        let e = preset.experts;
        let g = topo.microep_group_size();
        let bytes = expert_bytes(preset.hidden, preset.ffn_hidden, true);

        // one shared stream so every policy sees identical loads
        let mut rng = Rng::new(3);
        let zipf = Zipf::new(e, skew);
        let stream: Vec<LoadMatrix> = (0..batches)
            .map(|_| {
                let mut lm = LoadMatrix::zeros(e, g);
                for gi in 0..g {
                    for _ in 0..preset.assignments_per_gpu() / 4 {
                        lm.add(zipf.sample(&mut rng), gi, 1);
                    }
                }
                lm
            })
            .collect();

        let mut systems = fig6_policy_arms(&topo, e, Some((&model, bytes)));

        let mut table = Table::new(
            &format!(
                "{} — {} GPUs, {} experts, skew s={skew}",
                preset.name, preset.num_gpus, e
            ),
            &["system", "iter time", "tokens/s", "speedup"],
        );
        let mut base_tput = 0.0;
        for session in &mut systems {
            let (mean, migration_per_batch) =
                mean_layer_breakdown(session, &stream, &model, &topo);
            // migration (prep_extra) is a one-off per replacement, not a
            // per-layer recurring cost: account it per iteration
            let iter_t = iter_model.iteration_time(&mean) + migration_per_batch;
            let eff = iter_model.iteration_time(&mean) / iter_t;
            let tput = iter_model.throughput(&mean, preset.tokens_per_gpu() * 8) * eff;
            if base_tput == 0.0 {
                base_tput = tput;
            }
            table.row(vec![
                session.name().to_string(),
                micromoe::bench_harness::fmt_time(iter_t),
                format!("{tput:.0}"),
                format!("{:.2}x", tput / base_tput),
            ]);
        }
        table.print();
    }
    println!("\n(paper Fig. 6: MicroMoE up to 1.476x over Megatron-LM, avg 1.369x)");
}
