//! Full-cluster simulation of the paper's §7.2 end-to-end experiment:
//! every Table-2 model, every system, iteration times and throughput
//! speedups vs Megatron-LM under the calibrated H100 cost model.
//!
//! Run: `cargo run --release --example cluster_sim [-- --batches 16 --skew 1.0]`

use micromoe::adaptive::AdaptiveConfig;
use micromoe::baselines::{DeepSpeedPad, FlexMoe, MicroMoe, MoeSystem, SmartMoe, VanillaEp};
use micromoe::bench_harness::Table;
use micromoe::cli::Args;
use micromoe::cluster::migration::expert_bytes;
use micromoe::cluster::sim::{moe_layer_time, MoeLayerBreakdown, TrainIterationModel};
use micromoe::cluster::CostModel;
use micromoe::config::table2;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};

fn main() {
    let args = Args::from_env();
    let batches = args.usize_or("batches", 16);
    let skew = args.f64_or("skew", 1.0);

    for preset in table2() {
        let topo = preset.topology();
        let model = CostModel::h100_testbed().for_hidden_size(preset.hidden);
        let iter_model = TrainIterationModel::paper_default(
            preset.pp_degree,
            preset.layers,
            preset.num_microbatches(),
        );
        let e = preset.experts;
        let bytes = expert_bytes(preset.hidden, preset.ffn_hidden, true);

        let mut systems: Vec<Box<dyn MoeSystem>> = vec![
            Box::new(VanillaEp::new(topo.clone(), e)),
            Box::new(DeepSpeedPad::new(topo.clone(), e)),
            Box::new({ let mut sm = SmartMoe::new(topo.clone(), e).with_migration_cost(model.clone(), bytes); sm.replace_every = 4; sm }),
            Box::new({ let mut fx = FlexMoe::new(topo.clone(), e, 1).with_migration_cost(model.clone(), bytes); fx.adjust_every = 4; fx }),
            Box::new(MicroMoe::new(
                topo.clone(),
                symmetric_placement(&topo, e),
                SchedulerOptions::default(),
            )),
            Box::new(
                MicroMoe::new(
                    topo.clone(),
                    symmetric_placement(&topo, e),
                    SchedulerOptions::default(),
                )
                .with_adaptive(
                    AdaptiveConfig {
                        check_every: 8,
                        window: 8,
                        slots_per_gpu: topo.slots_per_gpu(e).max(2),
                        ..Default::default()
                    },
                    11,
                )
                .with_migration_cost(model.clone(), bytes),
            ),
        ];

        let mut table = Table::new(
            &format!(
                "{} — {} GPUs, {} experts, skew s={skew}",
                preset.name, preset.num_gpus, e
            ),
            &["system", "iter time", "tokens/s", "speedup"],
        );
        let mut base_tput = 0.0;
        for sys in &mut systems {
            let mut rng = Rng::new(3);
            let zipf = Zipf::new(e, skew);
            let mut acc = MoeLayerBreakdown::default();
            let mut migration_total = 0.0;
            for _ in 0..batches {
                let mut lm = LoadMatrix::zeros(e, topo.microep_group_size());
                for g in 0..topo.microep_group_size() {
                    for _ in 0..preset.assignments_per_gpu() / 4 {
                        lm.add(zipf.sample(&mut rng), g, 1);
                    }
                }
                let mut plan = sys.plan(&lm);
                // migration (prep_extra) is a one-off per replacement, not a
                // per-layer recurring cost: account it per iteration below
                migration_total += plan.prep_extra;
                plan.prep_extra = 0.0;
                let bd = moe_layer_time(&model, &topo, &plan);
                acc.prep += bd.prep;
                acc.dispatch += bd.dispatch;
                acc.compute += bd.compute;
                acc.combine += bd.combine;
            }
            let n = batches as f64;
            let mean = MoeLayerBreakdown {
                prep: acc.prep / n,
                dispatch: acc.dispatch / n,
                compute: acc.compute / n,
                combine: acc.combine / n,
            };
            // each simulated batch stream stands for one training iteration
            let iter_t = iter_model.iteration_time(&mean) + migration_total / n;
            let eff = iter_model.iteration_time(&mean) / iter_t;
            let tput = iter_model.throughput(&mean, preset.tokens_per_gpu() * 8) * eff;
            if base_tput == 0.0 {
                base_tput = tput;
            }
            table.row(vec![
                sys.name().to_string(),
                micromoe::bench_harness::fmt_time(iter_t),
                format!("{tput:.0}"),
                format!("{:.2}x", tput / base_tput),
            ]);
        }
        table.print();
    }
    println!("\n(paper Fig. 6: MicroMoE up to 1.476x over Megatron-LM, avg 1.369x)");
}
