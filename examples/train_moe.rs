//! End-to-end training: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (Pallas grouped-FFN kernel inside a JAX GPT-MoE
//! train step, lowered to HLO), trains for a few hundred steps on a
//! synthetic corpus via PJRT CPU — Python is never executed — and runs
//! MicroEP scheduling on the *real* per-expert gate counts each simulated
//! DP round, reporting the loss curve and balance improvement.
//!
//! Run: `make artifacts && cargo run --release --example train_moe -- --steps 240`
//! (artifact preset e2e-10m ≈ 9.6M params; see EXPERIMENTS.md §E2E)

use anyhow::Result;
use micromoe::bench_harness::Table;
use micromoe::cli::Args;
use micromoe::runtime::Runtime;
use micromoe::train::Trainer;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 240);
    let seed = args.u64_or("seed", 0);

    let rt = Runtime::load_default()?;
    println!(
        "platform {} | preset {} | {} params",
        rt.platform(),
        rt.manifest.preset,
        rt.manifest.num_params
    );

    let mut trainer = Trainer::new(rt, seed)?;
    println!(
        "training: vocab={} seq={} mbs={} layers={} experts={} ({} virtual DP ranks)",
        trainer.vocab, trainer.seq, trainer.micro_batch, trainer.layers, trainer.experts,
        trainer.dp_virtual
    );

    let t0 = std::time::Instant::now();
    let log = trainer.run(steps, args.usize_or("log-every", 16))?;
    let wall = t0.elapsed().as_secs_f64();

    // ---- loss curve ----
    let mut curve = Table::new("loss curve (real PJRT training)", &["step", "loss"]);
    let stride = (steps / 12).max(1);
    for (i, &l) in log.losses.iter().enumerate() {
        if i % stride == 0 || i == log.losses.len() - 1 {
            curve.row(vec![i.to_string(), format!("{l:.4}")]);
        }
    }
    curve.print();

    // ---- balance on the real gate trace ----
    let mut bal = Table::new(
        "max/avg GPU load per DP round (real gate counts)",
        &["round", "vanilla EP", "MicroEP", "gain"],
    );
    let stride = (log.imbalance.len() / 10).max(1);
    let mut acc = (0.0, 0.0);
    for (i, &(van, micro)) in log.imbalance.iter().enumerate() {
        acc.0 += van;
        acc.1 += micro;
        if i % stride == 0 {
            bal.row(vec![
                i.to_string(),
                format!("{van:.3}"),
                format!("{micro:.3}"),
                format!("{:.1}%", (van / micro - 1.0) * 100.0),
            ]);
        }
    }
    bal.print();

    let n = log.imbalance.len().max(1) as f64;
    let first = log.losses.first().copied().unwrap_or(f32::NAN);
    let last = log.losses.last().copied().unwrap_or(f32::NAN);
    println!("\nsummary:");
    println!("  steps            {steps} in {wall:.1}s ({:.2}s/step)", wall / steps as f64);
    println!("  loss             {first:.4} -> {last:.4}");
    println!("  mean max/avg     vanilla {:.4} vs MicroEP {:.4}", acc.0 / n, acc.1 / n);
    assert!(last < first, "loss did not decrease — e2e failure");

    if let Some(out) = args.str("trace-out") {
        Trainer::save_trace(&log, &out.into())?;
        println!("  gate trace       {out}");
    }
    Ok(())
}
