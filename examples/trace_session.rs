//! Traced session walkthrough: exercise every span kind in the ISSUE-9
//! observability vocabulary and export the results.
//!
//! One shared Wall-clock [`Tracer`] records (1) a speculative training
//! loop with an injected one-shot worker panic (solve, engine and
//! worker-respawn spans), (2) a Dantzig–Wolfe decomposed session
//! (decompose-round spans), and (3) an open-loop serving run whose
//! batching windows land on the virtual-time lane (serving-window spans).
//! The trace is written as Chrome-trace JSON — load it in
//! `chrome://tracing` or <https://ui.perfetto.dev> — next to a metrics
//! snapshot from the [`MetricsHub`], and the Prometheus text exposition is
//! printed to stdout.
//!
//! Run: `cargo run --release --example trace_session`
//! Artifacts: `target/bench-results/trace.json`,
//! `target/bench-results/trace_metrics.json`.

use std::sync::Arc;

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::save_json;
use micromoe::engine::EngineMode;
use micromoe::faults::{Fault, FaultPlan};
use micromoe::obs::{chrome_trace, prometheus, MetricsHub, TraceConfig, Tracer};
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, ScheduleMode, SchedulerOptions};
use micromoe::serving::{
    ArrivalGen, ArrivalProcess, DispatchCost, ServingConfig, SolveCost, TokenModel,
};
use micromoe::topology::Topology;
use micromoe::workload::TopicMix;

const EXPERTS: usize = 16;
const GPUS: usize = 8;

fn zipf_lm(seed: u64, per_gpu: u64, s: f64) -> LoadMatrix {
    let mut rng = Rng::new(seed);
    let z = Zipf::new(EXPERTS, s);
    let mut lm = LoadMatrix::zeros(EXPERTS, GPUS);
    for g in 0..GPUS {
        for _ in 0..per_gpu {
            lm.add(z.sample(&mut rng), g, 1);
        }
    }
    lm
}

fn session(topo: Topology, opts: SchedulerOptions, layers: usize) -> MoeSession {
    MoeSession::builder()
        .topology(topo)
        .experts(EXPERTS)
        .policy_name("micromoe")
        .options(opts)
        .layers(layers)
        .build()
        .expect("registered policy")
}

fn main() {
    let tracer = Tracer::new(TraceConfig::Wall);

    // 1. speculative training loop — autocorrelated loads so pre-solves
    //    hit, plus one injected one-shot worker panic so the trace shows a
    //    respawn discontinuity and the recovery that follows it
    let plan = FaultPlan::with_faults(vec![(2, 0, Fault::WorkerPanic { persistent: false })]);
    let opts = SchedulerOptions {
        engine: EngineMode::speculative(),
        faults: Some(Arc::new(plan)),
        trace: tracer.clone(),
        ..Default::default()
    };
    let mut train = session(Topology::new(8, 4, 2, 8), opts, 4);
    for step in 0..6usize {
        // the hot set rotates every other step: misses, then hits
        let loads: Vec<LoadMatrix> =
            (0..4).map(|l| zipf_lm((step / 2 * 4 + l) as u64, 900, 1.0)).collect();
        train.step(&loads);
    }

    // 2. decomposed solves: 2 nodes of 4 GPUs -> 2 subproblem blocks, each
    //    outer round leaving one span per block on the same buffer
    let dec_opts = SchedulerOptions {
        mode: ScheduleMode::Decomposed { nodes_per_block: 1, max_outer_iters: 6, tol: 1e-3 },
        trace: tracer.clone(),
        ..Default::default()
    };
    let mut dec = session(Topology::new(8, 4, 2, 4), dec_opts, 2);
    for step in 0..3usize {
        let loads: Vec<LoadMatrix> =
            (0..2).map(|l| zipf_lm((60 + step * 2 + l) as u64, 900, 1.0)).collect();
        dec.step(&loads);
    }

    // 3. open-loop serving: window spans carry the deterministic virtual
    //    clock, so the trace shows both timelines side by side
    let serve_opts = SchedulerOptions {
        engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
        trace: tracer.clone(),
        ..Default::default()
    };
    let sess = session(Topology::new(8, 4, 2, 8), serve_opts, 1);
    let reqs = ArrivalGen::new(
        ArrivalProcess::Poisson { rate_hz: 20_000.0 },
        TokenModel::Fixed(48),
        0xBEE,
    )
    .take(200);
    let cfg = ServingConfig {
        window_us: 400.0,
        max_batch: 24,
        slo_us: 900.0,
        shed_after_us: 1_500.0,
        solve_cost: SolveCost::Virtual { us: 50.0 },
        dispatch_cost: DispatchCost::PerToken { fixed_us: 10.0, us_per_token: 0.25 },
    };
    let mut server = sess.serve(cfg, TopicMix::new(EXPERTS, 1.1, 8, 9));
    server.run(&reqs);

    // span census
    let mut counts: std::collections::BTreeMap<&'static str, usize> = Default::default();
    for e in tracer.events() {
        *counts.entry(e.span.name()).or_default() += 1;
    }
    println!("recorded spans:");
    for (name, n) in &counts {
        println!("  {name:16} {n}");
    }

    // metrics: one hub over the training session's counters and the
    // server's SLO accounting (keys are namespaced, so they coexist)
    let mut hub = MetricsHub::new();
    hub.absorb_balancer(&train.stats());
    if let Some(es) = train.engine_stats() {
        hub.absorb_engine(&es);
    }
    hub.absorb_sla(server.sla());

    let trace_path = save_json("trace", &chrome_trace(&tracer)).expect("write trace.json");
    let metrics_path =
        save_json("trace_metrics", &hub.snapshot()).expect("write trace_metrics.json");
    println!("\nchrome trace -> {} ({} events)", trace_path.display(), tracer.event_count());
    println!("metrics snapshot -> {}", metrics_path.display());
    println!("\n{}", prometheus(&hub));
}
