"""AOT lowering: jax (L2 + L1) -> HLO text artifacts for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage (from ``python/``):
    python -m compile.aot --out ../artifacts [--preset e2e-10m]

Emits one ``<name>.hlo.txt`` per entry point plus ``manifest.json`` recording
shapes/dtypes and the model config, which the rust side parses.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import expert_ffn, topk_gate


PRESETS = {
    # ~9.7M params: the default end-to-end training config (a few hundred
    # steps on CPU PJRT in minutes).
    "e2e-10m": M.ModelConfig(),
    # ~104M params: proves the packing/AOT path scales to the paper-prompt
    # size; the e2e example runs a handful of steps of it.
    "e2e-100m": M.ModelConfig(
        vocab=512, seq=128, hidden=640, heads=10, ffn=1280, layers=8,
        experts=16, topk=2, micro_batch=2,
    ),
    # tiny smoke config for tests
    "smoke": M.ModelConfig(
        vocab=64, seq=16, hidden=32, heads=4, ffn=64, layers=2, experts=4,
        topk=2, micro_batch=2,
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": str(dtype)}


def emit(out_dir: str, name: str, fn, in_specs, in_names, out_names) -> dict:
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    out_avals = lowered.out_info
    flat_out, _ = jax.tree_util.tree_flatten(out_avals)
    inputs = [
        _io_entry(n, s.shape, jnp.dtype(s.dtype).name) for n, s in zip(in_names, in_specs)
    ]
    outputs = [
        _io_entry(n, o.shape, jnp.dtype(o.dtype).name) for n, o in zip(out_names, flat_out)
    ]
    print(f"  {name}: {len(text)} chars, {len(inputs)} in -> {len(outputs)} out")
    return {"name": name, "file": f"{name}.hlo.txt", "inputs": inputs, "outputs": outputs}


def emit_all(out_dir: str, preset: str) -> None:
    cfg = PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    p = M.num_params(cfg)
    b, s = cfg.micro_batch, cfg.seq
    l, e, c, h, f = cfg.layers, cfg.experts, cfg.capacity, cfg.hidden, cfg.ffn
    t = cfg.tokens_per_mb
    arts = []

    print(f"preset={preset}: P={p} params, B={b} S={s} L={l} E={e} C={c} H={h} F={f}")

    # --- model entry points (Layer 2) ---
    arts.append(emit(
        out_dir, "init_params",
        lambda seed: (M.init_params(seed, cfg),),
        [_spec((), jnp.int32)], ["seed"], ["params"],
    ))
    arts.append(emit(
        out_dir, "train_step",
        lambda fp, m, v, st, tok: M.train_step(fp, m, v, st, tok, cfg),
        [_spec((p,)), _spec((p,)), _spec((p,)), _spec(()), _spec((b, s + 1), jnp.int32)],
        ["params", "m", "v", "step", "tokens"],
        ["params", "m", "v", "step", "loss", "counts"],
    ))
    arts.append(emit(
        out_dir, "eval_loss",
        lambda fp, tok: M.eval_loss(fp, tok, cfg),
        [_spec((p,)), _spec((b, s + 1), jnp.int32)],
        ["params", "tokens"], ["loss", "counts"],
    ))

    # --- standalone kernel artifacts (Layer 1) ---
    arts.append(emit(
        out_dir, "gate",
        lambda logits: topk_gate(logits, k=cfg.topk),
        [_spec((t, e))], ["logits"], ["weights", "indices"],
    ))
    arts.append(emit(
        out_dir, "expert_ffn",
        lambda x, w1, w2: (expert_ffn(x, w1, w2),),
        [_spec((e, c, h)), _spec((e, h, f)), _spec((e, f, h))],
        ["x", "w1", "w2"], ["y"],
    ))
    # calibration shapes for the cluster simulator's compute model: same
    # kernel, three capacities, so the rust side can fit t_ffn = a + b*tokens
    for tag, cap in [("small", 64), ("large", 512)]:
        arts.append(emit(
            out_dir, f"expert_ffn_{tag}",
            lambda x, w1, w2: (expert_ffn(x, w1, w2),),
            [_spec((e, cap, h)), _spec((e, h, f)), _spec((e, f, h))],
            ["x", "w1", "w2"], ["y"],
        ))

    # --- one-layer MoE block forward (integration test target) ---
    arts.append(emit(
        out_dir, "moe_block",
        lambda x, wg, w1, w2: M.moe_block_fwd(x, wg, w1, w2, cfg),
        [_spec((t, h)), _spec((h, e)), _spec((e, h, f)), _spec((e, f, h))],
        ["x", "wg", "w1", "w2"], ["y", "counts"],
    ))

    manifest = {
        "preset": preset,
        "config": dataclasses.asdict(cfg),
        "num_params": p,
        "capacity": c,
        "artifacts": arts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {len(arts)} artifacts + manifest.json to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="e2e-10m", choices=sorted(PRESETS))
    args = ap.parse_args()
    emit_all(args.out, args.preset)


if __name__ == "__main__":
    main()
