"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are deliberately written in the most obvious way possible — no tiling,
no tricks — so that a mismatch against the kernels localizes the bug to the
kernel schedule, not the math.
"""

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, w2):
    """(E, C, H), (E, H, F), (E, F, H) -> (E, C, H)."""
    h = jnp.einsum("ech,ehf->ecf", x, w1)
    h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efh->ech", h, w2).astype(x.dtype)


def topk_gate_ref(logits, k: int = 2):
    """(T, E) -> (weights (T, K), indices (T, K) int32), renormalized."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w.astype(logits.dtype), idx.astype(jnp.int32)
