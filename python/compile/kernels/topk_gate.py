"""Top-K gating Pallas kernel.

Computes, for a tile of tokens, the softmax router probabilities and the
top-K expert indices/weights. K is a compile-time constant (the paper and
all Table-2 configs use K=2, but the kernel supports any K < E via iterated
masked argmax — the TPU-friendly formulation, since sorting networks map
poorly onto the VPU while max-reductions are native).

Layout: logits (T, E) -> (weights (T, K), indices (T, K) int32).
Weights are the softmax probabilities of the selected experts renormalized
to sum to 1 across K (Switch/Mixtral convention).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30  # python float: jnp scalars would be captured consts in pallas


def _gate_kernel(logits_ref, w_ref, idx_ref, *, k: int):
    logits = logits_ref[...].astype(jnp.float32)  # (tm, E)
    tm, e = logits.shape
    # numerically stable softmax over experts
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = jnp.exp(logits - m)
    probs = z / jnp.sum(z, axis=-1, keepdims=True)

    masked = probs
    cols = jax.lax.broadcasted_iota(jnp.int32, (tm, e), 1)
    ws, ids = [], []
    for _ in range(k):
        best = jnp.argmax(masked, axis=-1)  # (tm,)
        best_w = jnp.max(masked, axis=-1)
        ws.append(best_w)
        ids.append(best.astype(jnp.int32))
        # mask out the chosen column for the next round
        hit = cols == best[:, None]
        masked = jnp.where(hit, _NEG, masked)
    w = jnp.stack(ws, axis=-1)  # (tm, K)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    idx = jnp.stack(ids, axis=-1)  # (tm, K)
    w_ref[...] = w.astype(w_ref.dtype)
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k", "tile_m"))
def topk_gate(logits, k: int = 2, tile_m: int | None = None):
    """Top-K gate over router logits.

    Args:
      logits: (T, E) router logits.
      k: number of experts per token.
      tile_m: token-tile size; must divide T.

    Returns:
      (weights (T, K) same dtype as logits, indices (T, K) int32).
    """
    t, e = logits.shape
    assert 0 < k <= e
    tm = tile_m or _default_tile(t)
    assert t % tm == 0

    kernel = functools.partial(_gate_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(t // tm,),
        in_specs=[pl.BlockSpec((tm, e), lambda ti: (ti, 0))],
        out_specs=[
            pl.BlockSpec((tm, k), lambda ti: (ti, 0)),
            pl.BlockSpec((tm, k), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), logits.dtype),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
        ],
        interpret=True,
    )(logits)


def _default_tile(t: int, want: int = 128) -> int:
    tm = min(want, t)
    while t % tm != 0:
        tm -= 1
    return max(tm, 1)
