"""Grouped expert-FFN Pallas kernel — the MoE compute hot-spot.

The capacity layout mirrors what the rust coordinator dispatches: tokens are
grouped per expert into fixed-size slots ``x[E, C, H]`` (C = per-expert
capacity in this micro-batch; unused slots are zero-padded and masked by the
combine weights downstream). Each expert ``e`` applies a two-layer FFN:

    out[e] = gelu(x[e] @ w1[e]) @ w2[e]

Two variants are provided:

* :func:`expert_ffn` — grid ``(E, C // tm)``; one grid step holds a
  ``(tm, H)`` token tile plus expert ``e``'s full ``(H, F)`` and ``(F, H)``
  weight slabs. This is the VMEM-greedy schedule: footprint per step is
  ``tm*H + H*F + F*H + tm*F + tm*H`` elements. For the e2e configs used here
  (H<=512, F<=2048, tm<=128, f32) that is < 4 MiB, comfortably inside a
  TPU core's ~16 MiB VMEM, and it maximizes MXU-feeding contraction sizes.

* :func:`expert_ffn_tiled_f` — grid ``(E, C // tm, F // tf)``; additionally
  tiles the FFN-hidden dimension with an output accumulator revisited across
  the ``tf`` axis. This is the schedule for large F where full weight slabs
  exceed VMEM; it trades one extra pass over ``out`` for an ``F/tf``-fold
  smaller weight slab, the Pallas analogue of the threadblock K-loop a CUDA
  kernel would use (DESIGN.md §Hardware-Adaptation).

Hardware adaptation note: the paper's hot spot runs on H100s via cuBLAS
grouped GEMM. On TPU the same insight ("FFN time is proportional to the
number of tokens, so balance tokens") holds as long as the kernel's runtime
is linear in the number of occupied token tiles — both schedules satisfy
that, since the grid is linear in C.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One (expert, token-tile) step: full FFN for a tile of tokens.

    Block shapes carry a leading singleton expert axis; index it away so the
    contractions are plain 2-D matmuls (what the MXU consumes).
    """
    x = x_ref[0]  # (tm, H)
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h)
    o_ref[0] = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pick_tile(c: int, want: int = 128) -> int:
    """Largest divisor of ``c`` that is <= ``want`` (token-tile size)."""
    tm = min(want, c)
    while c % tm != 0:
        tm -= 1
    return max(tm, 1)


def _ffn_fwd_impl(tm, x, w1, w2):
    e, c, h = x.shape
    f = w1.shape[2]
    assert w1.shape == (e, h, f) and w2.shape == (e, f, h)
    assert c % tm == 0, f"tile_m={tm} must divide capacity C={c}"

    grid = (e, c // tm)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((1, h, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, f, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm, h), lambda ei, ti: (ei, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, h), x.dtype),
        interpret=True,
    )(x, w1, w2)


def _ffn_bwd_kernel(x_ref, w1_ref, w2_ref, dy_ref, dx_ref, dw1_ref, dw2_ref):
    """Backward step for one (expert, token-tile).

    Rematerializes the forward activations (h, a) in-tile — the standard
    memory/compute trade for MoE FFN backward — then produces dx for the
    tile and *accumulates* dw1/dw2 across token tiles (the weight-grad
    blocks are revisited for every ti with the same index, so init on
    ti == 0 and add afterwards).
    """
    ti = pl.program_id(1)
    x = x_ref[0]          # (tm, H)
    w1 = w1_ref[0]        # (H, F)
    w2 = w2_ref[0]        # (F, H)
    dy = dy_ref[0]        # (tm, H)

    h = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    a, gelu_vjp = jax.vjp(jax.nn.gelu, h)
    da = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    dh = gelu_vjp(da)[0]

    dx_ref[0] = jnp.dot(dh, w1.T, preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dw1_t = jnp.dot(x.T, dh, preferred_element_type=jnp.float32)
    dw2_t = jnp.dot(a.T, dy, preferred_element_type=jnp.float32)

    @pl.when(ti == 0)
    def _init():
        dw1_ref[0] = dw1_t.astype(dw1_ref.dtype)
        dw2_ref[0] = dw2_t.astype(dw2_ref.dtype)

    @pl.when(ti != 0)
    def _acc():
        dw1_ref[0] = (dw1_ref[0] + dw1_t).astype(dw1_ref.dtype)
        dw2_ref[0] = (dw2_ref[0] + dw2_t).astype(dw2_ref.dtype)


def _ffn_bwd_impl(tm, x, w1, w2, dy):
    e, c, h = x.shape
    f = w1.shape[2]
    grid = (e, c // tm)
    return pl.pallas_call(
        _ffn_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((1, h, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, f, h), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, tm, h), lambda ei, ti: (ei, ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tm, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((1, h, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, f, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, c, h), x.dtype),
            jax.ShapeDtypeStruct((e, h, f), w1.dtype),
            jax.ShapeDtypeStruct((e, f, h), w2.dtype),
        ],
        interpret=True,
    )(x, w1, w2, dy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ffn_vjp(tm, x, w1, w2):
    return _ffn_fwd_impl(tm, x, w1, w2)


def _ffn_vjp_fwd(tm, x, w1, w2):
    return _ffn_fwd_impl(tm, x, w1, w2), (x, w1, w2)


def _ffn_vjp_bwd(tm, res, dy):
    x, w1, w2 = res
    return _ffn_bwd_impl(tm, x, w1, w2, dy)


_ffn_vjp.defvjp(_ffn_vjp_fwd, _ffn_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def expert_ffn(x, w1, w2, tile_m: int | None = None):
    """Grouped FFN over capacity layout (differentiable).

    Args:
      x:  (E, C, H) tokens grouped per expert (zero-padded slots allowed).
      w1: (E, H, F) first projection per expert.
      w2: (E, F, H) second projection per expert.
      tile_m: token-tile size; must divide C. Default: largest divisor <=128.

    Returns:
      (E, C, H) FFN outputs. The backward pass is itself a Pallas kernel
      (:func:`_ffn_bwd_kernel`) with in-tile activation rematerialization.
    """
    c = x.shape[1]
    tm = tile_m or _pick_tile(c)
    return _ffn_vjp(tm, x, w1, w2)


def _ffn_kernel_tiled_f(x_ref, w1_ref, w2_ref, o_ref, *, nf: int):
    """F-tiled step: accumulate partial second-projection products.

    Grid order is (e, token-tile, f-tile) with the f-tile innermost, so the
    output block stays resident while partial products accumulate — the
    double-buffer-friendly ordering on real hardware.
    """
    fi = pl.program_id(2)
    x = x_ref[0]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h)
    part = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32)

    @pl.when(fi == 0)
    def _init():
        o_ref[0] = part.astype(o_ref.dtype)

    @pl.when(fi != 0)
    def _acc():
        o_ref[0] = (o_ref[0] + part).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_f"))
def expert_ffn_tiled_f(x, w1, w2, tile_m: int | None = None, tile_f: int | None = None):
    """Grouped FFN with the FFN-hidden dimension tiled (large-F schedule).

    Same contract as :func:`expert_ffn`; additionally ``tile_f`` must divide
    F. GeLU is applied per F-tile, which is exact because GeLU acts
    elementwise on ``x @ w1`` *columns* and each column lives in exactly one
    F-tile.
    """
    e, c, h = x.shape
    f = w1.shape[2]
    tm = tile_m or _pick_tile(c)
    tf = tile_f or _pick_tile(f, want=256)
    assert c % tm == 0 and f % tf == 0

    grid = (e, c // tm, f // tf)
    kernel = functools.partial(_ffn_kernel_tiled_f, nf=f // tf)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, h), lambda ei, ti, fi: (ei, ti, 0)),
            pl.BlockSpec((1, h, tf), lambda ei, ti, fi: (ei, 0, fi)),
            pl.BlockSpec((1, tf, h), lambda ei, ti, fi: (ei, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm, h), lambda ei, ti, fi: (ei, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, h), x.dtype),
        interpret=True,
    )(x, w1, w2)
