"""Layer-1 Pallas kernels for MicroMoE.

All kernels are authored with ``interpret=True`` so they lower to plain HLO
ops executable on the CPU PJRT client (real-TPU lowering emits Mosaic
custom-calls the CPU plugin cannot run). Tiling is still chosen for TPU
realism: token tiles sized for the MXU (multiples of 128 where shapes allow)
and per-step VMEM footprints documented in DESIGN.md §Perf.
"""

from .moe_ffn import expert_ffn, expert_ffn_tiled_f
from .topk_gate import topk_gate

__all__ = ["expert_ffn", "expert_ffn_tiled_f", "topk_gate"]
