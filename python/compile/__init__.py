"""MicroMoE build-time compile path (Layer 1 kernels + Layer 2 model + AOT).

Nothing in this package runs on the request path: ``aot.py`` lowers the jax
computations once to HLO text under ``artifacts/`` and the rust coordinator
loads them via PJRT.
"""
