"""Layer-2: JAX GPT-MoE model (fwd/bwd/optimizer), calling the L1 kernels.

The whole train step is a single jitted function over *packed* parameter
vectors so the rust runtime only shuttles five literals per step:

    train_step(params f32[P], m f32[P], v f32[P], step f32[], tokens i32[B,S+1])
      -> (params' f32[P], m' f32[P], v' f32[P], loss f32[], counts i32[L,E])

``counts`` is the per-layer, per-expert token count produced by the gate —
the real expert-load trace that the rust coordinator feeds into MicroEP's
LP scheduler (Fig. 2 / Fig. 7 inputs come from here in the e2e example).

MoE dispatch inside the model uses the standard dense capacity layout
(GShard-style one-hot dispatch/combine) so all shapes are static for AOT;
the grouped expert FFN itself is the Pallas kernel from Layer 1.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import expert_ffn
from .kernels.ref import expert_ffn_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    seq: int = 128
    hidden: int = 256
    heads: int = 8
    ffn: int = 512
    layers: int = 4
    experts: int = 8
    topk: int = 2
    capacity_factor: float = 2.0
    micro_batch: int = 4
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    use_pallas: bool = True  # False -> pure-jnp reference FFN (oracle path)

    @property
    def tokens_per_mb(self) -> int:
        return self.micro_batch * self.seq

    @property
    def capacity(self) -> int:
        cap = int(self.tokens_per_mb * self.topk * self.capacity_factor / self.experts)
        # round up to a multiple of 8 so token tiles divide evenly
        return max(8, (cap + 7) // 8 * 8)


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat (name, shape) list defining the packed parameter layout."""
    h, f, e = cfg.hidden, cfg.ffn, cfg.experts
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, h)),
        ("pos_embed", (cfg.seq, h)),
    ]
    for l in range(cfg.layers):
        spec += [
            (f"l{l}.ln1_scale", (h,)),
            (f"l{l}.ln1_bias", (h,)),
            (f"l{l}.wqkv", (h, 3 * h)),
            (f"l{l}.wo", (h, h)),
            (f"l{l}.ln2_scale", (h,)),
            (f"l{l}.ln2_bias", (h,)),
            (f"l{l}.wg", (h, e)),
            (f"l{l}.w1", (e, h, f)),
            (f"l{l}.w2", (e, f, h)),
        ]
    spec += [
        ("lnf_scale", (h,)),
        ("lnf_bias", (h,)),
        ("head", (h, cfg.vocab)),
    ]
    return spec


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def unpack(flat, cfg: ModelConfig):
    """Slice the packed f32[P] vector into the named parameter dict."""
    out = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = 1
        for d in shape:
            n *= d
        out[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
        off += n
    return out


def init_params(seed, cfg: ModelConfig):
    """seed i32[] -> packed params f32[P]. Lowered to its own artifact."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32) if hasattr(seed, "astype") else seed)
    chunks = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        n = 1
        for d in shape:
            n *= d
        if name.endswith("_scale"):
            chunks.append(jnp.ones((n,), jnp.float32))
        elif name.endswith("_bias"):
            chunks.append(jnp.zeros((n,), jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 0.02 if "embed" in name else (1.0 / jnp.sqrt(fan_in))
            chunks.append(jax.random.normal(sub, (n,), jnp.float32) * std)
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Model blocks
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def attention(x, p, l: int, cfg: ModelConfig):
    """Causal MHA. x: (B, S, H)."""
    b, s, h = x.shape
    nh = cfg.heads
    dh = h // nh
    qkv = x @ p[f"l{l}.wqkv"]  # (B, S, 3H)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)  # (B, nh, S, dh)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bnqd,bnkd->bnqk", q, k) / jnp.sqrt(dh).astype(x.dtype)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bnqk,bnkd->bnqd", att, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h)
    return o @ p[f"l{l}.wo"]


def topk_iterative(probs, k: int):
    """Top-K via iterated masked argmax.

    Functionally identical to ``jax.lax.top_k`` for distinct values, but
    lowers to reduce/select HLO only — jax ≥0.7 lowers ``lax.top_k`` to the
    dedicated ``topk(..., largest=true)`` HLO instruction, which the
    xla_extension 0.5.1 text parser (behind the rust ``xla`` crate) rejects.
    Gradients flow through the gathered probabilities exactly as with
    ``top_k`` (argmax indices are non-differentiable in both).
    """
    t, e = probs.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, e), 1)
    masked = probs
    ws, ids = [], []
    for _ in range(k):
        best = jnp.argmax(masked, axis=-1)
        ws.append(jnp.max(masked, axis=-1))
        ids.append(best.astype(jnp.int32))
        masked = jnp.where(cols == best[:, None], -jnp.inf, masked)
    return jnp.stack(ws, axis=-1), jnp.stack(ids, axis=-1)


def gate_fn(x2d, wg, cfg: ModelConfig):
    """Router: logits, softmax probabilities, top-K weights and indices.

    The top-K here is pure jnp: the router participates in the backward
    pass, and interpret-mode pallas inside grad is unnecessary overhead.
    The standalone pallas gate kernel is validated against this exact math
    in python/tests and exported as its own artifact.
    """
    logits = x2d @ wg  # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = topk_iterative(probs, cfg.topk)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return probs, w, idx


def moe_ffn_layer(x, p, l: int, cfg: ModelConfig):
    """MoE FFN with dense capacity dispatch. x: (B, S, H) -> (B, S, H, counts)."""
    b, s, h = x.shape
    t = b * s
    e, c, k = cfg.experts, cfg.capacity, cfg.topk
    x2d = x.reshape(t, h)

    probs, w, idx = gate_fn(x2d, p[f"l{l}.wg"], cfg)
    counts = jnp.sum(
        jax.nn.one_hot(idx, e, dtype=jnp.int32), axis=(0, 1)
    )  # (E,) pre-capacity loads — the trace MicroEP schedules on

    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (T, K, E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat  # (T*K, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, k)  # (T, K)
    keep = pos < c
    wk = w * keep.astype(w.dtype)

    # dispatch tensor (T, E, C): token t -> slot (e, pos)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)  # (T, K, C)
    disp = jnp.einsum("tke,tkc->tec", onehot * keep[..., None].astype(jnp.float32), pos_oh)
    xe = jnp.einsum("tec,th->ech", disp, x2d)  # (E, C, H)

    ffn = expert_ffn if cfg.use_pallas else expert_ffn_ref
    ye = ffn(xe, p[f"l{l}.w1"], p[f"l{l}.w2"])  # (E, C, H)

    # combine tensor: gate weight at each dispatched (token -> slot) pair,
    # zero elsewhere (dropped tokens contribute nothing)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, wk)
    y2d = jnp.einsum("tec,ech->th", comb, ye)
    # aux load-balancing loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(onehot[:, 0, :], axis=0)  # fraction routed (top-1 share)
    pe = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * pe)
    return y2d.reshape(b, s, h), counts, aux


def forward(flat_params, tokens, cfg: ModelConfig):
    """tokens i32 (B, S) -> (logits (B, S, V), counts (L, E), aux)."""
    p = unpack(flat_params, cfg)
    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos_embed"][None, :s, :]
    all_counts = []
    aux_total = 0.0
    for l in range(cfg.layers):
        x = x + attention(_layer_norm(x, p[f"l{l}.ln1_scale"], p[f"l{l}.ln1_bias"]), p, l, cfg)
        y, counts, aux = moe_ffn_layer(
            _layer_norm(x, p[f"l{l}.ln2_scale"], p[f"l{l}.ln2_bias"]), p, l, cfg
        )
        x = x + y
        all_counts.append(counts)
        aux_total = aux_total + aux
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["head"]
    return logits, jnp.stack(all_counts), aux_total / cfg.layers


def loss_fn(flat_params, tokens_io, cfg: ModelConfig, aux_coeff: float = 1e-2):
    """tokens_io i32 (B, S+1): inputs tokens[:, :-1], targets tokens[:, 1:]."""
    inp, tgt = tokens_io[:, :-1], tokens_io[:, 1:]
    logits, counts, aux = forward(flat_params, inp, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux_coeff * aux, counts


def train_step(flat_params, m, v, step, tokens_io, cfg: ModelConfig):
    """One Adam step. All I/O packed (see module docstring)."""
    (loss, counts), grads = jax.value_and_grad(
        lambda fp: loss_fn(fp, tokens_io, cfg), has_aux=True
    )(flat_params)
    step = step + 1.0
    m = cfg.beta1 * m + (1 - cfg.beta1) * grads
    v = cfg.beta2 * v + (1 - cfg.beta2) * grads * grads
    mhat = m / (1 - cfg.beta1**step)
    vhat = v / (1 - cfg.beta2**step)
    new_params = flat_params - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
    return new_params, m, v, step, loss, counts


def eval_loss(flat_params, tokens_io, cfg: ModelConfig):
    loss, counts = loss_fn(flat_params, tokens_io, cfg)
    return loss, counts


# Standalone MoE block forward (one layer) — used by the rust integration
# test and simulator calibration. x: (T, H) activations entering the block.
def moe_block_fwd(x2d, wg, w1, w2, cfg: ModelConfig):
    t, h = x2d.shape
    p = {"l0.wg": wg, "l0.w1": w1, "l0.w2": w2}
    y, counts, _aux = moe_ffn_layer(x2d.reshape(1, t, h), p, 0, cfg)
    return y.reshape(t, h), counts
