"""Python transliteration of the rust per-expert load forecaster.

The repo's containers have no rust toolchain, so new numerics land here
first: this module mirrors ``rust/src/engine/forecast.rs``
(``LoadForecaster`` — per-cell EMA blended with a sliding-window mean,
half-up integer rounding, normalized-L1 drift and the hit/miss threshold
decision) operation for operation, in the same evaluation order, so the
two implementations agree to float precision.

Two roles:

1. **Reference validation** — ``python3 python/tools/forecast_reference.py``
   runs a numpy-checked self-test (EMA recurrence vs closed form, window
   mean vs ``np.mean``, drift vs direct numpy L1) and exits non-zero on
   failure.
2. **Fixture generation** — it then regenerates
   ``rust/tests/golden_forecast.json``: deterministic multinomial load
   sequences (stationary, drifting, and jumping regimes) with the
   reference forecaster's dense predictions, rounded predictions, drift
   values, and hit/miss decisions recorded per step.
   ``rust/tests/golden_forecast.rs`` replays the sequences through the
   rust forecaster and must reproduce every recorded value.

The generator asserts that no recorded drift sits within 1e-6 of its
threshold and no unrounded prediction within 1e-9 of a .5 rounding
boundary, so float noise between the two implementations can never flip a
recorded decision. The fixture is committed; regenerate only when the
forecaster or the case set changes, and commit the result.
"""

import json
import math
import os

import numpy as np


class ForecastRef:
    """Mirror of rust ``LoadForecaster`` (keep in sync — see module docs)."""

    def __init__(self, experts, gpus, ema_alpha, window, blend, drift_threshold,
                 min_history):
        assert experts > 0 and gpus > 0
        assert 0.0 < ema_alpha <= 1.0
        assert 0.0 <= blend <= 1.0
        assert window > 0 and drift_threshold >= 0.0
        self.experts = experts
        self.gpus = gpus
        self.ema_alpha = ema_alpha
        self.window = window
        self.blend = blend
        self.drift_threshold = drift_threshold
        self.min_history = min_history
        self.ema = [0.0] * (experts * gpus)
        self.buf = []  # sliding window, oldest first (mirrors VecWindow)
        self.observed = 0

    def observe(self, loads):
        """loads: experts x gpus nested list of ints (expert-major)."""
        row = [float(loads[e][g]) for e in range(self.experts)
               for g in range(self.gpus)]
        if self.observed == 0:
            self.ema = list(row)
        else:
            a = self.ema_alpha
            # exact mirror of the rust update: a*x + (1-a)*m per cell
            self.ema = [a * x + (1.0 - a) * m for m, x in zip(self.ema, row)]
        if len(self.buf) == self.window:
            self.buf.pop(0)
        self.buf.append(row)
        self.observed += 1

    def window_mean(self):
        # mirror of stats::VecWindow::mean — sequential accumulate, then
        # one divide (NOT np.mean, whose pairwise summation differs)
        acc = [0.0] * len(self.buf[0])
        for xs in self.buf:
            for i, x in enumerate(xs):
                acc[i] += x
        n = float(len(self.buf))
        return [a / n for a in acc]

    def forecast_dense(self):
        if self.observed < max(self.min_history, 1):
            return None
        wmean = self.window_mean()
        b = self.blend
        return [b * m + (1.0 - b) * w for m, w in zip(self.ema, wmean)]

    def forecast(self):
        dense = self.forecast_dense()
        if dense is None:
            return None
        # round_half_up, mirroring rust: floor(v + 0.5), clamped at 0
        return [[int(max(math.floor(dense[e * self.gpus + g] + 0.5), 0))
                 for g in range(self.gpus)] for e in range(self.experts)]

    @staticmethod
    def drift(pred, actual):
        num = 0
        den = 0
        for pr, ar in zip(pred, actual):
            for p, a in zip(pr, ar):
                num += abs(int(p) - int(a))
                den += int(a)
        return float(num) / float(max(den, 1))


# ---------------------------------------------------------------------------
# self-test against numpy
# ---------------------------------------------------------------------------

def self_test():
    rng = np.random.default_rng(20260728)
    failures = 0

    # EMA recurrence vs numpy closed form
    f = ForecastRef(2, 3, ema_alpha=0.4, window=3, blend=1.0,
                    drift_threshold=0.5, min_history=1)
    seq = [rng.integers(0, 100, size=(2, 3)) for _ in range(6)]
    for lm in seq:
        f.observe(lm.tolist())
    a = 0.4
    expect = seq[0].astype(float).ravel()
    for lm in seq[1:]:
        expect = a * lm.astype(float).ravel() + (1 - a) * expect
    if not np.allclose(f.ema, expect, rtol=0, atol=1e-9):
        print("FAIL ema recurrence")
        failures += 1

    # window mean vs np.mean over the retained suffix
    f2 = ForecastRef(2, 3, ema_alpha=0.4, window=3, blend=0.0,
                     drift_threshold=0.5, min_history=1)
    for lm in seq:
        f2.observe(lm.tolist())
    expect_w = np.mean([s.astype(float).ravel() for s in seq[-3:]], axis=0)
    if not np.allclose(f2.forecast_dense(), expect_w, rtol=0, atol=1e-9):
        print("FAIL window mean")
        failures += 1

    # drift vs direct numpy L1
    p = rng.integers(0, 50, size=(4, 2))
    q = rng.integers(0, 50, size=(4, 2))
    d = ForecastRef.drift(p.tolist(), q.tolist())
    expect_d = np.abs(p - q).sum() / max(q.sum(), 1)
    if abs(d - expect_d) > 1e-12:
        print("FAIL drift")
        failures += 1

    # stationary loads forecast themselves exactly
    f3 = ForecastRef(2, 2, ema_alpha=0.4, window=4, blend=0.5,
                     drift_threshold=0.5, min_history=2)
    lm = [[10, 20], [5, 7]]
    for _ in range(5):
        f3.observe(lm)
    if f3.forecast() != lm or ForecastRef.drift(f3.forecast(), lm) != 0.0:
        print("FAIL stationary fixed point")
        failures += 1

    return failures


# ---------------------------------------------------------------------------
# fixture generation
# ---------------------------------------------------------------------------

def multinomial_loads(rng, experts, gpus, tokens_per_gpu, probs):
    """One input_e^g matrix: tokens_per_gpu tokens per GPU over `probs`."""
    lm = np.zeros((experts, gpus), dtype=np.int64)
    for g in range(gpus):
        lm[:, g] = rng.multinomial(tokens_per_gpu, probs)
    return lm


def zipf_probs(experts, s, perm):
    w = np.array([1.0 / (r + 1) ** s for r in range(experts)])
    w = w / w.sum()
    out = np.zeros(experts)
    out[perm] = w
    return out


def make_sequence(rng, regime, experts, gpus, tokens_per_gpu, steps):
    """Deterministic load sequences in three autocorrelation regimes."""
    perm = rng.permutation(experts)
    probs = zipf_probs(experts, 0.9, perm)
    seq = []
    for t in range(steps):
        if regime == "drifting" and t > 0 and t % 3 == 0:
            # rotate the hottest third of the ranking (Fig.-2 style drift)
            k = max(experts // 3, 2)
            perm[:k] = np.roll(perm[:k], -1)
            probs = zipf_probs(experts, 0.9, perm)
        elif regime == "jumping" and t > 0:
            # fresh ranking every step: speculation should mostly miss
            perm = rng.permutation(experts)
            probs = zipf_probs(experts, 0.9, perm)
        seq.append(multinomial_loads(rng, experts, gpus, tokens_per_gpu, probs))
    return seq


def build_case(rng, name, regime, experts, gpus, tokens_per_gpu, steps, cfg):
    seq = make_sequence(rng, regime, experts, gpus, tokens_per_gpu, steps)
    f = ForecastRef(experts, gpus, **cfg)
    recorded = []
    for t in range(steps - 1):
        f.observe(seq[t].tolist())
        dense = f.forecast_dense()
        if dense is None:
            continue
        pred = f.forecast()
        drift = ForecastRef.drift(pred, seq[t + 1].tolist())
        hit = drift <= cfg["drift_threshold"]
        # decision-stability guards: float noise between implementations
        # must not be able to flip anything the fixture pins
        assert abs(drift - cfg["drift_threshold"]) > 1e-6, \
            f"{name} t={t}: drift {drift} too close to threshold"
        for v in dense:
            # exact boundary values (e.g. window means ending in .5) round
            # identically in both implementations because every operation
            # is mirrored bit for bit; only *near*-boundary values could be
            # flipped by a last-ulp divergence
            frac = (v + 0.5) - math.floor(v + 0.5)
            assert frac == 0.0 or 1e-9 < frac < 1.0 - 1e-9, \
                f"{name} t={t}: prediction {v} within 1e-9 of a boundary"
        recorded.append({
            "t": t,
            "dense": dense,
            "pred": [[int(x) for x in row] for row in pred],
            "drift": drift,
            "hit": bool(hit),
        })
    assert recorded, f"{name}: no forecasts recorded"
    return {
        "name": name,
        "regime": regime,
        "experts": experts,
        "gpus": gpus,
        "cfg": cfg,
        "loads": [lm.tolist() for lm in seq],
        "steps": recorded,
    }


def main():
    failures = self_test()
    if failures:
        print(f"self-test FAILED ({failures})")
        raise SystemExit(1)
    print("self-test ok")

    rng = np.random.default_rng(1164)
    default_cfg = dict(ema_alpha=0.4, window=4, blend=0.5,
                       drift_threshold=0.5, min_history=2)
    cases = [
        build_case(rng, "stationary_small", "stationary", 8, 4, 512, 8,
                   dict(default_cfg)),
        build_case(rng, "stationary_wide", "stationary", 16, 8, 2048, 7,
                   dict(default_cfg)),
        build_case(rng, "drifting_mid", "drifting", 16, 8, 1024, 9,
                   dict(default_cfg)),
        build_case(rng, "jumping_missy", "jumping", 8, 4, 1024, 7,
                   dict(default_cfg)),
        build_case(rng, "ema_heavy", "drifting", 8, 4, 768, 8,
                   dict(ema_alpha=0.8, window=2, blend=0.9,
                        drift_threshold=0.6, min_history=3)),
        build_case(rng, "window_heavy", "stationary", 8, 4, 768, 8,
                   dict(ema_alpha=0.2, window=6, blend=0.1,
                        drift_threshold=0.4, min_history=2)),
    ]
    # the fixture must exercise both decisions somewhere
    hits = sum(s["hit"] for c in cases for s in c["steps"])
    total = sum(len(c["steps"]) for c in cases)
    assert 0 < hits < total, f"degenerate fixture: {hits}/{total} hits"

    here = os.path.dirname(os.path.abspath(__file__))
    out = os.path.join(here, "..", "..", "rust", "tests", "golden_forecast.json")
    with open(out, "w") as fh:
        json.dump({"cases": cases}, fh, indent=1)
    print(f"wrote {os.path.normpath(out)}: {len(cases)} cases, "
          f"{total} forecast steps, {hits} hits / {total - hits} misses")


if __name__ == "__main__":
    main()
