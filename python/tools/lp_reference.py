"""Python transliteration of the rust bounded-variable revised simplex.

The repo's containers have no rust toolchain, so changes to the LP
numerics are validated here first: this module mirrors
``rust/src/lp/revised.rs`` (standard-form build, two-phase primal, warm
dual repair) closely enough that pivot-level logic — in particular the
**long-step dual simplex with the bound-flipping ratio test (BFRT)** and
the **Markowitz-ordered sparse LU refactorization** — can be
differential-tested against scipy's HiGHS and numpy before the rust port
lands.  Run ``python3 python/tools/lp_reference.py`` to execute the full
validation suite (it prints a summary and exits non-zero on failure).

Scope notes:

* The basis engine here is a dense explicit ``B^-1`` (numpy), mirroring
  ``BasisInverse``; the sparse-LU *refactorization order* is validated
  separately by ``MarkowitzLu`` below because the Forrest-Tomlin update
  path is untouched by this PR.
* Pricing is Dantzig plus the dual-side candidate list; devex weighting
  only reorders heuristic choices and is not re-validated here.
"""

import math
import random

import numpy as np
from scipy.optimize import linprog

TOL = 1e-9


class Infeasible(Exception):
    pass


class Unbounded(Exception):
    pass


class IterLimit(Exception):
    pass


LE, GE, EQ = "le", "ge", "eq"


class RevisedRef:
    """Mirror of rust RevisedSolver (dense B^-1 engine, Dantzig pricing)."""

    def __init__(self, c, rows, upper, long_step=True, dual_cand_max=32):
        # rows: list of (terms [(var, coeff)...], rel, rhs)
        n = len(c)
        m = len(rows)
        n_slack = 0
        n_art = 0
        for terms, rel, rhs in rows:
            if rhs < 0.0:
                rel = {LE: GE, GE: LE, EQ: EQ}[rel]
            if rel == LE:
                n_slack += 1
            elif rel == GE:
                n_slack += 1
                n_art += 1
            else:
                n_art += 1
        art_base = n + n_slack
        ncols = art_base + n_art
        self.n_orig = n
        self.ncols = ncols
        self.m = m
        self.art_base = art_base
        self.cols = [[] for _ in range(ncols)]
        self.b = np.zeros(m)
        self.row_sign = np.ones(m)
        self.basis = [0] * m
        next_slack = n
        next_art = art_base
        for i, (terms, rel, rhs) in enumerate(rows):
            sign = 1.0
            if rhs < 0.0:
                sign = -1.0
                rhs = -rhs
                rel = {LE: GE, GE: LE, EQ: EQ}[rel]
            self.row_sign[i] = sign
            self.b[i] = rhs
            for v, co in terms:
                self.cols[v].append((i, sign * co))
            if rel == LE:
                self.cols[next_slack].append((i, 1.0))
                self.basis[i] = next_slack
                next_slack += 1
            elif rel == GE:
                self.cols[next_slack].append((i, -1.0))
                next_slack += 1
                self.cols[next_art].append((i, 1.0))
                self.basis[i] = next_art
                next_art += 1
            else:
                self.cols[next_art].append((i, 1.0))
                self.basis[i] = next_art
                next_art += 1
        assert next_slack == art_base and next_art == ncols
        self.cost = np.zeros(ncols)
        self.cost[:n] = c
        self.upper = np.full(ncols, math.inf)
        self.upper[:n] = [u if u is not None else math.inf for u in upper]
        self.state = ["L"] * ncols  # L / U / B
        for i, bi in enumerate(self.basis):
            self.state[bi] = "B"
        self.xb = self.b.copy()
        self.binv = np.eye(m)
        self.iterations = 0
        self.dual_pivots = 0
        self.bound_flips = 0
        self.phase1_done = False
        self.long_step = long_step
        self.y = np.zeros(m)
        # dual-side candidate list (leaving-row partial pricing)
        self.dcands = []
        self.dual_cand_max = dual_cand_max

    # ---- linear algebra (dense explicit inverse, mirrors BasisInverse) ----

    def col_vec(self, j):
        v = np.zeros(self.m)
        for i, a in self.cols[j]:
            v[i] += a
        return v

    def col_dot(self, j, dense):
        return sum(a * dense[i] for i, a in self.cols[j])

    def ftran_col(self, j):
        return self.binv @ self.col_vec(j)

    def fixed(self, j):
        return self.upper[j] <= 0.0

    def recompute_xb(self):
        rhs = self.b.copy()
        for j in range(self.ncols):
            if self.state[j] == "U":
                u = self.upper[j]
                if u > 0.0 and math.isfinite(u):
                    for i, a in self.cols[j]:
                        rhs[i] -= u * a
        self.xb = self.binv @ rhs

    def compute_y(self, cost):
        cb = np.array([cost[j] for j in self.basis])
        self.y = cb @ self.binv

    def refactor(self):
        bmat = np.zeros((self.m, self.m))
        for k, j in enumerate(self.basis):
            for i, a in self.cols[j]:
                bmat[i, k] += a
        self.binv = np.linalg.inv(bmat)
        self.recompute_xb()

    def apply_pivot(self, enter, enter_from_upper, leave, leave_to_upper, t, w):
        sigma = -1.0 if enter_from_upper else 1.0
        self.xb -= sigma * t * w
        entering_val = self.upper[enter] - t if enter_from_upper else t
        old = self.basis[leave]
        self.state[old] = "U" if leave_to_upper else "L"
        self.basis[leave] = enter
        self.state[enter] = "B"
        self.xb[leave] = entering_val
        # eta update of binv
        wr = w[leave]
        if abs(wr) < 1e-10:
            self.refactor()
        else:
            eta = np.eye(self.m)
            eta[:, leave] = -w / wr
            eta[leave, leave] = 1.0 / wr
            self.binv = eta @ self.binv
        self.iterations += 1

    # ---- primal (Dantzig + Bland), straight port of rust ----

    def attractiveness(self, j, cost):
        d = cost[j] - self.col_dot(j, self.y)
        if self.state[j] == "L":
            return -d
        if self.state[j] == "U":
            return d
        return 0.0

    def primal_iterate(self, cost):
        limit = 200 * (self.m + self.ncols) + 1000
        steps = 0
        while True:
            steps += 1
            if steps > limit:
                raise IterLimit()
            use_bland = steps > 2 * (self.m + self.ncols)
            self.compute_y(cost)
            enter = None
            best = TOL
            for j in range(self.ncols):
                if self.state[j] == "B" or self.fixed(j):
                    continue
                score = self.attractiveness(j, cost)
                if score > best:
                    enter = j
                    best = score
                    if use_bland:
                        break
            if enter is None:
                return
            enter_from_upper = self.state[enter] == "U"
            w = self.ftran_col(enter)
            sigma = -1.0 if enter_from_upper else 1.0
            t_best = self.upper[enter]
            leave = None
            leave_to_upper = False
            for i in range(self.m):
                delta = -sigma * w[i]
                if delta < -TOL:
                    ratio = self.xb[i] / -delta
                    if ratio < t_best - TOL or (
                        ratio < t_best + TOL
                        and leave is not None
                        and self.basis[i] < self.basis[leave]
                    ):
                        t_best = ratio
                        leave = i
                        leave_to_upper = False
                elif delta > TOL:
                    ub = self.upper[self.basis[i]]
                    if math.isfinite(ub):
                        ratio = (ub - self.xb[i]) / delta
                        if ratio < t_best - TOL or (
                            ratio < t_best + TOL
                            and leave is not None
                            and self.basis[i] < self.basis[leave]
                        ):
                            t_best = ratio
                            leave = i
                            leave_to_upper = True
            if math.isinf(t_best):
                raise Unbounded()
            t = max(t_best, 0.0)
            if leave is None:
                self.xb -= sigma * t * w
                self.state[enter] = "L" if enter_from_upper else "U"
                self.iterations += 1
                self.bound_flips += 1
                continue
            self.apply_pivot(enter, enter_from_upper, leave, leave_to_upper, t, w)

    # ---- dual: leaving-row candidate list + BFRT long step ----

    def row_violation(self, i):
        ub = self.upper[self.basis[i]]
        viol_low = -self.xb[i]
        viol_up = self.xb[i] - ub if math.isfinite(ub) else -math.inf
        if viol_up > viol_low:
            return viol_up, True
        return viol_low, False

    def best_dual_candidate(self):
        best = None
        best_score = 0.0
        kept = []
        for i in self.dcands:
            viol, above = self.row_violation(i)
            if viol <= TOL:
                continue
            kept.append(i)
            if viol > best_score:
                best_score = viol
                best = (i, viol, above)
        self.dcands = kept
        return best

    def rebuild_dual_candidates(self):
        scored = []
        for i in range(self.m):
            viol, _ = self.row_violation(i)
            if viol > TOL:
                scored.append((viol, i))
        scored.sort(key=lambda t: (-t[0], t[1]))
        self.dcands = [i for _, i in scored[: self.dual_cand_max]]

    def pick_leaving(self):
        pick = self.best_dual_candidate()
        if pick is not None:
            return pick
        self.rebuild_dual_candidates()
        return self.best_dual_candidate()

    def dual_iterate(self):
        limit = 200 * (self.m + self.ncols) + 1000
        steps = 0
        while True:
            steps += 1
            if steps > limit:
                raise IterLimit()
            pick = self.pick_leaving()
            if pick is None:
                return
            leave, worst, above = pick
            self.compute_y(self.cost)
            rho = self.binv[leave, :].copy()
            dir_ = 1.0 if above else -1.0
            bps = []  # (ratio, j, alpha, from_upper)
            for j in range(self.ncols):
                if self.state[j] == "B" or self.fixed(j):
                    continue
                alpha = self.col_dot(j, rho)
                abar = dir_ * alpha
                if self.state[j] == "L" and abar > TOL:
                    d = max(0.0, self.cost[j] - self.col_dot(j, self.y))
                    bps.append((d / abar, j, alpha, False))
                elif self.state[j] == "U" and abar < -TOL:
                    d = min(0.0, self.cost[j] - self.col_dot(j, self.y))
                    bps.append((d / abar, j, alpha, True))
            if not bps:
                raise Infeasible(worst)
            flips = []
            if not self.long_step:
                best_ratio = math.inf
                enter = None
                for ratio, j, alpha, fu in bps:  # index order, like rust
                    if ratio < best_ratio - TOL:
                        best_ratio = ratio
                        enter = (j, alpha, fu)
            else:
                bps.sort(key=lambda t: (t[0], t[1]))
                slope = worst
                enter = None
                for ratio, j, alpha, fu in bps:
                    u = self.upper[j]
                    flip_cost = u * abs(dir_ * alpha) if math.isfinite(u) else math.inf
                    if slope - flip_cost <= TOL:
                        enter = (j, alpha, fu)
                        break
                    slope -= flip_cost
                    flips.append((j, fu))
                if enter is None:
                    # slope positive past every breakpoint: dual unbounded
                    raise Infeasible(worst)
            if flips:
                delta_rhs = np.zeros(self.m)
                for j, fu in flips:
                    u = self.upper[j]
                    dx = -u if fu else u
                    for i, a in self.cols[j]:
                        delta_rhs[i] += a * dx
                    self.state[j] = "L" if fu else "U"
                    self.bound_flips += 1
                self.xb -= self.binv @ delta_rhs
            j, alpha, fu = enter
            target = self.upper[self.basis[leave]] if above else 0.0
            if fu:
                t = (target - self.xb[leave]) / alpha
            else:
                t = (self.xb[leave] - target) / alpha
            t = max(t, 0.0)
            w = self.ftran_col(j)
            self.apply_pivot(j, fu, leave, above, t, w)
            self.dual_pivots += 1

    # ---- driver, mirrors rust solve()/warm_resolve() ----

    def expel_artificials(self):
        for r in range(self.m):
            if self.basis[r] < self.art_base:
                continue
            rho = self.binv[r, :]
            found = None
            for j in range(self.art_base):
                if self.state[j] == "B" or self.fixed(j):
                    continue
                if abs(self.col_dot(j, rho)) > 1e-7:
                    found = j
                    break
            if found is None:
                continue
            fu = self.state[found] == "U"
            w = self.ftran_col(found)
            self.apply_pivot(found, fu, r, False, 0.0, w)

    def solve(self):
        if not self.phase1_done:
            if any(j >= self.art_base for j in self.basis):
                p1 = np.zeros(self.ncols)
                p1[self.art_base :] = 1.0
                self.primal_iterate(p1)
                infeas = sum(
                    max(self.xb[i], 0.0)
                    for i in range(self.m)
                    if self.basis[i] >= self.art_base
                )
                if infeas > 1e-7:
                    raise Infeasible(infeas)
                for j in range(self.art_base, self.ncols):
                    self.upper[j] = 0.0
                    if self.state[j] == "U":
                        self.state[j] = "L"
                for i in range(self.m):
                    if self.basis[i] >= self.art_base:
                        self.xb[i] = 0.0
                self.expel_artificials()
            self.phase1_done = True
        self.primal_iterate(self.cost)
        return self.extract()

    def warm_resolve(self):
        self.recompute_xb()
        self.dual_iterate()
        self.primal_iterate(self.cost)
        return self.extract()

    def update_rhs(self, row, rhs):
        self.b[row] = self.row_sign[row] * rhs

    def update_upper(self, var, ub):
        self.upper[var] = ub
        if self.state[var] == "U" and not math.isfinite(ub):
            self.state[var] = "L"

    def extract(self):
        x = np.zeros(self.n_orig)
        for j in range(self.n_orig):
            if self.state[j] == "U" and math.isfinite(self.upper[j]):
                x[j] = self.upper[j]
        for i in range(self.m):
            if self.basis[i] < self.n_orig:
                x[self.basis[i]] = max(self.xb[i], 0.0)
        obj = float(self.cost[: self.n_orig] @ x)
        duals = self.row_sign * self.y  # original-row duals
        return x, obj, duals.copy()


# ---------------------------------------------------------------------------
# Optimality certificate (the contract prop_lp_certificates.rs will pin)
# ---------------------------------------------------------------------------


def check_certificate(c, rows, upper, x, duals, tol=1e-6):
    """Full KKT certificate for min c'x s.t. rows, 0 <= x <= u.

    Conventions (minimization): Le rows carry y <= 0, Ge rows y >= 0, Eq
    free; reduced cost d = c - A'y obeys d >= 0 at lower bound, d <= 0 at
    upper bound, d ~ 0 strictly between; complementary slackness on rows;
    duality gap b'y + sum_{u finite} u_j * min(0, d_j) == c'x.
    """
    n = len(c)
    scale = 1.0 + max(abs(float(v)) for v in list(x) + [0.0])
    # primal feasibility
    for j in range(n):
        assert x[j] >= -tol * scale, f"x[{j}] negative: {x[j]}"
        u = upper[j]
        if u is not None and math.isfinite(u):
            assert x[j] <= u + tol * scale, f"x[{j}]={x[j]} above u={u}"
    for i, (terms, rel, rhs) in enumerate(rows):
        lhs = sum(co * x[v] for v, co in terms)
        rscale = 1.0 + abs(rhs)
        if rel == LE:
            assert lhs <= rhs + tol * rscale, f"row {i} Le violated: {lhs} > {rhs}"
        elif rel == GE:
            assert lhs >= rhs - tol * rscale, f"row {i} Ge violated: {lhs} < {rhs}"
        else:
            assert abs(lhs - rhs) <= tol * rscale, f"row {i} Eq violated: {lhs} != {rhs}"
    # dual feasibility on rows + complementary slackness
    dscale = 1.0 + max(abs(float(v)) for v in list(duals) + [0.0])
    for i, (terms, rel, rhs) in enumerate(rows):
        yi = duals[i]
        lhs = sum(co * x[v] for v, co in terms)
        slack = abs(lhs - rhs)
        if rel == LE:
            assert yi <= tol * dscale, f"row {i} Le dual sign: y={yi}"
        elif rel == GE:
            assert yi >= -tol * dscale, f"row {i} Ge dual sign: y={yi}"
        if rel != EQ and slack > tol * (1.0 + abs(rhs)) * 10:
            assert abs(yi) <= tol * dscale * 10, f"row {i} CS: slack={slack} y={yi}"
    # reduced costs vs variable position
    d = list(c)
    for i, (terms, _, _) in enumerate(rows):
        for v, co in terms:
            d[v] -= duals[i] * co
    gap_u = 0.0
    for j in range(n):
        u = upper[j] if upper[j] is not None else math.inf
        at_lower = x[j] <= tol * scale
        at_upper = math.isfinite(u) and x[j] >= u - tol * scale
        if at_lower and at_upper:
            pass  # fixed variable: any sign
        elif at_lower:
            assert d[j] >= -tol * dscale * 10, f"var {j} at lower, d={d[j]}"
        elif at_upper:
            assert d[j] <= tol * dscale * 10, f"var {j} at upper, d={d[j]}"
        else:
            assert abs(d[j]) <= tol * dscale * 10, f"var {j} interior, d={d[j]}"
        if math.isfinite(u):
            gap_u += u * min(0.0, d[j])
    primal_obj = sum(c[j] * x[j] for j in range(n))
    dual_obj = sum(duals[i] * rows[i][2] for i in range(len(rows))) + gap_u
    gscale = 1.0 + abs(primal_obj)
    assert abs(primal_obj - dual_obj) <= 10 * tol * gscale, (
        f"duality gap: primal {primal_obj} dual {dual_obj}"
    )


# ---------------------------------------------------------------------------
# Markowitz LU (mirror of the planned rust lu.rs refactor())
# ---------------------------------------------------------------------------

PIVOT_TOL = 1e-10
DROP_TOL = 1e-14
MARKOWITZ_U = 0.1
MARKOWITZ_SEARCH = 8


class MarkowitzLu:
    """Port of SparseLu::refactor with Markowitz threshold pivoting, plus the
    (unchanged) triangular solves, so fill and correctness can be compared
    against the old ascending-nnz order and numpy."""

    def __init__(self, m):
        self.m = m
        self.lops = []  # (target, source, mult)
        self.pr = list(range(m))
        self.urows = [[] for _ in range(m)]
        self.udiag = [1.0] * m
        self.lorder = list(range(m))

    def size(self):
        return self.m + sum(len(r) for r in self.urows) + len(self.lops)

    def refactor(self, cols, basis, markowitz=True):
        m = self.m
        rows = [[] for _ in range(m)]
        colrows = [[] for _ in range(m)]
        cnt = [0] * m  # exact nnz per active column over unpivoted rows
        for slot, j in enumerate(basis):
            for i, a in cols[j]:
                if a != 0.0:
                    rows[i].append((slot, a))
                    colrows[slot].append(i)
                    cnt[slot] += 1
        lops = []
        pr = [None] * m
        urows = [[] for _ in range(m)]
        udiag = [0.0] * m
        row_done = [False] * m
        col_done = [False] * m
        lorder = []
        # bucket lists over current column counts (lazy, stale-tolerant);
        # per-step visited stamp dedups columns pushed more than once
        buckets = [[] for _ in range(m + 1)]
        for s in range(m):
            buckets[cnt[s]].append(s)
        seen_step = [-1] * m

        def column_entries(s):
            """(row, value) pairs of active column s, deduped to live rows."""
            out = []
            seen = set()
            for i in colrows[s]:
                if row_done[i] or i in seen:
                    continue
                seen.add(i)
                for col, v in rows[i]:
                    if col == s:
                        out.append((i, v))
                        break
            return out

        for step in range(m):
            prow = None
            pcol = None
            best_cost = None
            best_val = 0.0
            if markowitz:
                searched = 0
                for nnz in range(1, m + 1):
                    # no count-based cutoff: a later bucket's column can
                    # still meet a singleton row (cost 0); the search
                    # budget + cost-0 exit bound the work instead
                    bucket = buckets[nnz]
                    keep = []
                    done_searching = False
                    for idx, s in enumerate(bucket):
                        if col_done[s] or cnt[s] != nnz or seen_step[s] == step:
                            continue  # stale or duplicate: drop this copy
                        seen_step[s] = step
                        keep.append(s)
                        entries = column_entries(s)
                        if not entries:
                            continue
                        colmax = max(abs(v) for _, v in entries)
                        if colmax < PIVOT_TOL:
                            continue
                        searched += 1
                        for i, v in entries:
                            if abs(v) < MARKOWITZ_U * colmax or abs(v) < PIVOT_TOL:
                                continue
                            cost = (len(rows[i]) - 1) * (cnt[s] - 1)
                            if (
                                best_cost is None
                                or cost < best_cost
                                or (cost == best_cost and abs(v) > abs(best_val))
                            ):
                                best_cost = cost
                                best_val = v
                                prow, pcol = i, s
                        if searched >= MARKOWITZ_SEARCH and best_cost is not None:
                            keep.extend(
                                s2
                                for s2 in bucket[idx + 1 :]
                                if not col_done[s2]
                                and cnt[s2] == nnz
                                and seen_step[s2] != step
                            )
                            done_searching = True
                            break
                    buckets[nnz] = keep
                    if done_searching or best_cost == 0:
                        break
            else:
                # old static ascending-nnz order with partial pivoting
                order = sorted(
                    (s for s in range(m) if not col_done[s]),
                    key=lambda s: (cnt[s], s),
                )
                s = order[0]
                best = 0.0
                for i, v in column_entries(s):
                    if abs(v) > best:
                        best = abs(v)
                        prow = i
                pcol = s
                best_val = best
            if prow is None:
                raise ValueError("singular basis")
            s = pcol
            pivot_row = rows[prow]
            rows[prow] = []
            piv = next(v for c2, v in pivot_row if c2 == s)
            # the pivot row leaves the active set: its columns lose a member
            for c2, _ in pivot_row:
                if not col_done[c2]:
                    cnt[c2] -= 1
                    buckets[min(cnt[c2], m)].append(c2)
            cands = colrows[s]
            colrows[s] = []
            for i in cands:
                if row_done[i] or i == prow:
                    continue
                a = next((v for c2, v in rows[i] if c2 == s), None)
                if a is None:
                    continue
                mult = a / piv
                lops.append((i, prow, mult))
                acc = {}
                for c2, v in rows[i]:
                    if c2 != s:
                        acc[c2] = v
                old_pattern = set(acc)
                for c2, v in pivot_row:
                    if c2 == s:
                        continue
                    if c2 not in acc:
                        acc[c2] = 0.0
                        colrows[c2].append(i)
                    acc[c2] -= mult * v
                new_row = [(c2, v) for c2, v in acc.items() if abs(v) > DROP_TOL]
                # exact count maintenance for every touched column
                new_pattern = {c2 for c2, _ in new_row}
                for c2 in old_pattern | set(acc):
                    if col_done[c2]:
                        continue
                    was = c2 in old_pattern
                    now = c2 in new_pattern
                    if was != now:
                        cnt[c2] += 1 if now else -1
                        buckets[min(cnt[c2], m)].append(c2)
                rows[i] = sorted(new_row)
            pr[s] = prow
            udiag[s] = piv
            urows[s] = [(c2, v) for c2, v in pivot_row if c2 != s]
            row_done[prow] = True
            col_done[s] = True
            lorder.append(s)
        self.lops = lops
        self.pr = pr
        self.urows = urows
        self.udiag = udiag
        self.lorder = lorder
        return self

    def ftran(self, v):
        m = self.m
        work = np.array(v, dtype=float)
        for t, s, mult in self.lops:
            if work[s] != 0.0:
                work[t] -= mult * work[s]
        work2 = np.zeros(m)
        for s in range(m):
            work2[s] = work[self.pr[s]]
        out = np.zeros(m)
        for s in reversed(self.lorder):
            val = work2[s]
            for c, u in self.urows[s]:
                val -= u * out[c]
            out[s] = val / self.udiag[s]
        return out

    def btran_unit(self, r):
        m = self.m
        work2 = np.zeros(m)
        work2[r] = 1.0
        for s in self.lorder:
            z = work2[s] / self.udiag[s]
            work2[s] = z
            if z != 0.0:
                for c, u in self.urows[s]:
                    work2[c] -= u * z
        work = np.zeros(m)
        for s in range(m):
            work[self.pr[s]] = work2[s]
        for t, s, mult in reversed(self.lops):
            if work[t] != 0.0:
                work[s] -= mult * work[t]
        return work


# ---------------------------------------------------------------------------
# Validation harness
# ---------------------------------------------------------------------------


def scipy_solve(c, rows, upper):
    a_ub, b_ub, a_eq, b_eq = [], [], [], []
    n = len(c)
    for terms, rel, rhs in rows:
        dense = [0.0] * n
        for v, co in terms:
            dense[v] += co
        if rel == LE:
            a_ub.append(dense)
            b_ub.append(rhs)
        elif rel == GE:
            a_ub.append([-x for x in dense])
            b_ub.append(-rhs)
        else:
            a_eq.append(dense)
            b_eq.append(rhs)
    bounds = [(0.0, u if u is not None and math.isfinite(u) else None) for u in upper]
    return linprog(
        c,
        A_ub=np.array(a_ub) if a_ub else None,
        b_ub=np.array(b_ub) if b_ub else None,
        A_eq=np.array(a_eq) if a_eq else None,
        b_eq=np.array(b_eq) if b_eq else None,
        bounds=bounds,
        method="highs",
    )


def random_instance(rng, n, m):
    c = [rng.uniform(-1.5, 1.0) for _ in range(n)]
    rows = []
    for _ in range(m):
        terms = [(j, rng.uniform(0.05, 1.0)) for j in range(n) if rng.random() < 0.8]
        if not terms:
            terms = [(rng.randrange(n), 1.0)]
        rel = [LE, LE, GE, EQ][rng.randrange(4)]
        rows.append((terms, rel, rng.uniform(0.5, 6.0)))
    upper = []
    for _ in range(n):
        r = rng.random()
        if r < 0.15:
            upper.append(0.0)
        elif r < 0.8:
            upper.append(rng.uniform(0.2, 5.0))
        else:
            upper.append(None)
    return c, rows, upper


def lpp1_instance(rng, g, e, d=2):
    edp = [sorted(rng.sample(range(g), d)) for _ in range(e)]
    loads = [rng.randint(0, 300) for _ in range(e)]
    nx = e * d
    c = [0.0] * (nx + 1)
    c[nx] = 1.0
    rows = []
    for gi in range(g):
        terms = [(nx, -1.0)]
        for ei, grp in enumerate(edp):
            for r, gg in enumerate(grp):
                if gg == gi:
                    terms.append((ei * d + r, 1.0))
        rows.append((terms, LE, 0.0))
    for ei in range(e):
        rows.append(([(ei * d + r, 1.0) for r in range(d)], EQ, float(loads[ei])))
    upper = [None] * (nx + 1)
    return c, rows, upper, edp, loads


def boxed_family(rng, n):
    """The BFRT showcase: max-profit knapsack-ish LP, many boxed variables,
    one capacity row; shrinking the capacity warm forces multi-flip dual
    repairs."""
    c = [-rng.uniform(0.5, 3.0) for _ in range(n)]
    # a couple of duplicated costs for dual-degenerate ties
    if n >= 4:
        c[1] = c[0]
        c[3] = c[2]
    upper = [rng.uniform(0.5, 2.0) for _ in range(n)]
    cap = sum(upper) * 0.9
    rows = [([(j, 1.0) for j in range(n)], LE, cap)]
    rows.append(([(j, 1.0) for j in range(0, n, 2)], LE, cap))
    return c, rows, upper


def validate_cold(seed=1, cases=300):
    rng = random.Random(seed)
    solved = 0
    for case in range(cases):
        n = 2 + case % 6
        m = 1 + case % 5
        c, rows, upper = random_instance(rng, n, m)
        ref = scipy_solve(c, rows, upper)
        s = RevisedRef(c, rows, upper)
        try:
            x, obj, duals = s.solve()
        except Infeasible:
            assert ref.status == 2, f"case {case}: we infeasible, scipy {ref.status}"
            continue
        except Unbounded:
            assert ref.status == 3, f"case {case}: we unbounded, scipy {ref.status}"
            continue
        assert ref.status == 0, f"case {case}: we solved, scipy {ref.status}"
        assert abs(obj - ref.fun) < 1e-6 * (1 + abs(ref.fun)), (
            f"case {case}: {obj} vs {ref.fun}"
        )
        check_certificate(c, rows, upper, x, duals)
        solved += 1
    print(f"cold: {solved}/{cases} optima agree with HiGHS, certificates pass")
    assert solved > cases // 3


def validate_warm(seed=2, cases=120):
    rng = random.Random(seed)
    flips_total = 0
    long_pivots = 0
    classic_pivots = 0
    for case in range(cases):
        n = 6 + case % 10
        c, rows, upper = boxed_family(rng, n)
        solvers = {
            "long": RevisedRef(c, rows, upper, long_step=True),
            "classic": RevisedRef(c, rows, upper, long_step=False),
        }
        for s in solvers.values():
            s.solve()
        for _round in range(6):
            cap = sum(u for u in upper) * rng.uniform(0.1, 1.0)
            objs = {}
            for name, s in solvers.items():
                s.update_rhs(0, cap)
                p0, d0 = s.dual_pivots, s.bound_flips
                x, obj, duals = s.warm_resolve()
                objs[name] = obj
                if name == "long":
                    flips_total += s.bound_flips - d0
                    long_pivots += s.dual_pivots - p0
                    check_certificate(
                        c, [(rows[0][0], LE, cap)] + rows[1:], upper, x, duals
                    )
                else:
                    classic_pivots += s.dual_pivots - p0
            ref = scipy_solve(c, [(rows[0][0], LE, cap)] + rows[1:], upper)
            assert ref.status == 0
            for name, obj in objs.items():
                assert abs(obj - ref.fun) < 1e-6 * (1 + abs(ref.fun)), (
                    f"case {case} {name}: {obj} vs scipy {ref.fun}"
                )
            # bound edits too
            j = rng.randrange(n)
            newu = rng.uniform(0.2, 2.5)
            upper = upper[:j] + [newu] + upper[j + 1 :]
            objs = {}
            for name, s in solvers.items():
                s.update_upper(j, newu)
                _, obj, _ = s.warm_resolve()
                objs[name] = obj
            ref = scipy_solve(c, [(rows[0][0], LE, cap)] + rows[1:], upper)
            assert ref.status == 0
            for name, obj in objs.items():
                assert abs(obj - ref.fun) < 1e-6 * (1 + abs(ref.fun)), (
                    f"case {case} {name} after bound edit: {obj} vs {ref.fun}"
                )
    print(
        f"warm: long-step flips={flips_total}, dual pivots long={long_pivots} "
        f"vs classic={classic_pivots}"
    )
    assert flips_total > 0, "BFRT never flipped a bound on the engineered family"
    assert long_pivots <= classic_pivots, "long step used MORE dual pivots"


def validate_warm_lpp1(seed=3, cases=40):
    rng = random.Random(seed)
    for case in range(cases):
        g = 4 + case % 4
        e = 2 * g
        c, rows, upper, edp, loads = lpp1_instance(rng, g, e)
        s = RevisedRef(c, rows, upper, long_step=True)
        s.solve()
        for _round in range(4):
            newloads = [rng.randint(0, 300) for _ in range(e)]
            for ei, l in enumerate(newloads):
                s.update_rhs(g + ei, float(l))
            x, obj, duals = s.warm_resolve()
            rows2 = rows[:g] + [
                (rows[g + ei][0], EQ, float(l)) for ei, l in enumerate(newloads)
            ]
            ref = scipy_solve(c, rows2, upper)
            assert ref.status == 0
            assert abs(obj - ref.fun) < 1e-6 * (1 + abs(ref.fun)), (
                f"case {case}: {obj} vs {ref.fun}"
            )
            check_certificate(c, rows2, upper, x, duals)
            rows = rows2
    print(f"warm lpp1: {cases} trajectories agree with HiGHS + certificates")


def validate_markowitz(seed=4, trials=60):
    rng = random.Random(seed)
    fill_m = 0
    fill_s = 0
    for trial in range(trials):
        m = 6 + trial % 30
        cols = []
        for j in range(m):
            col = [(j, 2.0 + rng.random())]
            for i in range(m):
                if i != j and rng.random() < min(0.25, 4.0 / m):
                    col.append((i, rng.uniform(-2.0, 2.0)))
            cols.append(sorted(col))
        basis = list(range(m))
        bmat = np.zeros((m, m))
        for k, j in enumerate(basis):
            for i, a in cols[j]:
                bmat[i, k] += a
        if abs(np.linalg.det(bmat)) < 1e-8:
            continue
        lu = MarkowitzLu(m).refactor(cols, basis, markowitz=True)
        lu_static = MarkowitzLu(m).refactor(cols, basis, markowitz=False)
        fill_m += lu.size()
        fill_s += lu_static.size()
        for _ in range(4):
            v = np.array([rng.uniform(-1, 1) for _ in range(m)])
            x = lu.ftran(v)
            assert np.allclose(bmat @ x, v, atol=1e-7), f"trial {trial}: ftran"
            r = rng.randrange(m)
            y = lu.btran_unit(r)
            assert np.allclose(y @ bmat, np.eye(m)[r], atol=1e-7), (
                f"trial {trial}: btran"
            )
    print(f"markowitz: fill {fill_m} vs static-order fill {fill_s}")
    assert fill_m <= fill_s * 1.05, "markowitz order grew fill vs static order"


if __name__ == "__main__":
    validate_cold()
    validate_warm()
    validate_warm_lpp1()
    validate_markowitz()
    print("ALL LP REFERENCE VALIDATIONS PASSED")
