#!/usr/bin/env python3
"""Reference implementation of the rust placement controller.

Two roles:

1. **Transliteration** — pure-python mirrors of the controller's decision
   path, operation for operation: ``LoadDetector`` (EWMA load shares +
   dual hysteresis, ``rust/src/control/detect.rs``), the exact Eq.-3
   density enumeration (``rust/src/placement/graph.rs``),
   ``placement_diff`` / ``migration_time``
   (``rust/src/cluster/migration.rs``) and the greedy replicate/evict
   ``decide`` loop (``rust/src/control/decide.rs``). Python floats are
   IEEE doubles and every sum/product is performed in the same order as
   the rust code, so the two implementations agree bit for bit. At the
   fixture's 8-GPU scale the rust density evaluator takes the exact
   (rng-free) path, which is what makes an rng-free python mirror
   possible.

2. **Fixture generation** — drives the mirror through drift regimes
   (stationary, sudden shift, oscillating load held off by hysteresis,
   move-capped, eviction-forced, rotating drift, budget-starved) and
   records the load traces plus every control-tick decision into
   ``rust/tests/golden_controller.json``. ``tests/golden_controller.rs``
   replays the traces through the rust detector + decider and must
   reproduce every EWMA value, flag, move list and accounting float
   exactly (``json.dump`` emits shortest-roundtrip floats; rust's
   ``str::parse::<f64>`` is correctly rounded, so the bits survive the
   trip).

Guard bands are asserted at generation time: no EWMA lands within 1e-9
of a hysteresis threshold, no migration time within 1e-9 of the budget,
no predicted gain within 1e-9 of the ``min_gain`` floor — a fixture
whose decisions hinge on the last ulp would be a flaky fixture.

Run from anywhere:  python3 python/tools/controller_reference.py
"""

import json
import os

# ---------------------------------------------------------------------------
# constants mirrored from rust (CostModel::h100_testbed + migration.rs)
# ---------------------------------------------------------------------------

NVLINK_BW = 900e9
IB_BW = 100e9
INTER_LAT = 25e-6
MIGRATION_EFF = 0.10
REINIT_OVERHEAD = 50e-3


def expert_bytes(hidden, ffn, with_optimizer):
    """Mirror of cluster::migration::expert_bytes."""
    params = 2 * hidden * ffn
    return params * (14 if with_optimizer else 2)


class ControlSpec:
    """Mirror of control::ControlSpec (defaults included)."""

    def __init__(self, **kw):
        self.interval = kw.pop("interval", 16)
        self.ema_alpha = kw.pop("ema_alpha", 0.25)
        self.hot_enter = kw.pop("hot_enter", 2.0)
        self.hot_exit = kw.pop("hot_exit", 1.5)
        self.cold_enter = kw.pop("cold_enter", 0.5)
        self.cold_exit = kw.pop("cold_exit", 0.75)
        self.dwell = kw.pop("dwell", 4)
        self.budget_seconds = kw.pop("budget_seconds", 0.5)
        self.max_moves = kw.pop("max_moves", 8)
        self.min_gain = kw.pop("min_gain", 0.01)
        self.bytes_per_expert = kw.pop("bytes_per_expert", expert_bytes(2048, 8192, True))
        self.slot_headroom = kw.pop("slot_headroom", 1)
        assert not kw, "unknown spec fields: %s" % sorted(kw)

    def to_json(self):
        return {
            "interval": self.interval,
            "ema_alpha": self.ema_alpha,
            "hot_enter": self.hot_enter,
            "hot_exit": self.hot_exit,
            "cold_enter": self.cold_enter,
            "cold_exit": self.cold_exit,
            "dwell": self.dwell,
            "budget_seconds": self.budget_seconds,
            "max_moves": self.max_moves,
            "min_gain": self.min_gain,
            "bytes_per_expert": self.bytes_per_expert,
            "slot_headroom": self.slot_headroom,
        }


class LoadDetector:
    """Mirror of control::detect::LoadDetector, op for op."""

    def __init__(self, num_experts, spec):
        assert num_experts > 0
        uniform = 1.0 / float(num_experts)
        self.alpha = spec.ema_alpha
        self.hot_enter = spec.hot_enter * uniform
        self.hot_exit = spec.hot_exit * uniform
        self.cold_enter = spec.cold_enter * uniform
        self.cold_exit = spec.cold_exit * uniform
        self.dwell = spec.dwell
        self.ema = [0.0] * num_experts
        self.primed = False
        self.hot = [False] * num_experts
        self.hot_run = [0] * num_experts
        self.cold = [False] * num_experts
        self.cold_run = [0] * num_experts
        self.observed = 0

    def observe(self, loads):
        assert len(loads) == len(self.ema)
        total = sum(loads)  # exact integer sum, same as rust's u64 sum
        if total == 0:
            return
        inv = 1.0 / float(total)
        if not self.primed:
            for e, x in enumerate(loads):
                self.ema[e] = float(x) * inv
            self.primed = True
        else:
            for e, x in enumerate(loads):
                self.ema[e] = self.alpha * (float(x) * inv) + (1.0 - self.alpha) * self.ema[e]
        self.observed += 1
        for e in range(len(self.ema)):
            m = self.ema[e]
            crossing = (m < self.hot_exit) if self.hot[e] else (m > self.hot_enter)
            if crossing:
                self.hot_run[e] += 1
                if self.hot_run[e] >= self.dwell:
                    self.hot[e] = not self.hot[e]
                    self.hot_run[e] = 0
            else:
                self.hot_run[e] = 0
            crossing = (m > self.cold_exit) if self.cold[e] else (m < self.cold_enter)
            if crossing:
                self.cold_run[e] += 1
                if self.cold_run[e] >= self.dwell:
                    self.cold[e] = not self.cold[e]
                    self.cold_run[e] = 0
            else:
                self.cold_run[e] = 0

    def threshold_guard(self, band=1e-9):
        """Generation-time guard: no EWMA within `band` of a threshold."""
        for m in self.ema:
            for thr in (self.hot_enter, self.hot_exit, self.cold_enter, self.cold_exit):
                assert abs(m - thr) > band, "EWMA %r within %g of threshold %r" % (m, band, thr)


def density_exact(replicas, loads, num_gpus):
    """Mirror of placement::graph::max_induced_density_exact (density only)."""
    assert num_gpus <= 26
    masks = []
    for grp in replicas:
        m = 0
        for gg in grp:
            m |= 1 << gg
        masks.append(m)
    best = 0.0
    for subset in range(1, 1 << num_gpus):
        total = 0.0
        for e, mask in enumerate(masks):
            if mask & subset == mask:
                total += loads[e]
        density = total / float(bin(subset).count("1"))
        if density > best + 1e-12:
            best = density
    return best


def same_node(a, b, gpus_per_node):
    return a // gpus_per_node == b // gpus_per_node


def placement_diff(old_replicas, new_replicas, gpus_per_node):
    """Mirror of cluster::migration::placement_diff; moves as (e, dst, src)."""
    assert len(old_replicas) == len(new_replicas)
    moves = []
    for e in range(len(new_replicas)):
        for dst in new_replicas[e]:
            if dst not in old_replicas[e]:
                src = min(
                    old_replicas[e],
                    key=lambda s: (int(not same_node(s, dst, gpus_per_node)), s),
                )
                moves.append((e, dst, src))
    moves.sort(key=lambda m: (m[0], m[2], m[1]))
    return moves


def migration_time(moves, bytes_per_expert, gpus_per_node, num_gpus):
    """Mirror of cluster::migration::migration_time (h100 testbed model)."""
    if not moves:
        return 0.0
    si = [0] * num_gpus
    ri = [0] * num_gpus
    sj = [0] * num_gpus
    rj = [0] * num_gpus
    for (_e, dst, src) in moves:
        if same_node(src, dst, gpus_per_node):
            si[src] += bytes_per_expert
            ri[dst] += bytes_per_expert
        else:
            sj[src] += bytes_per_expert
            rj[dst] += bytes_per_expert
    worst = 0.0
    for g in range(num_gpus):
        t = float(max(si[g], ri[g])) / (NVLINK_BW * MIGRATION_EFF) + float(
            max(sj[g], rj[g])
        ) / (IB_BW * MIGRATION_EFF)
        worst = max(worst, t)
    return worst + INTER_LAT + REINIT_OVERHEAD


def proxy_loads(replicas, ema, num_gpus):
    """Mirror of control::decide::proxy_loads."""
    proxy = [0.0] * num_gpus
    for e, group in enumerate(replicas):
        per = ema[e] / float(len(group))
        for g in group:
            proxy[g] += per
    return proxy


def decide(replicas, detector, gpus_per_node, spec, slot_budget, num_gpus, guards=None):
    """Mirror of control::decide::decide (exact-density path, rng-free).

    `guards`, when a dict, collects generation-time guard-band evidence:
    counts of ops rejected for the move cap / time budget, and asserts
    that no comparison in the decision path was decided within 1e-9.
    """
    if detector.observed == 0:
        return None
    ema = list(detector.ema)
    base = density_exact(replicas, ema, num_gpus)

    working = [list(grp) for grp in replicas]
    used = [sum(1 for grp in working if gpu in grp) for gpu in range(num_gpus)]

    hot = [e for e in range(len(working)) if detector.hot[e]]
    hot.sort(key=lambda e: (-ema[e], e))

    cur_density = base
    replications = 0
    evictions = 0

    for e in hot:
        if len(working[e]) >= num_gpus:
            continue
        proxy = proxy_loads(working, ema, num_gpus)
        cands = [g for g in range(num_gpus) if g not in working[e] and used[g] < slot_budget]
        dst = min(cands, key=lambda g: (proxy[g], g)) if cands else None
        evicted = None
        if dst is None:
            gpus = [g for g in range(num_gpus) if g not in working[e]]
            gpus.sort(key=lambda g: (proxy[g], g))
            for gpu in gpus:
                vcands = [
                    c
                    for c in range(len(working))
                    if c != e
                    and detector.cold[c]
                    and not detector.hot[c]
                    and len(working[c]) > 1
                    and gpu in working[c]
                ]
                if vcands:
                    victim = min(vcands, key=lambda c: (ema[c], c))
                    working[victim].remove(gpu)
                    used[gpu] -= 1
                    evicted = (victim, gpu)
                    dst = gpu
                    break
        if dst is None:
            continue

        working[e].append(dst)
        working[e].sort()
        used[dst] += 1
        moves = placement_diff(replicas, working, gpus_per_node)
        mig = migration_time(moves, spec.bytes_per_expert, gpus_per_node, num_gpus)
        over_moves = len(moves) > spec.max_moves
        over_time = mig > spec.budget_seconds
        if guards is not None:
            assert abs(mig - spec.budget_seconds) > 1e-9, "migration time hugs the budget"
            if over_moves:
                guards["rejected_moves"] = guards.get("rejected_moves", 0) + 1
            if over_time:
                guards["rejected_time"] = guards.get("rejected_time", 0) + 1
        over_budget = over_moves or over_time
        density = float("inf") if over_budget else density_exact(working, ema, num_gpus)
        if guards is not None and density != float("inf") and density != cur_density:
            assert abs(density - cur_density) > 1e-9, "density comparison hugs the slop"
        if not over_budget and density < cur_density - 1e-12:
            cur_density = density
            replications += 1
            if evicted is not None:
                evictions += 1
        else:
            working[e].remove(dst)
            used[dst] -= 1
            if evicted is not None:
                c, gpu = evicted
                working[c].append(gpu)
                working[c].sort()
                used[gpu] += 1

    if replications == 0:
        return None
    predicted_gain = base - cur_density
    if guards is not None:
        assert abs(predicted_gain - spec.min_gain * base) > 1e-9, "gain hugs the min_gain floor"
    if predicted_gain <= spec.min_gain * base:
        return None
    moves = placement_diff(replicas, working, gpus_per_node)
    downtime = migration_time(moves, spec.bytes_per_expert, gpus_per_node, num_gpus)
    nbytes = len(moves) * spec.bytes_per_expert
    return {
        "replicas": [list(grp) for grp in working],
        "moves": [list(m) for m in moves],
        "predicted_gain": predicted_gain,
        "downtime": downtime,
        "bytes": nbytes,
        "replications": replications,
        "evictions": evictions,
    }


# ---------------------------------------------------------------------------
# numpy self-test: the mirror vs an independent vectorized implementation
# ---------------------------------------------------------------------------


def self_test():
    import numpy as np

    failures = 0

    # 1. EWMA recurrence vs the vectorized numpy recurrence
    rng = np.random.default_rng(7)
    spec = ControlSpec(ema_alpha=0.3, dwell=2)
    det = LoadDetector(8, spec)
    ref = None
    for _ in range(40):
        loads = rng.integers(1, 500, size=8)
        det.observe([int(x) for x in loads])
        share = loads.astype(np.float64) / float(loads.sum())
        ref = share if ref is None else 0.3 * share + 0.7 * ref
    if not np.allclose(np.array(det.ema), ref, atol=1e-12):
        print("FAIL: detector EWMA diverged from numpy recurrence")
        failures += 1

    # 2. exact density vs numpy brute force (membership matrix + dot)
    replicas = [[0], [1], [0, 2], [3], [1, 3], [2]]
    loads = [0.3, 0.1, 0.25, 0.05, 0.2, 0.1]
    G = 4
    member = np.zeros((len(replicas), G), dtype=bool)
    for e, grp in enumerate(replicas):
        member[e, grp] = True
    best = 0.0
    for subset in range(1, 1 << G):
        inside = np.array([(subset >> g) & 1 == 1 for g in range(G)])
        covered = member[:, ~inside].sum(axis=1) == 0
        d = float(np.array(loads)[covered].sum()) / float(inside.sum())
        best = max(best, d)
    mine = density_exact(replicas, loads, G)
    if abs(mine - best) > 1e-9:
        print("FAIL: exact density %r vs numpy brute force %r" % (mine, best))
        failures += 1

    # 3. migration time vs a hand-computed value (one move per tier)
    moves = [(0, 1, 0), (1, 3, 0)]  # gpn=2: (0->1) intra, (0->3) inter
    b = 1 << 24
    t = migration_time(moves, b, 2, 4)
    hand = float(b) / (NVLINK_BW * MIGRATION_EFF) + float(b) / (IB_BW * MIGRATION_EFF)
    hand = hand + INTER_LAT + REINIT_OVERHEAD
    if abs(t - hand) > 1e-12:
        print("FAIL: migration_time %r vs hand %r" % (t, hand))
        failures += 1

    # 4. decide replicates a hot expert on the 4-GPU toy (mirrors the rust
    #    unit test) and is deterministic call to call
    spec = ControlSpec(dwell=2, bytes_per_expert=expert_bytes(256, 1024, True))
    det = LoadDetector(8, spec)
    skew = [40] * 8
    skew[0] = 1000
    for _ in range(12):
        det.observe(skew)
    if not det.hot[0]:
        print("FAIL: skewed trace did not flag expert 0 hot")
        failures += 1
    placement = [[e % 4] for e in range(8)]
    d1 = decide(placement, det, 2, spec, 3, 4)
    d2 = decide(placement, det, 2, spec, 3, 4)
    if d1 is None or len(d1["replicas"][0]) < 2:
        print("FAIL: decide did not replicate the hot expert: %r" % (d1,))
        failures += 1
    elif d1 != d2:
        print("FAIL: decide is not deterministic")
        failures += 1
    # a budget below the 50 ms re-init floor blocks everything
    starved = ControlSpec(dwell=2, budget_seconds=0.01, bytes_per_expert=spec.bytes_per_expert)
    if decide(placement, det, 2, starved, 3, 4) is not None:
        print("FAIL: sub-floor budget still produced a decision")
        failures += 1

    return failures


# ---------------------------------------------------------------------------
# fixture scenarios
# ---------------------------------------------------------------------------

# shared geometry: 16 experts on 8 GPUs, 2 nodes of 4 (dp=8, ep=4, d=2).
# 8 GPUs keeps the rust density evaluator on the exact, rng-free path.
TOPO = [8, 4, 2, 4]  # Topology::new(dp, ep, d, gpus_per_node)
SMALL_EXPERT = expert_bytes(256, 1024, True)


def symmetric_replicas(experts, gpus):
    assert experts % gpus == 0
    per = experts // gpus
    return [[e // per] for e in range(experts)]


def run_scenario(name, experts, gpus, gpn, spec, slot_budget, replicas, loads_per_step):
    """Drive the mirror through a load trace; record every control tick."""
    det = LoadDetector(experts, spec)
    current = [list(g) for g in replicas]
    ticks = []
    guards = {}
    max_hot_run = 0
    for step, loads in enumerate(loads_per_step, start=1):
        assert len(loads) == experts
        det.observe(loads)
        det.threshold_guard()
        max_hot_run = max(max_hot_run, max(det.hot_run))
        if step % spec.interval == 0:
            decision = decide(current, det, gpn, spec, slot_budget, gpus, guards=guards)
            ticks.append({"step": step, "decision": decision})
            if decision is not None:
                current = [list(g) for g in decision["replicas"]]
    return {
        "scenario": {
            "name": name,
            "experts": experts,
            "gpus": gpus,
            "topo": TOPO[:3] + [gpn],
            "slot_budget": slot_budget,
            "spec": spec.to_json(),
            "initial_replicas": [list(g) for g in replicas],
            "loads": [list(l) for l in loads_per_step],
            "ticks": ticks,
            "final": {
                "ema": list(det.ema),
                "hot": list(det.hot),
                "cold": list(det.cold),
                "observed": det.observed,
            },
        },
        "det": det,
        "guards": guards,
        "max_hot_run": max_hot_run,
        "decisions": [t["decision"] for t in ticks if t["decision"] is not None],
    }


def uniform_step(experts, base, t):
    # deterministic wobble: near-uniform, never crosses a band
    return [base + (3 * t + 5 * e) % 7 for e in range(experts)]


def build_scenarios():
    E, G, GPN = 16, 8, 4
    out = []

    # --- 1. stationary near-uniform: the controller must do nothing -------
    spec = ControlSpec(interval=4, dwell=2, bytes_per_expert=SMALL_EXPERT)
    loads = [uniform_step(E, 100, t) for t in range(16)]
    r = run_scenario("stationary_uniform", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert not r["decisions"], "stationary trace must produce no decisions"
    assert not any(r["det"].hot) and not any(r["det"].cold), "no flags on uniform load"
    out.append(r)

    # --- 2. sudden shift: hysteresis enter + dwell, then replication ------
    spec = ControlSpec(interval=4, ema_alpha=0.5, dwell=3, bytes_per_expert=SMALL_EXPERT)
    loads = [[100] * E for _ in range(8)]
    for _ in range(16):
        step = [60] * E
        step[5] = 700
        loads.append(step)
    r = run_scenario("sudden_shift", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert r["det"].hot[5], "sustained shift must flag expert 5 hot"
    assert r["decisions"], "shift must trigger at least one replication"
    assert all(
        t["decision"] is None for t in r["scenario"]["ticks"][:2]
    ), "pre-shift ticks must be quiet"
    first = r["decisions"][0]
    assert first["replications"] >= 1 and 5 in [m[0] for m in first["moves"]]
    assert first["bytes"] == len(first["moves"]) * spec.bytes_per_expert
    out.append(r)

    # --- 3. oscillating load: crossings happen, dwell blocks the flip -----
    spec = ControlSpec(interval=4, ema_alpha=0.25, dwell=3, bytes_per_expert=SMALL_EXPERT)
    loads = []
    for t in range(32):
        if t % 4 == 2:  # one burst step per 4-step cycle (primed on uniform)
            step = [100] * E
            step[2] = 808
        else:
            step = [100] * E
        loads.append(step)
    r = run_scenario("oscillating_hysteresis", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert r["max_hot_run"] >= 2, "bursts must at least start a crossing run"
    assert not any(r["det"].hot), "dwell must block the oscillating flip"
    assert not r["decisions"], "no flags means no decisions"
    out.append(r)

    # --- 4. two hot experts, move cap 1: budget-limited decision ----------
    spec = ControlSpec(
        interval=4, ema_alpha=0.5, dwell=2, max_moves=1, bytes_per_expert=SMALL_EXPERT
    )
    loads = []
    for _ in range(16):
        step = [40] * E
        step[3] = 500
        step[9] = 300
        loads.append(step)
    r = run_scenario("move_cap_limited", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert r["det"].hot[3] and r["det"].hot[9], "both spiked experts must be hot"
    assert r["decisions"], "the cap limits, it must not starve"
    assert all(len(d["moves"]) <= 1 for d in r["decisions"])
    assert r["guards"].get("rejected_moves", 0) >= 1, "cap must actually reject an op"
    out.append(r)

    # --- 5. packed slots: replication must evict a cold replica -----------
    E5 = 14
    spec = ControlSpec(interval=4, ema_alpha=0.5, dwell=2, bytes_per_expert=SMALL_EXPERT)
    replicas = [[e // 2] for e in range(12)] + [[6, 7], [6, 7]]
    loads = []
    for _ in range(12):
        step = [100] * E5
        step[0] = 800
        step[12] = 20
        step[13] = 20
        loads.append(step)
    r = run_scenario("eviction_under_full_slots", E5, G, GPN, spec, 2, replicas, loads)
    assert r["det"].hot[0] and r["det"].cold[12] and r["det"].cold[13]
    assert r["decisions"], "eviction path must free a slot"
    assert any(d["evictions"] >= 1 for d in r["decisions"])
    for d in r["decisions"]:
        assert all(len(grp) >= 1 for grp in d["replicas"]), "eviction orphaned an expert"
    out.append(r)

    # --- 6. rotating drift: hot expert moves, controller follows ----------
    spec = ControlSpec(interval=4, ema_alpha=0.5, dwell=2, bytes_per_expert=SMALL_EXPERT)
    loads = []
    for t in range(36):
        step = [60] * E
        step[[1, 6, 11][t // 12]] = 700
        loads.append(step)
    r = run_scenario("rotating_drift", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert len(r["decisions"]) >= 2, "rotation must trigger repeated adaptation"
    moved = set()
    for d in r["decisions"]:
        moved.update(m[0] for m in d["moves"])
    assert len(moved & {1, 6, 11}) >= 2, "decisions must chase the rotating hot expert"
    out.append(r)

    # --- 7. budget starvation: hot experts exist, migrations too costly ---
    # (a) every attractive destination is cross-node and the Table-2-sized
    # expert blows the 70 ms budget; (b) is the sub-floor variant.
    spec = ControlSpec(
        interval=4,
        ema_alpha=0.5,
        dwell=2,
        budget_seconds=0.07,
        bytes_per_expert=expert_bytes(2048, 8192, True),
    )
    loads = []
    for _ in range(8):
        step = [60] * E
        for e in range(1, 8):
            step[e] = 150  # keep node 0 warm so the coolest dst is cross-node
        step[0] = 700
        loads.append(step)
    r = run_scenario("budget_starved_cross_node", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert r["det"].hot[0], "expert 0 must be hot"
    assert not r["decisions"], "every candidate move must be over budget"
    assert r["guards"].get("rejected_time", 0) >= 1, "the budget must actually reject an op"
    out.append(r)

    spec = ControlSpec(interval=4, ema_alpha=0.5, dwell=2, budget_seconds=0.04,
                       bytes_per_expert=SMALL_EXPERT)
    loads = []
    for _ in range(8):
        step = [60] * E
        step[0] = 700
        loads.append(step)
    r = run_scenario("budget_below_reinit_floor", E, G, GPN, spec, 3, symmetric_replicas(E, G), loads)
    assert r["det"].hot[0] and not r["decisions"]
    assert r["guards"].get("rejected_time", 0) >= 1
    out.append(r)

    return out


def main():
    failures = self_test()
    assert failures == 0, "%d self-test failures; fixture not written" % failures

    results = build_scenarios()
    decided = sum(len(r["decisions"]) for r in results)
    quiet = sum(
        1 for r in results for t in r["scenario"]["ticks"] if t["decision"] is None
    )
    assert decided >= 4 and quiet >= 4, "fixture must exercise both outcomes"

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "..", "..", "rust", "tests", "golden_controller.json")
    with open(path, "w") as fh:
        json.dump({"scenarios": [r["scenario"] for r in results]}, fh, indent=1)
        fh.write("\n")
    print(
        "self-test clean; wrote %d scenarios (%d decisions, %d quiet ticks) to %s"
        % (len(results), decided, quiet, os.path.normpath(path))
    )


if __name__ == "__main__":
    main()
