"""Schema validator for the rust tracer's Chrome-trace JSON export.

CI's trace-smoke job runs ``cargo run --example trace_session`` and then
points this tool at the emitted ``target/bench-results/trace.json``. It
checks the file against the subset of the Trace Event Format that
``rust/src/obs/export.rs`` promises to produce — enough that the artifact
is guaranteed to load in ``chrome://tracing`` / Perfetto and that every
span kind of the ISSUE-9 vocabulary actually made it into the file:

* top level: an object with a ``traceEvents`` array and
  ``displayTimeUnit == "ms"``;
* every complete event (``ph == "X"``): string ``name``, integer
  ``pid``/``tid``, finite numeric ``ts``/``dur`` with ``ts >= 0`` and
  ``dur >= 0``, and an ``args`` object;
* per span kind, the required args emitted by the exporter (e.g. a solve
  span carries ``step``/``layer``/``mode``/``rung``/pivot counters);
* metadata events (``ph == "M"``) name both clock-domain process lanes;
* all five span kinds present (pass ``--require`` to narrow the set).

Usage: ``python3 python/tools/trace_check.py <trace.json>
[--require solve,engine,...]``. Exits non-zero with a description of the
first violation, or prints a per-kind census on success.

stdlib-only on purpose: the CI container for this job installs nothing.
"""

import argparse
import json
import math
import sys

# span kind -> args the exporter always attaches (values checked for
# presence, not type, except the counters listed in INT_ARGS)
REQUIRED_ARGS = {
    "solve": ["step", "layer", "mode", "rung", "warm", "pivots",
              "dual_pivots", "flips", "refactors"],
    "engine": ["step", "layer", "worker", "outcome", "inflight", "pivots"],
    "decompose_round": ["round", "block", "gap", "kappa"],
    "serving_window": ["index", "admitted", "shed", "deadline_miss"],
    "worker_respawn": ["worker", "attempt"],
}

INT_ARGS = {
    "solve": ["step", "layer", "pivots", "dual_pivots", "flips", "refactors"],
    "engine": ["step", "layer", "worker", "inflight", "pivots"],
    "decompose_round": ["round", "block"],
    "serving_window": ["index", "admitted", "shed", "deadline_miss"],
    "worker_respawn": ["worker", "attempt"],
}

SPAN_KINDS = sorted(REQUIRED_ARGS)


def fail(msg):
    print(f"trace_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_number(ev, field):
    v = ev.get(field)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        fail(f"event {ev.get('name')!r} id={ev.get('id')}: {field} is not a number: {v!r}")
    if not math.isfinite(v):
        fail(f"event {ev.get('name')!r} id={ev.get('id')}: {field} is not finite: {v!r}")
    if v < 0:
        fail(f"event {ev.get('name')!r} id={ev.get('id')}: {field} is negative: {v!r}")
    return v


def check_span(ev):
    name = ev.get("name")
    if name not in REQUIRED_ARGS:
        fail(f"unknown span kind {name!r}")
    check_number(ev, "ts")
    check_number(ev, "dur")
    for field in ("pid", "tid"):
        v = ev.get(field)
        if not isinstance(v, int) or isinstance(v, bool):
            fail(f"{name} span: {field} must be an integer, got {v!r}")
    if ev.get("pid") not in (0, 1):
        fail(f"{name} span: pid {ev['pid']} is neither the wall (0) nor virtual (1) lane")
    args = ev.get("args")
    if not isinstance(args, dict):
        fail(f"{name} span: args missing or not an object")
    for key in REQUIRED_ARGS[name]:
        if key not in args:
            fail(f"{name} span: missing arg {key!r} (has {sorted(args)})")
    for key in INT_ARGS[name]:
        v = args[key]
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            fail(f"{name} span: arg {key!r} must be a non-negative integer, got {v!r}")
    return name


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="path to the exported Chrome-trace JSON")
    ap.add_argument(
        "--require",
        default=",".join(SPAN_KINDS),
        help="comma list of span kinds that must appear (default: all five)",
    )
    opts = ap.parse_args()
    required = [k.strip() for k in opts.require.split(",") if k.strip()]
    for k in required:
        if k not in REQUIRED_ARGS:
            fail(f"--require names unknown span kind {k!r} (known: {SPAN_KINDS})")

    try:
        with open(opts.trace, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {opts.trace}: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    if doc.get("displayTimeUnit") != "ms":
        fail(f"displayTimeUnit must be 'ms', got {doc.get('displayTimeUnit')!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("traceEvents missing or not an array")

    census = {}
    meta_lanes = set()
    for ev in events:
        if not isinstance(ev, dict):
            fail(f"non-object entry in traceEvents: {ev!r}")
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "process_name":
                meta_lanes.add(ev.get("args", {}).get("name"))
            continue
        if ph != "X":
            fail(f"unexpected event phase {ph!r} (exporter emits only 'X' and 'M')")
        kind = check_span(ev)
        census[kind] = census.get(kind, 0) + 1

    for lane in ("wall", "virtual"):
        if not any(lane in str(n) for n in meta_lanes):
            fail(f"missing process_name metadata for the {lane} clock lane (saw {meta_lanes})")
    for k in required:
        if census.get(k, 0) == 0:
            fail(f"no {k!r} spans recorded (census: {census})")

    total = sum(census.values())
    print(f"trace_check: OK — {total} spans across {len(census)} kinds")
    for k in sorted(census):
        print(f"  {k:16} {census[k]}")


if __name__ == "__main__":
    main()
