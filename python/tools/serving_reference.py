#!/usr/bin/env python3
"""Reference implementation of the rust serving tier, and the generator of
``rust/tests/golden_serving.json``.

Transliterates, op-for-op:

* the arrival processes of ``rust/src/serving/arrivals.rs`` (Poisson,
  2-state MMPP "bursty", Lewis-Shedler-thinned diurnal) with their
  whole-microsecond gap quantization,
* the P^2 (Jain-Chlamtac 1985) streaming quantile estimator and the
  LatencyTrack accumulator of ``rust/src/stats.rs``,
* the batching-window loop and SLO accounting of
  ``rust/src/serving/server.rs`` / ``sla.rs`` under the deterministic
  charges (``SolveCost::Virtual`` + ``DispatchCost::PerToken``).

Bit-exactness contract: inter-arrival gaps are floored to whole
microseconds, so every arrival timestamp is an integer-valued float and all
downstream window/SLO arithmetic uses only +,-,*,/ and comparisons — which
are bit-identical IEEE-754 in Python and rust. The only transcendental math
(log, sin) lives in arrival generation; this generator therefore *guards*
every draw (the floored value must sit >= 1e-6 from an integer boundary,
thinning decisions >= 1e-9 from the accept threshold) so a 1-ulp libm
difference between Python and rust cannot flip any decision. Guarded-out
draws are simply redrawn and never recorded; the fixture stores exactly the
uniform stream rust replays through ``ArrivalGen::with_uniforms``.

Config constants are dyadic (0.0625, 0.125, 500.0, ...) so products and
sums round identically. ``json.dump`` emits shortest-round-trip floats and
rust's ``str::parse::<f64>`` is correctly rounded, so values survive the
trip exactly.

Run:  python3 python/tools/serving_reference.py
"""

import json
import math
import os

import numpy as np

FRAC_GUARD = 1e-6     # floored draws must sit this far from integer edges
ACCEPT_GUARD = 1e-9   # thinning draws must sit this far from the threshold


# ---------------------------------------------------------------- arrivals

class GuardedUniforms:
    """numpy-backed uniform source recording every draw rust will replay."""

    def __init__(self, seed):
        self.rng = np.random.default_rng(seed)
        self.recorded = []

    def _raw(self):
        return float(self.rng.random())

    def gap_us(self, rate_hz):
        """exp_gap_us: floor(-ln(1-u)/rate*1e6), min 1 us (guarded)."""
        while True:
            u = self._raw()
            x = -math.log(1.0 - u) / rate_hz * 1e6
            f = x - math.floor(x)
            if FRAC_GUARD < f < 1.0 - FRAC_GUARD:
                self.recorded.append(u)
                return max(float(math.floor(x)), 1.0)

    def dwell_us(self, mean_us):
        """exp_dwell_us: floor(-ln(1-u)*mean), min 1 us (guarded)."""
        while True:
            u = self._raw()
            x = -math.log(1.0 - u) * mean_us
            f = x - math.floor(x)
            if FRAC_GUARD < f < 1.0 - FRAC_GUARD:
                self.recorded.append(u)
                return max(float(math.floor(x)), 1.0)

    def accept_draw(self, threshold):
        """Thinning uniform, guarded away from the accept threshold."""
        while True:
            u = self._raw()
            if abs(u - threshold) > ACCEPT_GUARD:
                self.recorded.append(u)
                return u


def token_count(tokens, rid):
    if tokens["kind"] == "fixed":
        return tokens["value"]
    if tokens["kind"] == "ramp":
        return tokens["base"] + tokens["step"] * (rid // tokens["every"])
    raise ValueError(tokens)


class ArrivalGen:
    """Mirror of rust ``ArrivalGen`` driven by a GuardedUniforms source."""

    def __init__(self, process, tokens, uni):
        self.process = process
        self.tokens = tokens
        self.uni = uni
        self.clock_us = 0.0
        self.next_id = 0
        self.burst = False
        # MMPP draws its first (calm) dwell at construction — fixed order
        if process["kind"] == "bursty":
            self.phase_end_us = self.uni.dwell_us(process["mean_calm_us"])
        else:
            self.phase_end_us = math.inf

    def next_request(self):
        p = self.process
        if p["kind"] == "poisson":
            self.clock_us += self.uni.gap_us(p["rate_hz"])
        elif p["kind"] == "bursty":
            while True:
                rate = p["burst_hz"] if self.burst else p["calm_hz"]
                candidate = self.clock_us + self.uni.gap_us(rate)
                if candidate <= self.phase_end_us:
                    self.clock_us = candidate
                    break
                # phase flips first: jump to the boundary, toggle, new dwell,
                # re-draw the gap in the new phase (memorylessness)
                self.clock_us = self.phase_end_us
                self.burst = not self.burst
                mean = p["mean_burst_us"] if self.burst else p["mean_calm_us"]
                self.phase_end_us = self.clock_us + self.uni.dwell_us(mean)
        elif p["kind"] == "diurnal":
            peak_hz = p["base_hz"] * (1.0 + p["amplitude"])
            while True:
                self.clock_us += self.uni.gap_us(peak_hz)
                phase = math.tau * self.clock_us / p["period_us"]
                accept = p["base_hz"] * (1.0 + p["amplitude"] * math.sin(phase)) / peak_hz
                if self.uni.accept_draw(accept) < accept:
                    break
        else:
            raise ValueError(p)
        rid = self.next_id
        self.next_id += 1
        return {"id": rid, "arrival_us": self.clock_us,
                "tokens": token_count(self.tokens, rid)}

    def take(self, n):
        return [self.next_request() for _ in range(n)]


# ------------------------------------------------------------- percentiles

def percentile(sorted_xs, q):
    """Mirror of rust ``stats::percentile`` (interpolated, sorted input)."""
    n = len(sorted_xs)
    assert n > 0 and 0.0 <= q <= 1.0
    if n == 1:
        return sorted_xs[0]
    pos = q * float(n - 1)
    lo = int(math.floor(pos))
    hi = int(math.ceil(pos))
    frac = pos - float(lo)
    return sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac


class P2Quantile:
    """Mirror of rust ``stats::P2Quantile`` — keep arithmetic and
    evaluation order in lock-step with the rust implementation."""

    def __init__(self, p):
        assert 0.0 < p < 1.0
        self.p = p
        self.count = 0
        self.warmup = []
        self.q = [0.0] * 5
        self.pos = [0.0] * 5
        self.desired = [0.0] * 5
        self.dn = [0.0] * 5

    def observe(self, x):
        self.count += 1
        if self.count <= 5:
            self.warmup.append(x)
            if self.count == 5:
                init = sorted(self.warmup)
                for i in range(5):
                    self.q[i] = init[i]
                    self.pos[i] = float(i + 1)
                p = self.p
                self.desired = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0]
                self.dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]
            return
        if x < self.q[0]:
            self.q[0] = x
            k = 0
        elif x < self.q[1]:
            k = 0
        elif x < self.q[2]:
            k = 1
        elif x < self.q[3]:
            k = 2
        elif x <= self.q[4]:
            k = 3
        else:
            self.q[4] = x
            k = 3
        for i in range(k + 1, 5):
            self.pos[i] += 1.0
        for i in range(5):
            self.desired[i] += self.dn[i]
        for i in range(1, 4):
            d = self.desired[i] - self.pos[i]
            if (d >= 1.0 and self.pos[i + 1] - self.pos[i] > 1.0) or \
               (d <= -1.0 and self.pos[i - 1] - self.pos[i] < -1.0):
                s = 1.0 if d >= 0.0 else -1.0
                cand = self._parabolic(i, s)
                if self.q[i - 1] < cand < self.q[i + 1]:
                    self.q[i] = cand
                else:
                    self.q[i] = self._linear(i, s)
                self.pos[i] += s

    def _parabolic(self, i, s):
        q, n = self.q, self.pos
        return q[i] + s / (n[i + 1] - n[i - 1]) \
            * ((n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
               + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i, s):
        j = i + 1 if s > 0.0 else i - 1
        return self.q[i] + s * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])

    def estimate(self):
        if self.count == 0:
            return math.nan
        if self.count <= 5:
            return percentile(sorted(self.warmup), self.p)
        return self.q[2]


class LatencyTrack:
    """Mirror of rust ``stats::LatencyTrack``."""

    def __init__(self):
        self.samples = []
        self.sum = 0.0
        # NaN sentinel like the rust track: an empty track has no maximum
        self.max = math.nan
        self.p2_50 = P2Quantile(0.50)
        self.p2_95 = P2Quantile(0.95)
        self.p2_99 = P2Quantile(0.99)

    def record(self, x):
        self.sum += x
        # mirror rust f64::max (maxNum): NaN.max(x) == x — python's
        # builtin max() would instead propagate the NaN sentinel forever
        self.max = x if math.isnan(self.max) else max(self.max, x)
        self.p2_50.observe(x)
        self.p2_95.observe(x)
        self.p2_99.observe(x)
        self.samples.append(x)

    def mean(self):
        return math.nan if not self.samples else self.sum / float(len(self.samples))

    def exact(self, q):
        return math.nan if not self.samples else percentile(sorted(self.samples), q)

    def to_json(self):
        def num(x):
            return None if math.isnan(x) else x
        return {
            "count": len(self.samples),
            "mean_us": num(self.mean()),
            "max_us": num(self.max),
            "p50_us": num(self.exact(0.50)),
            "p95_us": num(self.exact(0.95)),
            "p99_us": num(self.exact(0.99)),
            "p2_p50_us": num(self.p2_50.estimate()),
            "p2_p95_us": num(self.p2_95.estimate()),
            "p2_p99_us": num(self.p2_99.estimate()),
        }


class SlaStats:
    """Mirror of rust ``serving::SlaStats``."""

    def __init__(self):
        self.arrived = 0
        self.served = 0
        self.shed = 0
        self.deadline_misses = 0
        self.windows = 0
        self.empty_windows = 0
        self.queue = LatencyTrack()
        self.solve = LatencyTrack()
        self.dispatch = LatencyTrack()
        self.e2e = LatencyTrack()

    def record_served(self, queue_us, solve_us, dispatch_us, slo_us):
        self.served += 1
        e2e = queue_us + solve_us + dispatch_us
        self.queue.record(queue_us)
        self.solve.record(solve_us)
        self.dispatch.record(dispatch_us)
        self.e2e.record(e2e)
        if e2e > slo_us:
            self.deadline_misses += 1

    def record_shed(self):
        self.shed += 1

    def to_json(self):
        return {
            "arrived": self.arrived,
            "served": self.served,
            "shed": self.shed,
            "deadline_misses": self.deadline_misses,
            "windows": self.windows,
            "empty_windows": self.empty_windows,
            "queue": self.queue.to_json(),
            "solve": self.solve.to_json(),
            "dispatch": self.dispatch.to_json(),
            "e2e": self.e2e.to_json(),
        }


# ------------------------------------------------------------------ server

def run_server(reqs, cfg):
    """Mirror of ``MoeServer::run`` under Virtual solve + PerToken dispatch.

    Policy plans (gpu_compute / routes) are *not* mirrored — they depend on
    the scheduler; the golden fixture pins every field that is a pure
    function of the trace and the config.
    """
    shed_after = cfg["shed_after_us"] if cfg["shed_after_us"] is not None else math.inf
    sla = SlaStats()
    sla.arrived = len(reqs)
    n = len(reqs)
    now = 0.0
    windows = []
    queue = []   # indices; pops at the front (FIFO)
    head = 0     # front of `queue` (avoid O(n) list.pop(0))
    i = 0
    index = 0
    while i < n or head < len(queue):
        while i < n and reqs[i]["arrival_us"] <= now:
            queue.append(i)
            i += 1
        if head == len(queue):
            now = reqs[i]["arrival_us"]
            continue
        open_us = now
        close_us = open_us + cfg["window_us"]
        while (len(queue) - head) < cfg["max_batch"] and i < n \
                and reqs[i]["arrival_us"] <= close_us:
            queue.append(i)
            i += 1
        if (len(queue) - head) >= cfg["max_batch"]:
            close_us = max(open_us, reqs[queue[head + cfg["max_batch"] - 1]]["arrival_us"])
        # shed the ENTIRE stale prefix at close (queue is in arrival
        # order, so stale requests sit at the front), then take the batch
        # FIFO from the fresh remainder — op-for-op with MoeServer::run
        shed = []
        while head < len(queue):
            j = queue[head]
            if close_us - reqs[j]["arrival_us"] > shed_after:
                head += 1
                shed.append(reqs[j]["id"])
                sla.record_shed()
            else:
                break
        batch = []
        while len(batch) < cfg["max_batch"] and head < len(queue):
            batch.append(queue[head])
            head += 1
        sla.windows += 1
        if not batch:
            sla.empty_windows += 1
            tokens = 0
            solve_us = 0.0
            dispatch_us = 0.0
        else:
            tokens = 0
            for j in batch:
                tokens += reqs[j]["tokens"]
            solve_us = cfg["virtual_solve_us"]
            dispatch_us = cfg["dispatch_fixed_us"] + cfg["dispatch_us_per_token"] * float(tokens)
        service_us = solve_us + dispatch_us
        for j in batch:
            wait = close_us - reqs[j]["arrival_us"]
            sla.record_served(wait, solve_us, dispatch_us, cfg["slo_us"])
        windows.append({
            "index": index,
            "open_us": open_us,
            "close_us": close_us,
            "served": [reqs[j]["id"] for j in batch],
            "shed": shed,
            "tokens": tokens,
            "solve_us": solve_us,
            "dispatch_us": dispatch_us,
        })
        index += 1
        now = close_us + service_us
    return windows, sla


# ------------------------------------------------------------------- cases

def cases():
    """>= 6 regimes; every numeric knob dyadic so arithmetic is exact."""
    return [
        {
            "name": "steady_poisson",
            "seed": 11,
            "requests": 300,
            "process": {"kind": "poisson", "rate_hz": 20000.0},
            "tokens": {"kind": "fixed", "value": 32},
            "config": {"window_us": 500.0, "max_batch": 16, "slo_us": 2000.0,
                       "shed_after_us": None, "virtual_solve_us": 64.0,
                       "dispatch_fixed_us": 32.0, "dispatch_us_per_token": 0.0625},
        },
        {
            "name": "burst",
            "seed": 23,
            "requests": 400,
            "process": {"kind": "bursty", "calm_hz": 4000.0, "burst_hz": 64000.0,
                        "mean_calm_us": 8000.0, "mean_burst_us": 2000.0},
            "tokens": {"kind": "fixed", "value": 16},
            "config": {"window_us": 500.0, "max_batch": 8, "slo_us": 1500.0,
                       "shed_after_us": None, "virtual_solve_us": 32.0,
                       "dispatch_fixed_us": 16.0, "dispatch_us_per_token": 0.125},
        },
        {
            "name": "diurnal_ramp",
            "seed": 37,
            "requests": 400,
            "process": {"kind": "diurnal", "base_hz": 10000.0, "amplitude": 0.75,
                        "period_us": 50000.0},
            "tokens": {"kind": "fixed", "value": 24},
            "config": {"window_us": 250.0, "max_batch": 8, "slo_us": 1000.0,
                       "shed_after_us": None, "virtual_solve_us": 16.0,
                       "dispatch_fixed_us": 8.0, "dispatch_us_per_token": 0.25},
        },
        {
            "name": "overload_shed",
            "seed": 41,
            "requests": 400,
            "process": {"kind": "poisson", "rate_hz": 50000.0},
            "tokens": {"kind": "fixed", "value": 8},
            "config": {"window_us": 500.0, "max_batch": 4, "slo_us": 2000.0,
                       "shed_after_us": 3000.0, "virtual_solve_us": 2000.0,
                       "dispatch_fixed_us": 64.0, "dispatch_us_per_token": 0.5},
        },
        {
            "name": "drift",
            "seed": 53,
            "requests": 400,
            "process": {"kind": "poisson", "rate_hz": 15000.0},
            "tokens": {"kind": "ramp", "base": 8, "step": 8, "every": 50},
            "config": {"window_us": 500.0, "max_batch": 16, "slo_us": 3000.0,
                       "shed_after_us": None, "virtual_solve_us": 64.0,
                       "dispatch_fixed_us": 32.0, "dispatch_us_per_token": 0.0625},
        },
        {
            "name": "empty_window",
            "seed": 67,
            "requests": 120,
            "process": {"kind": "poisson", "rate_hz": 10000.0},
            "tokens": {"kind": "fixed", "value": 4},
            "config": {"window_us": 500.0, "max_batch": 8, "slo_us": 1000.0,
                       "shed_after_us": 0.0, "virtual_solve_us": 64.0,
                       "dispatch_fixed_us": 32.0, "dispatch_us_per_token": 0.0625},
        },
    ]


def self_test(case, reqs, windows, sla):
    """Invariants every regime must satisfy before it is committed."""
    n = case["requests"]
    assert sla.served + sla.shed == n, case["name"]
    seen = sorted(
        [rid for w in windows for rid in w["served"]]
        + [rid for w in windows for rid in w["shed"]]
    )
    assert seen == list(range(n)), f"{case['name']}: conservation"
    for w in windows:
        assert len(w["served"]) <= case["config"]["max_batch"]
        for rid in w["served"]:
            assert reqs[rid]["arrival_us"] <= w["close_us"], "served before arrival"
    assert all(r["arrival_us"] == math.floor(r["arrival_us"]) for r in reqs), \
        "arrivals must be integer microseconds"
    if case["name"] == "overload_shed":
        assert sla.shed > 0, "overload regime must shed"
    if case["name"] == "empty_window":
        assert sla.empty_windows > 0, "empty-window regime must form empty windows"
    # P^2 vs exact: loose sanity only (the fixture pins both separately)
    if sla.e2e.samples and len(sla.e2e.samples) >= 100:
        exact = sla.e2e.exact(0.50)
        est = sla.e2e.p2_50.estimate()
        assert abs(est - exact) <= 0.5 * max(abs(exact), 1.0), \
            f"{case['name']}: P2 p50 {est} vs exact {exact}"


def main():
    out = {"cases": []}
    for case in cases():
        uni = GuardedUniforms(case["seed"])
        gen = ArrivalGen(case["process"], case["tokens"], uni)
        reqs = gen.take(case["requests"])
        windows, sla = run_server(reqs, case["config"])
        self_test(case, reqs, windows, sla)
        out["cases"].append({
            "name": case["name"],
            "seed": case["seed"],
            "requests": case["requests"],
            "process": case["process"],
            "tokens": case["tokens"],
            "config": case["config"],
            "uniforms": uni.recorded,
            "arrival_us": [r["arrival_us"] for r in reqs],
            "arrival_tokens": [r["tokens"] for r in reqs],
            "windows": windows,
            "sla": sla.to_json(),
        })
        print(f"{case['name']}: {case['requests']} reqs, "
              f"{len(uni.recorded)} uniforms, {len(windows)} windows, "
              f"served {sla.served} shed {sla.shed} "
              f"empty {sla.empty_windows} misses {sla.deadline_misses}")
    path = os.path.normpath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)),
        "..", "..", "rust", "tests", "golden_serving.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1, allow_nan=False)
        f.write("\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
