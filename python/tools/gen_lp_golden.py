"""Generate golden LP fixtures with scipy's HiGHS solver.

The paper solves LPP 1 with HiGHS; our rust simplex backends must agree.
This tool builds five instance families —

* ``lpp1``    — random LPP-1 minimax instances (EDP groups, integer loads);
* ``generic`` — random bounded-feasible min-LPs with ``A x <= b`` rows;
* ``bounded`` — like ``generic`` but with finite per-variable upper bounds
  (some degenerate at 0), the structure the revised simplex handles as
  implicit bounds and the dense tableau expands into rows;
* ``boxed_degen``   — heavily-boxed instances with *duplicated* objective
  coefficients, so the dual ratio test sees tied (degenerate) breakpoints;
* ``boxed_resolve`` — a base problem plus a sequence of correlated
  rhs/bound edit steps (each step's HiGHS optimum recorded). The capacity
  swings are engineered so the warm dual repair must cross several
  breakpoints at once — the long-step dual's bound-flipping ratio test
  batches those as bound flips, which tests/golden_lp.rs asserts on —

solves them with scipy.optimize.linprog (method="highs" — the same HiGHS),
and writes objective values to ``rust/tests/golden_lp.json``. The rust
test re-solves each instance with every backend (replaying the
``boxed_resolve`` steps through the warm-start path) and compares
objectives to 1e-6.

Run from the repo root or python/:  python3 python/tools/gen_lp_golden.py
The fixture is committed; regenerate only when the format or the case set
changes, and commit the result (tests/golden_lp.rs hard-fails without it).
"""

import json
import os
import random

import numpy as np
from scipy.optimize import linprog


def lpp1_instance(rng, num_gpus, num_experts, d):
    """Random LPP-1: EDP groups of size d, integer loads."""
    edp = []
    for _ in range(num_experts):
        edp.append(sorted(rng.sample(range(num_gpus), d)))
    loads = [rng.randint(0, 500) for _ in range(num_experts)]

    # vars: x[e][r] .. then t
    nx = num_experts * d
    c = np.zeros(nx + 1)
    c[nx] = 1.0
    # A_ub x <= b_ub : per gpu sum x - t <= 0
    a_ub = np.zeros((num_gpus, nx + 1))
    for e, grp in enumerate(edp):
        for r, g in enumerate(grp):
            a_ub[g, e * d + r] = 1.0
    a_ub[:, nx] = -1.0
    b_ub = np.zeros(num_gpus)
    # A_eq: per expert sum x = load
    a_eq = np.zeros((num_experts, nx + 1))
    for e in range(num_experts):
        for r in range(d):
            a_eq[e, e * d + r] = 1.0
    b_eq = np.array(loads, dtype=float)

    res = linprog(c, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=b_eq, method="highs")
    assert res.status == 0, res.message
    return {
        "kind": "lpp1",
        "num_gpus": num_gpus,
        "d": d,
        "edp": edp,
        "loads": loads,
        "objective": float(res.fun),
    }


def generic_instance(rng, n, m):
    """Random bounded min-LP: c >= 0ish, A x <= b with b > 0 (x=0 feasible)."""
    c = [round(rng.uniform(-0.2, 1.0), 4) for _ in range(n)]
    rows = []
    for _ in range(m):
        rows.append([round(rng.uniform(0.05, 1.0), 4) for _ in range(n)])
    b = [round(rng.uniform(1.0, 8.0), 4) for _ in range(m)]
    res = linprog(c, A_ub=np.array(rows), b_ub=np.array(b), method="highs")
    if res.status != 0:
        return None
    return {"kind": "generic", "c": c, "a_ub": rows, "b_ub": b, "objective": float(res.fun)}


def bounded_instance(rng, n, m):
    """Random min-LP with finite upper bounds on most variables.

    Mixed-sign objective so optima land on bounds; a few bounds are
    degenerate (0), pinning the variable exactly the way LPP-4's empty
    per-replica input caps do.
    """
    c = [round(rng.uniform(-1.5, 1.0), 4) for _ in range(n)]
    rows = []
    for _ in range(m):
        rows.append([round(rng.uniform(0.05, 1.0), 4) for _ in range(n)])
    b = [round(rng.uniform(1.0, 8.0), 4) for _ in range(m)]
    upper = []
    for _ in range(n):
        r = rng.random()
        if r < 0.15:
            upper.append(0.0)  # degenerate: variable pinned at 0
        elif r < 0.85:
            upper.append(round(rng.uniform(0.2, 5.0), 4))
        else:
            upper.append(None)  # unbounded above
    bounds = [(0.0, u) for u in upper]
    res = linprog(
        c, A_ub=np.array(rows), b_ub=np.array(b), bounds=bounds, method="highs"
    )
    if res.status != 0:
        return None
    return {
        "kind": "bounded",
        "c": c,
        "a_ub": rows,
        "b_ub": b,
        "upper": [u if u is not None else -1.0 for u in upper],
        "objective": float(res.fun),
    }


def boxed_degen_instance(rng, n, m):
    """Heavily boxed + dual-degenerate: every variable finitely bounded and
    the objective built from a handful of *repeated* values, so many
    reduced costs tie and the dual ratio test must break degenerate
    breakpoint clusters deterministically."""
    pool = [round(rng.uniform(-2.0, 1.0), 4) for _ in range(max(2, n // 3))]
    c = [pool[rng.randrange(len(pool))] for _ in range(n)]
    rows = []
    for _ in range(m):
        rows.append([round(rng.uniform(0.05, 1.0), 4) for _ in range(n)])
    b = [round(rng.uniform(1.0, 6.0), 4) for _ in range(m)]
    upper = [round(rng.uniform(0.1, 3.0), 4) for _ in range(n)]
    bounds = [(0.0, u) for u in upper]
    res = linprog(
        c, A_ub=np.array(rows), b_ub=np.array(b), bounds=bounds, method="highs"
    )
    if res.status != 0:
        return None
    return {
        "kind": "boxed_degen",
        "c": c,
        "a_ub": rows,
        "b_ub": b,
        "upper": upper,
        "objective": float(res.fun),
    }


def boxed_resolve_instance(rng, n, num_steps):
    """Warm-replay fixture for the long-step dual: a knapsack-shaped
    max-profit LP over boxed variables whose capacity swings sharply
    between steps. A capacity drop pushes many at-upper variables' worth of
    load out in one dual repair, so the BFRT crosses several breakpoints —
    visible to rust as ``bound_flips > 0`` on the warm re-solve."""
    c = [round(-rng.uniform(0.5, 3.0), 4) for _ in range(n)]
    if n >= 4:  # duplicated costs: tied (dual-degenerate) breakpoints
        c[1] = c[0]
        c[3] = c[2]
    upper = [round(rng.uniform(0.5, 2.0), 4) for _ in range(n)]
    total = sum(upper)
    rows = [[1.0] * n, [1.0 if j % 2 == 0 else 0.0 for j in range(n)]]
    b = [round(total * 0.9, 4), round(total * 0.9, 4)]

    def solve(b_now, upper_now):
        res = linprog(
            c,
            A_ub=np.array(rows),
            b_ub=np.array(b_now),
            bounds=[(0.0, u) for u in upper_now],
            method="highs",
        )
        assert res.status == 0, res.message
        return float(res.fun)

    case = {
        "kind": "boxed_resolve",
        "c": c,
        "a_ub": rows,
        "b_ub": b,
        "upper": list(upper),
        "objective": solve(b, upper),
        "steps": [],
    }
    for k in range(num_steps):
        # alternate permissive/tight so each tightening forces a multi-flip
        # dual repair from a mostly-at-upper optimal basis
        frac = 0.95 if k % 2 == 0 else rng.uniform(0.1, 0.4)
        b = [round(sum(upper) * frac, 4), round(sum(upper) * 0.9, 4)]
        j = rng.randrange(n)
        upper = list(upper)
        upper[j] = round(rng.uniform(0.3, 2.5), 4)
        case["steps"].append(
            {"b_ub": b, "upper": list(upper), "objective": solve(b, upper)}
        )
    return case


def main():
    rng = random.Random(20250710)
    cases = []
    for num_gpus, num_experts, d in [
        (4, 8, 2), (8, 16, 2), (8, 32, 2), (16, 32, 2), (6, 8, 3), (8, 16, 4),
    ]:
        for _ in range(4):
            cases.append(lpp1_instance(rng, num_gpus, num_experts, d))
    for n, m in [(3, 2), (5, 4), (8, 6), (12, 10)]:
        for _ in range(4):
            inst = generic_instance(rng, n, m)
            if inst:
                cases.append(inst)
    for n, m in [(3, 2), (6, 4), (10, 7), (14, 10)]:
        for _ in range(5):
            inst = bounded_instance(rng, n, m)
            if inst:
                cases.append(inst)
    for n, m in [(6, 3), (10, 5), (16, 8), (24, 10)]:
        for _ in range(3):
            inst = boxed_degen_instance(rng, n, m)
            if inst:
                cases.append(inst)
    for n, steps in [(8, 6), (12, 6), (20, 8), (30, 8)]:
        for _ in range(2):
            cases.append(boxed_resolve_instance(rng, n, steps))
    out = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden_lp.json")
    with open(out, "w") as fh:
        json.dump({"cases": cases}, fh)
    print(f"wrote {len(cases)} cases to {out}")


if __name__ == "__main__":
    main()
