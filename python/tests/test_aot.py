"""AOT path tests: lowering emits parseable HLO text + a coherent manifest.

Uses the tiny `smoke` preset so the full emit runs in seconds.
"""

import json

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def smoke_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_smoke")
    aot.emit_all(str(out), "smoke")
    return out


def test_manifest_structure(smoke_dir):
    manifest = json.loads((smoke_dir / "manifest.json").read_text())
    assert manifest["preset"] == "smoke"
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"init_params", "train_step", "eval_loss", "gate", "expert_ffn", "moe_block"} <= names
    cfg = manifest["config"]
    assert manifest["num_params"] == M.num_params(M.ModelConfig(**cfg))


def test_hlo_files_exist_and_are_text(smoke_dir):
    manifest = json.loads((smoke_dir / "manifest.json").read_text())
    for art in manifest["artifacts"]:
        path = smoke_dir / art["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), f"{art['name']} not HLO text"
        # the xla_extension 0.5.1 parser rejects the dedicated topk op —
        # must never appear (see model.topk_iterative)
        assert " topk(" not in text, f"{art['name']} contains unparseable topk"


def test_train_step_io_arity(smoke_dir):
    manifest = json.loads((smoke_dir / "manifest.json").read_text())
    ts = next(a for a in manifest["artifacts"] if a["name"] == "train_step")
    assert [i["name"] for i in ts["inputs"]] == ["params", "m", "v", "step", "tokens"]
    assert [o["name"] for o in ts["outputs"]] == ["params", "m", "v", "step", "loss", "counts"]
    p = manifest["num_params"]
    assert ts["inputs"][0]["shape"] == [p]
    assert ts["outputs"][0]["shape"] == [p]
    cfg = manifest["config"]
    assert ts["outputs"][5]["shape"] == [cfg["layers"], cfg["experts"]]
    assert ts["outputs"][5]["dtype"] == "int32"


def test_roundtrip_through_jax_runtime(smoke_dir):
    """The lowered train_step must agree with direct jax execution."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax._src.lib import xla_client as xc

    manifest = json.loads((smoke_dir / "manifest.json").read_text())
    cfg = M.ModelConfig(**manifest["config"])
    params = M.init_params(jnp.int32(0), cfg)
    z = jnp.zeros_like(params)
    key = jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (cfg.micro_batch, cfg.seq + 1), 0, cfg.vocab)

    direct = M.train_step(params, z, z, jnp.float32(0), tok, cfg)

    # execute the lowered HLO through jax's own client
    text = (smoke_dir / "train_step.hlo.txt").read_text()
    comp = xc._xla.hlo_module_from_text(text)
    # (fall back: recompile from the source fn; identical lowering path)
    lowered_fn = jax.jit(lambda fp, m, v, st, t: M.train_step(fp, m, v, st, t, cfg))
    relowered = lowered_fn(params, z, z, jnp.float32(0), tok)
    np.testing.assert_allclose(np.asarray(direct[4]), np.asarray(relowered[4]), rtol=1e-5)
    assert comp is not None
