"""Layer-2 model tests: shapes, packing, dispatch math, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    vocab=64, seq=16, hidden=32, heads=4, ffn=64, layers=2, experts=4,
    topk=2, micro_batch=2,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jnp.int32(0), CFG)


def _tokens(seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (CFG.micro_batch, CFG.seq + 1), 0, CFG.vocab)


class TestPacking:
    def test_num_params_matches_spec(self, params):
        assert params.shape == (M.num_params(CFG),)

    def test_unpack_shapes(self, params):
        p = M.unpack(params, CFG)
        assert p["embed"].shape == (CFG.vocab, CFG.hidden)
        assert p["l0.w1"].shape == (CFG.experts, CFG.hidden, CFG.ffn)
        assert p["l1.w2"].shape == (CFG.experts, CFG.ffn, CFG.hidden)
        assert p["head"].shape == (CFG.hidden, CFG.vocab)

    def test_unpack_is_partition(self, params):
        # every packed element lands in exactly one unpacked tensor
        total = sum(int(np.prod(v.shape)) for v in M.unpack(params, CFG).values())
        assert total == params.shape[0]

    def test_scales_init_to_one(self, params):
        p = M.unpack(params, CFG)
        np.testing.assert_allclose(p["l0.ln1_scale"], 1.0)
        np.testing.assert_allclose(p["lnf_bias"], 0.0)

    def test_init_deterministic_in_seed(self):
        a = M.init_params(jnp.int32(7), CFG)
        b = M.init_params(jnp.int32(7), CFG)
        c = M.init_params(jnp.int32(8), CFG)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)


class TestForward:
    def test_logits_shape_and_finite(self, params):
        logits, counts, aux = M.forward(params, _tokens()[:, :-1], CFG)
        assert logits.shape == (CFG.micro_batch, CFG.seq, CFG.vocab)
        assert counts.shape == (CFG.layers, CFG.experts)
        assert np.isfinite(np.asarray(logits)).all()
        assert float(aux) > 0.0

    def test_counts_sum_to_topk_tokens(self, params):
        _, counts, _ = M.forward(params, _tokens()[:, :-1], CFG)
        t = CFG.tokens_per_mb
        np.testing.assert_array_equal(
            np.asarray(counts).sum(axis=1), [t * CFG.topk] * CFG.layers
        )

    def test_causality(self, params):
        """Changing a late token must not affect earlier logits."""
        tok = _tokens()[:, :-1]
        l1, _, _ = M.forward(params, tok, CFG)
        tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % CFG.vocab)
        l2, _, _ = M.forward(params, tok2, CFG)
        np.testing.assert_allclose(
            np.asarray(l1)[:, : CFG.seq - 1], np.asarray(l2)[:, : CFG.seq - 1],
            rtol=1e-4, atol=1e-5,
        )

    def test_pallas_and_ref_paths_agree(self, params):
        tok = _tokens()[:, :-1]
        ref_cfg = M.ModelConfig(**{**CFG.__dict__, "use_pallas": False})
        l1, c1, _ = M.forward(params, tok, CFG)
        l2, c2, _ = M.forward(params, tok, ref_cfg)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


class TestTrainStep:
    def test_loss_decreases(self, params):
        """A few Adam steps on one repeated batch must reduce loss."""
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        step = jnp.float32(0)
        tok = _tokens()
        fp = params
        losses = []
        for _ in range(5):
            fp, m, v, step, loss, _counts = M.train_step(fp, m, v, step, tok, CFG)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses

    def test_initial_loss_near_uniform(self, params):
        loss, _ = M.eval_loss(params, _tokens(), CFG)
        # aux coefficient is small; CE should sit near ln(vocab)
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0

    def test_step_counter_increments(self, params):
        z = jnp.zeros_like(params)
        _, _, _, step, _, _ = M.train_step(params, z, z, jnp.float32(3), _tokens(), CFG)
        assert float(step) == 4.0

    def test_counts_dtype_and_bounds(self, params):
        z = jnp.zeros_like(params)
        *_, counts = M.train_step(params, z, z, jnp.float32(0), _tokens(), CFG)
        counts = np.asarray(counts)
        assert counts.dtype == np.int32
        assert (counts >= 0).all()
        assert (counts <= CFG.tokens_per_mb * CFG.topk).all()


class TestMoeBlock:
    def test_moe_block_fwd_shapes(self):
        key = jax.random.split(jax.random.PRNGKey(0), 4)
        t, h, e, f = CFG.tokens_per_mb, CFG.hidden, CFG.experts, CFG.ffn
        x = jax.random.normal(key[0], (t, h))
        wg = jax.random.normal(key[1], (h, e)) * 0.1
        w1 = jax.random.normal(key[2], (e, h, f)) * 0.1
        w2 = jax.random.normal(key[3], (e, f, h)) * 0.1
        y, counts = M.moe_block_fwd(x, wg, w1, w2, CFG)
        assert y.shape == (t, h)
        assert int(np.asarray(counts).sum()) == t * CFG.topk

    def test_uniform_gate_spreads_load(self):
        """Zero gate weights -> uniform probs -> top-k ties; loads bounded."""
        key = jax.random.split(jax.random.PRNGKey(1), 3)
        t, h, e, f = CFG.tokens_per_mb, CFG.hidden, CFG.experts, CFG.ffn
        x = jax.random.normal(key[0], (t, h))
        wg = jnp.zeros((h, e))
        w1 = jax.random.normal(key[1], (e, h, f)) * 0.1
        w2 = jax.random.normal(key[2], (e, f, h)) * 0.1
        _, counts = M.moe_block_fwd(x, wg, w1, w2, CFG)
        assert int(np.asarray(counts).sum()) == t * CFG.topk
