"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes/dtypes/seeds; every property failing here indicates
a kernel-schedule bug (BlockSpec/index-map/accumulation), not model math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_ffn, expert_ffn_tiled_f, topk_gate
from compile.kernels.ref import expert_ffn_ref, topk_gate_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def _ffn_inputs(seed, e, c, h, f):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    return _rand(k[0], e, c, h), _rand(k[1], e, h, f) * 0.1, _rand(k[2], e, f, h) * 0.1


# ---------------------------------------------------------------------------
# expert_ffn
# ---------------------------------------------------------------------------

class TestExpertFfn:
    def test_matches_ref_basic(self):
        x, w1, w2 = _ffn_inputs(0, e=4, c=32, h=16, f=64)
        np.testing.assert_allclose(
            expert_ffn(x, w1, w2), expert_ffn_ref(x, w1, w2), rtol=1e-5, atol=1e-5
        )

    def test_zero_padding_slots_stay_zero(self):
        x, w1, w2 = _ffn_inputs(1, e=2, c=16, h=8, f=16)
        x = x.at[:, 8:, :].set(0.0)
        y = expert_ffn(x, w1, w2)
        # gelu(0 @ w1) @ w2 == 0
        np.testing.assert_allclose(y[:, 8:, :], 0.0, atol=1e-6)

    def test_experts_are_independent(self):
        x, w1, w2 = _ffn_inputs(2, e=3, c=8, h=8, f=16)
        y = expert_ffn(x, w1, w2)
        # perturbing expert 1's input must not change expert 0/2 outputs
        x2 = x.at[1].add(1.0)
        y2 = expert_ffn(x2, w1, w2)
        np.testing.assert_allclose(y2[0], y[0], atol=1e-6)
        np.testing.assert_allclose(y2[2], y[2], atol=1e-6)
        assert not np.allclose(y2[1], y[1])

    @pytest.mark.parametrize("tile_m", [1, 2, 4, 8, 16])
    def test_tile_m_invariance(self, tile_m):
        x, w1, w2 = _ffn_inputs(3, e=2, c=16, h=8, f=16)
        ref = expert_ffn_ref(x, w1, w2)
        np.testing.assert_allclose(
            expert_ffn(x, w1, w2, tile_m=tile_m), ref, rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        e=st.integers(1, 6),
        c=st.sampled_from([8, 16, 24, 32]),
        h=st.sampled_from([4, 8, 16]),
        f=st.sampled_from([8, 16, 32]),
    )
    def test_matches_ref_hypothesis(self, seed, e, c, h, f):
        x, w1, w2 = _ffn_inputs(seed, e, c, h, f)
        np.testing.assert_allclose(
            expert_ffn(x, w1, w2), expert_ffn_ref(x, w1, w2), rtol=2e-5, atol=2e-5
        )


class TestExpertFfnTiledF:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        e=st.integers(1, 4),
        c=st.sampled_from([8, 16]),
        h=st.sampled_from([8, 16]),
        f=st.sampled_from([16, 32, 64]),
        tf=st.sampled_from([4, 8, 16]),
    )
    def test_matches_ref_hypothesis(self, seed, e, c, h, f, tf):
        x, w1, w2 = _ffn_inputs(seed, e, c, h, f)
        np.testing.assert_allclose(
            expert_ffn_tiled_f(x, w1, w2, tile_f=tf),
            expert_ffn_ref(x, w1, w2),
            rtol=2e-5, atol=2e-5,
        )

    def test_accumulation_matches_untiled(self):
        x, w1, w2 = _ffn_inputs(7, e=2, c=16, h=8, f=32)
        np.testing.assert_allclose(
            expert_ffn_tiled_f(x, w1, w2, tile_f=8),
            expert_ffn(x, w1, w2),
            rtol=1e-5, atol=1e-5,
        )


# ---------------------------------------------------------------------------
# topk_gate
# ---------------------------------------------------------------------------

class TestTopkGate:
    def test_matches_ref_basic(self):
        logits = _rand(jax.random.PRNGKey(0), 64, 8)
        w, idx = topk_gate(logits, k=2)
        wr, idxr = topk_gate_ref(logits, k=2)
        np.testing.assert_array_equal(np.sort(idx, -1), np.sort(idxr, -1))
        np.testing.assert_allclose(w, wr, rtol=1e-5, atol=1e-6)

    def test_weights_sum_to_one(self):
        logits = _rand(jax.random.PRNGKey(1), 32, 16)
        w, _ = topk_gate(logits, k=4)
        np.testing.assert_allclose(np.sum(np.asarray(w), -1), 1.0, rtol=1e-5)

    def test_indices_distinct_per_token(self):
        logits = _rand(jax.random.PRNGKey(2), 128, 8)
        _, idx = topk_gate(logits, k=3)
        idx = np.asarray(idx)
        for row in idx:
            assert len(set(row.tolist())) == 3

    def test_k_equals_e_selects_all(self):
        logits = _rand(jax.random.PRNGKey(3), 16, 4)
        _, idx = topk_gate(logits, k=4)
        for row in np.asarray(idx):
            assert sorted(row.tolist()) == [0, 1, 2, 3]

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        t=st.sampled_from([8, 32, 96]),
        e=st.sampled_from([4, 8, 32]),
        k=st.integers(1, 4),
    )
    def test_matches_ref_hypothesis(self, seed, t, e, k):
        logits = _rand(jax.random.PRNGKey(seed), t, e)
        w, idx = topk_gate(logits, k=k)
        wr, idxr = topk_gate_ref(logits, k=k)
        # expert sets must agree (ties can permute order within equal probs)
        np.testing.assert_array_equal(np.sort(idx, -1), np.sort(idxr, -1))
        np.testing.assert_allclose(np.sort(w, -1), np.sort(wr, -1), rtol=1e-4, atol=1e-5)

    def test_skewed_logits_pick_hot_expert(self):
        logits = jnp.zeros((16, 8)).at[:, 3].set(10.0)
        _, idx = topk_gate(logits, k=1)
        assert np.all(np.asarray(idx) == 3)
