//! ISSUE-10 acceptance: the two-timescale placement controller on drifting
//! Zipf traffic.
//!
//! * the controlled session achieves strictly lower mean imbalance than
//!   the static-placement session, and wins on *net* step time — FFN
//!   compute plus every second of charged migration downtime;
//! * migration downtime is charged honestly (`ControlStats::downtime`
//!   equals the `prep_extra` the plans carry);
//! * controller runs are bit-deterministic end to end;
//! * `Span::PlacementChange` trace spans reconcile exactly with
//!   `ControlStats`, and a standalone detector+decider replay of the raw
//!   load trace — no scheduling involved at all — reproduces the
//!   balancer's decision stream span for span, which is the
//!   worker-count-independence argument in executable form (decisions are
//!   a pure function of the load trace, spec, and seed).

use micromoe::balancer::{Balancer, MoeSession, StepInput};
use micromoe::cluster::CostModel;
use micromoe::control::{decide, ControlSpec, ControlledLppBalancer, LoadDetector};
use micromoe::obs::{Span, TraceConfig, Tracer};
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::Rng;
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::topology::Topology;
use micromoe::workload::{DriftingWorkload, Workload};

const EXPERTS: usize = 16;
const GPUS: usize = 8;
const TOKENS: u64 = 8192;
const STEPS: usize = 96;
const MIG_BYTES: u64 = 1 << 22;

fn topo() -> Topology {
    Topology::new(8, 4, 2, 4)
}

fn cspec() -> ControlSpec {
    ControlSpec { interval: 8, dwell: 2, ..Default::default() }
}

/// Drifting Zipf trace: heavy skew (s=1.4) whose hot set rotates slowly.
fn drift_trace(seed: u64) -> Vec<LoadMatrix> {
    let mut wl = DriftingWorkload::new(EXPERTS, GPUS, TOKENS, 1.4, 32, seed);
    (0..STEPS).map(|_| wl.next_batch()).collect()
}

fn controlled_session() -> MoeSession {
    MoeSession::builder()
        .topology(topo())
        .experts(EXPERTS)
        .policy_name("micromoe")
        .layers(1)
        .control(cspec())
        .migration_cost(CostModel::h100_testbed(), MIG_BYTES)
        .build()
        .expect("controlled session builds")
}

fn static_session() -> MoeSession {
    MoeSession::builder()
        .topology(topo())
        .experts(EXPERTS)
        .policy_name("micromoe")
        .layers(1)
        .build()
        .expect("static session builds")
}

/// max/mean GPU compute of a one-layer step.
fn imbalance(gpu_compute: &[u64]) -> f64 {
    let max = *gpu_compute.iter().max().unwrap();
    let total: u64 = gpu_compute.iter().sum();
    max as f64 * gpu_compute.len() as f64 / total as f64
}

/// The headline acceptance run: same seeded trace through both arms.
/// The controller must beat static placement on mean imbalance AND on net
/// step time = Σ ffn_time(max gpu load) + every charged downtime second.
#[test]
fn controller_beats_static_net_of_migration_downtime() {
    let trace = drift_trace(0xA11CE);
    let model = CostModel::h100_testbed();
    let mut ctrl = controlled_session();
    let mut stat = static_session();

    let warmup = cspec().interval; // no decision can land before tick 1
    let (mut imb_c, mut imb_s) = (0.0, 0.0);
    let (mut time_c, mut time_s) = (0.0, 0.0);
    let mut charged = 0.0;
    for (i, lm) in trace.iter().enumerate() {
        let loads = std::slice::from_ref(lm);
        let oc = ctrl.step(loads);
        let os = stat.step(loads);
        for out in [&oc, &os] {
            assert_eq!(
                out.layers[0].gpu_compute.iter().sum::<u64>(),
                lm.total(),
                "step {i}: token conservation"
            );
        }
        let (pc, ps) = (&oc.layers[0], &os.layers[0]);
        // net step time: compute bottleneck + charged migration downtime
        time_c += model.ffn_time(*pc.gpu_compute.iter().max().unwrap()) + pc.prep_extra;
        time_s += model.ffn_time(*ps.gpu_compute.iter().max().unwrap()) + ps.prep_extra;
        charged += pc.prep_extra;
        assert_eq!(ps.prep_extra, 0.0, "static arm must never be charged downtime");
        if i >= warmup {
            imb_c += imbalance(&pc.gpu_compute);
            imb_s += imbalance(&ps.gpu_compute);
        }
    }

    let st = ctrl.stats();
    assert!(st.control.decisions > 0, "drifting skew must trigger migrations: {:?}", st.control);
    assert!(st.control.downtime > 0.0, "{:?}", st.control);
    // honest accounting: every downtime second shows up as plan prep
    assert!(
        (charged - st.control.downtime).abs() <= 1e-12,
        "charged {charged} != ControlStats downtime {}",
        st.control.downtime
    );
    assert!(st.prep_seconds >= st.control.downtime - 1e-12, "prep must include downtime");

    let n = (STEPS - warmup) as f64;
    assert!(
        imb_c / n < imb_s / n,
        "controller imbalance {} must beat static {}",
        imb_c / n,
        imb_s / n
    );
    assert!(
        time_c < time_s,
        "controller net step time {time_c}s (incl. {charged}s downtime) must beat \
         static {time_s}s"
    );
}

/// Bit-determinism at the session level: identical trace, identical
/// session → identical plans and identical control accounting, to the bit.
#[test]
fn controlled_sessions_are_bit_deterministic() {
    let trace = drift_trace(0xD0_0D);
    let run = || {
        let mut s = controlled_session();
        let mut computes = Vec::new();
        for lm in &trace {
            let out = s.step(std::slice::from_ref(lm));
            computes.push(out.layers[0].gpu_compute.clone());
        }
        (computes, s.stats().control)
    };
    let (ca, sa) = run();
    let (cb, sb) = run();
    assert_eq!(ca, cb, "per-step GPU loads diverged between reruns");
    assert_eq!(sa, sb, "control accounting diverged between reruns");
    assert_eq!(sa.downtime.to_bits(), sb.downtime.to_bits());
    assert_eq!(sa.predicted_gain.to_bits(), sb.predicted_gain.to_bits());
    assert_eq!(sa.realized_gain.to_bits(), sb.realized_gain.to_bits());
}

/// Placement-change spans are the exact ledger of `ControlStats`, and a
/// standalone detector+decider replay of the raw load trace reproduces
/// them one for one — no scheduler state involved, proving the decision
/// stream independent of how the fast loop runs.
#[test]
fn placement_spans_reconcile_with_stats_and_replay() {
    let trace = drift_trace(0x5EED);
    let spec = ControlSpec { bytes_per_expert: MIG_BYTES, ..cspec() };
    let topo = topo();
    let placement = symmetric_placement(&topo, EXPERTS);
    let model = CostModel::h100_testbed();

    let tracer = Tracer::new(TraceConfig::Wall);
    let opts = SchedulerOptions { trace: tracer.clone(), ..Default::default() };
    let mut b = ControlledLppBalancer::new(
        placement.clone(),
        topo.clone(),
        opts,
        1,
        false,
        spec.clone(),
        model.clone(),
        9,
    );
    for lm in &trace {
        b.step(&StepInput { loads: std::slice::from_ref(lm) });
    }
    let st = b.stats().control;

    let spans: Vec<(usize, usize, usize, u64, f64, f64)> = tracer
        .events()
        .into_iter()
        .filter_map(|e| match e.span {
            Span::PlacementChange { step, tick, moves, bytes, predicted_gain, downtime } => {
                Some((step, tick, moves, bytes, predicted_gain, downtime))
            }
            _ => None,
        })
        .collect();

    // spans ↔ stats, field by field
    assert_eq!(spans.len() as u64, st.decisions, "one span per decision: {st:?}");
    assert!(st.decisions > 0, "vacuous without decisions: {st:?}");
    assert_eq!(spans.iter().map(|s| s.2 as u64).sum::<u64>(), st.moves);
    assert_eq!(spans.iter().map(|s| s.3).sum::<u64>(), st.bytes);
    let gain: f64 = spans.iter().map(|s| s.4).sum();
    assert_eq!(gain.to_bits(), st.predicted_gain.to_bits(), "gain ledger");
    let down: f64 = spans.iter().map(|s| s.5).sum();
    assert_eq!(down.to_bits(), st.downtime.to_bits(), "downtime ledger");

    // standalone replay: detector + decider on the raw loads, nothing else
    let slot_budget =
        (0..GPUS).map(|g| placement.slots_used(g)).max().unwrap() + spec.slot_headroom;
    let mut det = LoadDetector::new(EXPERTS, &spec);
    let mut current = placement;
    let mut rng = Rng::new(0); // never consumed at 8 GPUs (exact density)
    let mut si = 0usize;
    let mut ticks = 0usize;
    for (i, lm) in trace.iter().enumerate() {
        det.observe(&lm.expert_loads());
        let step = i + 1;
        if step % spec.interval != 0 {
            continue;
        }
        ticks += 1;
        let Some(d) = decide(&current, &det, &topo, &model, &spec, slot_budget, &mut rng)
        else {
            continue;
        };
        let (s_step, s_tick, s_moves, s_bytes, s_gain, s_down) = spans[si];
        assert_eq!(s_step, step, "replay decided at a different step");
        assert_eq!(s_tick, ticks, "replay tick index");
        assert_eq!(s_moves, d.moves.len(), "replay move count");
        assert_eq!(s_bytes, d.bytes, "replay bytes");
        assert_eq!(s_gain.to_bits(), d.predicted_gain.to_bits(), "replay gain");
        assert_eq!(s_down.to_bits(), d.downtime.to_bits(), "replay downtime");
        current = d.placement;
        si += 1;
    }
    assert_eq!(si, spans.len(), "replay must account for every span");
    // and the end states agree exactly
    assert_eq!(b.placements()[0].replicas, current.replicas, "final placement");
    let bal_ema: Vec<u64> = b.detector(0).ema().iter().map(|x| x.to_bits()).collect();
    let rep_ema: Vec<u64> = det.ema().iter().map(|x| x.to_bits()).collect();
    assert_eq!(bal_ema, rep_ema, "final detector EWMA");
}
