//! Differential testing of the Dantzig–Wolfe decomposed scheduler
//! (`ScheduleMode::Decomposed`) against the exact monolithic LP.
//!
//! The exact LPP-4 solve is the optimality oracle: on every seeded
//! instance the decomposed max GPU load must land within 1% of the exact
//! optimum (plus one token of integer-rounding slack). The default suite
//! runs 256- and 512-GPU groups; the 1024/2048-GPU shapes the
//! `hierarchical_scale` bench reports are `#[ignore]`d here (the exact
//! oracle alone is minutes of debug-mode simplex) and run in release in
//! the CI `hierarchical-scale` job via `cargo test --release -- --ignored`.
//!
//! Every randomized test derives its RNG from `LP_FUZZ_SEED` (default:
//! the per-test constant) and prints the seed it ran with, so failures
//! replay with `LP_FUZZ_SEED=<seed> cargo test --test
//! differential_decompose`.

use micromoe::placement::Placement;
use micromoe::prop::fuzz_seed;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::stats::DegradationRung;
use micromoe::topology::Topology;

/// Each expert gets two adjacent-GPU pairs half a ring apart — replica
/// freedom inside a node block (the pair) times master freedom across
/// blocks (the pairs land in far-apart blocks).
fn paired_placement(gpus: usize, experts: usize) -> Placement {
    let half = gpus / 2;
    let reps = (0..experts)
        .map(|e| {
            let a = (2 * e) % half;
            let mut v = vec![a, a + 1, a + half, a + half + 1];
            v.sort_unstable();
            v.dedup();
            v
        })
        .collect();
    Placement::from_replicas(gpus, reps)
}

/// Adversarial structure: replicas strided `gpus/replicas` apart, so most
/// blocks hold exactly one replica of each resident expert and the master
/// alone carries the balancing burden.
fn strided_placement(gpus: usize, experts: usize, replicas: usize) -> Placement {
    let stride = gpus / replicas;
    let reps = (0..experts)
        .map(|e| {
            let mut v: Vec<usize> = (0..replicas).map(|k| (e + k * stride) % gpus).collect();
            v.sort_unstable();
            v.dedup();
            v
        })
        .collect();
    Placement::from_replicas(gpus, reps)
}

/// Zipf-skewed token batch: `per_gpu` tokens drawn on each GPU, expert
/// picked by a Zipf(1.05) over a seed-rotated expert permutation (so the
/// hot experts decorrelate from the placement layout).
fn zipf_batch(rng: &mut Rng, experts: usize, gpus: usize, per_gpu: usize) -> LoadMatrix {
    let zipf = Zipf::new(experts, 1.05);
    let mut perm: Vec<usize> = (0..experts).collect();
    for i in (1..experts).rev() {
        perm.swap(i, rng.below(i as u64 + 1) as usize);
    }
    let mut lm = LoadMatrix::zeros(experts, gpus);
    for g in 0..gpus {
        for _ in 0..per_gpu {
            lm.add(perm[zipf.sample(rng)], g, 1);
        }
    }
    lm
}

/// One 8-GPUs-per-node topology spanning the whole group.
fn group_topo(gpus: usize) -> Topology {
    Topology::new(gpus, gpus / 2, 2, 8)
}

fn dec_opts(nodes_per_block: usize) -> SchedulerOptions {
    SchedulerOptions {
        mode: ScheduleMode::Decomposed { nodes_per_block, max_outer_iters: 6, tol: 1e-3 },
        ..Default::default()
    }
}

/// Run `batches` seeded micro-batches through both schedulers and assert
/// conservation, a healthy (non-degraded) decomposed solve, and the 1%
/// optimality envelope.
fn assert_within_one_percent(
    placement: Placement,
    gpus: usize,
    nodes_per_block: usize,
    seed: u64,
    per_gpu: usize,
    batches: usize,
) {
    let experts = placement.num_experts;
    let mut rng = Rng::new(seed);
    let mut exact =
        MicroEpScheduler::new(placement.clone(), None, SchedulerOptions::default());
    let mut dec =
        MicroEpScheduler::new(placement, Some(group_topo(gpus)), dec_opts(nodes_per_block));
    for batch in 0..batches {
        let lm = zipf_batch(&mut rng, experts, gpus, per_gpu);
        let a = exact.schedule(&lm);
        let b = dec.schedule(&lm);
        for e in 0..experts {
            assert_eq!(
                b.replica_loads[e].iter().sum::<u64>(),
                lm.expert_load(e),
                "batch {batch} expert {e}: decomposed plan must conserve tokens"
            );
        }
        assert_ne!(b.stats.rung, DegradationRung::Greedy, "batch {batch}: no degradation");
        let m = b.stats.decompose.expect("decomposed meters recorded");
        assert!(m.blocks > 1, "partition must be nontrivial, got {} blocks", m.blocks);
        assert_eq!(m.blocks_degraded, 0, "batch {batch}");
        let (ea, eb) = (a.stats.max_gpu_load, b.stats.max_gpu_load);
        assert!(
            eb as f64 <= ea as f64 * 1.01 + 1.0,
            "batch {batch}: decomposed max load {eb} exceeds exact {ea} by >1%"
        );
    }
}

#[test]
fn decomposed_within_one_percent_256_gpus_paired() {
    let seed = fuzz_seed(0xdec0_0256);
    assert_within_one_percent(paired_placement(256, 96), 256, 1, seed, 200, 3);
}

#[test]
fn decomposed_within_one_percent_256_gpus_strided() {
    // one-replica-per-block blocks: the master water-fill alone must hit
    // the envelope
    let seed = fuzz_seed(0xdec0_0257);
    assert_within_one_percent(strided_placement(256, 128, 4), 256, 1, seed, 200, 3);
}

#[test]
fn decomposed_within_one_percent_512_gpus_two_node_blocks() {
    let seed = fuzz_seed(0xdec0_0512);
    assert_within_one_percent(paired_placement(512, 256), 512, 2, seed, 150, 2);
}

#[test]
#[ignore = "exact 1024-GPU oracle is minutes of debug-mode simplex; run with --release --ignored (CI hierarchical-scale job)"]
fn decomposed_within_one_percent_1024_gpus() {
    let seed = fuzz_seed(0xdec0_1024);
    assert_within_one_percent(paired_placement(1024, 512), 1024, 2, seed, 400, 1);
}

#[test]
#[ignore = "exact 2048-GPU oracle is minutes of debug-mode simplex; run with --release --ignored (CI hierarchical-scale job)"]
fn decomposed_within_one_percent_2048_gpus() {
    let seed = fuzz_seed(0xdec0_2048);
    assert_within_one_percent(paired_placement(2048, 1024), 2048, 2, seed, 400, 1);
}

#[test]
fn warm_start_reaches_the_same_envelope() {
    // repeated correlated batches: the warm path (rung WarmLp from batch
    // 2 on) must stay inside the envelope, not just the cold first solve
    let seed = fuzz_seed(0xdec0_aaaa);
    let gpus = 256;
    let placement = paired_placement(gpus, 96);
    let mut rng = Rng::new(seed);
    let mut exact =
        MicroEpScheduler::new(placement.clone(), None, SchedulerOptions::default());
    let mut dec = MicroEpScheduler::new(placement, Some(group_topo(gpus)), dec_opts(1));
    let mut saw_warm = false;
    for batch in 0..4 {
        let lm = zipf_batch(&mut rng, 96, gpus, 120);
        let a = exact.schedule(&lm);
        let b = dec.schedule(&lm);
        if batch > 0 && b.stats.rung == DegradationRung::WarmLp {
            saw_warm = true;
        }
        assert!(
            b.stats.max_gpu_load as f64 <= a.stats.max_gpu_load as f64 * 1.01 + 1.0,
            "batch {batch} (seed {seed})"
        );
    }
    assert!(saw_warm, "warm rung never engaged across correlated batches (seed {seed})");
}
