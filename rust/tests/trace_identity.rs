//! Tracing identity + span-accounting suite — ISSUE-9's observability
//! acceptance criteria.
//!
//! Two contracts are pinned here. **Identity**: tracing observes, never
//! steers — a session with an enabled [`Tracer`] emits bit-identical
//! schedules to an untraced one, and the disabled default records nothing
//! at all. **Accounting**: the recorded span set is exact — one solve span
//! per committed layer plan, one engine span per in-order emission, one
//! decompose-round span per outer round per block, one serving-window span
//! per formed window — so span counts reconcile against the stats structs
//! (`DegradationStats`, `EngineStats`, `DecomposeStats`, `SlaStats`)
//! without slack.

use micromoe::balancer::MoeSession;
use micromoe::engine::EngineMode;
use micromoe::obs::{ClockDomain, Span, SpanOutcome, TraceConfig, TraceEvent, Tracer};
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, ScheduleMode, SchedulerOptions};
use micromoe::topology::Topology;

const EXPERTS: usize = 16;
const GPUS: usize = 8;

fn zipf_lm(seed: u64, per_gpu: u64, s: f64) -> LoadMatrix {
    let mut rng = Rng::new(seed);
    let z = Zipf::new(EXPERTS, s);
    let mut lm = LoadMatrix::zeros(EXPERTS, GPUS);
    for g in 0..GPUS {
        for _ in 0..per_gpu {
            lm.add(z.sample(&mut rng), g, 1);
        }
    }
    lm
}

fn session(topo: Topology, opts: SchedulerOptions, layers: usize) -> MoeSession {
    MoeSession::builder()
        .topology(topo)
        .experts(EXPERTS)
        .policy_name("micromoe")
        .options(opts)
        .layers(layers)
        .build()
        .expect("session builds")
}

fn pipeline_opts(trace: Tracer) -> SchedulerOptions {
    SchedulerOptions {
        engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
        trace,
        ..Default::default()
    }
}

fn named<'a>(evs: &'a [TraceEvent], name: &str) -> Vec<&'a TraceEvent> {
    evs.iter().filter(|e| e.span.name() == name).collect()
}

/// The identity contract: an enabled Wall tracer changes no schedule, the
/// disabled default records no event, and the traced run's span set is the
/// exact commit/emission ledger of the pipelined session.
#[test]
fn enabled_tracing_is_bit_identical_and_off_records_nothing() {
    const LAYERS: usize = 3;
    const STEPS: usize = 4;
    let tracer = Tracer::new(TraceConfig::Wall);
    let mut plain = session(Topology::new(8, 4, 2, 8), pipeline_opts(Tracer::off()), LAYERS);
    let mut traced = session(Topology::new(8, 4, 2, 8), pipeline_opts(tracer.clone()), LAYERS);

    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> =
            (0..LAYERS).map(|l| zipf_lm(11 + (step * LAYERS + l) as u64, 700, 1.0)).collect();
        let a = plain.step(&loads);
        let b = traced.step(&loads);
        for (l, (pa, pb)) in a.layers.iter().zip(&b.layers).enumerate() {
            assert_eq!(pa.routes, pb.routes, "step {step} layer {l}: tracing changed routing");
            assert_eq!(pa.gpu_compute, pb.gpu_compute, "step {step} layer {l}");
            assert_eq!(pa.replica_loads, pb.replica_loads, "step {step} layer {l}");
        }
    }

    assert!(!plain.tracer().enabled(), "default tracer is off");
    assert_eq!(plain.tracer().event_count(), 0, "disabled tracer must record nothing");
    assert!(plain.tracer().events().is_empty());

    let evs = tracer.events();
    let total = STEPS * LAYERS;
    let solves = named(&evs, "solve");
    let engines = named(&evs, "engine");
    assert_eq!(solves.len(), total, "one solve span per committed layer plan");
    assert_eq!(engines.len(), total, "one engine span per in-order emission");
    assert_eq!(
        engines.len() as u64,
        traced.engine_stats().expect("pipeline engine").schedules
    );

    // every (step, layer) slot commits exactly once, in compute mode
    let mut seen = vec![false; total];
    for e in &solves {
        let Span::Solve { step, layer, mode, .. } = &e.span else { unreachable!() };
        assert_eq!(*mode, "compute");
        let k = *step * LAYERS + *layer;
        assert!(!seen[k], "duplicate solve span for step {step} layer {layer}");
        seen[k] = true;
    }
    assert!(seen.iter().all(|&s| s), "a committed plan is missing its solve span");

    // the pipeline engine never speculates: every emission is fresh
    for e in &engines {
        let Span::Engine { outcome, .. } = &e.span else { unreachable!() };
        assert_eq!(*outcome, SpanOutcome::Fresh);
    }

    // well-formed events: globally unique ids, wall domain, finite stamps
    let mut ids: Vec<u64> = evs.iter().map(|e| e.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), evs.len(), "span ids must be globally unique");
    for e in &evs {
        assert_eq!(e.domain, ClockDomain::Wall);
        assert!(e.ts_us.is_finite() && e.ts_us >= 0.0, "bad ts {}", e.ts_us);
        assert!(e.dur_us.is_finite() && e.dur_us >= 0.0, "bad dur {}", e.dur_us);
    }
}

/// Speculative-engine emissions carry hit/miss/fresh tags that reconcile
/// exactly against `EngineStats`' speculation counters.
#[test]
fn speculative_engine_spans_tag_every_emission() {
    const LAYERS: usize = 4;
    const STEPS: usize = 6;
    let tracer = Tracer::new(TraceConfig::Wall);
    let opts = SchedulerOptions {
        engine: EngineMode::speculative(),
        trace: tracer.clone(),
        ..Default::default()
    };
    let mut session = session(Topology::new(8, 4, 2, 8), opts, LAYERS);
    // identical loads every step: past warmup the forecast is exact, so
    // speculation must start hitting
    let loads: Vec<LoadMatrix> = (0..LAYERS).map(|l| zipf_lm(40 + l as u64, 800, 1.1)).collect();
    for _ in 0..STEPS {
        session.step(&loads);
    }

    let es = session.engine_stats().expect("speculative engine");
    let engines = named(&tracer.events(), "engine");
    assert_eq!(engines.len() as u64, es.schedules, "one engine span per emission");

    let (mut hits, mut misses, mut fresh) = (0u64, 0u64, 0u64);
    for e in &engines {
        let Span::Engine { outcome, .. } = &e.span else { unreachable!() };
        match outcome {
            SpanOutcome::Hit => hits += 1,
            SpanOutcome::Miss => misses += 1,
            SpanOutcome::Fresh => fresh += 1,
        }
    }
    assert_eq!(hits, es.spec_hits, "hit tags != judged hits: {es:?}");
    assert_eq!(misses, es.spec_misses, "miss tags != judged misses: {es:?}");
    assert_eq!(hits + misses + fresh, es.schedules, "{es:?}");
    assert!(hits > 0, "an exact forecast must produce speculation hits: {es:?}");
}

/// Decomposed-mode solves trace one round span per outer iteration per
/// block, reconciling against `DecomposeStats::outer_iters`, with the
/// master gap and κ feedback attributes well-formed.
#[test]
fn decomposed_rounds_trace_once_per_round_per_block() {
    const LAYERS: usize = 2;
    const STEPS: usize = 3;
    let tracer = Tracer::new(TraceConfig::Wall);
    let opts = SchedulerOptions {
        mode: ScheduleMode::Decomposed { nodes_per_block: 1, max_outer_iters: 6, tol: 1e-3 },
        trace: tracer.clone(),
        ..Default::default()
    };
    // 2 nodes of 4 GPUs -> 2 subproblem blocks per solve
    let mut session = session(Topology::new(8, 4, 2, 4), opts, LAYERS);
    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> =
            (0..LAYERS).map(|l| zipf_lm(60 + (step * LAYERS + l) as u64, 900, 1.0)).collect();
        session.step(&loads);
    }

    let dec = session.stats().decompose;
    assert_eq!(dec.solves, (STEPS * LAYERS) as u64, "{dec:?}");
    assert!(dec.outer_iters >= dec.solves, "at least one round per solve: {dec:?}");

    let evs = tracer.events();
    let solves = named(&evs, "solve");
    assert_eq!(solves.len(), STEPS * LAYERS, "one solve span per committed plan");
    for e in &solves {
        let Span::Solve { mode, .. } = &e.span else { unreachable!() };
        assert_eq!(*mode, "decomposed");
    }

    let rounds = named(&evs, "decompose_round");
    let mut per_block = [0u64; 2];
    for e in &rounds {
        let Span::DecomposeRound { round, block, gap, kappa } = &e.span else { unreachable!() };
        assert!(*round < 6, "round index beyond max_outer_iters");
        assert!(*block < 2, "unexpected block index {block}");
        per_block[*block] += 1;
        assert!(gap.is_finite(), "non-finite master gap");
        // κ is clamped into (0, block GPU count]
        assert!(*kappa > 0.0 && *kappa <= 4.0 + 1e-9, "kappa {kappa} out of range");
    }
    assert_eq!(per_block[0], per_block[1], "every round covers every block");
    assert_eq!(
        rounds.len() as u64,
        dec.outer_iters * 2,
        "one round span per outer iteration per block: {dec:?}"
    );
}

/// Serving-window spans on the virtual timeline reconcile exactly against
/// `SlaStats`: one span per formed window, with admitted/shed/deadline-miss
/// attributes summing to the server's cumulative accounting.
#[test]
fn serving_window_spans_match_sla_accounting() {
    use micromoe::serving::{
        ArrivalGen, ArrivalProcess, DispatchCost, ServingConfig, SolveCost, TokenModel,
    };
    use micromoe::workload::TopicMix;

    let tracer = Tracer::new(TraceConfig::Virtual);
    let sess = session(Topology::new(8, 4, 2, 8), pipeline_opts(tracer.clone()), 1);
    let reqs = ArrivalGen::new(
        ArrivalProcess::Poisson { rate_hz: 20_000.0 },
        TokenModel::Fixed(48),
        0x7E57,
    )
    .take(300);
    let cfg = ServingConfig {
        window_us: 400.0,
        max_batch: 24,
        slo_us: 900.0,
        shed_after_us: 1_500.0,
        solve_cost: SolveCost::Virtual { us: 50.0 },
        dispatch_cost: DispatchCost::PerToken { fixed_us: 10.0, us_per_token: 0.25 },
    };
    let mut server = sess.serve(cfg, TopicMix::new(EXPERTS, 1.1, 8, 9));
    let trace = server.run(&reqs);
    let sla = server.sla();

    let evs = tracer.events();
    let windows = named(&evs, "serving_window");
    assert_eq!(windows.len() as u64, sla.windows, "one span per formed window");
    assert_eq!(windows.len(), trace.windows.len());

    let (mut admitted, mut shed, mut misses, mut empty) = (0u64, 0u64, 0u64, 0u64);
    let mut prev_ts = f64::NEG_INFINITY;
    for e in &windows {
        let Span::ServingWindow { admitted: a, shed: s, deadline_miss: m, .. } = &e.span else {
            unreachable!()
        };
        admitted += *a as u64;
        shed += *s as u64;
        misses += *m as u64;
        if *a == 0 {
            empty += 1;
        }
        assert_eq!(e.domain, ClockDomain::Virtual, "window spans live on the virtual clock");
        assert!(e.ts_us >= prev_ts, "window spans must open in order");
        prev_ts = e.ts_us;
    }
    assert_eq!(admitted, sla.served, "admitted sums to served: {sla:?}");
    assert_eq!(shed, sla.shed, "shed attributes sum to shed requests: {sla:?}");
    assert_eq!(misses, sla.deadline_misses, "{sla:?}");
    assert_eq!(empty, sla.empty_windows, "{sla:?}");

    // the session's solve spans ride the same buffer, stamped by the
    // advancing virtual clock: one committed solve per non-empty window
    let solves = named(&evs, "solve");
    assert_eq!(solves.len() as u64, sla.windows - sla.empty_windows, "{sla:?}");
    for e in &solves {
        assert_eq!(e.domain, ClockDomain::Virtual);
    }
}
