//! Integration: all five systems run the same workload streams through the
//! cluster model — the Fig. 6/7 relationships must hold qualitatively
//! (who wins, in what order, and by roughly what kind of factor).

use micromoe::adaptive::AdaptiveConfig;
use micromoe::balancer::Balancer;
use micromoe::baselines::{DeepSpeedPad, FlexMoe, MicroMoe, SmartMoe, VanillaEp};
use micromoe::cluster::sim::{moe_layer_time, TrainIterationModel};
use micromoe::cluster::CostModel;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::stats::imbalance_ratio;
use micromoe::topology::Topology;

fn topo() -> Topology {
    Topology::new(8, 4, 2, 8)
}

fn workload(batches: usize, s: f64, seed: u64) -> Vec<LoadMatrix> {
    let mut rng = Rng::new(seed);
    let z = Zipf::new(32, s);
    (0..batches)
        .map(|_| {
            let mut lm = LoadMatrix::zeros(32, 8);
            for g in 0..8 {
                for _ in 0..2000 {
                    lm.add(z.sample(&mut rng), g, 1);
                }
            }
            lm
        })
        .collect()
}

fn mean_imbalance(sys: &mut dyn Balancer, batches: &[LoadMatrix], skip: usize) -> f64 {
    let mut acc = 0.0;
    let mut n = 0usize;
    for (i, lm) in batches.iter().enumerate() {
        let plan = sys.plan(lm);
        if i >= skip {
            acc += imbalance_ratio(&plan.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>());
            n += 1;
        }
    }
    acc / n as f64
}

/// Fig. 7 ordering below the capacity edge (s = 0.8): MicroMoE (w/o AR) is
/// near-perfect and at least matches FlexMoE; both beat SmartMoE/vanilla.
/// (At s = 1.0 the hottest expert holds ~24.6% of tokens against a 25%
/// two-replica ceiling, and FlexMoE's extra replicas can edge out the
/// static symmetric placement — the crossover Fig. 7 shows past s ≈ 1,
/// where the paper switches to asymmetric placements.)
#[test]
fn fig7_ordering_holds() {
    let batches = workload(40, 0.8, 42);
    let t = topo();
    let mut vanilla = VanillaEp::new(t.clone(), 32);
    let mut smart = SmartMoe::new(t.clone(), 32);
    smart.replace_every = 8;
    let mut flex = FlexMoe::new(t.clone(), 32, 1);
    flex.adjust_every = 8;
    let mut micro = MicroMoe::new(
        t.clone(),
        symmetric_placement(&t, 32),
        SchedulerOptions::default(),
    );
    let iv = mean_imbalance(&mut vanilla, &batches, 16);
    let is = mean_imbalance(&mut smart, &batches, 16);
    let ifx = mean_imbalance(&mut flex, &batches, 16);
    let im = mean_imbalance(&mut micro, &batches, 16);
    assert!(im < 1.02, "MicroMoE imbalance {im}");
    assert!(im <= ifx + 1e-9, "MicroMoE {im} vs FlexMoE {ifx}");
    assert!(ifx <= iv * 1.05, "FlexMoE {ifx} vs vanilla {iv}");
    assert!(im < is, "MicroMoE {im} vs SmartMoE {is}");
}

/// Past the edge (s = 1.4): the full MicroMoE (asymmetric via AR) restores
/// balance and beats FlexMoE — Fig. 7's top line.
#[test]
fn fig7_heavy_skew_with_ar() {
    let batches = workload(48, 1.4, 43);
    let t = topo();
    let mut flex = FlexMoe::new(t.clone(), 32, 1);
    flex.adjust_every = 8;
    let mut micro_ar = MicroMoe::new(
        t.clone(),
        symmetric_placement(&t, 32),
        SchedulerOptions::default(),
    )
    .with_adaptive(
        micromoe::adaptive::AdaptiveConfig {
            check_every: 4,
            window: 8,
            slots_per_gpu: 8,
            ..Default::default()
        },
        5,
    );
    let ifx = mean_imbalance(&mut flex, &batches, 24);
    let im = mean_imbalance(&mut micro_ar, &batches, 24);
    assert!(im <= ifx + 0.01, "MicroMoE+AR {im} vs FlexMoE {ifx} at s=1.4");
    assert!(im < 1.1, "MicroMoE+AR imbalance {im} at s=1.4");
}

/// Fig. 6's headline: MicroMoE end-to-end throughput beats Megatron
/// (vanilla EP) by a significant factor under skewed loads.
#[test]
fn fig6_throughput_relationship() {
    let batches = workload(32, 1.0, 7);
    let t = topo();
    let model = CostModel::h100_testbed().for_hidden_size(2048);
    let iter_model = TrainIterationModel::paper_default(2, 24, 16);

    let bench = |sys: &mut dyn Balancer| -> f64 {
        let mut total = 0.0;
        for lm in &batches {
            let plan = sys.plan(lm);
            let bd = moe_layer_time(&model, &t, &plan);
            total += iter_model.throughput(&bd, 8 * 8192);
        }
        total / batches.len() as f64
    };

    let mut vanilla = VanillaEp::new(t.clone(), 32);
    let mut micro = MicroMoe::new(
        t.clone(),
        symmetric_placement(&t, 32),
        SchedulerOptions::default(),
    );
    let tv = bench(&mut vanilla);
    let tm = bench(&mut micro);
    let speedup = tm / tv;
    assert!(
        speedup > 1.05 && speedup < 2.5,
        "MicroMoE speedup {speedup} out of plausible Fig-6 band"
    );
}

/// DeepSpeed's padding pathology: worse than vanilla under skew, and the
/// gap shrinks with fewer experts (§7.2's explanation).
#[test]
fn deepspeed_padding_pathology() {
    let t = topo();
    let model = CostModel::h100_testbed();
    let compute_total = |experts: usize, s: f64| -> (f64, f64) {
        let mut rng = Rng::new(5);
        let z = Zipf::new(experts, s);
        let mut lm = LoadMatrix::zeros(experts, 8);
        for g in 0..8 {
            for _ in 0..2000 {
                lm.add(z.sample(&mut rng), g, 1);
            }
        }
        let mut pad = DeepSpeedPad::new(t.clone(), experts);
        let mut van = VanillaEp::new(t.clone(), experts);
        let bp = moe_layer_time(&model, &t, &pad.plan(&lm));
        let bv = moe_layer_time(&model, &t, &van.plan(&lm));
        (bp.compute, bv.compute)
    };
    let (pad32, van32) = compute_total(32, 1.2);
    assert!(pad32 > van32, "padding should cost more at 32 experts");
    let (pad8, van8) = compute_total(8, 1.2);
    // fewer experts -> padding waste relatively smaller
    assert!(pad8 / van8 < pad32 / van32, "padding gap must shrink with fewer experts");
}

/// Adaptive replacement on a *drifting* heavy-skew workload: the full
/// MicroMoE (w/ AR) must beat the static symmetric arm (Fig. 7 s>1 story).
#[test]
fn adaptive_beats_static_on_drifting_skew() {
    let t = topo();
    // drifting: rotate the hot expert every 12 batches
    let mut batches = Vec::new();
    let mut rng = Rng::new(9);
    for phase in 0..4u64 {
        let z = Zipf::new(32, 1.8);
        let mut perm: Vec<usize> = (0..32).collect();
        let mut r2 = Rng::new(phase);
        r2.shuffle(&mut perm);
        for _ in 0..12 {
            let mut lm = LoadMatrix::zeros(32, 8);
            for g in 0..8 {
                for _ in 0..3000 {
                    lm.add(perm[z.sample(&mut rng)], g, 1);
                }
            }
            batches.push(lm);
        }
    }
    let placement = symmetric_placement(&t, 32);
    let mut no_ar = MicroMoe::new(t.clone(), placement.clone(), SchedulerOptions::default());
    let mut with_ar = MicroMoe::new(t.clone(), placement, SchedulerOptions::default())
        .with_adaptive(
            AdaptiveConfig { check_every: 4, window: 8, slots_per_gpu: 8, ..Default::default() },
            3,
        );
    let ia = mean_imbalance(&mut no_ar, &batches, 12);
    let ib = mean_imbalance(&mut with_ar, &batches, 12);
    assert!(
        ib <= ia + 0.02,
        "AR ({ib}) should not lose to static ({ia}) under drifting heavy skew"
    );
}

/// Every system conserves compute: Σ gpu_compute >= total tokens (padding
/// may exceed; none may lose tokens).
#[test]
fn no_system_loses_tokens() {
    let t = topo();
    let batches = workload(6, 1.4, 11);
    let mut systems: Vec<Box<dyn Balancer>> = vec![
        Box::new(VanillaEp::new(t.clone(), 32)),
        Box::new(SmartMoe::new(t.clone(), 32)),
        Box::new(FlexMoe::new(t.clone(), 32, 2)),
        Box::new(DeepSpeedPad::new(t.clone(), 32)),
        Box::new(MicroMoe::new(
            t.clone(),
            symmetric_placement(&t, 32),
            SchedulerOptions::default(),
        )),
    ];
    for sys in &mut systems {
        for lm in &batches {
            let plan = sys.plan(lm);
            assert!(
                plan.gpu_compute.iter().sum::<u64>() >= lm.total(),
                "{} lost tokens",
                sys.name()
            );
        }
    }
}
