//! Golden test: the rust placement controller vs its python reference.
//!
//! `python/tools/controller_reference.py` transliterates the controller's
//! decision path — [`LoadDetector`]'s EWMA + dual hysteresis, the exact
//! Eq.-3 density enumeration, `placement_diff` / `migration_time` and the
//! greedy replicate/evict [`decide`] loop — self-tests it against numpy,
//! and records drift-regime load traces (stationary, sudden shift,
//! oscillation held off by hysteresis, move-capped, eviction-forced,
//! rotating drift, budget-starved) with every control tick's decision in
//! `tests/golden_controller.json`. Replaying the traces here must
//! reproduce every decision **bit-exactly**: the two implementations
//! mirror each other operation for operation, python floats are IEEE
//! doubles, the fixture's 8-GPU scale keeps the density evaluator on the
//! exact (rng-free) path, and `json.dump`'s shortest-roundtrip floats
//! survive rust's correctly-rounded `str::parse::<f64>` unchanged.
//!
//! This is also the worker-count-independence proof for the controller:
//! the replay drives the detector + decider with nothing but the raw load
//! trace, and `ControlledLppBalancer` feeds them exactly that — so
//! decisions cannot depend on scheduler threading or engine workers.
//!
//! The fixture is committed; a missing file is a hard failure (regenerate
//! with the tool above and commit the result).

use micromoe::cluster::CostModel;
use micromoe::control::{decide, ControlSpec, LoadDetector};
use micromoe::placement::Placement;
use micromoe::rng::Rng;
use micromoe::ser::Json;
use micromoe::topology::Topology;

fn fixture() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_controller.json");
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        panic!(
            "{path} missing ({e}) — regenerate with \
             python/tools/controller_reference.py and commit"
        )
    });
    Json::parse(&text).unwrap()
}

fn usize_vec(j: &Json) -> Vec<usize> {
    j.as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect()
}

fn spec_from_json(j: &Json) -> ControlSpec {
    let f = |k: &str| j.get(k).unwrap().as_f64().unwrap();
    let u = |k: &str| j.get(k).unwrap().as_usize().unwrap();
    ControlSpec {
        interval: u("interval"),
        ema_alpha: f("ema_alpha"),
        hot_enter: f("hot_enter"),
        hot_exit: f("hot_exit"),
        cold_enter: f("cold_enter"),
        cold_exit: f("cold_exit"),
        dwell: u("dwell"),
        budget_seconds: f("budget_seconds"),
        max_moves: u("max_moves"),
        min_gain: f("min_gain"),
        bytes_per_expert: f("bytes_per_expert") as u64,
        slot_headroom: u("slot_headroom"),
    }
}

#[test]
fn controller_matches_python_reference() {
    let fx = fixture();
    let scenarios = fx.get("scenarios").unwrap().as_arr().unwrap();
    assert!(scenarios.len() >= 4, "suspiciously few controller scenarios");
    let (mut decided, mut quiet) = (0u64, 0u64);
    for sc in scenarios {
        let name = sc.get("name").unwrap().as_str().unwrap();
        let experts = sc.get("experts").unwrap().as_usize().unwrap();
        let gpus = sc.get("gpus").unwrap().as_usize().unwrap();
        let t = usize_vec(sc.get("topo").unwrap());
        let topo = Topology::new(t[0], t[1], t[2], t[3]);
        let slot_budget = sc.get("slot_budget").unwrap().as_usize().unwrap();
        let spec = spec_from_json(sc.get("spec").unwrap());
        spec.validate().unwrap();
        let model = CostModel::h100_testbed();

        let initial: Vec<Vec<usize>> = sc
            .get("initial_replicas")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(usize_vec)
            .collect();
        let mut current = Placement::from_replicas(gpus, initial);
        current.validate().unwrap();
        let mut det = LoadDetector::new(experts, &spec);
        // never consumed: 8 GPUs stay on the exact density path
        let mut rng = Rng::new(0);

        let loads = sc.get("loads").unwrap().as_arr().unwrap();
        let ticks = sc.get("ticks").unwrap().as_arr().unwrap();
        let mut ti = 0usize;
        for (i, row) in loads.iter().enumerate() {
            let step_loads: Vec<u64> = row
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as u64)
                .collect();
            assert_eq!(step_loads.len(), experts, "{name}: fixture load row shape");
            det.observe(&step_loads);
            let step = i + 1;
            if step % spec.interval != 0 {
                continue;
            }
            let tick = &ticks[ti];
            assert_eq!(
                tick.get("step").unwrap().as_usize().unwrap(),
                step,
                "{name}: tick schedule diverged"
            );
            let dec = decide(&current, &det, &topo, &model, &spec, slot_budget, &mut rng);
            let want = tick.get("decision").unwrap();
            match dec {
                None => {
                    assert_eq!(want, &Json::Null, "{name} step {step}: reference decided, rust did not");
                    quiet += 1;
                }
                Some(d) => {
                    assert_ne!(
                        want,
                        &Json::Null,
                        "{name} step {step}: rust decided, reference did not"
                    );
                    let want_replicas: Vec<Vec<usize>> =
                        want.get("replicas").unwrap().as_arr().unwrap().iter().map(usize_vec).collect();
                    assert_eq!(d.placement.replicas, want_replicas, "{name} step {step}: placement");
                    let want_moves: Vec<Vec<usize>> =
                        want.get("moves").unwrap().as_arr().unwrap().iter().map(usize_vec).collect();
                    let got_moves: Vec<Vec<usize>> =
                        d.moves.iter().map(|m| vec![m.expert, m.dst, m.src]).collect();
                    assert_eq!(got_moves, want_moves, "{name} step {step}: move list");
                    // accounting floats must match to the bit — python and
                    // rust perform the identical IEEE operation sequence
                    let want_gain = want.get("predicted_gain").unwrap().as_f64().unwrap();
                    assert_eq!(
                        d.predicted_gain.to_bits(),
                        want_gain.to_bits(),
                        "{name} step {step}: predicted_gain {} vs reference {want_gain}",
                        d.predicted_gain
                    );
                    let want_dt = want.get("downtime").unwrap().as_f64().unwrap();
                    assert_eq!(
                        d.downtime.to_bits(),
                        want_dt.to_bits(),
                        "{name} step {step}: downtime {} vs reference {want_dt}",
                        d.downtime
                    );
                    assert_eq!(d.bytes, want.get("bytes").unwrap().as_f64().unwrap() as u64);
                    assert_eq!(d.replications, want.get("replications").unwrap().as_usize().unwrap());
                    assert_eq!(d.evictions, want.get("evictions").unwrap().as_usize().unwrap());
                    d.placement.validate().unwrap();
                    current = d.placement;
                    decided += 1;
                }
            }
            ti += 1;
        }
        assert_eq!(ti, ticks.len(), "{name}: fixture has unreplayed ticks");

        // final detector state, bit for bit
        let fin = sc.get("final").unwrap();
        let want_ema: Vec<f64> = fin
            .get("ema")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        assert_eq!(det.ema().len(), want_ema.len(), "{name}: final EWMA shape");
        for (e, (a, w)) in det.ema().iter().zip(&want_ema).enumerate() {
            assert_eq!(a.to_bits(), w.to_bits(), "{name}: final EWMA[{e}] {a} vs reference {w}");
        }
        let want_hot: Vec<bool> = fin
            .get("hot")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_bool().unwrap())
            .collect();
        assert_eq!(det.hot(), &want_hot[..], "{name}: final hot flags");
        let want_cold: Vec<bool> = fin
            .get("cold")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_bool().unwrap())
            .collect();
        assert_eq!(det.cold(), &want_cold[..], "{name}: final cold flags");
        assert_eq!(det.observed(), fin.get("observed").unwrap().as_usize().unwrap(), "{name}");
    }
    assert!(
        decided > 0 && quiet > 0,
        "fixture no longer exercises both outcomes (decided {decided}, quiet {quiet})"
    );
}
