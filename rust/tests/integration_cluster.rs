//! Integration: cluster model end-to-end — layer breakdowns, iteration
//! times, backends, and migration compose into the paper's qualitative
//! behaviours.

use micromoe::balancer::Balancer;
use micromoe::baselines::{MicroMoe, VanillaEp};
use micromoe::cluster::sim::{moe_layer_time, TrainIterationModel};
use micromoe::cluster::{CommBackend, CostModel};
use micromoe::moe::PipelinedMicroEp;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::topology::Topology;

fn topo() -> Topology {
    Topology::new(8, 4, 2, 8)
}

fn zipf_lm(e: usize, g: usize, per_gpu: u64, s: f64, seed: u64) -> LoadMatrix {
    let mut rng = Rng::new(seed);
    let z = Zipf::new(e, s);
    let mut lm = LoadMatrix::zeros(e, g);
    for gi in 0..g {
        for _ in 0..per_gpu {
            lm.add(z.sample(&mut rng), gi, 1);
        }
    }
    lm
}

/// Fig. 8 structure: compute dominates the MoE layer; MicroMoE's compute
/// segment is shorter than vanilla's; dispatch differences stay small.
#[test]
fn fig8_breakdown_structure() {
    let t = topo();
    let model = CostModel::h100_testbed(); // h=4096 defaults
    let lm = zipf_lm(32, 8, 16_384, 1.0, 1);
    let mut van = VanillaEp::new(t.clone(), 32);
    let mut mm = MicroMoe::new(t.clone(), symmetric_placement(&t, 32), SchedulerOptions::default());
    let bv = moe_layer_time(&model, &t, &van.plan(&lm));
    let bm = moe_layer_time(&model, &t, &mm.plan(&lm));
    // compute dominates in both systems (paper: "primary bottleneck")
    assert!(bv.compute > bv.dispatch, "vanilla: {bv:?}");
    assert!(bm.compute > bm.dispatch * 0.5, "micromoe: {bm:?}");
    // balance shortens compute
    assert!(bm.compute < bv.compute, "micromoe {:?} vs vanilla {:?}", bm.compute, bv.compute);
    // and total layer time improves
    assert!(bm.total() < bv.total());
}

/// Fig. 14 shape: DeepEP dispatch beats NCCL at every group size, and
/// inter-node groups are slower than intra-node ones.
#[test]
fn fig14_backend_shape() {
    let lm_routes = |g: usize, seed: u64| {
        // one MicroEP group spanning all g GPUs (App. C.2 expands the
        // communication group across nodes)
        let t = Topology::new(g, g / 2, 2, 8);
        let p = symmetric_placement(&t, 2 * g.max(8));
        let mut mm = MicroMoe::new(t.clone(), p, SchedulerOptions::default());
        let lm = zipf_lm(2 * g.max(8), g, 4096, 0.8, seed);
        (t, mm.plan(&lm))
    };
    for g in [8usize, 16, 32] {
        let (t, plan) = lm_routes(g, 3);
        let nccl = CostModel::h100_testbed().with_backend(CommBackend::Nccl);
        let deep = CostModel::h100_testbed().with_backend(CommBackend::DeepEp);
        let tn = nccl.a2a_time_from_routes(&plan.routes, g, &t);
        let td = deep.a2a_time_from_routes(&plan.routes, g, &t);
        assert!(td < tn, "G={g}: DeepEP {td} !< NCCL {tn}");
        if g > 8 {
            // crossing nodes: must exceed the 8-GPU intra-node time
            let (t8, plan8) = lm_routes(8, 3);
            let t8n = nccl.a2a_time_from_routes(&plan8.routes, 8, &t8);
            assert!(tn > t8n, "G={g} inter-node {tn} !> intra {t8n}");
        }
    }
}

/// Fig. 16 mechanism: with a large scheduling time, moderate pipelining
/// ratios reduce visible dispatch time vs scheduling-exposed ratio 1.0
/// when scheduling cannot overlap elsewhere.
#[test]
fn fig16_pipelining_hides_scheduling() {
    let t = topo();
    let model = CostModel::h100_testbed().with_backend(CommBackend::DeepEp);
    let p = symmetric_placement(&t, 32);
    let lm = zipf_lm(32, 8, 16_384, 0.8, 4);

    let time_at = |ratio: f64| -> f64 {
        let mut pm =
            PipelinedMicroEp::new(p.clone(), t.clone(), SchedulerOptions::default(), ratio);
        let (_, bd) = pm.plan(&lm, &model);
        // inflate sched to the large-scale regime the appendix targets
        let mut bd = bd;
        bd.sched = bd.sched.max(400e-6);
        bd.total()
    };
    let full = time_at(1.0);
    let half = time_at(0.5);
    // at ratio 1.0 there is no EP A2A to hide behind: sched is exposed
    assert!(half < full, "pipelined {half} !< exposed {full}");
}

/// Iteration model: more GPUs with PP reduce per-stage work; Fig. 6's
/// "speedup vs #GPUs" axis behaves monotonically for a fixed breakdown.
#[test]
fn iteration_model_scaling() {
    let moe = micromoe::cluster::sim::MoeLayerBreakdown {
        prep: 0.1e-3,
        dispatch: 1.3e-3,
        compute: 3e-3,
        combine: 1.3e-3,
    };
    let t16 = TrainIterationModel::paper_default(2, 24, 16).iteration_time(&moe);
    let t32 = TrainIterationModel::paper_default(4, 24, 16).iteration_time(&moe);
    assert!(t32 < t16, "scaling 16->32 GPUs should shrink iteration time");
}

/// Migration magnitudes for all Table-2 models land in Fig. 10's
/// hundreds-of-ms band when half the experts move.
#[test]
fn fig10_migration_magnitudes() {
    use micromoe::cluster::migration::{expert_bytes, migration_time, Move};
    let model = CostModel::h100_testbed();
    let t = topo();
    for preset in micromoe::config::table2() {
        let bytes = expert_bytes(preset.hidden, preset.ffn_hidden, true);
        let moves: Vec<Move> = (0..preset.experts / 2)
            .map(|i| Move { expert: i, dst: (i + 1) % 8, src: i % 8 })
            .collect();
        let time = migration_time(&moves, bytes, &model, &t, 8);
        assert!(
            (0.05..5.0).contains(&time),
            "{}: migration {time}s outside Fig-10 band",
            preset.name
        );
    }
}
