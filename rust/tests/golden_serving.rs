//! Golden test: the serving tier vs its Python reference.
//!
//! `python/tools/serving_reference.py` transliterates the arrival
//! processes, the P² estimator, and the batching-window/SLO loop, then
//! records — per regime — the uniform stream it consumed, the arrival
//! trace, every window's decisions, and the full `SlaStats`. This suite
//! replays the recorded uniforms through the *real* rust generator and
//! server and demands agreement:
//!
//! * arrival timestamps are integer microseconds by construction, so they
//!   must match **exactly** (a libm `ln`/`sin` divergence would flip a
//!   floor or a thinning accept — the generator guards every draw against
//!   that);
//! * every downstream number (window bounds, charged latencies, SLO
//!   accounting, P² marker heights) is pure IEEE-754 `+,-,*,/` on those
//!   integers and dyadic config constants, so it is compared at 1e-9 —
//!   effectively bit-exact.
//!
//! The fixture `tests/golden_serving.json` is committed; a missing file is
//! a hard failure (regenerate with the tool above and commit the result).

use micromoe::balancer::MoeSession;
use micromoe::ser::Json;
use micromoe::serving::{
    ArrivalGen, ArrivalProcess, DispatchCost, ServingConfig, SolveCost, TokenModel,
};
use micromoe::stats::LatencyTrack;
use micromoe::topology::Topology;
use micromoe::workload::TopicMix;

fn fixture() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_serving.json");
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        panic!("{path} missing ({e}) — regenerate with python/tools/serving_reference.py and commit")
    });
    Json::parse(&text).unwrap()
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).unwrap_or_else(|| panic!("missing '{key}'")).as_f64().unwrap()
}

/// `null` in the fixture encodes NaN (empty-track statistics).
fn num_or_nan(j: &Json, key: &str) -> f64 {
    match j.get(key).unwrap_or_else(|| panic!("missing '{key}'")) {
        Json::Null => f64::NAN,
        v => v.as_f64().unwrap(),
    }
}

fn as_f64s(j: &Json) -> Vec<f64> {
    j.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect()
}

fn as_u64s(j: &Json) -> Vec<u64> {
    j.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as u64).collect()
}

/// Same-order IEEE arithmetic on identical inputs: 1e-9 relative is
/// "bit-exact with headroom".
fn close(a: f64, b: f64, what: &str) {
    if a.is_nan() && b.is_nan() {
        return;
    }
    assert!((a - b).abs() <= 1e-9 * b.abs().max(1.0), "{what}: rust {a} vs reference {b}");
}

fn process_of(j: &Json) -> ArrivalProcess {
    match j.get("kind").unwrap().as_str().unwrap() {
        "poisson" => ArrivalProcess::Poisson { rate_hz: num(j, "rate_hz") },
        "bursty" => ArrivalProcess::Bursty {
            calm_hz: num(j, "calm_hz"),
            burst_hz: num(j, "burst_hz"),
            mean_calm_us: num(j, "mean_calm_us"),
            mean_burst_us: num(j, "mean_burst_us"),
        },
        "diurnal" => ArrivalProcess::Diurnal {
            base_hz: num(j, "base_hz"),
            amplitude: num(j, "amplitude"),
            period_us: num(j, "period_us"),
        },
        other => panic!("unknown process kind '{other}'"),
    }
}

fn tokens_of(j: &Json) -> TokenModel {
    match j.get("kind").unwrap().as_str().unwrap() {
        "fixed" => TokenModel::Fixed(num(j, "value") as u64),
        "ramp" => TokenModel::Ramp {
            base: num(j, "base") as u64,
            step: num(j, "step") as u64,
            every: num(j, "every") as u64,
        },
        other => panic!("unknown token model '{other}'"),
    }
}

fn config_of(j: &Json) -> ServingConfig {
    let shed_after_us = match j.get("shed_after_us").unwrap() {
        Json::Null => f64::INFINITY,
        v => v.as_f64().unwrap(),
    };
    ServingConfig {
        window_us: num(j, "window_us"),
        max_batch: num(j, "max_batch") as usize,
        slo_us: num(j, "slo_us"),
        shed_after_us,
        solve_cost: SolveCost::Virtual { us: num(j, "virtual_solve_us") },
        dispatch_cost: DispatchCost::PerToken {
            fixed_us: num(j, "dispatch_fixed_us"),
            us_per_token: num(j, "dispatch_us_per_token"),
        },
    }
}

fn check_track(t: &LatencyTrack, j: &Json, what: &str) {
    assert_eq!(t.count(), num(j, "count") as usize, "{what}: sample count");
    close(t.mean(), num_or_nan(j, "mean_us"), &format!("{what}: mean"));
    close(t.max(), num_or_nan(j, "max_us"), &format!("{what}: max"));
    close(t.exact(0.50), num_or_nan(j, "p50_us"), &format!("{what}: p50"));
    close(t.exact(0.95), num_or_nan(j, "p95_us"), &format!("{what}: p95"));
    close(t.exact(0.99), num_or_nan(j, "p99_us"), &format!("{what}: p99"));
    close(t.p2_p50(), num_or_nan(j, "p2_p50_us"), &format!("{what}: P2 p50"));
    close(t.p2_p95(), num_or_nan(j, "p2_p95_us"), &format!("{what}: P2 p95"));
    close(t.p2_p99(), num_or_nan(j, "p2_p99_us"), &format!("{what}: P2 p99"));
}

#[test]
fn replays_every_golden_regime() {
    let fx = fixture();
    let cases = fx.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 6, "fixture must cover at least 6 regimes, has {}", cases.len());
    let mut names = Vec::new();
    for case in cases {
        let name = case.get("name").unwrap().as_str().unwrap().to_string();
        let n = num(case, "requests") as usize;
        let uniforms = as_f64s(case.get("uniforms").unwrap());
        let process = process_of(case.get("process").unwrap());
        let tokens = tokens_of(case.get("tokens").unwrap());

        // 1. regenerate arrivals from the recorded uniforms — this runs the
        //    rust process logic (phase jumps, thinning, quantization), not a
        //    byte copy of the reference's output
        let mut gen = ArrivalGen::with_uniforms(process, tokens, uniforms.clone());
        let reqs = gen.take(n);
        assert_eq!(
            gen.uniforms_consumed() as usize,
            uniforms.len(),
            "{name}: rust consumed a different number of uniform draws"
        );
        let exp_arrival = as_f64s(case.get("arrival_us").unwrap());
        let exp_tokens = as_u64s(case.get("arrival_tokens").unwrap());
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64, "{name}: id order");
            assert!(
                r.arrival_us == exp_arrival[i],
                "{name}: arrival {i}: rust {} vs reference {}",
                r.arrival_us,
                exp_arrival[i]
            );
            assert_eq!(r.tokens, exp_tokens[i], "{name}: tokens {i}");
        }

        // 2. serve the trace through the real server + a real policy; every
        //    fixture-pinned field is policy-independent
        let session = MoeSession::builder()
            .topology(Topology::new(8, 4, 2, 8))
            .experts(16)
            .policy_name("vanilla-ep")
            .build()
            .unwrap();
        let cfg = config_of(case.get("config").unwrap());
        let mut server = session.serve(cfg, TopicMix::new(16, 1.1, 4, 5));
        let trace = server.run(&reqs);

        let exp_windows = case.get("windows").unwrap().as_arr().unwrap();
        assert_eq!(trace.windows.len(), exp_windows.len(), "{name}: window count");
        for (w, ej) in trace.windows.iter().zip(exp_windows) {
            let what = format!("{name}: window {}", w.index);
            assert_eq!(w.index, num(ej, "index") as u64, "{what}: index");
            close(w.open_us, num(ej, "open_us"), &format!("{what}: open"));
            close(w.close_us, num(ej, "close_us"), &format!("{what}: close"));
            assert_eq!(w.served, as_u64s(ej.get("served").unwrap()), "{what}: served ids");
            assert_eq!(w.shed, as_u64s(ej.get("shed").unwrap()), "{what}: shed ids");
            assert_eq!(w.tokens, num(ej, "tokens") as u64, "{what}: tokens");
            close(w.solve_us, num(ej, "solve_us"), &format!("{what}: solve"));
            close(w.dispatch_us, num(ej, "dispatch_us"), &format!("{what}: dispatch"));
            // policy-side sanity the reference can't model: the emitted plan
            // covers the window's tokens (vanilla EP may pad, never lose)
            assert!(
                w.gpu_compute.iter().sum::<u64>() >= w.tokens,
                "{what}: plan lost tokens"
            );
        }

        let sla = server.sla();
        let ej = case.get("sla").unwrap();
        assert_eq!(sla.arrived, num(ej, "arrived") as u64, "{name}: arrived");
        assert_eq!(sla.served, num(ej, "served") as u64, "{name}: served");
        assert_eq!(sla.shed, num(ej, "shed") as u64, "{name}: shed");
        assert_eq!(
            sla.deadline_misses,
            num(ej, "deadline_misses") as u64,
            "{name}: deadline misses"
        );
        assert_eq!(sla.windows, num(ej, "windows") as u64, "{name}: windows");
        assert_eq!(sla.empty_windows, num(ej, "empty_windows") as u64, "{name}: empty windows");
        for (track, key) in [
            (&sla.queue, "queue"),
            (&sla.solve, "solve"),
            (&sla.dispatch, "dispatch"),
            (&sla.e2e, "e2e"),
        ] {
            check_track(track, ej.get(key).unwrap(), &format!("{name}: {key}"));
        }
        names.push(name);
    }
    // the six regimes the issue demands, by name
    for required in
        ["steady_poisson", "burst", "diurnal_ramp", "overload_shed", "drift", "empty_window"]
    {
        assert!(names.iter().any(|n| n == required), "fixture missing regime '{required}'");
    }
}
