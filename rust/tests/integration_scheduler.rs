//! Integration: scheduler end-to-end on paper-scale configurations —
//! LP + rounding + routing against brute-force and analytic references.

use micromoe::engine::{EngineMode, ScheduleEngine};
use micromoe::placement::cayley::{symmetric_placement, torus_placement, z2xz4_placement};
use micromoe::placement::graph::{max_induced_density_exact, perfect_balance_bound};
use micromoe::placement::Placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::routing::check_routes;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::topology::Topology;

fn zipf_lm(e: usize, g: usize, per_gpu: u64, s: f64, seed: u64) -> LoadMatrix {
    let mut rng = Rng::new(seed);
    let z = Zipf::new(e, s);
    let mut lm = LoadMatrix::zeros(e, g);
    for gi in 0..g {
        for _ in 0..per_gpu {
            lm.add(z.sample(&mut rng), gi, 1);
        }
    }
    lm
}

/// Brute force over all integer splits for a tiny instance: 2 experts on a
/// path of 3 GPUs. The LP must find the true integer-ish optimum.
#[test]
fn matches_brute_force_tiny() {
    let p = Placement::from_replicas(3, vec![vec![0, 1], vec![1, 2]]);
    for (l0, l1) in [(10u64, 10u64), (20, 4), (0, 9), (7, 13), (1, 1)] {
        let mut lm = LoadMatrix::zeros(2, 3);
        lm.set(0, 0, l0);
        lm.set(1, 2, l1);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        // brute force: expert0 puts a on GPU0 (rest GPU1); expert1 puts b
        // on GPU2 (rest GPU1) — minimize max(a, l0-a + l1-b, b)
        let mut best = u64::MAX;
        for a in 0..=l0 {
            for b in 0..=l1 {
                best = best.min(a.max(b).max(l0 - a + l1 - b));
            }
        }
        assert_eq!(
            sched.stats.max_gpu_load, best,
            "loads ({l0},{l1}): got {} want {best}",
            sched.stats.max_gpu_load
        );
    }
}

/// Paper §7.4 scale (DP=8, 32 experts): scheduling must equalize GPU loads
/// to within rounding at s = 1.0, and track Eq. 3 exactly.
#[test]
fn paper_scale_schedule_is_optimal() {
    let topo = Topology::new(8, 4, 2, 8);
    let p = symmetric_placement(&topo, 32);
    let mut s = MicroEpScheduler::new(p.clone(), Some(topo), SchedulerOptions::default());
    for seed in 0..5 {
        let lm = zipf_lm(32, 8, 16_384, 1.0, seed); // Fig-8 token volume
        let sched = s.schedule(&lm);
        let loads_f: Vec<f64> = lm.expert_loads().iter().map(|&l| l as f64).collect();
        let density = max_induced_density_exact(&p, &loads_f).density;
        assert!((sched.stats.lp_objective - density).abs() < 1e-4 * density);
        check_routes(&p, &lm, &sched.replica_loads, &sched.routes).unwrap();
        let max = sched.stats.max_gpu_load as f64;
        assert!(max <= density + 40.0, "rounded max {max} vs density {density}");
    }
}

/// The Appendix-B example placements behave as the theory says under
/// uniform loads: optimum == perfect balance.
#[test]
fn appendix_b_placements_balance_uniform_loads() {
    for p in [torus_placement(4), z2xz4_placement()] {
        let e = p.num_experts;
        let g = p.num_gpus;
        let lm = zipf_lm(e, g, 4_000, 0.0, 3);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        let ideal = perfect_balance_bound(
            &lm.expert_loads().iter().map(|&l| l as f64).collect::<Vec<_>>(),
            g,
        );
        assert!(
            (sched.stats.lp_objective - ideal) / ideal < 0.02,
            "G={g} E={e}: {} vs ideal {ideal}",
            sched.stats.lp_objective
        );
    }
}

/// All three LP modes agree on expert-load conservation and produce
/// verifiable routes on a 2-node topology.
#[test]
fn all_modes_route_correctly_across_nodes() {
    let topo = Topology::new(8, 4, 2, 4); // 2 nodes × 4 GPUs
    let p = symmetric_placement(&topo, 16);
    for mode in [
        ScheduleMode::Compute,
        ScheduleMode::CommAware { alpha: 1.0 },
        ScheduleMode::TopoAware { alpha1: 0.1, alpha2: 1.0 },
    ] {
        let mut s = MicroEpScheduler::new(
            p.clone(),
            Some(topo.clone()),
            SchedulerOptions {
                mode: mode.clone(),
                topo_aware_routing: matches!(mode, ScheduleMode::TopoAware { .. }),
                ..Default::default()
            },
        );
        for seed in 0..3 {
            let lm = zipf_lm(16, 8, 1000, 0.9, 100 + seed);
            let sched = s.schedule(&lm);
            check_routes(&p, &lm, &sched.replica_loads, &sched.routes)
                .unwrap_or_else(|e| panic!("{mode:?}: {e}"));
        }
    }
}

/// Warm-start stays correct over a long stream of drifting micro-batches
/// (the actual §5.1 usage pattern) — 200 batches, every 10th cross-checked
/// against a cold solve.
#[test]
fn warm_start_long_stream() {
    let topo = Topology::new(8, 4, 2, 8);
    let p = symmetric_placement(&topo, 32);
    let mut warm = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
    let mut rng = Rng::new(77);
    let mut warm_pivots = 0usize;
    let mut n_warm = 0usize;
    let mut cold_pivots_at_checks = 0usize;
    let mut checks = 0usize;
    for batch in 0..200u64 {
        let s = 0.3 + 0.7 * ((batch as f64 / 20.0).sin().abs());
        let lm = zipf_lm(32, 8, 2000, s, rng.next_u64());
        let sched = warm.schedule(&lm);
        if batch > 0 {
            assert!(sched.stats.warm);
            warm_pivots += sched.stats.lp_iterations;
            n_warm += 1;
        }
        if batch % 10 == 0 {
            let mut cold = MicroEpScheduler::new(
                p.clone(),
                None,
                SchedulerOptions { warm_start: false, ..Default::default() },
            );
            let c = cold.schedule(&lm);
            assert!(
                (sched.stats.lp_objective - c.stats.lp_objective).abs()
                    < 1e-5 * (1.0 + c.stats.lp_objective),
                "batch {batch}"
            );
            cold_pivots_at_checks += c.stats.lp_iterations;
            checks += 1;
        }
    }
    let avg_warm = warm_pivots as f64 / n_warm as f64;
    let avg_cold = cold_pivots_at_checks as f64 / checks as f64;
    assert!(
        avg_warm < avg_cold * 0.6,
        "warm avg {avg_warm} pivots vs cold {avg_cold}: warm start not paying off"
    );
}

/// §5.3 determinism extended to the pipelined engine: for fixed seeds the
/// engine must produce bit-identical `Schedule`s to the sequential
/// per-layer loop, across 1/2/8 workers — layer→worker pinning plus
/// per-worker FIFO queues make worker count irrelevant to the result.
#[test]
fn engine_pipeline_bit_identical_to_sequential_across_worker_counts() {
    let topo = Topology::new(8, 4, 2, 8);
    let p = symmetric_placement(&topo, 16);
    let layers = 8usize;
    let mut sequential: Vec<MicroEpScheduler> = (0..layers)
        .map(|_| {
            MicroEpScheduler::new(p.clone(), Some(topo.clone()), SchedulerOptions::default())
        })
        .collect();
    let mut engines: Vec<ScheduleEngine> = [1usize, 2, 8]
        .into_iter()
        .map(|workers| {
            ScheduleEngine::new(
                p.clone(),
                Some(topo.clone()),
                SchedulerOptions {
                    engine: EngineMode::Pipeline { workers, inflight: 3 },
                    ..Default::default()
                },
                layers,
            )
            .unwrap()
        })
        .collect();
    for round in 0..4u64 {
        let loads: Vec<LoadMatrix> = (0..layers)
            .map(|l| zipf_lm(16, 8, 1500, 0.9, round * 100 + l as u64))
            .collect();
        let want: Vec<_> =
            sequential.iter_mut().zip(&loads).map(|(s, lm)| s.schedule(lm)).collect();
        for engine in &mut engines {
            let got = engine.schedule_step(&loads).unwrap();
            for (l, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.replica_loads, b.replica_loads,
                    "round {round} layer {l} workers {}",
                    engine.workers()
                );
                assert_eq!(
                    a.routes, b.routes,
                    "round {round} layer {l} workers {}",
                    engine.workers()
                );
            }
        }
    }
}

/// The speculative engine is not bit-identical to the sequential path (the
/// pre-solve legitimately moves the warm basis), but it must still be
/// deterministic: identical load histories give identical schedules *and*
/// identical hit/miss/pivot counters regardless of worker count.
#[test]
fn engine_speculation_deterministic_across_worker_counts() {
    let topo = Topology::new(8, 4, 2, 8);
    let p = symmetric_placement(&topo, 16);
    let layers = 4usize;
    let mut engines: Vec<ScheduleEngine> = [1usize, 2, 8]
        .into_iter()
        .map(|workers| {
            ScheduleEngine::new(
                p.clone(),
                Some(topo.clone()),
                SchedulerOptions {
                    engine: match EngineMode::speculative() {
                        EngineMode::Speculative { forecast, .. } => {
                            EngineMode::Speculative { workers, inflight: 2, forecast }
                        }
                        _ => unreachable!(),
                    },
                    ..Default::default()
                },
                layers,
            )
            .unwrap()
        })
        .collect();
    for round in 0..6u64 {
        // mild drift: autocorrelated enough that speculation gets judged
        let loads: Vec<LoadMatrix> = (0..layers)
            .map(|l| zipf_lm(16, 8, 2000, 0.8, 7 + l as u64 + (round / 3)))
            .collect();
        let reference = engines[0].schedule_step(&loads).unwrap();
        for engine in &mut engines[1..] {
            let got = engine.schedule_step(&loads).unwrap();
            for (l, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(a.replica_loads, b.replica_loads, "round {round} layer {l}");
                assert_eq!(a.routes, b.routes, "round {round} layer {l}");
            }
        }
    }
    let st0 = engines[0].stats();
    assert!(st0.spec_issued > 0, "speculation never engaged: {st0:?}");
    for engine in &engines[1..] {
        assert_eq!(engine.stats(), st0, "engine counters diverged across worker counts");
    }
}

/// d > 2 (hyper-edges): scheduling still optimal and conservative.
#[test]
fn d3_hypergraph_scheduling() {
    let p = micromoe::placement::cayley::hyper_circulant(6, 8, 3);
    let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
    let lm = zipf_lm(8, 6, 900, 1.2, 5);
    let sched = s.schedule(&lm);
    check_routes(&p, &lm, &sched.replica_loads, &sched.routes).unwrap();
    let loads_f: Vec<f64> = lm.expert_loads().iter().map(|&l| l as f64).collect();
    let density = max_induced_density_exact(&p, &loads_f).density;
    assert!((sched.stats.lp_objective - density).abs() < 1e-5 * (1.0 + density));
}
