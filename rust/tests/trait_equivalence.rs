//! Trait-equivalence suite: every `Balancer` impl reachable through the
//! `MoeSession` registry must produce bit-identical schedules to its
//! pre-refactor direct entry point on golden Zipf traces —
//!
//! * the five plan-based systems vs direct struct construction + per-batch
//!   planning (the old `MoeSystem::plan` loop),
//! * the `micromoe` Barrier policy vs per-layer `MicroEpScheduler`s driven
//!   through `schedule_layers_parallel`,
//! * the engine-backed policy vs a directly constructed `ScheduleEngine`
//!   at 1 / 2 / 8 workers (and vs the sequential per-layer loop),
//! * the speculative policy deterministic across worker counts through
//!   the facade,
//! * the `least-loaded-inference` serving policy vs the promoted
//!   `inference_router` max-flow routing logic it was lifted from.

use micromoe::adaptive::AdaptiveConfig;
use micromoe::balancer::{Balancer, MoeLayerPlan, MoeSession};
use micromoe::baselines::{DeepSpeedPad, FlexMoe, MicroMoe, SmartMoe, VanillaEp};
use micromoe::engine::{EngineMode, ScheduleEngine};
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{
    schedule_layers_parallel, LoadMatrix, MicroEpScheduler, SchedulerOptions,
};
use micromoe::topology::Topology;

fn topo() -> Topology {
    Topology::new(8, 4, 2, 8)
}

/// Golden trace: fixed-seed Zipf streams (what every assertion replays).
fn golden_trace(
    experts: usize,
    gpus: usize,
    per_gpu: u64,
    s: f64,
    batches: usize,
) -> Vec<LoadMatrix> {
    let mut rng = Rng::new(0xE0_17);
    let z = Zipf::new(experts, s);
    (0..batches)
        .map(|_| {
            let mut lm = LoadMatrix::zeros(experts, gpus);
            for g in 0..gpus {
                for _ in 0..per_gpu {
                    lm.add(z.sample(&mut rng), g, 1);
                }
            }
            lm
        })
        .collect()
}

/// The bit-identity check: compute loads, routes, and migration charges
/// must match exactly (solve wall time is measured, so it is excluded).
fn assert_plan_eq(a: &MoeLayerPlan, b: &MoeLayerPlan, what: &str) {
    assert_eq!(a.gpu_compute, b.gpu_compute, "{what}: gpu_compute");
    assert_eq!(a.routes, b.routes, "{what}: routes");
    assert_eq!(a.prep_extra, b.prep_extra, "{what}: prep_extra");
    assert_eq!(a.sched_overlapped, b.sched_overlapped, "{what}: overlap flag");
}

fn session(name: &str, seed: u64, replan: Option<usize>) -> MoeSession {
    let mut b = MoeSession::builder().topology(topo()).experts(16).policy_name(name).seed(seed);
    if let Some(every) = replan {
        b = b.replan_every(every);
    }
    b.build().unwrap_or_else(|e| panic!("{name}: {e}"))
}

/// Each plan-based system through the registry vs its direct pre-refactor
/// construction, batch by batch on the same golden trace.
#[test]
fn plan_based_policies_match_direct_construction() {
    let trace = golden_trace(16, 8, 1500, 1.1, 24);
    let t = topo();
    let directs: Vec<(&str, u64, Option<usize>, Box<dyn Balancer>)> = vec![
        ("vanilla-ep", 0, None, Box::new(VanillaEp::new(t.clone(), 16))),
        ("deepspeed-pad", 0, None, Box::new(DeepSpeedPad::new(t.clone(), 16))),
        ("smartmoe", 0, Some(8), {
            let mut s = SmartMoe::new(t.clone(), 16);
            s.replace_every = 8;
            Box::new(s)
        }),
        ("flexmoe", 7, Some(8), {
            let mut f = FlexMoe::new(t.clone(), 16, 7);
            f.adjust_every = 8;
            Box::new(f)
        }),
        ("micromoe-ar", 5, Some(4), {
            Box::new(
                MicroMoe::new(t.clone(), symmetric_placement(&t, 16), SchedulerOptions::default())
                    .with_adaptive(
                        AdaptiveConfig {
                            check_every: 4,
                            window: 8,
                            slots_per_gpu: t.slots_per_gpu(16).max(2),
                            ..Default::default()
                        },
                        5,
                    ),
            )
        }),
    ];
    for (name, seed, replan, mut direct) in directs {
        let mut via_registry = session(name, seed, replan);
        for (i, lm) in trace.iter().enumerate() {
            let got = via_registry.step(std::slice::from_ref(lm));
            let want = direct.plan(lm);
            assert_plan_eq(&got.layers[0], &want, &format!("{name} batch {i}"));
        }
    }
}

/// `micromoe` (Barrier) through the facade vs per-layer schedulers driven
/// through the pre-refactor `schedule_layers_parallel` fan-out.
#[test]
fn micromoe_barrier_matches_schedule_layers_parallel() {
    let t = topo();
    let p = symmetric_placement(&t, 16);
    let layers = 6usize;
    let mut via_facade = MoeSession::builder()
        .topology(t.clone())
        .placement(p.clone())
        .policy_name("micromoe")
        .layers(layers)
        .build()
        .unwrap();
    let mut direct: Vec<MicroEpScheduler> = (0..layers)
        .map(|_| MicroEpScheduler::new(p.clone(), Some(t.clone()), SchedulerOptions::default()))
        .collect();
    for round in 0..4usize {
        let mut loads = golden_trace(16, 8, 1200, 0.9, layers);
        for (l, lm) in loads.iter_mut().enumerate() {
            // perturb per (round, layer) so warm-start history matters
            lm.add((round + l) % 16, l % 8, 17 * (round as u64 + 1));
        }
        let out = via_facade.step(&loads);
        let want = schedule_layers_parallel(&mut direct, &loads);
        for (l, (plan, sched)) in out.layers.iter().zip(&want).enumerate() {
            assert_eq!(plan.routes, sched.routes, "round {round} layer {l}");
            assert_eq!(plan.gpu_compute, sched.gpu_loads(&p), "round {round} layer {l}");
        }
    }
}

/// The engine-backed policy through the facade vs a directly constructed
/// `ScheduleEngine` — and vs the plain sequential per-layer loop — at
/// 1 / 2 / 8 workers, on the same golden trace.
#[test]
fn micromoe_pipeline_matches_direct_engine_across_worker_counts() {
    let t = topo();
    let p = symmetric_placement(&t, 16);
    let layers = 4usize;
    for workers in [1usize, 2, 8] {
        let mode = EngineMode::Pipeline { workers, inflight: 2 };
        let mut via_facade = MoeSession::builder()
            .topology(t.clone())
            .placement(p.clone())
            .policy_name("micromoe")
            .engine(mode)
            .layers(layers)
            .build()
            .unwrap();
        let mut direct = ScheduleEngine::new(
            p.clone(),
            Some(t.clone()),
            SchedulerOptions { engine: mode, ..Default::default() },
            layers,
        )
        .unwrap();
        let mut fresh_sequential: Vec<MicroEpScheduler> = (0..layers)
            .map(|_| {
                MicroEpScheduler::new(p.clone(), Some(t.clone()), SchedulerOptions::default())
            })
            .collect();
        for round in 0..3usize {
            let mut loads = golden_trace(16, 8, 1400, 0.9, layers);
            for (l, lm) in loads.iter_mut().enumerate() {
                // perturb per (round, layer) so warm-start history matters
                lm.add((round + l) % 16, l % 8, 23 * (round as u64 + 1));
            }
            let out = via_facade.step(&loads);
            let want = direct.schedule_step(&loads).unwrap();
            for (l, (plan, sched)) in out.layers.iter().zip(&want).enumerate() {
                assert_eq!(plan.routes, sched.routes, "workers {workers} layer {l}");
                assert_eq!(plan.gpu_compute, sched.gpu_loads(&p), "workers {workers} layer {l}");
            }
            // and both equal the plain sequential per-layer loop
            for (l, (plan, (s, lm))) in
                out.layers.iter().zip(fresh_sequential.iter_mut().zip(&loads)).enumerate()
            {
                let seq = s.schedule(lm);
                assert_eq!(plan.routes, seq.routes, "workers {workers} layer {l} (sequential)");
            }
        }
    }
}

/// The serving policy through the registry vs the promoted
/// `inference_router` logic (`LeastLoadedInference::plan_one`: max-flow +
/// locality-first route lowering), bit-identical batch by batch — plus the
/// optimality theorem the seed example asserted: the flow max-load is
/// exact, and no feasible integral plan (e.g. the warm LP's) beats it.
#[test]
fn least_loaded_inference_matches_seed_router_logic() {
    use micromoe::balancer::LeastLoadedInference;
    use micromoe::scheduler::flow::flow_schedule;

    let trace = golden_trace(16, 8, 1500, 1.1, 24);
    let p = symmetric_placement(&topo(), 16);
    let mut via_registry = session("least-loaded-inference", 0, None);
    let mut warm_lp = session("micromoe", 0, None);
    for (i, lm) in trace.iter().enumerate() {
        let got = via_registry.step(std::slice::from_ref(lm));
        let want = LeastLoadedInference::plan_one(&p, lm, true); // builder default overlap
        assert_plan_eq(&got.layers[0], &want, &format!("batch {i}"));

        let flow_max = *want.gpu_compute.iter().max().unwrap();
        assert_eq!(
            flow_max,
            flow_schedule(&p, lm).max_load,
            "batch {i}: lowering must preserve the flow bottleneck"
        );
        let warm = warm_lp.step(std::slice::from_ref(lm));
        let warm_max = *warm.layers[0].gpu_compute.iter().max().unwrap();
        assert!(
            flow_max <= warm_max,
            "batch {i}: flow optimum {flow_max} beaten by warm LP {warm_max}"
        );
    }
}

/// The speculative policy is deterministic across worker counts through
/// the facade: identical schedules and identical hit/miss counters.
#[test]
fn micromoe_speculative_deterministic_across_worker_counts_via_facade() {
    let t = topo();
    let p = symmetric_placement(&t, 16);
    let layers = 3usize;
    let mut sessions: Vec<MoeSession> = [1usize, 2, 8]
        .into_iter()
        .map(|workers| {
            let mode = match EngineMode::speculative() {
                EngineMode::Speculative { forecast, .. } => {
                    EngineMode::Speculative { workers, inflight: 2, forecast }
                }
                _ => unreachable!(),
            };
            MoeSession::builder()
                .topology(t.clone())
                .placement(p.clone())
                .policy_name("micromoe")
                .engine(mode)
                .layers(layers)
                .build()
                .unwrap()
        })
        .collect();
    for round in 0..6usize {
        // mildly drifting: autocorrelated enough that speculation is judged
        let mut loads = golden_trace(16, 8, 1800, 0.8, layers);
        for (l, lm) in loads.iter_mut().enumerate() {
            lm.add((round / 3 + l) % 16, 0, 40);
        }
        let (first, rest) = sessions.split_first_mut().unwrap();
        let reference = first.step(&loads);
        for session in rest {
            let got = session.step(&loads);
            for (l, (a, b)) in got.layers.iter().zip(&reference.layers).enumerate() {
                assert_plan_eq(a, b, &format!("round {round} layer {l}"));
            }
        }
    }
    let st0 = sessions[0].engine_stats().unwrap();
    assert!(st0.spec_issued > 0, "speculation never engaged: {st0:?}");
    for session in &sessions[1..] {
        assert_eq!(session.engine_stats().unwrap(), st0, "counters diverged across workers");
    }
}
