//! Property-based optimality certificates for every LP backend cell.
//!
//! Objective agreement between backends (tests/differential_lp.rs) cannot
//! tell a wrong-but-consistent pair of solvers from a correct one. This
//! suite pins each solver to the *mathematical* definition of optimality
//! instead: for every solved instance, the full KKT certificate must hold —
//!
//! 1. **primal feasibility**: rows and variable bounds at the returned `x`;
//! 2. **dual feasibility**: row duals signed by relation (`≤` → `y ≤ 0`,
//!    `≥` → `y ≥ 0` under minimization) and reduced costs `d = c − A'y`
//!    signed by variable position (`d ≥ 0` at lower bound, `d ≤ 0` at
//!    upper, `d ≈ 0` strictly between);
//! 3. **complementary slackness**: a slack row carries a zero dual;
//! 4. **duality gap**: `b'y + Σ_{u_j finite} u_j·min(0, d_j) = c'x`.
//!
//! The randomized families are LPP-1-shaped (minimax over EDP groups) and
//! LPP-4-shaped (the same plus per-replica caps as *variable bounds*, the
//! structure whose warm bound edits drive the long-step dual), plus a
//! generic mixed-relation fuzz family; warm re-solves re-check the
//! certificate after every rhs/bound edit, so the bound-flipping ratio
//! test is exercised and certified, not just the cold path. A dedicated
//! differential test pins the long-step dual to the classic
//! one-flip-per-pivot dual, and the PR-1 `Infeasible` → cold-fallback
//! contract is re-pinned through the same boxed instances.
//!
//! Seeds come from `LP_FUZZ_SEED` (printed per test; libtest surfaces the
//! output on failure) so CI failures replay exactly.

use micromoe::lp::{
    FactorKind, LpProblem, Pricing, Relation, RevisedSolver, SimplexError, Solution, SolverKind,
    WarmSolver,
};
use micromoe::prop::fuzz_seed;
use micromoe::rng::Rng;

/// Every backend cell: four revised (pricing × factorization) combos plus
/// the dense tableau.
fn all_kinds() -> [SolverKind; 5] {
    SolverKind::all_cells()
}

/// Assert the full optimality certificate of `sol` for `p` (see module
/// docs). `ctx` labels the failing instance for replay.
fn assert_certificate(p: &LpProblem, sol: &Solution, ctx: &str) {
    let tol = 1e-6;
    let m = p.constraints.len();
    let x = &sol.x;
    assert_eq!(x.len(), p.num_vars, "{ctx}: x length");
    assert!(
        sol.duals.len() >= m,
        "{ctx}: {} duals for {m} rows (bound-expanded backends append, never drop)",
        sol.duals.len()
    );
    let duals = &sol.duals[..m];
    let xmax = x.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
    let scale = 1.0 + xmax;
    // 1. primal feasibility
    assert!(p.is_feasible(x, tol * scale), "{ctx}: primal infeasible x = {x:?}");
    // 2.+3. row dual signs and complementary slackness
    let dmax = duals.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
    let dscale = 1.0 + dmax;
    for (i, c) in p.constraints.iter().enumerate() {
        let yi = duals[i];
        match c.rel {
            Relation::Le => assert!(yi <= tol * dscale, "{ctx}: row {i} (≤) dual {yi} > 0"),
            Relation::Ge => assert!(yi >= -tol * dscale, "{ctx}: row {i} (≥) dual {yi} < 0"),
            Relation::Eq => {}
        }
        if c.rel != Relation::Eq {
            let slack = (p.row_dot(i, x) - c.rhs).abs();
            if slack > 10.0 * tol * (1.0 + c.rhs.abs()) {
                assert!(
                    yi.abs() <= 10.0 * tol * dscale,
                    "{ctx}: row {i} slack {slack} with dual {yi}"
                );
            }
        }
    }
    // reduced costs d = c − A'y against variable positions
    let mut d = p.objective.clone();
    for (i, c) in p.constraints.iter().enumerate() {
        for &(v, co) in &c.terms {
            d[v] -= duals[i] * co;
        }
    }
    let mut gap_u = 0.0;
    for j in 0..p.num_vars {
        let u = p.upper[j];
        let at_lower = x[j] <= tol * scale;
        let at_upper = u.is_finite() && x[j] >= u - tol * scale;
        if at_lower && at_upper {
            // fixed variable (u ≈ 0): both multipliers live, any sign
        } else if at_lower {
            assert!(d[j] >= -10.0 * tol * dscale, "{ctx}: var {j} at lower, d = {}", d[j]);
        } else if at_upper {
            assert!(d[j] <= 10.0 * tol * dscale, "{ctx}: var {j} at upper, d = {}", d[j]);
        } else {
            assert!(d[j].abs() <= 10.0 * tol * dscale, "{ctx}: var {j} interior, d = {}", d[j]);
        }
        if u.is_finite() {
            gap_u += u * d[j].min(0.0);
        }
    }
    // 4. duality gap
    let primal: f64 = p.objective.iter().zip(x).map(|(c, v)| c * v).sum();
    let dual: f64 = duals.iter().zip(&p.constraints).map(|(y, c)| y * c.rhs).sum::<f64>() + gap_u;
    assert!(
        (primal - dual).abs() <= 10.0 * tol * (1.0 + primal.abs()),
        "{ctx}: duality gap, primal {primal} vs dual {dual}"
    );
    assert!(
        (sol.objective - primal).abs() <= tol * (1.0 + primal.abs()),
        "{ctx}: reported objective {} vs c'x {primal}",
        sol.objective
    );
}

/// Random LPP-1 minimax instance: EDP groups of size 2, integer loads.
/// Returns the problem plus the load-row indices for warm rhs edits.
fn lpp1_instance(rng: &mut Rng, g: usize, e: usize) -> (LpProblem, Vec<usize>) {
    let homes: Vec<[usize; 2]> = (0..e)
        .map(|_| {
            let a = rng.below(g as u64) as usize;
            let b = (a + 1 + rng.below((g - 1) as u64) as usize) % g;
            [a, b]
        })
        .collect();
    let nv = 2 * e + 1;
    let t = nv - 1;
    let mut p = LpProblem::new(nv);
    p.set_objective(t, 1.0);
    for gi in 0..g {
        let mut terms = vec![(t, -1.0)];
        for (ei, h) in homes.iter().enumerate() {
            for (r, &hh) in h.iter().enumerate() {
                if hh == gi {
                    terms.push((ei * 2 + r, 1.0));
                }
            }
        }
        p.add(terms, Relation::Le, 0.0);
    }
    let mut load_rows = Vec::with_capacity(e);
    for ei in 0..e {
        let row = p.add(
            vec![(ei * 2, 1.0), (ei * 2 + 1, 1.0)],
            Relation::Eq,
            rng.below(300) as f64,
        );
        load_rows.push(row);
    }
    (p, load_rows)
}

/// LPP-4-shaped: LPP-1 plus finite per-replica caps as *variable bounds*
/// (generous enough to stay feasible: each expert's two caps sum to at
/// least its load ceiling of 300 + slack).
fn lpp4ish_instance(rng: &mut Rng, g: usize, e: usize) -> (LpProblem, Vec<usize>) {
    let (mut p, load_rows) = lpp1_instance(rng, g, e);
    for ei in 0..e {
        let split = 0.2 + 0.6 * rng.f64();
        let total = 320.0 + rng.below(100) as f64;
        p.set_upper(ei * 2, split * total);
        p.set_upper(ei * 2 + 1, (1.0 - split) * total);
    }
    (p, load_rows)
}

/// The BFRT showcase family: max-profit over many boxed variables with a
/// shared capacity row (two of the costs duplicated for dual-degenerate
/// breakpoint ties); shrinking the capacity warm forces multi-flip dual
/// repairs.
fn boxed_instance(rng: &mut Rng, n: usize) -> LpProblem {
    let mut p = LpProblem::new(n);
    let mut costs: Vec<f64> = (0..n).map(|_| -(0.5 + rng.f64() * 2.5)).collect();
    if n >= 4 {
        costs[1] = costs[0];
        costs[3] = costs[2];
    }
    let mut cap = 0.0;
    for (j, &c) in costs.iter().enumerate() {
        p.set_objective(j, c);
        let u = 0.5 + rng.f64() * 2.0;
        p.set_upper(j, u);
        cap += u;
    }
    p.add((0..n).map(|j| (j, 1.0)).collect(), Relation::Le, cap * 0.9);
    p.add((0..n).step_by(2).map(|j| (j, 1.0)).collect(), Relation::Le, cap * 0.9);
    p
}

/// Certificates hold for every cell on cold LPP-1 solves and across warm
/// rhs-edit trajectories.
#[test]
fn certificates_lpp1_cold_and_warm() {
    let mut rng = Rng::new(fuzz_seed(0x5EED1));
    for case in 0..25 {
        let g = 4 + case % 5;
        let e = 2 * g;
        let (p, load_rows) = lpp1_instance(&mut rng, g, e);
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(p.clone(), kind);
            let s0 = warm.solve_cold().unwrap();
            assert_certificate(warm.problem(), &s0, &format!("case {case} {} cold", kind.label()));
            for round in 0..3 {
                let updates: Vec<(usize, f64)> = load_rows
                    .iter()
                    .map(|&row| (row, rng.below(300) as f64))
                    .collect();
                let s = warm.resolve(&updates).unwrap();
                assert_certificate(
                    warm.problem(),
                    &s,
                    &format!("case {case} {} warm round {round}", kind.label()),
                );
            }
        }
    }
}

/// Certificates hold for every cell on the LPP-4-shaped family, including
/// warm *bound* edits — the path that drives the long-step dual's
/// bound-flipping ratio test.
#[test]
fn certificates_lpp4ish_bound_edits() {
    let mut rng = Rng::new(fuzz_seed(0x5EED2));
    for case in 0..20 {
        let g = 4 + case % 4;
        let e = 2 * g;
        let (p, load_rows) = lpp4ish_instance(&mut rng, g, e);
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(p.clone(), kind);
            let s0 = warm.solve_cold().unwrap();
            assert_certificate(warm.problem(), &s0, &format!("case {case} {} cold", kind.label()));
            for round in 0..3 {
                let rhs: Vec<(usize, f64)> = load_rows
                    .iter()
                    .map(|&row| (row, rng.below(300) as f64))
                    .collect();
                // caps stay generous enough for feasibility (≥ load ceiling)
                let bounds: Vec<(usize, f64)> = (0..e)
                    .flat_map(|ei| {
                        let split = 0.2 + 0.6 * rng.f64();
                        let total = 320.0 + rng.below(100) as f64;
                        [(ei * 2, split * total), (ei * 2 + 1, (1.0 - split) * total)]
                    })
                    .collect();
                let s = warm.resolve_with_bounds(&rhs, &bounds).unwrap();
                assert_certificate(
                    warm.problem(),
                    &s,
                    &format!("case {case} {} warm round {round}", kind.label()),
                );
            }
        }
    }
}

/// Certificates hold on generic mixed-relation fuzz instances (whenever an
/// optimum exists) for every cell.
#[test]
fn certificates_generic_fuzz() {
    let mut rng = Rng::new(fuzz_seed(0x5EED3));
    let mut optima = 0usize;
    for case in 0..120 {
        let n = 2 + case % 6;
        let m = 1 + case % 5;
        let mut p = LpProblem::new(n);
        for j in 0..n {
            p.set_objective(j, rng.f64() * 3.0 - 1.5);
            let r = rng.f64();
            if r < 0.15 {
                p.set_upper(j, 0.0);
            } else if r < 0.75 {
                p.set_upper(j, rng.f64() * 4.0 + 0.2);
            }
        }
        for _ in 0..m {
            let terms: Vec<(usize, f64)> =
                (0..n).filter(|_| rng.f64() < 0.8).map(|j| (j, rng.f64())).collect();
            if terms.is_empty() {
                continue;
            }
            let rel = match rng.below(4) {
                0 => Relation::Ge,
                1 => Relation::Eq,
                _ => Relation::Le,
            };
            p.add(terms, rel, rng.f64() * 5.0 - 0.5);
        }
        for kind in all_kinds() {
            let mut warm = WarmSolver::with_kind(p.clone(), kind);
            match warm.solve_cold() {
                Ok(s) => {
                    assert_certificate(&p, &s, &format!("case {case} {}", kind.label()));
                    optima += 1;
                }
                Err(SimplexError::Infeasible(_)) | Err(SimplexError::Unbounded) => {}
                Err(e) => panic!("case {case} {}: {e}", kind.label()),
            }
        }
    }
    assert!(optima > 50, "only {optima} certified optima — generator degenerated");
}

/// Differential: the long-step (bound-flipping) dual and the classic
/// one-flip-per-pivot dual must reach the same optimum after every
/// rhs/bound edit, with the long step spending no more dual pivots in
/// aggregate and actually batching flips on this family.
#[test]
fn long_step_matches_classic_dual_and_flips() {
    // Pinned seed, deliberately NOT LP_FUZZ_SEED: the aggregate
    // dual-pivot comparison below is a performance property, not a
    // theorem per instance set, and CI rotates LP_FUZZ_SEED per run — a
    // fuzzing seed belongs on the correctness assertions (the suites
    // above), not on a comparative count that an unlucky sample could
    // tip by a pivot or two.
    let mut rng = Rng::new(0x5EED4);
    let mut flips_long = 0usize;
    let mut dual_long = 0usize;
    let mut dual_classic = 0usize;
    for case in 0..40 {
        let n = 6 + case % 12;
        let p = boxed_instance(&mut rng, n);
        let cap_full = p.constraints[0].rhs;
        let configs = [
            (Pricing::Devex, FactorKind::DenseInverse),
            (Pricing::Devex, FactorKind::SparseLu),
            (Pricing::Dantzig, FactorKind::DenseInverse),
        ];
        let (pricing, factor) = configs[case % configs.len()];
        let mut long = RevisedSolver::with_config(&p, pricing, factor);
        let mut classic = RevisedSolver::with_config(&p, pricing, factor);
        classic.set_long_step(false);
        long.solve().unwrap();
        classic.solve().unwrap();
        for round in 0..6 {
            let cap = cap_full * (0.1 + 0.9 * rng.f64());
            let ub_edit = (rng.below(n as u64) as usize, 0.2 + rng.f64() * 2.3);
            let mut objs = [0.0f64; 2];
            for (idx, s) in [&mut long, &mut classic].into_iter().enumerate() {
                s.update_rhs(0, cap);
                s.update_upper(ub_edit.0, ub_edit.1);
                let before = s.stats();
                let sol = s.warm_resolve().unwrap();
                let spent = s.stats().since(before);
                objs[idx] = sol.objective;
                if idx == 0 {
                    flips_long += spent.bound_flips;
                    dual_long += spent.dual_pivots;
                } else {
                    // (the classic path can still flip bounds in its primal
                    // cleanup pass, so only the dual pivot count is compared)
                    dual_classic += spent.dual_pivots;
                }
            }
            assert!(
                (objs[0] - objs[1]).abs() < 1e-6 * (1.0 + objs[1].abs()),
                "case {case} round {round} ({pricing:?}/{factor:?}): long {} vs classic {}",
                objs[0],
                objs[1]
            );
            // cold oracle on the edited problem
            let mut pe = p.clone();
            pe.set_rhs(0, cap);
            pe.set_upper(ub_edit.0, ub_edit.1);
            let cold = micromoe::lp::revised::solve(&pe).unwrap();
            assert!(
                (objs[0] - cold.objective).abs() < 1e-6 * (1.0 + cold.objective.abs()),
                "case {case} round {round}: warm {} vs cold {}",
                objs[0],
                cold.objective
            );
        }
    }
    eprintln!(
        "long-step dual: {flips_long} flips, {dual_long} dual pivots vs classic {dual_classic}"
    );
    assert!(flips_long > 0, "BFRT never batched a flip on the boxed family");
    assert!(
        dual_long <= dual_classic,
        "long step spent more dual pivots ({dual_long}) than classic ({dual_classic})"
    );
}

/// PR-1 contract, re-pinned through the long-step path: a warm `Infeasible`
/// (from rhs or bound edits) falls back to a cold solve, and the solver
/// warm-starts again once feasibility returns.
#[test]
fn infeasible_warm_still_falls_back_to_cold() {
    for kind in all_kinds() {
        // x0 ≥ lo (Ge row), x0 ≤ 5 (bound); lo > 5 is infeasible
        let mut p = LpProblem::new(1);
        p.set_objective(0, 1.0);
        p.set_upper(0, 5.0);
        p.add(vec![(0, 1.0)], Relation::Ge, 1.0);
        let mut warm = WarmSolver::with_kind(p, kind);
        warm.solve_cold().unwrap();
        // infeasible via rhs edit
        let err = warm.resolve(&[(0, 7.0)]).unwrap_err();
        assert!(matches!(err, SimplexError::Infeasible(_)), "{kind:?}: {err}");
        // infeasible via bound edit (rhs back in range, bound below it)
        let err = warm.resolve_with_bounds(&[(0, 4.0)], &[(0, 2.0)]).unwrap_err();
        assert!(matches!(err, SimplexError::Infeasible(_)), "{kind:?}: {err}");
        // feasible again: must solve, then warm again on the next call
        let s = warm.resolve_with_bounds(&[(0, 4.0)], &[(0, 6.0)]).unwrap();
        assert!((s.objective - 4.0).abs() < 1e-7, "{kind:?}");
        let s2 = warm.resolve(&[(0, 2.0)]).unwrap();
        assert!((s2.objective - 2.0).abs() < 1e-7, "{kind:?}");
        assert!(warm.last_was_warm, "{kind:?}: warm path not restored");
    }
}
