//! Golden test: our simplex backends vs HiGHS (the paper's solver).
//!
//! `python/tools/gen_lp_golden.py` solved these instances with
//! scipy.optimize.linprog(method="highs") and recorded the optimal
//! objectives; every backend — the dense tableau and all four revised
//! (pricing × factorization) cells — must agree to 1e-6 on every one.
//! The `boxed_resolve` family additionally records warm *trajectories*
//! (rhs/bound edit steps with per-step HiGHS optima) engineered so the
//! long-step dual must batch multi-breakpoint bound flips; the replay
//! asserts those flips actually happen (`bound_flips > 0` per revised
//! cell).
//!
//! The fixture `tests/golden_lp.json` is committed; a missing file is a
//! hard failure (regenerate with the tool above and commit the result —
//! see README.md § "Golden LP fixture").

use micromoe::lp::{
    FactorKind, LpProblem, Pricing, Relation, SimplexError, Solution, SolverKind, WarmSolver,
};
use micromoe::ser::Json;

fn fixture() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_lp.json");
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        panic!("{path} missing ({e}) — regenerate with python/tools/gen_lp_golden.py and commit")
    });
    Json::parse(&text).unwrap()
}

fn as_f64s(j: &Json) -> Vec<f64> {
    j.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect()
}

/// (label, solve fn) for every backend cell.
fn backends() -> Vec<(&'static str, fn(&LpProblem) -> Result<Solution, SimplexError>)> {
    fn rev(p: &LpProblem, pricing: Pricing, factor: FactorKind) -> Result<Solution, SimplexError> {
        micromoe::lp::revised::RevisedSolver::with_config(p, pricing, factor).solve()
    }
    vec![
        ("tableau", micromoe::lp::simplex::solve),
        ("dantzig+dense", |p| rev(p, Pricing::Dantzig, FactorKind::DenseInverse)),
        ("dantzig+lu", |p| rev(p, Pricing::Dantzig, FactorKind::SparseLu)),
        ("devex+dense", |p| rev(p, Pricing::Devex, FactorKind::DenseInverse)),
        ("devex+lu", |p| rev(p, Pricing::Devex, FactorKind::SparseLu)),
    ]
}

#[test]
fn matches_highs_on_all_cases() {
    let fx = fixture();
    let cases = fx.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 30, "suspiciously few golden cases");
    let mut lpp1 = 0;
    let mut generic = 0;
    let mut bounded = 0;
    let mut boxed_degen = 0;
    let mut boxed_resolve = 0;
    for (i, case) in cases.iter().enumerate() {
        let expect = case.get("objective").unwrap().as_f64().unwrap();
        let problem = match case.get("kind").unwrap().as_str().unwrap() {
            "lpp1" => {
                lpp1 += 1;
                build_lpp1(case)
            }
            "generic" => {
                generic += 1;
                build_generic(case)
            }
            "bounded" => {
                bounded += 1;
                build_bounded(case)
            }
            // same shape as `bounded`; the duplicated costs / replay steps
            // matter to the dedicated tests, the base case is checked here
            "boxed_degen" => {
                boxed_degen += 1;
                build_bounded(case)
            }
            "boxed_resolve" => {
                boxed_resolve += 1;
                build_bounded(case)
            }
            k => panic!("unknown kind {k}"),
        };
        // every backend must agree with HiGHS
        for (name, solve) in backends() {
            let sol = solve(&problem).unwrap_or_else(|e| panic!("case {i} ({name}): {e}"));
            assert!(
                (sol.objective - expect).abs() < 1e-6 * (1.0 + expect.abs()),
                "case {i} ({name}): ours {} vs HiGHS {}",
                sol.objective,
                expect
            );
            assert!(
                problem.is_feasible(&sol.x, 1e-6),
                "case {i} ({name}): infeasible solution"
            );
        }
    }
    assert!(lpp1 > 0 && generic > 0, "fixture missing a family");
    assert!(bounded > 0, "fixture predates bounded-variable cases — regenerate");
    assert!(
        boxed_degen > 0 && boxed_resolve > 0,
        "fixture predates the dual-degenerate/boxed warm-replay families — regenerate"
    );
}

fn build_lpp1(case: &Json) -> LpProblem {
    let num_gpus = case.get("num_gpus").unwrap().as_usize().unwrap();
    let d = case.get("d").unwrap().as_usize().unwrap();
    let edp: Vec<Vec<usize>> = case
        .get("edp")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|g| g.as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect())
        .collect();
    let loads = as_f64s(case.get("loads").unwrap());
    let e_count = edp.len();
    let nx = e_count * d;
    let t = nx;
    let mut p = LpProblem::new(nx + 1);
    p.set_objective(t, 1.0);
    for g in 0..num_gpus {
        let mut terms = vec![(t, -1.0)];
        for (e, grp) in edp.iter().enumerate() {
            for (r, &gg) in grp.iter().enumerate() {
                if gg == g {
                    terms.push((e * d + r, 1.0));
                }
            }
        }
        p.add(terms, Relation::Le, 0.0);
    }
    for (e, _) in edp.iter().enumerate() {
        let terms = (0..d).map(|r| (e * d + r, 1.0)).collect();
        p.add(terms, Relation::Eq, loads[e]);
    }
    p
}

fn build_generic(case: &Json) -> LpProblem {
    let c = as_f64s(case.get("c").unwrap());
    let b = as_f64s(case.get("b_ub").unwrap());
    let rows: Vec<Vec<f64>> = case
        .get("a_ub")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(as_f64s)
        .collect();
    let mut p = LpProblem::new(c.len());
    for (j, &cj) in c.iter().enumerate() {
        p.set_objective(j, cj);
    }
    for (row, &bi) in rows.iter().zip(&b) {
        let terms = row.iter().enumerate().map(|(j, &a)| (j, a)).collect();
        p.add(terms, Relation::Le, bi);
    }
    p
}

/// `generic` plus per-variable upper bounds; `-1.0` in the fixture encodes
/// "unbounded above" (JSON has no infinity).
fn build_bounded(case: &Json) -> LpProblem {
    let mut p = build_generic(case);
    let upper = as_f64s(case.get("upper").unwrap());
    assert_eq!(upper.len(), p.num_vars);
    for (j, &u) in upper.iter().enumerate() {
        if u >= 0.0 {
            p.set_upper(j, u);
        }
    }
    p
}

/// Replay the `boxed_resolve` warm trajectories — correlated rhs *and*
/// bound edits with per-step HiGHS optima — through every backend cell.
/// The capacity swings are engineered to force multi-breakpoint dual
/// repairs, so on top of objective agreement this asserts the §5.1 warm
/// path is actually taken and that the long-step dual batches bound flips:
/// every revised cell must report `bound_flips > 0` (and dual pivots spent)
/// across its replay.
#[test]
fn boxed_resolve_warm_replay_matches_highs_and_flips_bounds() {
    let fx = fixture();
    let cases: Vec<&Json> = fx
        .get("cases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|c| c.get("kind").unwrap().as_str() == Some("boxed_resolve"))
        .collect();
    assert!(cases.len() >= 4, "fixture predates boxed_resolve — regenerate");
    for kind in SolverKind::all_cells() {
        let revised = matches!(kind, SolverKind::Revised { .. });
        let mut flips = 0usize;
        let mut dual_pivots = 0usize;
        for (ci, case) in cases.iter().enumerate() {
            let p = build_bounded(case);
            let expect = case.get("objective").unwrap().as_f64().unwrap();
            let mut warm = WarmSolver::with_kind(p, kind);
            let s0 = warm.solve_cold().unwrap();
            assert!(
                (s0.objective - expect).abs() < 1e-6 * (1.0 + expect.abs()),
                "case {ci} ({}) cold: {} vs HiGHS {}",
                kind.label(),
                s0.objective,
                expect
            );
            let steps = case.get("steps").unwrap().as_arr().unwrap();
            for (si, step) in steps.iter().enumerate() {
                let rhs: Vec<(usize, f64)> =
                    as_f64s(step.get("b_ub").unwrap()).into_iter().enumerate().collect();
                let bounds: Vec<(usize, f64)> = as_f64s(step.get("upper").unwrap())
                    .into_iter()
                    .map(|u| if u >= 0.0 { u } else { f64::INFINITY })
                    .enumerate()
                    .collect();
                let expect = step.get("objective").unwrap().as_f64().unwrap();
                let s = warm.resolve_with_bounds(&rhs, &bounds).unwrap();
                assert!(
                    (s.objective - expect).abs() < 1e-6 * (1.0 + expect.abs()),
                    "case {ci} step {si} ({}): {} vs HiGHS {}",
                    kind.label(),
                    s.objective,
                    expect
                );
                if revised {
                    // the dense tableau may legitimately fall back to cold
                    // on a stalled dual; the revised cells must not
                    assert!(
                        warm.last_was_warm,
                        "case {ci} step {si} ({}): cold fallback on the warm path",
                        kind.label()
                    );
                    flips += warm.last_stats.bound_flips;
                    dual_pivots += warm.last_stats.dual_pivots;
                }
            }
        }
        if revised {
            assert!(
                flips > 0,
                "{}: long-step dual never flipped a bound across the boxed_resolve replay",
                kind.label()
            );
            assert!(
                dual_pivots > 0,
                "{}: replay exercised no dual pivots — fixture no longer stresses the dual path",
                kind.label()
            );
        }
    }
}

/// Pivot-count pin for the bound-flip-aware devex weight maintenance:
/// across the `boxed_resolve` warm trajectories, the warm path (long-step
/// dual + weight-preserving primal cleanup) must not spend more total
/// pivots than solving every post-edit problem from scratch. A weight-
/// maintenance regression (stale or wrongly invalidated weights) shows up
/// here as warm pivot counts ballooning past the cold reference.
#[test]
fn boxed_resolve_warm_pivots_do_not_regress_cold() {
    let fx = fixture();
    let cases: Vec<&Json> = fx
        .get("cases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|c| c.get("kind").unwrap().as_str() == Some("boxed_resolve"))
        .collect();
    assert!(cases.len() >= 4, "fixture predates boxed_resolve — regenerate");
    for kind in SolverKind::all_cells() {
        if !matches!(kind, SolverKind::Revised { .. }) {
            continue;
        }
        let mut warm_pivots = 0usize;
        let mut cold_pivots = 0usize;
        for case in &cases {
            let p = build_bounded(case);
            let mut warm = WarmSolver::with_kind(p, kind);
            warm.solve_cold().unwrap();
            let steps = case.get("steps").unwrap().as_arr().unwrap();
            for step in steps {
                let rhs: Vec<(usize, f64)> =
                    as_f64s(step.get("b_ub").unwrap()).into_iter().enumerate().collect();
                let bounds: Vec<(usize, f64)> = as_f64s(step.get("upper").unwrap())
                    .into_iter()
                    .map(|u| if u >= 0.0 { u } else { f64::INFINITY })
                    .enumerate()
                    .collect();
                warm.resolve_with_bounds(&rhs, &bounds).unwrap();
                warm_pivots += warm.last_stats.pivots;
                // cold reference: the identical post-edit problem from scratch
                let mut cold = WarmSolver::with_kind(warm.problem().clone(), kind);
                cold.solve_cold().unwrap();
                cold_pivots += cold.last_stats.pivots;
            }
        }
        assert!(
            warm_pivots <= cold_pivots,
            "{}: warm path spent {warm_pivots} pivots vs {cold_pivots} cold across the \
             boxed_resolve replay — devex weight maintenance regressed",
            kind.label()
        );
    }
}

#[test]
fn lpp1_warm_start_agrees_with_highs_objectives() {
    // replay lpp1 cases through a warm solver, exercising the §5.1
    // warm-start path against golden objectives
    let fx = fixture();
    let cases: Vec<&Json> = fx
        .get("cases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|c| c.get("kind").unwrap().as_str() == Some("lpp1"))
        .collect();
    assert!(cases.len() >= 10);
    for case in cases {
        let expect = case.get("objective").unwrap().as_f64().unwrap();
        let num_gpus = case.get("num_gpus").unwrap().as_usize().unwrap();
        let p = build_lpp1(case);
        let mut warm = micromoe::lp::WarmSolver::new(p);
        let s0 = warm.solve_cold().unwrap();
        assert!((s0.objective - expect).abs() < 1e-6 * (1.0 + expect.abs()));
        // scale all loads by 2 via rhs updates: optimum must scale by 2
        let loads = as_f64s(case.get("loads").unwrap());
        let updates: Vec<(usize, f64)> = loads
            .iter()
            .enumerate()
            .map(|(e, &l)| (num_gpus + e, 2.0 * l))
            .collect();
        let s1 = warm.resolve(&updates).unwrap();
        assert!(
            (s1.objective - 2.0 * expect).abs() < 1e-5 * (1.0 + expect.abs()),
            "warm rescale: {} vs {}",
            s1.objective,
            2.0 * expect
        );
    }
}
