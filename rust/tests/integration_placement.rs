//! Integration: placement theory at paper scale — Cayley constructions vs
//! random vs asymmetric under the §7.3 workloads, validated through the
//! scheduler (not just the density evaluator).

use micromoe::placement::asymmetric::asymmetric_placement;
use micromoe::placement::cayley::{cayley_graph_placement, symmetric_placement};
use micromoe::placement::random::random_placement;
use micromoe::placement::Placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::topology::Topology;

fn zipf_lm(e: usize, g: usize, per_gpu: u64, s: f64, rng: &mut Rng) -> LoadMatrix {
    let z = Zipf::new(e, s);
    let mut lm = LoadMatrix::zeros(e, g);
    for gi in 0..g {
        for _ in 0..per_gpu {
            lm.add(z.sample(rng), gi, 1);
        }
    }
    lm
}

fn mean_imbalance(p: &Placement, skew: f64, batches: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
    let mut acc = 0.0;
    for _ in 0..batches {
        let lm = zipf_lm(p.num_experts, p.num_gpus, 2000, skew, &mut rng);
        acc += s.schedule(&lm).imbalance(p);
    }
    acc / batches as f64
}

/// §7.3: symmetric (Cayley) placement slightly beats pure random — the
/// "MicroMoE (random)" vs "MicroMoE (w/o AR)" gap in Fig. 7.
#[test]
fn cayley_beats_or_matches_random() {
    let topo = Topology::new(8, 4, 2, 8);
    let sym = symmetric_placement(&topo, 32);
    let mut rng = Rng::new(12);
    // average several random placements to smooth sampling luck
    let mut rnd_acc = 0.0;
    for k in 0..5 {
        let r = random_placement(8, 32, 2, &mut rng);
        rnd_acc += mean_imbalance(&r, 1.2, 10, 100 + k);
    }
    let rnd = rnd_acc / 5.0;
    let sym_imb = mean_imbalance(&sym, 1.2, 10, 55);
    assert!(
        sym_imb <= rnd * 1.02,
        "symmetric {sym_imb} should be <= random {rnd} (within noise)"
    );
}

/// §7.3 Fig. 7: at heavy skew, uniform replica counts saturate and the
/// asymmetric placement restores (near-)perfect balance.
#[test]
fn asymmetric_restores_balance_at_heavy_skew() {
    let topo = Topology::new(8, 4, 2, 8);
    let sym = symmetric_placement(&topo, 32);
    let s = 1.6f64;
    let sym_imb = mean_imbalance(&sym, s, 8, 21);
    assert!(sym_imb > 1.05, "symmetric should struggle at s={s}: {sym_imb}");

    // build asymmetric from the observed long-run loads (as AR would)
    let mut rng = Rng::new(23);
    let probe = zipf_lm(32, 8, 20_000, s, &mut rng);
    let loads: Vec<f64> = probe.expert_loads().iter().map(|&l| l as f64).collect();
    let asym = asymmetric_placement(8, &loads, 8, 200, &mut rng);
    let asym_imb = mean_imbalance(&asym, s, 8, 21);
    assert!(
        asym_imb < sym_imb,
        "asymmetric {asym_imb} must beat symmetric {sym_imb} at s={s}"
    );
    assert!(asym_imb < 1.12, "asymmetric imbalance {asym_imb} too high");
}

/// Scheduling-space monotonicity across scales: at fixed GPU count, a
/// denser placement graph (more experts per GPU) can only improve the
/// achievable balance, and high expert-per-GPU ratios reach near-perfect
/// balance at mild skew. (G=16 with only 32 experts — degree 4 — has a
/// genuine capacity floor above 1.0 at s=0.6: the hot expert's mass
/// exceeds its two replicas' 2/16 share; richer graphs dissolve it.)
#[test]
fn other_scales_balance_mild_skew() {
    let s = 0.6;
    let sparse = mean_imbalance(&cayley_graph_placement(16, 32), s, 6, 31);
    let dense = mean_imbalance(&cayley_graph_placement(16, 64), s, 6, 31);
    assert!(
        dense <= sparse + 1e-9,
        "denser graph regressed: E=64 {dense} vs E=32 {sparse}"
    );
    for (g, e) in [(8usize, 32usize), (4, 16)] {
        let imb = mean_imbalance(&cayley_graph_placement(g, e), s, 6, 31);
        assert!(imb < 1.06, "G={g} E={e}: imbalance {imb}");
    }
}

/// Vanilla-EP placement through the *same* scheduler: disjoint EDP groups
/// mean the LP has no room and imbalance stays high — the Fig. 3b lesson.
#[test]
fn vanilla_placement_gives_lp_no_room() {
    let topo = Topology::new(8, 4, 2, 8);
    let vanilla = Placement::vanilla_ep(&topo, 32);
    let shuffled = symmetric_placement(&topo, 32);
    let iv = mean_imbalance(&vanilla, 1.2, 8, 41);
    let is = mean_imbalance(&shuffled, 1.2, 8, 41);
    assert!(
        iv > is + 0.05,
        "identical-per-group placement ({iv}) should trail shuffled ({is})"
    );
}

/// B.3 consistency restriction survives every generator at paper scale.
#[test]
fn consistency_at_scale() {
    let mut rng = Rng::new(61);
    let topo = Topology::new(8, 4, 2, 8);
    symmetric_placement(&topo, 32).check_consistency().unwrap();
    for _ in 0..10 {
        random_placement(8, 32, 2, &mut rng).check_consistency().unwrap();
    }
    let loads: Vec<f64> = (0..32).map(|_| rng.below(500) as f64 + 1.0).collect();
    asymmetric_placement(8, &loads, 8, 50, &mut rng)
        .check_consistency()
        .unwrap();
}
