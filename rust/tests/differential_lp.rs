//! Differential testing: every LP backend cell against every other.
//!
//! The backends implement the same mathematical contract through very
//! different machinery — implicit bounds + (dense-eta | sparse-LU
//! Forrest–Tomlin) factors + (Dantzig | devex candidate-list) pricing on
//! the revised side, bound rows + a full tableau on the dense side — which
//! makes them near-perfect oracles for each other: on every instance they
//! must agree on feasibility classification and, when an optimum exists,
//! on the optimal objective to 1e-6. The suite covers randomized LPP-1 /
//! LPP-4 (CommAware) / TopoAware scheduling instances end-to-end through
//! `MicroEpScheduler`, raw-LP fuzz with upper-bound edge cases
//! (bound-tight optima, degenerate bounds at 0), and 128–256-GPU-shaped
//! instances where the sparse-LU engine is the one actually exercised in
//! production (`FactorKind::Auto` cuts over at m > 128).
//!
//! Every randomized test derives its RNG from `LP_FUZZ_SEED` (default: the
//! per-test constant below) and prints the seed it ran with — libtest
//! shows that output exactly when the test fails, so a CI failure is
//! replayable with `LP_FUZZ_SEED=<seed> cargo test --test differential_lp`.

use micromoe::lp::{FactorKind, LpProblem, Pricing, Relation, SimplexError, SolverKind, WarmSolver};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::prop::fuzz_seed;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::flow::flow_schedule;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::topology::Topology;

/// The four revised (pricing × factorization) cells.
fn revised_kinds() -> [SolverKind; 4] {
    [
        SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::DenseInverse },
        SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::SparseLu },
        SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::DenseInverse },
        SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::SparseLu },
    ]
}

/// All five backends, dense tableau first (the oracle the others are
/// compared against in the smaller suites).
fn all_kinds() -> [SolverKind; 5] {
    let r = revised_kinds();
    [SolverKind::DenseTableau, r[0], r[1], r[2], r[3]]
}

fn zipf_batch(
    rng: &mut Rng,
    zipf: &Zipf,
    experts: usize,
    gpus: usize,
    per_gpu: usize,
) -> LoadMatrix {
    let mut lm = LoadMatrix::zeros(experts, gpus);
    for g in 0..gpus {
        for _ in 0..per_gpu {
            lm.add(zipf.sample(rng), g, 1);
        }
    }
    lm
}

/// Every backend cell, all three schedule modes, warm-started across
/// batches: objectives agree to 1e-6 and replica loads conserve expert
/// totals.
#[test]
fn schedulers_agree_across_modes_and_batches() {
    let gpus = 8usize;
    let experts = 16usize;
    let placement = cayley_graph_placement(gpus, experts);
    let topo = Topology::new(gpus, 4, 2, 4); // 2 nodes of 4 GPUs
    let modes = [
        ScheduleMode::Compute,
        ScheduleMode::CommAware { alpha: 0.7 },
        ScheduleMode::TopoAware { alpha1: 0.1, alpha2: 1.0 },
    ];
    for mode in modes {
        let opts = |solver: SolverKind| SchedulerOptions {
            mode: mode.clone(),
            solver,
            topo_aware_routing: matches!(mode, ScheduleMode::TopoAware { .. }),
            ..Default::default()
        };
        let mut scheds: Vec<MicroEpScheduler> = all_kinds()
            .into_iter()
            .map(|k| MicroEpScheduler::new(placement.clone(), Some(topo.clone()), opts(k)))
            .collect();
        let mut rng = Rng::new(fuzz_seed(42));
        let zipf = Zipf::new(experts, 0.9);
        for batch in 0..12 {
            let lm = zipf_batch(&mut rng, &zipf, experts, gpus, 1024);
            let outs: Vec<_> = scheds.iter_mut().map(|s| s.schedule(&lm)).collect();
            let base = outs[0].stats.lp_objective;
            assert!(base.is_finite(), "{mode:?} batch {batch}: tableau LP fallback triggered");
            for (k, out) in all_kinds().into_iter().zip(&outs) {
                assert!(
                    out.stats.lp_objective.is_finite(),
                    "{mode:?} batch {batch} {}: LP fallback triggered",
                    k.label()
                );
                let scale = 1.0 + base.abs();
                assert!(
                    (out.stats.lp_objective - base).abs() < 1e-6 * scale,
                    "{mode:?} batch {batch} {}: {} vs tableau {}",
                    k.label(),
                    out.stats.lp_objective,
                    base
                );
                if batch > 0 {
                    assert!(
                        out.stats.warm,
                        "{mode:?} batch {batch} {}: warm path not taken",
                        k.label()
                    );
                }
                for e in 0..experts {
                    assert_eq!(
                        out.replica_loads[e].iter().sum::<u64>(),
                        lm.expert_load(e),
                        "{mode:?} batch {batch} {}: expert {e} total",
                        k.label()
                    );
                }
            }
        }
    }
}

/// Raw-LP fuzz: random rows of every relation plus random finite upper
/// bounds. All backends must agree on the error class or on the objective.
#[test]
fn random_instances_agree() {
    let mut rng = Rng::new(fuzz_seed(2024));
    let mut optima = 0usize;
    let mut infeasible = 0usize;
    let mut unbounded = 0usize;
    for case in 0..200 {
        let n = 2 + (case % 5);
        let m = 1 + (case % 6);
        let mut p = LpProblem::new(n);
        for j in 0..n {
            p.set_objective(j, rng.f64() * 4.0 - 2.0);
        }
        for j in 0..n {
            let r = rng.f64();
            if r < 0.25 {
                p.set_upper(j, rng.f64() * 4.0);
            } else if r < 0.35 {
                p.set_upper(j, 0.0); // degenerate bound at 0
            }
        }
        for _ in 0..m {
            let terms: Vec<(usize, f64)> = (0..n)
                .filter(|_| rng.f64() < 0.8)
                .map(|j| (j, rng.f64() * 2.0 - 0.5))
                .collect();
            if terms.is_empty() {
                continue;
            }
            let rel = match rng.below(4) {
                0 => Relation::Ge,
                1 => Relation::Eq,
                _ => Relation::Le,
            };
            p.add(terms, rel, rng.f64() * 6.0 - 1.0);
        }
        let oracle = micromoe::lp::simplex::solve(&p);
        for kind in revised_kinds() {
            let SolverKind::Revised { pricing, factor } = kind else { unreachable!() };
            let got =
                micromoe::lp::revised::RevisedSolver::with_config(&p, pricing, factor).solve();
            match (&got, &oracle) {
                (Ok(sa), Ok(sb)) => {
                    let scale = 1.0 + sa.objective.abs();
                    assert!(
                        (sa.objective - sb.objective).abs() < 1e-6 * scale,
                        "case {case} {}: revised {} vs tableau {}",
                        kind.label(),
                        sa.objective,
                        sb.objective
                    );
                    assert!(
                        p.is_feasible(&sa.x, 1e-6),
                        "case {case} {}: revised point infeasible",
                        kind.label()
                    );
                    assert!(p.is_feasible(&sb.x, 1e-6), "case {case}: tableau point infeasible");
                }
                (Err(SimplexError::Infeasible(_)), Err(SimplexError::Infeasible(_))) => {}
                (Err(SimplexError::Unbounded), Err(SimplexError::Unbounded)) => {}
                (a, b) => panic!("case {case} {}: revised {a:?} vs tableau {b:?}", kind.label()),
            }
        }
        match oracle {
            Ok(_) => optima += 1,
            Err(SimplexError::Infeasible(_)) => infeasible += 1,
            Err(SimplexError::Unbounded) => unbounded += 1,
            Err(e) => panic!("case {case}: tableau {e}"),
        }
    }
    // the generator must produce a healthy share of solvable instances;
    // the error-class tallies are informational (they vary with the seed)
    assert!(optima > 20, "only {optima} optima");
    eprintln!("differential fuzz: {optima} optima, {infeasible} infeasible, {unbounded} unbounded");
}

/// Bound-tight optimum: the argmax sits exactly on variable bounds, with
/// one variable pinned by a degenerate 0 bound.
#[test]
fn bound_tight_optimum_agrees() {
    // max 3a + 2b + 5c (min negative) s.t. a+b+c <= 10, a <= 4, b <= 2, c <= 0
    let mut p = LpProblem::new(3);
    p.set_objective(0, -3.0);
    p.set_objective(1, -2.0);
    p.set_objective(2, -5.0);
    p.set_upper(0, 4.0);
    p.set_upper(1, 2.0);
    p.set_upper(2, 0.0);
    p.add(vec![(0, 1.0), (1, 1.0), (2, 1.0)], Relation::Le, 10.0);
    let b = micromoe::lp::simplex::solve(&p).unwrap();
    assert!((b.objective - (-16.0)).abs() < 1e-9, "tableau {}", b.objective);
    for kind in revised_kinds() {
        let SolverKind::Revised { pricing, factor } = kind else { unreachable!() };
        let a = micromoe::lp::revised::RevisedSolver::with_config(&p, pricing, factor)
            .solve()
            .unwrap();
        assert!((a.objective - (-16.0)).abs() < 1e-9, "{}: {}", kind.label(), a.objective);
        assert!((a.x[0] - 4.0).abs() < 1e-9 && (a.x[1] - 2.0).abs() < 1e-9, "{}", kind.label());
        assert!(a.x[2].abs() < 1e-9, "{}", kind.label());
    }
}

/// Warm bound updates through `WarmSolver` agree between all backends over
/// a trajectory of correlated cap changes (the LPP-4 micro-batch pattern).
#[test]
fn warm_bound_trajectories_agree() {
    let build = || {
        // min comp s.t. comp >= x0 + x1, x0 + x1 = 6, x0 <= c0, x1 <= c1
        // (caps start permissive and move each "micro-batch")
        let mut p = LpProblem::new(3);
        p.set_objective(2, 1.0);
        p.add(vec![(0, 1.0), (1, 1.0), (2, -1.0)], Relation::Le, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Relation::Eq, 6.0);
        p.set_upper(0, 6.0);
        p.set_upper(1, 6.0);
        p
    };
    let mut solvers: Vec<WarmSolver> =
        all_kinds().into_iter().map(|k| WarmSolver::with_kind(build(), k)).collect();
    for s in &mut solvers {
        s.solve_cold().unwrap();
    }
    let mut rng = Rng::new(fuzz_seed(9));
    for round in 0..25 {
        let c0 = rng.f64() * 6.0;
        let c1 = (6.0 - c0).max(0.0) + rng.f64() * 3.0;
        let load = 2.0 + rng.f64() * (c0 + c1 - 2.0).max(0.1);
        let rhs = [(1usize, load.min(c0 + c1))];
        let caps = [(0usize, c0), (1usize, c1)];
        let results: Vec<_> =
            solvers.iter_mut().map(|s| s.resolve_with_bounds(&rhs, &caps)).collect();
        let (first, rest) = results.split_first().unwrap();
        for (k, r) in all_kinds().into_iter().skip(1).zip(rest) {
            match (first, r) {
                (Ok(sa), Ok(sb)) => {
                    assert!(
                        (sa.objective - sb.objective).abs() < 1e-6,
                        "round {round} {}: tableau {} vs {}",
                        k.label(),
                        sa.objective,
                        sb.objective
                    );
                }
                (Err(SimplexError::Infeasible(_)), Err(SimplexError::Infeasible(_))) => {}
                (sa, sb) => {
                    panic!("round {round} {}: tableau {sa:?} vs {sb:?}", k.label())
                }
            }
        }
    }
}

/// 128–256-GPU-shaped instances — the regime the sparse-LU factors and
/// devex candidate lists exist for (the dense tableau is too slow to be an
/// oracle here, so the cells cross-check each other, with the max-flow
/// solver as an independent integer-optimum oracle on LPP-1).
#[test]
fn large_scale_cells_agree() {
    // (gpus, experts, which cells) — dense-inverse cells are included at
    // 128 GPUs; at 256 GPUs (and for the 1152-row LPP-4) the LU cells
    // cross-check each other, which is also what Auto would pick there.
    let lu_only: Vec<SolverKind> = revised_kinds()
        .into_iter()
        .filter(|k| matches!(k, SolverKind::Revised { factor: FactorKind::SparseLu, .. }))
        .collect();
    let all: Vec<SolverKind> = revised_kinds().to_vec();
    let cases: [(usize, usize, ScheduleMode, &Vec<SolverKind>); 3] = [
        (128, 256, ScheduleMode::Compute, &all),
        (256, 256, ScheduleMode::Compute, &lu_only),
        (128, 256, ScheduleMode::CommAware { alpha: 0.7 }, &lu_only),
    ];
    for (gpus, experts, mode, kinds) in cases {
        let placement = cayley_graph_placement(gpus, experts);
        let opts = |solver: SolverKind| SchedulerOptions {
            mode: mode.clone(),
            solver,
            ..Default::default()
        };
        let mut scheds: Vec<MicroEpScheduler> = kinds
            .iter()
            .map(|&k| MicroEpScheduler::new(placement.clone(), None, opts(k)))
            .collect();
        let mut rng = Rng::new(fuzz_seed(4096));
        let zipf = Zipf::new(experts, 0.8);
        for batch in 0..3 {
            let lm = zipf_batch(&mut rng, &zipf, experts, gpus, 512);
            let outs: Vec<_> = scheds.iter_mut().map(|s| s.schedule(&lm)).collect();
            let base = outs[0].stats.lp_objective;
            assert!(
                base.is_finite(),
                "{gpus}x{experts} {mode:?} batch {batch}: LP fallback triggered"
            );
            for (k, out) in kinds.iter().zip(&outs) {
                assert!(
                    (out.stats.lp_objective - base).abs() < 1e-6 * (1.0 + base.abs()),
                    "{gpus}x{experts} {mode:?} batch {batch} {}: {} vs {}",
                    k.label(),
                    out.stats.lp_objective,
                    base
                );
                if batch > 0 {
                    assert!(
                        out.stats.warm,
                        "{gpus}x{experts} {mode:?} batch {batch} {}: warm path not taken",
                        k.label()
                    );
                }
                for e in 0..experts {
                    assert_eq!(
                        out.replica_loads[e].iter().sum::<u64>(),
                        lm.expert_load(e),
                        "{gpus}x{experts} {mode:?} batch {batch} {}: expert {e}",
                        k.label()
                    );
                }
            }
            if matches!(mode, ScheduleMode::Compute) {
                // independent oracle: the binary-search max-flow integer
                // optimum brackets the fractional LP optimum
                let fl = flow_schedule(&placement, &lm).max_load;
                assert!(
                    (base.ceil() as i64 - fl as i64).abs() <= 1,
                    "{gpus}x{experts} batch {batch}: LP {} vs flow {}",
                    base,
                    fl
                );
            }
        }
    }
}
