//! Differential testing: bounded-variable revised simplex vs dense
//! full-tableau simplex.
//!
//! The two backends implement the same mathematical contract through very
//! different machinery (implicit bounds + eta-updated B⁻¹ vs bound rows +
//! full tableau), which makes them near-perfect oracles for each other:
//! on every instance they must agree on feasibility classification and,
//! when an optimum exists, on the optimal objective to 1e-6. The suite
//! covers randomized LPP-1 / LPP-4 (CommAware) / TopoAware scheduling
//! instances end-to-end through `MicroEpScheduler`, plus raw-LP fuzz with
//! upper-bound edge cases (bound-tight optima, degenerate bounds at 0).

use micromoe::lp::{LpProblem, Relation, SimplexError, SolverKind, WarmSolver};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::topology::Topology;

fn zipf_batch(rng: &mut Rng, zipf: &Zipf, experts: usize, gpus: usize, per_gpu: usize) -> LoadMatrix {
    let mut lm = LoadMatrix::zeros(experts, gpus);
    for g in 0..gpus {
        for _ in 0..per_gpu {
            lm.add(zipf.sample(rng), g, 1);
        }
    }
    lm
}

/// Both backends, all three schedule modes, warm-started across batches:
/// objectives agree to 1e-6 and replica loads conserve expert totals.
#[test]
fn schedulers_agree_across_modes_and_batches() {
    let gpus = 8usize;
    let experts = 16usize;
    let placement = cayley_graph_placement(gpus, experts);
    let topo = Topology::new(gpus, 4, 2, 4); // 2 nodes of 4 GPUs
    let modes = [
        ScheduleMode::Compute,
        ScheduleMode::CommAware { alpha: 0.7 },
        ScheduleMode::TopoAware { alpha1: 0.1, alpha2: 1.0 },
    ];
    for mode in modes {
        let opts = |solver: SolverKind| SchedulerOptions {
            mode: mode.clone(),
            solver,
            topo_aware_routing: matches!(mode, ScheduleMode::TopoAware { .. }),
            ..Default::default()
        };
        let mut revised = MicroEpScheduler::new(
            placement.clone(),
            Some(topo.clone()),
            opts(SolverKind::Revised),
        );
        let mut tableau = MicroEpScheduler::new(
            placement.clone(),
            Some(topo.clone()),
            opts(SolverKind::DenseTableau),
        );
        let mut rng = Rng::new(42);
        let zipf = Zipf::new(experts, 0.9);
        for batch in 0..12 {
            let lm = zipf_batch(&mut rng, &zipf, experts, gpus, 1024);
            let a = revised.schedule(&lm);
            let b = tableau.schedule(&lm);
            assert!(
                a.stats.lp_objective.is_finite() && b.stats.lp_objective.is_finite(),
                "{mode:?} batch {batch}: LP fallback triggered (rev {}, tab {})",
                a.stats.lp_objective,
                b.stats.lp_objective
            );
            let scale = 1.0 + a.stats.lp_objective.abs();
            assert!(
                (a.stats.lp_objective - b.stats.lp_objective).abs() < 1e-6 * scale,
                "{mode:?} batch {batch}: revised {} vs tableau {}",
                a.stats.lp_objective,
                b.stats.lp_objective
            );
            if batch > 0 {
                assert!(a.stats.warm, "{mode:?} batch {batch}: revised warm path not taken");
                assert!(b.stats.warm, "{mode:?} batch {batch}: tableau warm path not taken");
            }
            for e in 0..experts {
                assert_eq!(
                    a.replica_loads[e].iter().sum::<u64>(),
                    lm.expert_load(e),
                    "{mode:?} batch {batch}: revised expert {e} total"
                );
                assert_eq!(
                    b.replica_loads[e].iter().sum::<u64>(),
                    lm.expert_load(e),
                    "{mode:?} batch {batch}: tableau expert {e} total"
                );
            }
        }
    }
}

/// Raw-LP fuzz: random rows of every relation plus random finite upper
/// bounds. Backends must agree on the error class or on the objective.
#[test]
fn random_instances_agree() {
    let mut rng = Rng::new(2024);
    let mut optima = 0usize;
    let mut infeasible = 0usize;
    let mut unbounded = 0usize;
    for case in 0..200 {
        let n = 2 + (case % 5);
        let m = 1 + (case % 6);
        let mut p = LpProblem::new(n);
        for j in 0..n {
            p.set_objective(j, rng.f64() * 4.0 - 2.0);
        }
        for j in 0..n {
            let r = rng.f64();
            if r < 0.25 {
                p.set_upper(j, rng.f64() * 4.0);
            } else if r < 0.35 {
                p.set_upper(j, 0.0); // degenerate bound at 0
            }
        }
        for _ in 0..m {
            let terms: Vec<(usize, f64)> = (0..n)
                .filter(|_| rng.f64() < 0.8)
                .map(|j| (j, rng.f64() * 2.0 - 0.5))
                .collect();
            if terms.is_empty() {
                continue;
            }
            let rel = match rng.below(4) {
                0 => Relation::Ge,
                1 => Relation::Eq,
                _ => Relation::Le,
            };
            p.add(terms, rel, rng.f64() * 6.0 - 1.0);
        }
        let a = micromoe::lp::revised::solve(&p);
        let b = micromoe::lp::simplex::solve(&p);
        match (a, b) {
            (Ok(sa), Ok(sb)) => {
                optima += 1;
                let scale = 1.0 + sa.objective.abs();
                assert!(
                    (sa.objective - sb.objective).abs() < 1e-6 * scale,
                    "case {case}: revised {} vs tableau {}",
                    sa.objective,
                    sb.objective
                );
                assert!(p.is_feasible(&sa.x, 1e-6), "case {case}: revised point infeasible");
                assert!(p.is_feasible(&sb.x, 1e-6), "case {case}: tableau point infeasible");
            }
            (Err(SimplexError::Infeasible(_)), Err(SimplexError::Infeasible(_))) => {
                infeasible += 1;
            }
            (Err(SimplexError::Unbounded), Err(SimplexError::Unbounded)) => {
                unbounded += 1;
            }
            (a, b) => panic!("case {case}: revised {a:?} vs tableau {b:?}"),
        }
    }
    // the generator must produce a healthy share of solvable instances;
    // the error-class tallies are informational (they vary with the seed)
    assert!(optima > 20, "only {optima} optima");
    eprintln!("differential fuzz: {optima} optima, {infeasible} infeasible, {unbounded} unbounded");
}

/// Bound-tight optimum: the argmax sits exactly on variable bounds, with
/// one variable pinned by a degenerate 0 bound.
#[test]
fn bound_tight_optimum_agrees() {
    // max 3a + 2b + 5c (min negative) s.t. a+b+c <= 10, a <= 4, b <= 2, c <= 0
    let mut p = LpProblem::new(3);
    p.set_objective(0, -3.0);
    p.set_objective(1, -2.0);
    p.set_objective(2, -5.0);
    p.set_upper(0, 4.0);
    p.set_upper(1, 2.0);
    p.set_upper(2, 0.0);
    p.add(vec![(0, 1.0), (1, 1.0), (2, 1.0)], Relation::Le, 10.0);
    let a = micromoe::lp::revised::solve(&p).unwrap();
    let b = micromoe::lp::simplex::solve(&p).unwrap();
    assert!((a.objective - (-16.0)).abs() < 1e-9, "revised {}", a.objective);
    assert!((b.objective - (-16.0)).abs() < 1e-9, "tableau {}", b.objective);
    assert!((a.x[0] - 4.0).abs() < 1e-9 && (a.x[1] - 2.0).abs() < 1e-9);
    assert!(a.x[2].abs() < 1e-9);
}

/// Warm bound updates through `WarmSolver` agree between backends over a
/// trajectory of correlated cap changes (the LPP-4 micro-batch pattern).
#[test]
fn warm_bound_trajectories_agree() {
    let build = || {
        // min comp s.t. comp >= x0 + x1, x0 + x1 = 6, x0 <= c0, x1 <= c1
        // (caps start permissive and move each "micro-batch")
        let mut p = LpProblem::new(3);
        p.set_objective(2, 1.0);
        p.add(vec![(0, 1.0), (1, 1.0), (2, -1.0)], Relation::Le, 0.0);
        p.add(vec![(0, 1.0), (1, 1.0)], Relation::Eq, 6.0);
        p.set_upper(0, 6.0);
        p.set_upper(1, 6.0);
        p
    };
    let mut wa = WarmSolver::with_kind(build(), SolverKind::Revised);
    let mut wb = WarmSolver::with_kind(build(), SolverKind::DenseTableau);
    wa.solve_cold().unwrap();
    wb.solve_cold().unwrap();
    let mut rng = Rng::new(9);
    for round in 0..25 {
        let c0 = rng.f64() * 6.0;
        let c1 = (6.0 - c0).max(0.0) + rng.f64() * 3.0;
        let load = 2.0 + rng.f64() * (c0 + c1 - 2.0).max(0.1);
        let rhs = [(1usize, load.min(c0 + c1))];
        let caps = [(0usize, c0), (1usize, c1)];
        let sa = wa.resolve_with_bounds(&rhs, &caps);
        let sb = wb.resolve_with_bounds(&rhs, &caps);
        match (sa, sb) {
            (Ok(sa), Ok(sb)) => {
                assert!(
                    (sa.objective - sb.objective).abs() < 1e-6,
                    "round {round}: revised {} vs tableau {}",
                    sa.objective,
                    sb.objective
                );
            }
            (Err(SimplexError::Infeasible(_)), Err(SimplexError::Infeasible(_))) => {}
            (sa, sb) => panic!("round {round}: revised {sa:?} vs tableau {sb:?}"),
        }
    }
}
