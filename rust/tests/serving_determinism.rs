//! Serving-tier determinism: with [`SolveCost::Virtual`] an entire serving
//! run — request trace, per-window plans (including routes), and
//! [`SlaStats`] down to the P² marker heights — is a pure function of
//! `(process, token model, seed, config)`.
//!
//! Pinned here:
//! * identical `ARRIVAL_SEED` ⇒ bit-identical request traces,
//! * re-running the same server ⇒ bit-identical [`ServingTrace`] and
//!   [`SlaStats`] (both `PartialEq`, compared whole),
//! * the engine worker count (barrier, 1, 2, 8 workers) changes *nothing*:
//!   layer `l` pins to worker `l % workers`, so single-layer decode steps
//!   always solve on worker 0 with identical warm state.
//!
//! Override the trace seed with `ARRIVAL_SEED=<seed>` to replay a failure
//! (the seed used is printed and surfaced by libtest on failure).

use micromoe::balancer::MoeSession;
use micromoe::engine::EngineMode;
use micromoe::serving::{
    arrival_seed, ArrivalGen, ArrivalProcess, DispatchCost, Request, ServingConfig, ServingTrace,
    SlaStats, SolveCost, TokenModel,
};
use micromoe::topology::Topology;
use micromoe::workload::TopicMix;

const DEFAULT_SEED: u64 = 0xA221;

fn process() -> ArrivalProcess {
    ArrivalProcess::Bursty {
        calm_hz: 6_000.0,
        burst_hz: 60_000.0,
        mean_calm_us: 10_000.0,
        mean_burst_us: 3_000.0,
    }
}

fn trace_reqs(seed: u64) -> Vec<Request> {
    ArrivalGen::new(process(), TokenModel::Ramp { base: 16, step: 8, every: 40 }, seed).take(600)
}

fn cfg() -> ServingConfig {
    ServingConfig {
        window_us: 400.0,
        max_batch: 24,
        slo_us: 4_000.0,
        // sustained ~3x overload: service >= 3 ms per <= 24-request window
        // against a ~18.5k req/s MMPP, so queues grow and shedding is
        // exercised on every seed
        shed_after_us: 2_000.0,
        solve_cost: SolveCost::Virtual { us: 3_000.0 },
        dispatch_cost: DispatchCost::PerToken { fixed_us: 16.0, us_per_token: 0.125 },
    }
}

/// Serve the trace through the LPP policy; `workers == 0` means barrier,
/// otherwise the pipelined engine with that worker count.
fn serve(workers: usize, reqs: &[Request]) -> (ServingTrace, SlaStats) {
    let mut b = MoeSession::builder()
        .topology(Topology::new(8, 4, 2, 8))
        .experts(16)
        .policy_name("micromoe");
    if workers > 0 {
        b = b.engine(EngineMode::Pipeline { workers, inflight: 2 });
    }
    let mut server = b.build().unwrap().serve(cfg(), TopicMix::new(16, 1.1, 8, 42));
    let trace = server.run(reqs);
    let sla = server.sla().clone();
    (trace, sla)
}

#[test]
fn identical_seed_identical_request_trace() {
    let seed = arrival_seed(DEFAULT_SEED);
    let a = trace_reqs(seed);
    let b = trace_reqs(seed);
    assert_eq!(a, b, "same seed must reproduce the trace bit-for-bit");
    let c = trace_reqs(seed ^ 1);
    assert_ne!(a, c, "a different seed must produce a different trace");
}

#[test]
fn rerun_is_bit_identical() {
    let seed = arrival_seed(DEFAULT_SEED);
    let reqs = trace_reqs(seed);
    let (trace_a, sla_a) = serve(0, &reqs);
    let (trace_b, sla_b) = serve(0, &reqs);
    assert_eq!(trace_a, trace_b, "re-run changed the serving trace");
    assert_eq!(sla_a, sla_b, "re-run changed the SLO accounting");
    assert!(trace_a.windows.iter().any(|w| !w.routes.is_empty()), "trace exercised routing");
}

#[test]
fn engine_worker_count_changes_nothing() {
    let seed = arrival_seed(DEFAULT_SEED);
    let reqs = trace_reqs(seed);
    let (barrier_trace, barrier_sla) = serve(0, &reqs);
    assert!(barrier_sla.served > 0 && barrier_sla.shed > 0, "trace must exercise shedding");
    assert_eq!(barrier_sla.accounted(), 600, "conservation under overload");
    for workers in [1usize, 2, 8] {
        let (trace, sla) = serve(workers, &reqs);
        assert_eq!(
            trace, barrier_trace,
            "{workers}-worker engine diverged from the barrier serving trace"
        );
        assert_eq!(sla, barrier_sla, "{workers}-worker engine diverged on SlaStats");
    }
}
