//! Golden test: the rust [`LoadForecaster`] vs its numpy reference.
//!
//! `python/tools/forecast_reference.py` transliterates the forecaster
//! (EMA + sliding-window blend, half-up rounding, normalized-L1 drift and
//! the hit/miss threshold decision), self-tests it against numpy, and
//! records deterministic multinomial load sequences with the reference's
//! predictions and decisions in `tests/golden_forecast.json`. Replaying
//! the sequences here must reproduce every recorded value — the two
//! implementations mirror each other operation for operation, so dense
//! predictions agree to float precision and every rounded forecast,
//! drift, and hit/miss decision matches exactly.
//!
//! The fixture is committed; a missing file is a hard failure (regenerate
//! with the tool above and commit the result).

use micromoe::engine::{ForecastConfig, LoadForecaster};
use micromoe::scheduler::LoadMatrix;
use micromoe::ser::Json;

fn fixture() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_forecast.json");
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        panic!(
            "{path} missing ({e}) — regenerate with \
             python/tools/forecast_reference.py and commit"
        )
    });
    Json::parse(&text).unwrap()
}

fn lm_from_json(j: &Json, e: usize, g: usize) -> LoadMatrix {
    let rows = j.as_arr().unwrap();
    assert_eq!(rows.len(), e, "fixture row count");
    let mut lm = LoadMatrix::zeros(e, g);
    for (ei, row) in rows.iter().enumerate() {
        let cells = row.as_arr().unwrap();
        assert_eq!(cells.len(), g, "fixture column count");
        for (gi, c) in cells.iter().enumerate() {
            lm.set(ei, gi, c.as_f64().unwrap() as u64);
        }
    }
    lm
}

#[test]
fn forecaster_matches_numpy_reference() {
    let fx = fixture();
    let cases = fx.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 4, "suspiciously few forecast cases");
    let mut hits = 0u64;
    let mut misses = 0u64;
    for case in cases {
        let name = case.get("name").unwrap().as_str().unwrap();
        let e = case.get("experts").unwrap().as_usize().unwrap();
        let g = case.get("gpus").unwrap().as_usize().unwrap();
        let cfg_j = case.get("cfg").unwrap();
        let cfg = ForecastConfig {
            ema_alpha: cfg_j.get("ema_alpha").unwrap().as_f64().unwrap(),
            window: cfg_j.get("window").unwrap().as_usize().unwrap(),
            blend: cfg_j.get("blend").unwrap().as_f64().unwrap(),
            drift_threshold: cfg_j.get("drift_threshold").unwrap().as_f64().unwrap(),
            min_history: cfg_j.get("min_history").unwrap().as_usize().unwrap(),
        };
        let loads: Vec<LoadMatrix> = case
            .get("loads")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| lm_from_json(b, e, g))
            .collect();
        let steps = case.get("steps").unwrap().as_arr().unwrap();
        let mut f = LoadForecaster::new(e, g, cfg);
        let mut si = 0usize;
        for t in 0..loads.len() - 1 {
            f.observe(&loads[t]);
            let Some(dense) = f.forecast_dense() else {
                continue; // warmup: the reference recorded nothing either
            };
            let step = &steps[si];
            assert_eq!(
                step.get("t").unwrap().as_usize().unwrap(),
                t,
                "{name}: forecast availability diverged from the reference"
            );
            let want_dense = step.get("dense").unwrap().as_arr().unwrap();
            assert_eq!(dense.len(), want_dense.len(), "{name} t={t}");
            for (i, (a, w)) in dense.iter().zip(want_dense).enumerate() {
                let w = w.as_f64().unwrap();
                assert!(
                    (a - w).abs() <= 1e-9 * (1.0 + w.abs()),
                    "{name} t={t} cell {i}: dense {a} vs reference {w}"
                );
            }
            let pred = f.forecast().unwrap();
            let want_pred = lm_from_json(step.get("pred").unwrap(), e, g);
            assert_eq!(pred, want_pred, "{name} t={t}: rounded forecast diverged");
            let drift = LoadForecaster::drift(&pred, &loads[t + 1]);
            let want_drift = step.get("drift").unwrap().as_f64().unwrap();
            assert!(
                (drift - want_drift).abs() <= 1e-9 * (1.0 + want_drift),
                "{name} t={t}: drift {drift} vs reference {want_drift}"
            );
            let hit = f.is_hit(&pred, &loads[t + 1]);
            assert_eq!(
                hit,
                step.get("hit").unwrap().as_bool().unwrap(),
                "{name} t={t}: hit/miss decision flipped (drift {drift})"
            );
            if hit {
                hits += 1;
            } else {
                misses += 1;
            }
            si += 1;
        }
        assert_eq!(si, steps.len(), "{name}: fixture has unreplayed steps");
    }
    assert!(
        hits > 0 && misses > 0,
        "fixture no longer exercises both decisions (hits {hits}, misses {misses})"
    );
}
