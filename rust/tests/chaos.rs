//! Deterministic fault-injection (chaos) suite — ISSUE-6's robustness
//! acceptance criteria.
//!
//! A seeded [`FaultPlan`] injects worker panics, budget starvation,
//! NaN/overflow load poisoning, and forced infeasibility into a live
//! [`MoeSession`]; the session must still emit a feasible plan for every
//! layer of every step, never panic or deadlock, and its
//! `DegradationStats` must match the injected plan exactly. Replay a CI
//! failure with `FAULT_SEED=<seed> cargo test --test chaos` (the seed is
//! printed by every run, surfaced by libtest on failure).

use std::sync::Arc;

use micromoe::balancer::{MoeSession, StepOutput};
use micromoe::engine::EngineMode;
use micromoe::faults::{fault_seed, Fault, FaultPlan};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::placement::Placement;
use micromoe::prop::forall;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{fallback, LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::topology::Topology;

const EXPERTS: usize = 16;
const GPUS: usize = 8;

fn topo() -> Topology {
    Topology::new(8, 4, 2, 8)
}

fn zipf_lm(seed: u64, per_gpu: u64, s: f64) -> LoadMatrix {
    let mut rng = Rng::new(seed);
    let z = Zipf::new(EXPERTS, s);
    let mut lm = LoadMatrix::zeros(EXPERTS, GPUS);
    for g in 0..GPUS {
        for _ in 0..per_gpu {
            lm.add(z.sample(&mut rng), g, 1);
        }
    }
    lm
}

fn session_with(plan: Option<FaultPlan>, workers: usize, layers: usize) -> MoeSession {
    let opts = SchedulerOptions {
        engine: EngineMode::Pipeline { workers, inflight: 2 },
        faults: plan.map(Arc::new),
        ..Default::default()
    };
    MoeSession::builder()
        .topology(topo())
        .experts(EXPERTS)
        .policy_name("micromoe")
        .options(opts)
        .layers(layers)
        .build()
        .expect("chaos session builds")
}

/// Every layer of a step must be present and conserve the batch's tokens,
/// no matter what was injected.
fn assert_step_feasible(out: &StepOutput, loads: &[LoadMatrix], step: usize) {
    assert_eq!(out.layers.len(), loads.len(), "step {step}: missing layers");
    for (l, (plan, lm)) in out.layers.iter().zip(loads).enumerate() {
        assert_eq!(
            plan.gpu_compute.iter().sum::<u64>(),
            lm.total(),
            "step {step} layer {l}: plan lost tokens"
        );
    }
}

/// The headline chaos run: a seeded fault plan over a pipelined session.
/// Feasible output every layer/step, and the final `DegradationStats`
/// match the plan exactly — every scheduler-level fault lands on the
/// greedy rung (budget starvations also counted by reason), worker panics
/// are recovered by respawn without ever degrading below the LP rungs.
#[test]
fn seeded_fault_plan_degrades_exactly_as_injected() {
    const STEPS: usize = 20;
    const LAYERS: usize = 4;
    let seed = fault_seed(0x0C4A05);
    let plan = FaultPlan::from_seed(seed, STEPS, LAYERS, 0.3);
    assert!(!plan.is_empty(), "density 0.3 over {} slots injected nothing", STEPS * LAYERS);

    // expected degradation, simulated straight from the plan
    let mut expect_greedy = 0u64;
    let mut expect_budget_pivots = 0u64;
    for &(_, _, fault) in plan.faults() {
        if !fault.is_worker_fault() {
            expect_greedy += 1;
        }
        if fault == Fault::BudgetStarvation {
            expect_budget_pivots += 1;
        }
    }

    let mut session = session_with(Some(plan), 2, LAYERS);
    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> = (0..LAYERS)
            .map(|l| zipf_lm(seed ^ (step * LAYERS + l) as u64, 900, 1.0))
            .collect();
        let out = session.step(&loads);
        assert_step_feasible(&out, &loads, step);
        assert_eq!(
            out.stats.degradation.total(),
            LAYERS as u64,
            "step {step}: every layer records exactly one rung"
        );
    }

    let st = session.stats().degradation;
    let total = (STEPS * LAYERS) as u64;
    assert_eq!(st.total(), total, "one rung per layer per step: {st:?}");
    assert_eq!(st.greedy, expect_greedy, "greedy rung != injected scheduler faults: {st:?}");
    assert_eq!(st.passthrough, 0, "no persistent panics were injected: {st:?}");
    assert_eq!(st.budget_pivots, expect_budget_pivots, "starvation counts: {st:?}");
    assert_eq!(st.budget_refactors, 0, "{st:?}");
    assert_eq!(st.budget_wall, 0, "no wall-clock budget was set: {st:?}");
    assert_eq!(st.warm_lp + st.cold_lp, total - expect_greedy, "LP rungs cover the rest: {st:?}");
    assert!(st.fallback_excess_sum.is_finite() && st.fallback_excess_sum >= 0.0, "{st:?}");
}

/// Zero faults + unlimited budget must be bit-identical to a session with
/// no fault plan at all — the robustness machinery is inert by default.
#[test]
fn empty_fault_plan_is_bit_identical_to_none() {
    const LAYERS: usize = 3;
    let mut plain = session_with(None, 2, LAYERS);
    let mut chaos = session_with(Some(FaultPlan::empty()), 2, LAYERS);
    for step in 0..4 {
        let loads: Vec<LoadMatrix> =
            (0..LAYERS).map(|l| zipf_lm(77 + (step * LAYERS + l) as u64, 700, 0.9)).collect();
        let a = plain.step(&loads);
        let b = chaos.step(&loads);
        for (l, (pa, pb)) in a.layers.iter().zip(&b.layers).enumerate() {
            assert_eq!(pa.routes, pb.routes, "step {step} layer {l}");
            assert_eq!(pa.gpu_compute, pb.gpu_compute, "step {step} layer {l}");
        }
    }
    let st = chaos.stats().degradation;
    assert_eq!(st.fallbacks(), 0, "no injected fault may degrade a plan: {st:?}");
    assert_eq!(plain.stats().degradation, st, "rung accounting must match too");
}

/// One-shot worker panics: the pool respawns the worker, replays its jobs,
/// and the session keeps emitting LP plans (never a fallback rung) — at
/// the price of cold re-solves on the respawned worker's layers.
#[test]
fn worker_panics_recover_without_leaving_the_lp_rungs() {
    const STEPS: usize = 4;
    const LAYERS: usize = 4;
    let plan = FaultPlan::with_faults(vec![
        (1, 0, Fault::WorkerPanic { persistent: false }),
        (2, 3, Fault::WorkerPanic { persistent: false }),
    ]);
    let mut session = session_with(Some(plan), 2, LAYERS);
    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> =
            (0..LAYERS).map(|l| zipf_lm(300 + (step * LAYERS + l) as u64, 800, 1.1)).collect();
        let out = session.step(&loads);
        assert_step_feasible(&out, &loads, step);
    }
    let st = session.stats().degradation;
    assert_eq!(st.total(), (STEPS * LAYERS) as u64, "{st:?}");
    assert_eq!(st.fallbacks(), 0, "panics respawn onto LP rungs, not fallbacks: {st:?}");
    // step 0 starts every layer cold, and each panic rebuilds its worker's
    // schedulers cold — so strictly more cold solves than the fault-free
    // baseline's initial ones
    assert!(st.cold_lp > LAYERS as u64, "respawns must re-solve cold: {st:?}");
}

/// A persistently dying worker exhausts the respawn limit; the session
/// still covers every layer of every step via passthrough plans — the
/// ladder's terminal rung — instead of hanging or panicking.
#[test]
fn respawn_limit_degrades_to_passthrough_but_still_plans() {
    const LAYERS: usize = 2;
    let plan =
        FaultPlan::with_faults(vec![(0, 0, Fault::WorkerPanic { persistent: true })]);
    let mut session = session_with(Some(plan), 1, LAYERS);
    for step in 0..2 {
        let loads: Vec<LoadMatrix> =
            (0..LAYERS).map(|l| zipf_lm(500 + (step * LAYERS + l) as u64, 600, 1.0)).collect();
        let out = session.step(&loads);
        assert_step_feasible(&out, &loads, step);
    }
    let st = session.stats().degradation;
    assert_eq!(st.passthrough, (2 * LAYERS) as u64, "dead engine => all passthrough: {st:?}");
    assert_eq!(st.total(), (2 * LAYERS) as u64, "{st:?}");
}

/// Serving under chaos: worker panics and budget starvation strike
/// mid-stream while the batching-window loop is live. The server must keep
/// emitting feasible per-window plans (token-exact for the LPP policy),
/// its SLO accounting must stay conservative (every request served or shed,
/// one e2e sample per served request), and the session's
/// `DegradationStats` must record exactly one rung per non-empty window
/// with the injected faults landing on the expected rungs.
#[test]
fn serving_survives_worker_panics_and_budget_starvation_mid_stream() {
    use micromoe::serving::{
        ArrivalGen, ArrivalProcess, DispatchCost, ServingConfig, SolveCost, TokenModel,
    };
    use micromoe::workload::TopicMix;

    // non-empty windows drive the session step counter one-for-one (empty
    // windows never step), so these (step, layer=0) slots hit the 2nd, 4th
    // and 7th served windows
    let plan = FaultPlan::with_faults(vec![
        (1, 0, Fault::BudgetStarvation),
        (3, 0, Fault::WorkerPanic { persistent: false }),
        (6, 0, Fault::BudgetStarvation),
    ]);
    let session = session_with(Some(plan), 2, 1);

    let reqs = ArrivalGen::new(
        ArrivalProcess::Poisson { rate_hz: 20_000.0 },
        TokenModel::Fixed(48),
        0xC4A05,
    )
    .take(400);
    let cfg = ServingConfig {
        window_us: 400.0,
        max_batch: 32,
        slo_us: 2_000.0,
        shed_after_us: f64::INFINITY, // nothing shed => every request planned
        solve_cost: SolveCost::Virtual { us: 50.0 },
        dispatch_cost: DispatchCost::PerToken { fixed_us: 10.0, us_per_token: 0.25 },
    };
    let mut server = session.serve(cfg, TopicMix::new(EXPERTS, 1.1, 8, 9));
    let trace = server.run(&reqs);

    let non_empty: Vec<_> = trace.windows.iter().filter(|w| !w.served.is_empty()).collect();
    assert!(non_empty.len() >= 8, "need >= 8 served windows, got {}", non_empty.len());
    for w in &non_empty {
        // LPP plans are token-exact even on the greedy rung
        assert_eq!(
            w.gpu_compute.iter().sum::<u64>(),
            w.tokens,
            "window {}: plan lost tokens under chaos",
            w.index
        );
    }

    let sla = server.sla();
    assert_eq!(sla.arrived, 400, "arrived");
    assert_eq!(sla.served, 400, "infinite shed_after must serve everything");
    assert_eq!(sla.shed, 0);
    assert_eq!(sla.e2e.count(), 400, "one e2e sample per served request");
    assert_eq!(sla.windows, trace.windows.len() as u64);

    // DegradationStats consistent with SlaStats: one rung per non-empty
    // window, faults on the expected rungs
    let st = server.session().stats().degradation;
    assert_eq!(st.total(), non_empty.len() as u64, "one rung per served window: {st:?}");
    assert_eq!(st.total(), sla.windows - sla.empty_windows, "{st:?}");
    assert_eq!(st.greedy, 2, "both starvations land on the greedy rung: {st:?}");
    assert_eq!(st.budget_pivots, 2, "{st:?}");
    assert_eq!(st.passthrough, 0, "one-shot panic respawns, never passthrough: {st:?}");
    assert!(st.cold_lp >= 2, "initial cold solve + post-respawn re-solve: {st:?}");
}

/// ISSUE-9 acceptance: on a seeded chaos run, the recorded span set is the
/// exact ledger of the stats structs — solve spans bucketed by rung
/// reproduce `DegradationStats`, engine spans count every in-order
/// emission, and respawn markers count every injected worker panic.
#[test]
fn seeded_chaos_trace_reconciles_with_stats() {
    use micromoe::obs::{Span, TraceConfig, Tracer};
    use micromoe::stats::DegradationRung;

    const STEPS: usize = 20;
    const LAYERS: usize = 4;
    let seed = fault_seed(0x0C4A06);
    let plan = FaultPlan::from_seed(seed, STEPS, LAYERS, 0.3);
    let worker_faults =
        plan.faults().iter().filter(|(_, _, f)| f.is_worker_fault()).count();

    let tracer = Tracer::new(TraceConfig::Wall);
    let opts = SchedulerOptions {
        engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
        faults: Some(Arc::new(plan)),
        trace: tracer.clone(),
        ..Default::default()
    };
    let mut session = MoeSession::builder()
        .topology(topo())
        .experts(EXPERTS)
        .policy_name("micromoe")
        .options(opts)
        .layers(LAYERS)
        .build()
        .expect("chaos session builds");
    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> = (0..LAYERS)
            .map(|l| zipf_lm(seed ^ (step * LAYERS + l) as u64, 900, 1.0))
            .collect();
        let out = session.step(&loads);
        assert_step_feasible(&out, &loads, step);
    }

    let st = session.stats().degradation;
    let es = session.engine_stats().expect("pipeline engine");
    let evs = tracer.events();

    let (mut warm, mut cold, mut greedy, mut pass) = (0u64, 0u64, 0u64, 0u64);
    let mut engine_spans = 0u64;
    let mut respawns = 0usize;
    for e in &evs {
        match &e.span {
            Span::Solve { rung, .. } => match rung {
                DegradationRung::WarmLp => warm += 1,
                DegradationRung::ColdLp => cold += 1,
                DegradationRung::Greedy => greedy += 1,
                DegradationRung::Passthrough => pass += 1,
            },
            Span::Engine { .. } => engine_spans += 1,
            Span::WorkerRespawn { .. } => respawns += 1,
            _ => {}
        }
    }
    assert_eq!(warm, st.warm_lp, "warm-lp spans != stats: {st:?}");
    assert_eq!(cold, st.cold_lp, "cold-lp spans != stats: {st:?}");
    assert_eq!(greedy, st.greedy, "greedy spans != stats: {st:?}");
    assert_eq!(pass, st.passthrough, "passthrough spans != stats: {st:?}");
    assert_eq!(warm + cold + greedy + pass, st.total(), "{st:?}");
    assert_eq!(engine_spans, es.schedules, "one engine span per emission: {es:?}");
    assert_eq!(respawns, worker_faults, "one respawn span per one-shot panic");
}

/// Each recovered worker panic leaves exactly one respawn marker in the
/// trace, and span ids stay globally unique across the discontinuity (the
/// respawned schedulers record into the same shared buffer).
#[test]
fn respawn_spans_mark_each_recovery() {
    use micromoe::obs::{Span, TraceConfig, Tracer};

    const STEPS: usize = 4;
    const LAYERS: usize = 4;
    let plan = FaultPlan::with_faults(vec![
        (1, 0, Fault::WorkerPanic { persistent: false }),
        (2, 3, Fault::WorkerPanic { persistent: false }),
    ]);
    let tracer = Tracer::new(TraceConfig::Wall);
    let opts = SchedulerOptions {
        engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
        faults: Some(Arc::new(plan)),
        trace: tracer.clone(),
        ..Default::default()
    };
    let mut session = MoeSession::builder()
        .topology(topo())
        .experts(EXPERTS)
        .policy_name("micromoe")
        .options(opts)
        .layers(LAYERS)
        .build()
        .expect("chaos session builds");
    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> =
            (0..LAYERS).map(|l| zipf_lm(300 + (step * LAYERS + l) as u64, 800, 1.1)).collect();
        let out = session.step(&loads);
        assert_step_feasible(&out, &loads, step);
    }

    let evs = tracer.events();
    let respawns: Vec<_> = evs
        .iter()
        .filter_map(|e| match &e.span {
            Span::WorkerRespawn { worker, attempt } => Some((*worker, *attempt)),
            _ => None,
        })
        .collect();
    assert_eq!(respawns.len(), 2, "one marker per injected panic: {respawns:?}");
    for &(_, attempt) in &respawns {
        assert_eq!(attempt, 1, "one-shot panics respawn once: {respawns:?}");
    }

    let mut ids: Vec<u64> = evs.iter().map(|e| e.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), evs.len(), "span ids must survive respawn uniquely");
}

/// Placement controller under chaos (ISSUE-10): scheduler-level faults and
/// a (barrier-inert) worker panic strike a controller-enabled session while
/// migrations land mid-run. Every step must stay feasible, and the control
/// loop must be completely blind to the faults — `ControlStats` is driven
/// by the raw load trace alone, so a faulted run and a fault-free run make
/// bit-identical placement decisions while their scheduling rungs diverge.
#[test]
fn controller_chaos_faults_steer_scheduling_never_control() {
    use micromoe::cluster::CostModel;
    use micromoe::control::ControlSpec;

    const STEPS: usize = 16;
    const LAYERS: usize = 2;
    // all scheduler-level slots fire before the first control tick (step 4)
    // so they cannot be skipped by a placement-change scheduler rebuild
    // (a rebuilt layer restarts its fault clock); the WorkerPanic slot must
    // be inert — the barrier engine has no workers to kill
    let faults = vec![
        (1, 0, Fault::NanLoads),
        (2, 1, Fault::ForceInfeasible),
        (2, 0, Fault::WorkerPanic { persistent: false }),
        (3, 0, Fault::BudgetStarvation),
    ];
    let spec = ControlSpec { interval: 4, dwell: 2, ..Default::default() };
    let build = |plan: Option<FaultPlan>| {
        let opts = SchedulerOptions { faults: plan.map(Arc::new), ..Default::default() };
        MoeSession::builder()
            .topology(topo())
            .experts(EXPERTS)
            .policy_name("micromoe")
            .options(opts)
            .layers(LAYERS)
            .control(spec.clone())
            .migration_cost(CostModel::h100_testbed(), 1 << 22)
            .build()
            .expect("controlled chaos session builds")
    };
    let mut chaos = build(Some(FaultPlan::with_faults(faults)));
    let mut clean = build(None);
    assert!(chaos.engine_stats().is_none(), "controller runs on the barrier engine");

    for step in 0..STEPS {
        let loads: Vec<LoadMatrix> = (0..LAYERS)
            .map(|l| zipf_lm(0xC0DE ^ (step * LAYERS + l) as u64, 900, 1.4))
            .collect();
        let a = chaos.step(&loads);
        let b = clean.step(&loads);
        assert_step_feasible(&a, &loads, step);
        assert_step_feasible(&b, &loads, step);
        // identical control accounting step by step, faults or not
        assert_eq!(a.stats.control, b.stats.control, "step {step}: control diverged");
    }

    let (sa, sb) = (chaos.stats(), clean.stats());
    assert_eq!(sa.control, sb.control, "faults must never steer the controller");
    assert_eq!(sa.control.ticks, (STEPS / 4) as u64, "one tick per interval");
    assert!(sa.control.decisions > 0, "zipf 1.4 skew must trigger migrations: {:?}", sa.control);
    assert!(sa.control.downtime > 0.0 && sa.control.bytes > 0, "{:?}", sa.control);

    // scheduling, by contrast, must have degraded exactly where injected:
    // the three scheduler faults land on the greedy rung (possibly again on
    // a rebuilt layer's restarted fault clock), the panic slot is a no-op
    let (da, db) = (sa.degradation, sb.degradation);
    assert_eq!(da.total(), (STEPS * LAYERS) as u64, "one rung per layer per step: {da:?}");
    assert_eq!(db.total(), (STEPS * LAYERS) as u64, "{db:?}");
    assert!(da.greedy >= 3, "injected scheduler faults must hit the greedy rung: {da:?}");
    assert_eq!(db.greedy, 0, "fault-free run must stay on the LP rungs: {db:?}");
    assert_eq!(da.passthrough, 0, "barrier mode has no workers to lose: {da:?}");
    assert_eq!(db.passthrough, 0, "{db:?}");
    // warm-basis invalidation is controller-driven and thus identical:
    // initial cold solves plus exactly one per placement decision
    assert_eq!(db.cold_lp, LAYERS as u64 + sb.control.decisions, "{db:?}");
}

fn used_gpus(p: &Placement) -> usize {
    let mut used = vec![false; p.num_gpus];
    for grp in &p.replicas {
        for &g in grp {
            used[g] = true;
        }
    }
    used.iter().filter(|&&u| u).count().max(1)
}

/// Property (satellite d): the greedy fallback is always feasible, and on
/// instances where the LP also solves, its max GPU load stays within the
/// proven `G_used / R_min` factor of the LP objective (see
/// `scheduler::fallback`'s module docs for the derivation).
#[test]
fn greedy_fallback_is_feasible_and_within_proven_bound_of_lp() {
    forall("greedy fallback vs LP", 40, |rng, _case| {
        let gpus = 4 + 2 * rng.below(3) as usize; // 4, 6, or 8
        let experts = 2 * gpus;
        let p = cayley_graph_placement(gpus, experts);
        let z = Zipf::new(experts, 0.5 + rng.f64());
        let mut lm = LoadMatrix::zeros(experts, gpus);
        for _ in 0..(400 + rng.below(2600)) {
            let g = rng.below(gpus as u64) as usize;
            lm.add(z.sample(rng), g, 1);
        }

        // feasibility: non-negative, conserves every expert's load
        let frac = fallback::greedy_fraction(&p, &lm, &[]);
        let mut gpu_load = vec![0.0f64; gpus];
        for (e, grp) in p.replicas.iter().enumerate() {
            let sum: f64 = frac[e].iter().sum();
            assert!(
                (sum - lm.expert_load(e) as f64).abs() < 1e-6,
                "expert {e}: greedy assigned {sum} of {}",
                lm.expert_load(e)
            );
            for (r, &g) in grp.iter().enumerate() {
                assert!(frac[e][r] >= 0.0, "expert {e} replica {r} negative");
                gpu_load[g] += frac[e][r];
            }
        }
        let greedy_max = gpu_load.iter().cloned().fold(0.0, f64::max);

        // unconditional half of the bound: greedy_max <= T / R_min
        let r_min = (0..experts).map(|e| p.replica_count(e)).min().unwrap();
        assert!(
            greedy_max <= lm.total() as f64 / r_min as f64 + 1e-6,
            "greedy max {greedy_max} breaks T/R_min"
        );

        // vs the LP, where it solves: greedy_max <= OPT * G_used / R_min
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        let opt = sched.stats.lp_objective;
        if opt.is_finite() && opt > 0.0 {
            let factor = used_gpus(&p) as f64 / r_min as f64;
            assert!(
                greedy_max <= opt * factor + 1e-6,
                "greedy max {greedy_max} > LP opt {opt} x {factor}"
            );
        }
    });
}
