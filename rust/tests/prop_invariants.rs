//! Property-based invariants across the MicroEP core (own `prop` helper;
//! proptest is unavailable offline). Every property runs over hundreds of
//! seeded random cases; failures report a replayable seed.

use micromoe::placement::asymmetric::{asymmetric_placement, greedy_replica_counts};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::placement::graph::{max_induced_density_exact, perfect_balance_bound};
use micromoe::placement::random::random_placement;
use micromoe::placement::Placement;
use micromoe::prop::{forall, forall_sizes};
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::routing::check_routes;
use micromoe::scheduler::{
    LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions,
};
use micromoe::topology::Topology;

fn random_loadmatrix(rng: &mut Rng, e: usize, g: usize, tokens: u64, skew: f64) -> LoadMatrix {
    let z = Zipf::new(e, skew);
    let mut lm = LoadMatrix::zeros(e, g);
    for gi in 0..g {
        for _ in 0..tokens {
            lm.add(z.sample(rng), gi, 1);
        }
    }
    lm
}

fn random_small_placement(rng: &mut Rng) -> Placement {
    let g = 4 + 2 * (rng.below(3) as usize); // 4, 6, 8
    let e = g * (1 + rng.below(3) as usize); // g..3g
    random_placement(g, e, 2, rng)
}

/// Eq. 3: for every placement and load vector, the LP optimum equals the
/// maximum induced subgraph density — the paper's central identity.
#[test]
fn prop_lp_objective_is_eq3_density() {
    forall("eq3 identity", 120, |rng, _| {
        let p = random_small_placement(rng);
        let skew = rng.f64() * 2.0;
        let lm = random_loadmatrix(rng, p.num_experts, p.num_gpus, 200, skew);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        let loads: Vec<f64> = lm.expert_loads().iter().map(|&l| l as f64).collect();
        let density = max_induced_density_exact(&p, &loads).density;
        assert!(
            (sched.stats.lp_objective - density).abs() < 1e-5 * (1.0 + density),
            "LP {} != density {}",
            sched.stats.lp_objective,
            density
        );
    });
}

/// Token conservation: every schedule routes every token exactly once and
/// replica loads match their budgets.
#[test]
fn prop_schedule_conserves_tokens() {
    forall("conservation", 150, |rng, case| {
        let p = random_small_placement(rng);
        let skew = rng.f64() * 1.5;
        let lm = random_loadmatrix(rng, p.num_experts, p.num_gpus, 150, skew);
        let locality = case % 2 == 0;
        let mut s = MicroEpScheduler::new(
            p.clone(),
            None,
            SchedulerOptions { locality_aware: locality, ..Default::default() },
        );
        let sched = s.schedule(&lm);
        check_routes(&p, &lm, &sched.replica_loads, &sched.routes).unwrap();
        // gpu loads sum == total tokens
        assert_eq!(sched.gpu_loads(&p).iter().sum::<u64>(), lm.total());
    });
}

/// Integer rounding changes the optimal max by less than the max number of
/// experts resident on any GPU.
#[test]
fn prop_rounding_slack_bounded() {
    forall("rounding slack", 100, |rng, _| {
        let p = random_small_placement(rng);
        let skew = rng.f64();
        let lm = random_loadmatrix(rng, p.num_experts, p.num_gpus, 300, skew);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        let max_resident = (0..p.num_gpus).map(|g| p.slots_used(g)).max().unwrap() as f64;
        assert!(
            (sched.stats.max_gpu_load as f64) < sched.stats.lp_objective + max_resident + 1.0,
            "rounded {} vs LP {} (+{max_resident})",
            sched.stats.max_gpu_load,
            sched.stats.lp_objective
        );
    });
}

/// Warm-started solves reach the same objective as cold solves.
#[test]
fn prop_warm_equals_cold() {
    forall("warm == cold", 40, |rng, _| {
        let p = random_small_placement(rng);
        let mut warm = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let mut cold = MicroEpScheduler::new(
            p,
            None,
            SchedulerOptions { warm_start: false, ..Default::default() },
        );
        for _ in 0..6 {
            let skew = rng.f64() * 2.0;
            let lm = random_loadmatrix(
                rng,
                warm.placement.num_experts,
                warm.placement.num_gpus,
                100,
                skew,
            );
            let a = warm.schedule(&lm);
            let b = cold.schedule(&lm);
            assert!(
                (a.stats.lp_objective - b.stats.lp_objective).abs()
                    < 1e-5 * (1.0 + b.stats.lp_objective)
            );
        }
    });
}

/// The LP objective is sandwiched: perfect-balance bound <= m <= vanilla
/// max-GPU load for any placement covering the same experts.
#[test]
fn prop_objective_bounds() {
    forall("objective bounds", 100, |rng, _| {
        let p = random_small_placement(rng);
        let skew = rng.f64() * 2.0;
        let lm = random_loadmatrix(rng, p.num_experts, p.num_gpus, 200, skew);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let sched = s.schedule(&lm);
        let loads: Vec<f64> = lm.expert_loads().iter().map(|&l| l as f64).collect();
        let lower = perfect_balance_bound(&loads, p.num_gpus);
        // upper: put every expert fully on its first replica
        let mut naive = vec![0.0; p.num_gpus];
        for (e, &l) in loads.iter().enumerate() {
            naive[p.replicas[e][0]] += l;
        }
        let upper = naive.iter().cloned().fold(0.0, f64::max);
        assert!(sched.stats.lp_objective >= lower - 1e-6);
        assert!(sched.stats.lp_objective <= upper + 1e-6);
    });
}

/// Placement invariants hold for every generator across sizes.
#[test]
fn prop_placement_generators_consistent() {
    forall_sizes("placement generators", &[4, 8, 16], 25, |rng, g| {
        let e = g * 2;
        let which = rng.below(3);
        let p = match which {
            0 => cayley_graph_placement(g, e),
            1 => random_placement(g, e, 2, rng),
            _ => {
                let loads: Vec<f64> = (0..e).map(|_| rng.below(100) as f64 + 1.0).collect();
                asymmetric_placement(g, &loads, 4, 10, rng)
            }
        };
        p.check_consistency().unwrap();
        // slot conservation: total replicas == E·d (uniform) or == slots
        let total: usize = (0..g).map(|gi| p.slots_used(gi)).sum();
        assert_eq!(total, (0..e).map(|ei| p.replica_count(ei)).sum::<usize>());
        for ei in 0..e {
            assert!(p.replica_count(ei) >= 1);
        }
    });
}

/// `Placement::validate` accepts every generator's output and catches a
/// random structural mutation of it (B.3 slot break, ghost occupant, or
/// slotless replica).
#[test]
fn prop_validate_accepts_generators_rejects_mutations() {
    forall("validate placements", 120, |rng, _| {
        let p = match rng.below(3) {
            0 => {
                let g = [4, 8, 16][rng.below(3) as usize];
                cayley_graph_placement(g, g * 2)
            }
            1 => random_small_placement(rng),
            _ => {
                let g = 4;
                let e = 8;
                let loads: Vec<f64> = (0..e).map(|_| rng.below(100) as f64 + 1.0).collect();
                asymmetric_placement(g, &loads, 4, 10, rng)
            }
        };
        p.validate().unwrap();

        let mut broken = p.clone();
        match rng.below(3) {
            0 => {
                // B.3 break: relocate one replica of an expert to a fresh slot
                let e = rng.below(broken.num_experts as u64) as usize;
                let s = broken.slot_of(e).unwrap();
                let &g = broken.replicas[e].last().unwrap();
                // only a break if the expert has >1 replica; otherwise
                // moving its single slot keeps B.3 — force multi-replica
                if broken.replicas[e].len() > 1 {
                    broken.local_slots[g][s] = None;
                    broken.local_slots[g].push(Some(e));
                    assert!(broken.validate().is_err(), "moved slot must fail B.3");
                }
            }
            1 => {
                // ghost occupant: a slot holding an expert not placed there
                let g = rng.below(broken.num_gpus as u64) as usize;
                let e = (0..broken.num_experts).find(|&e| !broken.hosts(g, e));
                if let Some(e) = e {
                    broken.local_slots[g].push(Some(e));
                    assert!(broken.validate().is_err(), "ghost occupant must fail");
                }
            }
            _ => {
                // slotless replica: list a GPU without giving it a slot
                let e = rng.below(broken.num_experts as u64) as usize;
                let extra = (0..broken.num_gpus).find(|&g| !broken.hosts(g, e));
                if let Some(g) = extra {
                    broken.replicas[e].push(g);
                    broken.replicas[e].sort_unstable();
                    assert!(broken.validate().is_err(), "slotless replica must fail");
                }
            }
        }
    });
}

/// Greedy replica counts: monotone in load (heavier experts never get
/// fewer replicas) and always sum to the slot budget.
#[test]
fn prop_greedy_counts_monotone() {
    forall("greedy monotone", 150, |rng, _| {
        let e = 4 + rng.below(12) as usize;
        let loads: Vec<f64> = (0..e).map(|_| rng.below(1000) as f64).collect();
        let max_count = 8;
        let slots = e + rng.below((e * (max_count - 1)) as u64 + 1) as usize;
        let slots = slots.min(e * max_count);
        let counts = greedy_replica_counts(&loads, slots, max_count);
        assert_eq!(counts.iter().sum::<usize>(), slots);
        for i in 0..e {
            for j in 0..e {
                if loads[i] > loads[j] {
                    assert!(
                        counts[i] + 1 >= counts[j],
                        "heavier expert {i} ({}) got {} vs {} for {j} ({})",
                        loads[i],
                        counts[i],
                        counts[j],
                        loads[j]
                    );
                }
            }
        }
    });
}

/// Adding load to one expert never *decreases* the LP optimum
/// (monotonicity of the makespan).
#[test]
fn prop_lp_monotone_in_loads() {
    forall("lp monotone", 60, |rng, _| {
        let p = random_small_placement(rng);
        let skew = rng.f64();
        let mut lm = random_loadmatrix(rng, p.num_experts, p.num_gpus, 100, skew);
        let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let before = s.schedule(&lm).stats.lp_objective;
        let e = rng.below(p.num_experts as u64) as usize;
        let g = rng.below(p.num_gpus as u64) as usize;
        lm.add(e, g, 50);
        let after = s.schedule(&lm).stats.lp_objective;
        assert!(after >= before - 1e-6, "objective dropped: {before} -> {after}");
    });
}

/// Comm-aware scheduling (LPP 4) never increases total cross-GPU traffic
/// relative to compute-only scheduling at equal alpha weighting, and its
/// compute balance degrades by at most the comm trade-off.
#[test]
fn prop_comm_aware_traffic_no_worse() {
    forall("comm-aware traffic", 40, |rng, _| {
        let p = random_small_placement(rng);
        let skew = rng.f64();
        let lm = random_loadmatrix(rng, p.num_experts, p.num_gpus, 150, skew);
        let mut plain = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        let mut comm = MicroEpScheduler::new(
            p.clone(),
            None,
            SchedulerOptions {
                mode: ScheduleMode::CommAware { alpha: 10.0 },
                ..Default::default()
            },
        );
        let a = plain.schedule(&lm);
        let b = comm.schedule(&lm);
        // LPP 4's comm objective is the max over GPUs of max(send, recv) —
        // that metric (not total traffic) must not get worse, modulo
        // per-expert rounding slack.
        let comm_metric = |s: &micromoe::scheduler::Schedule| -> u64 {
            let (send, recv) = s.comm_volumes(lm.num_gpus);
            send.iter().zip(&recv).map(|(&s, &r)| s.max(r)).max().unwrap_or(0)
        };
        let slack = p.num_experts as u64;
        assert!(
            comm_metric(&b) <= comm_metric(&a) + slack,
            "alpha=10 comm {} > compute-only {}",
            comm_metric(&b),
            comm_metric(&a)
        );
    });
}

/// Distributed determinism (§5.3): two scheduler instances fed identical
/// input streams stay bit-identical through warm-start state.
#[test]
fn prop_distributed_determinism() {
    forall("determinism", 30, |rng, _| {
        let topo = Topology::new(8, 4, 2, 8);
        let p = random_placement(8, 16, 2, rng);
        let mk = || {
            MicroEpScheduler::new(p.clone(), Some(topo.clone()), SchedulerOptions::default())
        };
        let mut a = mk();
        let mut b = mk();
        for _ in 0..5 {
            let skew = rng.f64() * 1.5;
            let lm = random_loadmatrix(rng, 16, 8, 120, skew);
            let sa = a.schedule(&lm);
            let sb = b.schedule(&lm);
            assert_eq!(sa.replica_loads, sb.replica_loads);
            assert_eq!(sa.routes, sb.routes);
        }
    });
}

/// Failure injection: corrupted (inconsistent) gathered loads on one
/// device would break consistency — the checker must catch it.
#[test]
fn prop_divergence_detected() {
    use micromoe::scheduler::distributed::DistributedSchedulers;
    forall("divergence detection", 20, |rng, _| {
        let p = random_placement(8, 16, 2, rng);
        let mut fleet =
            DistributedSchedulers::new(p, None, SchedulerOptions::default(), 3);
        let lm = random_loadmatrix(rng, 16, 8, 200, 1.0);
        let round = fleet.round(&lm);
        assert!(round.consistent);
        // now simulate one device seeing corrupted loads: schedules differ
        let mut corrupted = lm.clone();
        corrupted.add(0, 0, 997);
        let r2 = fleet.round(&corrupted);
        // both rounds individually consistent; cross-round divergence is
        // visible through differing schedules
        assert!(r2.consistent);
        assert_ne!(
            round.schedule.replica_loads, r2.schedule.replica_loads,
            "poisoned loads must change the schedule"
        );
    });
}
