//! Property tests for the serving tier: batching-window invariants over
//! random arrival processes and server configs, plus the exact-vs-P²
//! percentile error bound.
//!
//! Replay a failing case with `MICROMOE_PROP_SEED=<seed>` (printed by the
//! harness on failure).

use micromoe::balancer::MoeSession;
use micromoe::prop::forall;
use micromoe::rng::Rng;
use micromoe::serving::{
    ArrivalGen, ArrivalProcess, DispatchCost, ServingConfig, SolveCost, TokenModel,
};
use micromoe::stats::LatencyTrack;
use micromoe::topology::Topology;
use micromoe::workload::TopicMix;

fn random_process(rng: &mut Rng) -> ArrivalProcess {
    match rng.below(3) {
        0 => ArrivalProcess::Poisson { rate_hz: 2_000.0 + rng.f64() * 60_000.0 },
        1 => ArrivalProcess::Bursty {
            calm_hz: 1_000.0 + rng.f64() * 8_000.0,
            burst_hz: 20_000.0 + rng.f64() * 80_000.0,
            mean_calm_us: 2_000.0 + rng.f64() * 20_000.0,
            mean_burst_us: 1_000.0 + rng.f64() * 8_000.0,
        },
        _ => ArrivalProcess::Diurnal {
            base_hz: 4_000.0 + rng.f64() * 30_000.0,
            amplitude: rng.f64() * 0.95,
            period_us: 10_000.0 + rng.f64() * 200_000.0,
        },
    }
}

fn random_config(rng: &mut Rng) -> ServingConfig {
    ServingConfig {
        window_us: 100.0 + rng.f64() * 900.0,
        max_batch: 1 + rng.below(32) as usize,
        slo_us: 500.0 + rng.f64() * 4_000.0,
        shed_after_us: if rng.below(2) == 0 {
            f64::INFINITY
        } else {
            500.0 + rng.f64() * 4_000.0
        },
        solve_cost: SolveCost::Virtual { us: rng.f64() * 2_000.0 },
        dispatch_cost: DispatchCost::PerToken {
            fixed_us: rng.f64() * 100.0,
            us_per_token: rng.f64() * 0.5,
        },
    }
}

#[test]
fn window_invariants_hold_for_any_process_and_config() {
    forall("serving window invariants", 30, |rng, case| {
        let n = 100 + rng.below(200) as usize;
        let process = random_process(rng);
        let cfg = random_config(rng);
        let tokens = match rng.below(2) {
            0 => TokenModel::Fixed(1 + rng.below(64)),
            _ => TokenModel::Ramp {
                base: 1 + rng.below(32),
                step: rng.below(8),
                every: 1 + rng.below(50),
            },
        };
        let reqs = ArrivalGen::new(process, tokens, 0x5E_ED ^ case as u64).take(n);

        let session = MoeSession::builder()
            .topology(Topology::new(8, 4, 2, 8))
            .experts(16)
            .policy_name("vanilla-ep")
            .build()
            .unwrap();
        let mut server = session.serve(cfg.clone(), TopicMix::new(16, 1.0 + rng.f64(), 4, 3));
        let trace = server.run(&reqs);
        let sla = server.sla();

        // conservation: every admitted request is served or shed exactly once
        assert_eq!(sla.arrived, n as u64, "arrived");
        assert_eq!(sla.accounted(), n as u64, "served {} + shed {}", sla.served, sla.shed);
        let mut seen: Vec<u64> = trace
            .windows
            .iter()
            .flat_map(|w| w.served.iter().chain(w.shed.iter()).copied())
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "each id exactly once");
        assert_eq!(sla.e2e.count() as u64, sla.served, "one e2e sample per served request");
        assert_eq!(sla.windows, trace.windows.len() as u64);
        assert_eq!(
            sla.empty_windows,
            trace.windows.iter().filter(|w| w.served.is_empty()).count() as u64
        );

        let mut prev_close = 0.0f64;
        for w in &trace.windows {
            // windows are well-formed and serially ordered
            assert!(w.close_us >= w.open_us, "window {} closes before it opens", w.index);
            assert!(w.open_us >= prev_close, "window {} overlaps the previous service", w.index);
            prev_close = w.close_us;
            // batch-size cap
            assert!(w.served.len() <= cfg.max_batch, "window {} overfull", w.index);
            // no request served (or shed) before it arrived
            let mut tokens = 0u64;
            for &id in w.served.iter().chain(w.shed.iter()) {
                assert!(
                    reqs[id as usize].arrival_us <= w.close_us,
                    "window {}: request {id} handled before arrival",
                    w.index
                );
            }
            for &id in &w.served {
                tokens += reqs[id as usize].tokens;
            }
            assert_eq!(tokens, w.tokens, "window {} token accounting", w.index);
            if !w.served.is_empty() {
                assert!(
                    w.gpu_compute.iter().sum::<u64>() >= w.tokens,
                    "window {} plan lost tokens",
                    w.index
                );
            } else {
                assert_eq!(w.tokens, 0, "empty window {} with tokens", w.index);
                assert_eq!(w.solve_us, 0.0, "empty window {} charged solve", w.index);
            }
        }
    });
}

/// P² streaming percentiles track the exact percentiles on long random
/// streams. Bounds are ~2x the worst relative error observed over hundreds
/// of calibration runs of the reference implementation (uniform /
/// exponential / bimodal, 2000 samples): p50 6%, p95 4%, p99 14%.
#[test]
fn p2_tracks_exact_percentiles_within_bounds() {
    forall("P2 vs exact", 40, |rng, _| {
        let scale = 10f64.powf(rng.f64() * 3.0);
        let kind = rng.below(3);
        let mut track = LatencyTrack::new();
        for _ in 0..2_000 {
            let u = rng.f64();
            let x = match kind {
                0 => u * scale,
                1 => -(1.0 - u).ln() * scale,
                _ => u * scale + if rng.f64() < 0.2 { scale * 10.0 } else { 0.0 },
            };
            track.record(x);
        }
        for (q, p2, bound) in [
            (0.50, track.p2_p50(), 0.15),
            (0.95, track.p2_p95(), 0.15),
            (0.99, track.p2_p99(), 0.30),
        ] {
            let exact = track.exact(q);
            let rel = (p2 - exact).abs() / exact.abs().max(1e-9);
            assert!(rel <= bound, "p{}: P2 {p2} vs exact {exact} (rel {rel:.4})", q * 100.0);
        }
    });
}
