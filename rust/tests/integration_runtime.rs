//! Integration: the AOT bridge — rust loads `artifacts/*.hlo.txt`, compiles
//! on PJRT CPU, executes, and the numerics match what the Pallas kernels /
//! JAX model computed at build time (cross-checked structurally here;
//! value-level kernel-vs-ref checks live in python/tests).
//!
//! Requires `make artifacts` (skips with a message otherwise) and the
//! `xla` feature (the whole file is compiled out without it).
#![cfg(feature = "xla")]

use micromoe::runtime::{lit, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: {e:#} — run `make artifacts`");
            None
        }
    }
}

fn cfg(rt: &Runtime, key: &str) -> usize {
    rt.manifest.cfg(key).unwrap() as usize
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in ["init_params", "train_step", "eval_loss", "gate", "expert_ffn", "moe_block"] {
        assert!(rt.manifest.artifact(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn gate_kernel_topk_properties() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest.artifact("gate").unwrap().clone();
    let (t, e) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let k = spec.outputs[0].shape[1];

    // deterministic pseudo-logits
    let logits: Vec<f32> =
        (0..t * e).map(|i| ((i * 37 + 11) % 101) as f32 / 50.0 - 1.0).collect();
    let outs = rt
        .execute("gate", &[lit::f32_matrix(&logits, t, e).unwrap()])
        .unwrap();
    assert_eq!(outs.len(), 2);
    let w = outs[0].to_vec::<f32>().unwrap();
    let idx = outs[1].to_vec::<i32>().unwrap();
    assert_eq!(w.len(), t * k);
    assert_eq!(idx.len(), t * k);
    for row in 0..t {
        let ws = &w[row * k..(row + 1) * k];
        let ids = &idx[row * k..(row + 1) * k];
        // weights positive and normalized
        let sum: f32 = ws.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row {row}: weights sum {sum}");
        assert!(ws.iter().all(|&x| x > 0.0));
        // indices in range and distinct
        let mut sorted: Vec<i32> = ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), k, "row {row}: duplicate experts {ids:?}");
        assert!(ids.iter().all(|&i| (i as usize) < e));
    }
}

#[test]
fn expert_ffn_kernel_zero_in_zero_out_and_finite() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest.artifact("expert_ffn").unwrap().clone();
    let (e, c, h) = (
        spec.inputs[0].shape[0],
        spec.inputs[0].shape[1],
        spec.inputs[0].shape[2],
    );
    let f = spec.inputs[1].shape[2];

    let x = lit::f32_tensor3(&vec![0.0; e * c * h], e, c, h).unwrap();
    let w1v: Vec<f32> = (0..e * h * f).map(|i| ((i % 13) as f32 - 6.0) / 60.0).collect();
    let w2v: Vec<f32> = (0..e * f * h).map(|i| ((i % 17) as f32 - 8.0) / 80.0).collect();
    let w1 = lit::f32_tensor3(&w1v, e, h, f).unwrap();
    let w2 = lit::f32_tensor3(&w2v, e, f, h).unwrap();

    // zero input -> exactly zero output (gelu(0) = 0)
    let outs = rt
        .execute("expert_ffn", &[x, w1.clone(), w2.clone()])
        .unwrap();
    let y = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(y.len(), e * c * h);
    assert!(y.iter().all(|&v| v.abs() < 1e-6), "zero input produced nonzero output");

    // nonzero input -> finite, nonzero output
    let xs: Vec<f32> = (0..e * c * h).map(|i| ((i % 7) as f32 - 3.0) / 10.0).collect();
    let x2 = lit::f32_tensor3(&xs, e, c, h).unwrap();
    let outs2 = rt.execute("expert_ffn", &[x2, w1, w2]).unwrap();
    let y2 = outs2[0].to_vec::<f32>().unwrap();
    assert!(y2.iter().all(|v| v.is_finite()));
    assert!(y2.iter().any(|&v| v.abs() > 1e-6));
}

#[test]
fn moe_block_counts_match_topk_budget() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest.artifact("moe_block").unwrap().clone();
    let (t, h) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let e = spec.inputs[1].shape[1];
    let f = spec.inputs[2].shape[2];
    let topk = cfg(&rt, "topk");

    let x: Vec<f32> = (0..t * h).map(|i| (((i * 29) % 83) as f32 / 41.0) - 1.0).collect();
    let wg: Vec<f32> = (0..h * e).map(|i| (((i * 31) % 67) as f32 / 33.0) - 1.0).collect();
    let w1: Vec<f32> = (0..e * h * f).map(|i| ((i % 11) as f32 - 5.0) / 100.0).collect();
    let w2: Vec<f32> = (0..e * f * h).map(|i| ((i % 19) as f32 - 9.0) / 100.0).collect();

    let outs = rt
        .execute(
            "moe_block",
            &[
                lit::f32_matrix(&x, t, h).unwrap(),
                lit::f32_matrix(&wg, h, e).unwrap(),
                lit::f32_tensor3(&w1, e, h, f).unwrap(),
                lit::f32_tensor3(&w2, e, f, h).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 2);
    let y = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(y.len(), t * h);
    assert!(y.iter().all(|v| v.is_finite()));
    let counts = outs[1].to_vec::<i32>().unwrap();
    assert_eq!(counts.len(), e);
    let total: i64 = counts.iter().map(|&c| c as i64).sum();
    assert_eq!(total, (t * topk) as i64, "gate counts must equal T·K");
    assert!(counts.iter().all(|&c| c >= 0));
}

#[test]
fn init_params_deterministic_and_scaled() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let p = rt.manifest.num_params;
    let a = rt.execute("init_params", &[lit::i32_scalar(7)]).unwrap();
    let b = rt.execute("init_params", &[lit::i32_scalar(7)]).unwrap();
    let c = rt.execute("init_params", &[lit::i32_scalar(8)]).unwrap();
    let av = a[0].to_vec::<f32>().unwrap();
    let bv = b[0].to_vec::<f32>().unwrap();
    let cv = c[0].to_vec::<f32>().unwrap();
    assert_eq!(av.len(), p);
    assert_eq!(av, bv, "same seed must give identical params");
    assert_ne!(av, cv, "different seeds must differ");
    // sane init scale
    let rms = (av.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / p as f64).sqrt();
    assert!(rms > 1e-4 && rms < 1.0, "init rms {rms}");
    assert!(av.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_roundtrip_shapes() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let p = rt.manifest.num_params;
    let b = cfg(&rt, "micro_batch");
    let s = cfg(&rt, "seq");
    let l = cfg(&rt, "layers");
    let e = cfg(&rt, "experts");

    let params = rt.execute("init_params", &[lit::i32_scalar(0)]).unwrap().remove(0);
    let zeros = lit::f32_vec(&vec![0f32; p]);
    let tokens: Vec<i32> =
        (0..b * (s + 1)).map(|i| (i % cfg(&rt, "vocab")) as i32).collect();
    let outs = rt
        .execute(
            "train_step",
            &[
                params,
                zeros.clone(),
                zeros,
                lit::f32_scalar(0.0),
                lit::i32_matrix(&tokens, b, s + 1).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(outs.len(), 6, "train_step must emit params', m', v', step', loss, counts");
    assert_eq!(outs[0].to_vec::<f32>().unwrap().len(), p);
    let step = outs[3].to_vec::<f32>().unwrap()[0];
    assert_eq!(step, 1.0);
    let loss = outs[4].to_vec::<f32>().unwrap()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    let counts = outs[5].to_vec::<i32>().unwrap();
    assert_eq!(counts.len(), l * e);
    let per_layer_budget = (b * s * cfg(&rt, "topk")) as i64;
    for layer in 0..l {
        let sum: i64 =
            counts[layer * e..(layer + 1) * e].iter().map(|&c| c as i64).sum();
        assert_eq!(sum, per_layer_budget, "layer {layer} counts");
    }
}
