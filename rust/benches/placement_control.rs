//! Placement-control regenerator: static placement vs the two-timescale
//! controller vs a free-replacement oracle on a drifting-Zipf trace.
//!
//! Three arms schedule the identical seeded trace:
//!
//! * **static** — the plain `micromoe` LPP session; the placement laid
//!   down at build time never changes, so drift shows up as imbalance.
//! * **controller** — `MoeSession::builder().control(..)`: EWMA + dual
//!   hysteresis detection, Eq.-3-scored replicate/evict decisions, every
//!   committed migration's downtime charged into the step.
//! * **oracle** — a clairvoyant upper bound: every control interval the
//!   placement is rebuilt from scratch (greedy replica counts +
//!   Monte-Carlo location search on the same EWMA) at **zero** migration
//!   cost. The controller cannot beat it; the gap it closes from static
//!   toward the oracle is the headline number.
//!
//! Reported per arm: mean imbalance (max/mean GPU compute, post-warmup),
//! net step time (FFN bottleneck under `CostModel::h100_testbed` plus all
//! charged downtime), and the migration ledger. Knobs:
//! `PLACEMENT_CONTROL_GPUS` (default 64; CI smoke uses the default),
//! `PLACEMENT_CONTROL_STEPS` (default 96), `PLACEMENT_CONTROL_TOKENS`
//! (tokens per source GPU, default 2048). Results land in
//! `target/bench-results/placement_control.json`.

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::CostModel;
use micromoe::control::{ControlSpec, LoadDetector};
use micromoe::placement::asymmetric::asymmetric_placement;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::Rng;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::ser::Json;
use micromoe::topology::Topology;
use micromoe::workload::{DriftingWorkload, Workload};

fn knob(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct ArmResult {
    name: &'static str,
    mean_imbalance: f64,
    net_time_s: f64,
    downtime_s: f64,
    decisions: u64,
    moves: u64,
    bytes: u64,
}

fn imbalance(max: u64, total: u64, gpus: usize) -> f64 {
    max as f64 * gpus as f64 / total as f64
}

/// Drive a `MoeSession` arm over the trace; `warmup` steps are excluded
/// from the imbalance mean (no decision can land before the first tick).
fn run_session(
    name: &'static str,
    mut session: MoeSession,
    trace: &[LoadMatrix],
    model: &CostModel,
    gpus: usize,
    warmup: usize,
) -> ArmResult {
    let mut imb = 0.0;
    let mut net = 0.0;
    for (i, lm) in trace.iter().enumerate() {
        let out = session.step(std::slice::from_ref(lm));
        let plan = &out.layers[0];
        let max = *plan.gpu_compute.iter().max().unwrap();
        net += model.ffn_time(max) + plan.prep_extra;
        if i >= warmup {
            imb += imbalance(max, lm.total(), gpus);
        }
    }
    let st = session.stats();
    ArmResult {
        name,
        mean_imbalance: imb / (trace.len() - warmup) as f64,
        net_time_s: net,
        downtime_s: st.control.downtime,
        decisions: st.control.decisions,
        moves: st.control.moves,
        bytes: st.control.bytes,
    }
}

/// The oracle: re-place for free from the EWMA every `interval` steps.
#[allow(clippy::too_many_arguments)]
fn run_oracle(
    trace: &[LoadMatrix],
    topo: &Topology,
    model: &CostModel,
    spec: &ControlSpec,
    experts: usize,
    gpus: usize,
    warmup: usize,
    seed: u64,
) -> ArmResult {
    let slots_per_gpu = experts / gpus + spec.slot_headroom;
    let mut rng = Rng::new(seed);
    let mut det = LoadDetector::new(experts, spec);
    let mut sched = MicroEpScheduler::new(
        symmetric_placement(topo, experts),
        Some(topo.clone()),
        SchedulerOptions::default(),
    );
    let mut imb = 0.0;
    let mut net = 0.0;
    let mut replans = 0u64;
    for (i, lm) in trace.iter().enumerate() {
        det.observe(&lm.expert_loads());
        if (i + 1) % spec.interval == 0 {
            // clairvoyant and free: full Monte-Carlo re-placement, no
            // migration charged, warm basis thrown away without penalty
            let p = asymmetric_placement(gpus, det.ema(), slots_per_gpu, 64, &mut rng);
            sched = MicroEpScheduler::new(p, Some(topo.clone()), SchedulerOptions::default());
            replans += 1;
        }
        let s = sched.schedule(lm);
        let max = s.stats.max_gpu_load;
        net += model.ffn_time(max);
        if i >= warmup {
            imb += imbalance(max, lm.total(), gpus);
        }
    }
    ArmResult {
        name: "oracle",
        mean_imbalance: imb / (trace.len() - warmup) as f64,
        net_time_s: net,
        downtime_s: 0.0,
        decisions: replans,
        moves: 0,
        bytes: 0,
    }
}

fn main() {
    let gpus = knob("PLACEMENT_CONTROL_GPUS", 64);
    let steps = knob("PLACEMENT_CONTROL_STEPS", 96);
    let tokens = knob("PLACEMENT_CONTROL_TOKENS", 2048) as u64;
    let experts = 2 * gpus;
    let topo = Topology::new(gpus, gpus / 2, 2, 8);
    let model = CostModel::h100_testbed();
    let spec = ControlSpec { interval: 8, dwell: 2, ..Default::default() };
    let warmup = spec.interval;

    let mut wl = DriftingWorkload::new(experts, gpus, tokens, 1.3, 24, 0xCAFE);
    let trace: Vec<LoadMatrix> = (0..steps).map(|_| wl.next_batch()).collect();

    let session = |controlled: bool| {
        let mut b = MoeSession::builder()
            .topology(topo.clone())
            .experts(experts)
            .policy_name("micromoe")
            .layers(1);
        if controlled {
            b = b
                .control(spec.clone())
                .migration_cost(CostModel::h100_testbed(), 1 << 22);
        }
        b.build().expect("session builds")
    };

    let arms = vec![
        run_session("static", session(false), &trace, &model, gpus, warmup),
        run_session("controller", session(true), &trace, &model, gpus, warmup),
        run_oracle(&trace, &topo, &model, &spec, experts, gpus, warmup, 0xFEED),
    ];

    let mut table = Table::new(
        &format!(
            "Placement control: drifting Zipf, {gpus} GPUs x {experts} experts, \
             {steps} steps, interval {}",
            spec.interval
        ),
        &["arm", "mean imbalance", "net step time", "downtime", "decisions", "moves"],
    );
    let mut json = Vec::new();
    for a in &arms {
        table.row(vec![
            a.name.to_string(),
            format!("{:.3}x", a.mean_imbalance),
            fmt_time(a.net_time_s),
            fmt_time(a.downtime_s),
            a.decisions.to_string(),
            a.moves.to_string(),
        ]);
        json.push(Json::obj(vec![
            ("arm", Json::Str(a.name.into())),
            ("mean_imbalance", Json::Num(a.mean_imbalance)),
            ("net_time_s", Json::Num(a.net_time_s)),
            ("downtime_s", Json::Num(a.downtime_s)),
            ("decisions", Json::Num(a.decisions as f64)),
            ("moves", Json::Num(a.moves as f64)),
            ("bytes", Json::Num(a.bytes as f64)),
        ]));
    }
    table.print();

    let [s, c, o] = &arms[..] else { unreachable!() };
    let closed = if s.mean_imbalance > o.mean_imbalance {
        (s.mean_imbalance - c.mean_imbalance) / (s.mean_imbalance - o.mean_imbalance)
    } else {
        0.0
    };
    println!(
        "\ncontroller closes {:.0}% of the static→oracle imbalance gap while \
         paying {} of migration downtime; net step time {} vs static {} \
         (oracle floor {}).",
        closed * 100.0,
        fmt_time(c.downtime_s),
        fmt_time(c.net_time_s),
        fmt_time(s.net_time_s),
        fmt_time(o.net_time_s),
    );
    let _ = save_json(
        "placement_control",
        &Json::obj(vec![
            ("gpus", Json::Num(gpus as f64)),
            ("experts", Json::Num(experts as f64)),
            ("steps", Json::Num(steps as f64)),
            ("tokens_per_gpu", Json::Num(tokens as f64)),
            ("interval", Json::Num(spec.interval as f64)),
            ("gap_closed", Json::Num(closed)),
            ("arms", Json::Arr(json)),
        ]),
    );
}
