//! Fig. 10 regenerator: adaptive-replacement migration time (expert
//! parameters + optimizer states) across the Table-2 model configurations,
//! varying how many experts move.

use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::migration::{expert_bytes, migration_time, Move};
use micromoe::cluster::CostModel;
use micromoe::config::table2;
use micromoe::ser::Json;

fn main() {
    let model = CostModel::h100_testbed();
    let mut table = Table::new(
        "Fig 10: migration time for adaptive replacement (params + Adam states)",
        &["model", "bytes/expert", "12.5% moved", "25% moved", "50% moved", "100% moved"],
    );
    let mut json = Vec::new();
    for preset in table2() {
        let topo = preset.topology();
        let g = topo.microep_group_size();
        let bytes = expert_bytes(preset.hidden, preset.ffn_hidden, true);
        let mut cells = vec![
            preset.name.to_string(),
            format!("{:.1} MB", bytes as f64 / 1e6),
        ];
        let mut series = Vec::new();
        for frac_i in [8usize, 4, 2, 1] {
            let count = (preset.experts / frac_i).max(1);
            // alternate intra/inter-node moves like a real re-placement
            let moves: Vec<Move> = (0..count)
                .map(|i| Move { expert: i, dst: (i + g / 2) % g, src: i % g })
                .collect();
            let t = migration_time(&moves, bytes, &model, &topo, g);
            cells.push(fmt_time(t));
            series.push(Json::Num(t));
        }
        table.row(cells);
        json.push(Json::obj(vec![
            ("model", Json::Str(preset.name.into())),
            ("times_s", Json::Arr(series)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 10: total migration time typically spans hundreds of \
         milliseconds across model configurations."
    );
    let _ = save_json("fig10", &Json::Arr(json));
}
