//! Hierarchical-scale regenerator: exact monolithic LPP vs the
//! Dantzig–Wolfe decomposed scheduler (`ScheduleMode::Decomposed`) on
//! thousand-GPU groups — 256 → 2048 GPUs, up to 1024 experts.
//!
//! Two claims are tracked per shape: the decomposed warm solve stays
//! under the ~1 ms per-micro-batch budget where the monolithic LP has
//! long since blown it, and it does so without giving up optimality —
//! the `gap` column is the worst `(dec_max − exact_max)/exact_max` over
//! the measured batches (the differential suite pins the same quantity
//! at 1%). The `rung` column must stay off the greedy passthrough: a
//! decomposed run that only hits the budget by degrading its blocks to
//! water-fills would be cheating.
//!
//! `HIER_BENCH_MAX_GPUS` caps the shape list (CI smoke runs 256); the
//! full sweep is the default. Results land in
//! `target/bench-results/hierarchical_scale.json`.

use micromoe::bench_harness::{bench, fmt_time, save_json, Table};
use micromoe::placement::Placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::ser::Json;
use micromoe::stats::DegradationRung;
use micromoe::topology::Topology;

/// Each expert: two adjacent-GPU pairs half a ring apart (same structure
/// the differential suite pins — subproblem freedom inside a block,
/// master freedom across blocks).
fn paired_placement(gpus: usize, experts: usize) -> Placement {
    let half = gpus / 2;
    let reps = (0..experts)
        .map(|e| {
            let a = (2 * e) % half;
            let mut v = vec![a, a + 1, a + half, a + half + 1];
            v.sort_unstable();
            v.dedup();
            v
        })
        .collect();
    Placement::from_replicas(gpus, reps)
}

fn zipf_batch(rng: &mut Rng, zipf: &Zipf, experts: usize, gpus: usize, per_gpu: usize) -> LoadMatrix {
    let mut lm = LoadMatrix::zeros(experts, gpus);
    for g in 0..gpus {
        for _ in 0..per_gpu {
            lm.add(zipf.sample(rng), g, 1);
        }
    }
    lm
}

struct Measured {
    p50_us: f64,
    p95_us: f64,
    /// max GPU load per batch index (deterministic per batch)
    max_loads: Vec<u64>,
    rung: DegradationRung,
    blocks: u32,
    outer_iters: f64,
}

fn measure(
    name: &str,
    placement: &Placement,
    topo: Option<Topology>,
    opts: SchedulerOptions,
    batches: &[LoadMatrix],
    warmup: usize,
    iters: usize,
) -> Measured {
    let mut s = MicroEpScheduler::new(placement.clone(), topo, opts);
    // prime the warm state: the steady-state per-micro-batch cost is the
    // warm repair, not the one-off cold factorization
    s.schedule(&batches[0]);
    let mut max_loads = vec![0u64; batches.len()];
    let mut rung = DegradationRung::WarmLp;
    let mut blocks = 0u32;
    let mut outer = 0u64;
    let mut solves = 0u64;
    let mut i = 0usize;
    let r = bench(name, warmup, iters, || {
        let sched = s.schedule(&batches[i % batches.len()]);
        max_loads[i % batches.len()] = sched.stats.max_gpu_load;
        rung = sched.stats.rung;
        if let Some(m) = sched.stats.decompose {
            blocks = m.blocks;
            outer += m.outer_iters as u64;
        }
        solves += 1;
        i += 1;
        std::hint::black_box(&sched);
    });
    Measured {
        p50_us: r.summary.p50 * 1e6,
        p95_us: r.summary.p95 * 1e6,
        max_loads,
        rung,
        blocks,
        outer_iters: outer as f64 / solves as f64,
    }
}

fn main() {
    let max_gpus: usize = std::env::var("HIER_BENCH_MAX_GPUS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048);
    // (gpus, experts, nodes_per_block, tokens per GPU)
    let shapes: Vec<(usize, usize, usize, usize)> = [
        (256, 128, 1, 240),
        (512, 256, 2, 240),
        (1024, 512, 2, 200),
        (2048, 1024, 2, 160),
    ]
    .into_iter()
    .filter(|&(g, _, _, _)| g <= max_gpus)
    .collect();

    let mut table = Table::new(
        "Hierarchical scale: exact LPP vs Dantzig–Wolfe decomposition (warm, per micro-batch)",
        &[
            "GPUs", "experts", "blocks", "exact p50", "exact p95", "dec p50", "dec p95",
            "speedup", "gap", "iters", "<1ms", "rung",
        ],
    );
    let mut json = Vec::new();
    for (gpus, experts, npb, per_gpu) in shapes {
        let placement = paired_placement(gpus, experts);
        let mut rng = Rng::new(0xbea7 + gpus as u64);
        let zipf = Zipf::new(experts, 1.05);
        let batches: Vec<LoadMatrix> =
            (0..4).map(|_| zipf_batch(&mut rng, &zipf, experts, gpus, per_gpu)).collect();
        // fewer timed iterations at the scales where the exact oracle is
        // the thing being measured as too slow
        let (warmup, iters) = if gpus >= 1024 { (1, 6) } else { (2, 12) };

        let exact = measure(
            &format!("exact_{gpus}x{experts}"),
            &placement,
            None,
            SchedulerOptions::default(),
            &batches,
            warmup,
            iters,
        );
        let topo = Topology::new(gpus, gpus / 2, 2, 8);
        let dec = measure(
            &format!("decomposed_{gpus}x{experts}"),
            &placement,
            Some(topo),
            SchedulerOptions {
                mode: ScheduleMode::Decomposed {
                    nodes_per_block: npb,
                    max_outer_iters: 4,
                    tol: 1e-2,
                },
                ..Default::default()
            },
            &batches,
            warmup,
            iters,
        );

        let gap = exact
            .max_loads
            .iter()
            .zip(&dec.max_loads)
            .filter(|&(&e, _)| e > 0)
            .map(|(&e, &d)| (d as f64 - e as f64) / e as f64)
            .fold(0.0f64, f64::max);
        let under_1ms = dec.p50_us < 1000.0;
        let speedup = exact.p50_us / dec.p50_us;
        table.row(vec![
            gpus.to_string(),
            experts.to_string(),
            dec.blocks.to_string(),
            fmt_time(exact.p50_us * 1e-6),
            fmt_time(exact.p95_us * 1e-6),
            fmt_time(dec.p50_us * 1e-6),
            fmt_time(dec.p95_us * 1e-6),
            format!("{speedup:.1}x"),
            format!("{:.2}%", gap * 100.0),
            format!("{:.1}", dec.outer_iters),
            if under_1ms { "yes".into() } else { "NO".into() },
            format!("{:?}", dec.rung),
        ]);
        json.push(Json::obj(vec![
            ("gpus", Json::Num(gpus as f64)),
            ("experts", Json::Num(experts as f64)),
            ("nodes_per_block", Json::Num(npb as f64)),
            ("blocks", Json::Num(dec.blocks as f64)),
            ("exact_p50_us", Json::Num(exact.p50_us)),
            ("exact_p95_us", Json::Num(exact.p95_us)),
            ("dec_p50_us", Json::Num(dec.p50_us)),
            ("dec_p95_us", Json::Num(dec.p95_us)),
            ("speedup", Json::Num(speedup)),
            ("optimality_gap", Json::Num(gap)),
            ("outer_iters", Json::Num(dec.outer_iters)),
            ("under_1ms", Json::Bool(under_1ms)),
            ("rung", Json::Str(format!("{:?}", dec.rung))),
        ]));
    }
    table.print();
    println!(
        "\nthe decomposed column must stay under the ~1 ms per-micro-batch \
         budget at 2048 GPUs x 1024 experts with rung WarmLp (no greedy \
         passthrough) and a gap within the differential suite's 1% envelope."
    );
    let _ = save_json("hierarchical_scale", &Json::Arr(json));
}
