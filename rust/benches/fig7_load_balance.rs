//! Fig. 7 regenerator: max/avg GPU load vs Zipf skewness for SmartMoE,
//! FlexMoE, MicroMoE (random / w/o AR / full). DP=8, 32 experts, d=2 —
//! exactly the paper's §7.3 setting.

use micromoe::adaptive::AdaptiveConfig;
use micromoe::baselines::{FlexMoe, MicroMoe, MoeSystem, SmartMoe, VanillaEp};
use micromoe::bench_harness::{save_json, Table};
use micromoe::placement::cayley::symmetric_placement;
use micromoe::placement::random::random_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::stats::imbalance_ratio;
use micromoe::topology::Topology;

fn mean_imbalance(sys: &mut dyn MoeSystem, s: f64, batches: usize) -> f64 {
    let mut rng = Rng::new(1);
    let zipf = Zipf::new(32, s);
    let mut acc = 0.0;
    let mut n = 0usize;
    for b in 0..batches {
        let mut lm = LoadMatrix::zeros(32, 8);
        for g in 0..8 {
            for _ in 0..2000 {
                lm.add(zipf.sample(&mut rng), g, 1);
            }
        }
        let plan = sys.plan(&lm);
        if b >= batches / 3 {
            acc += imbalance_ratio(
                &plan.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            );
            n += 1;
        }
    }
    acc / n as f64
}

fn main() {
    let batches = 24;
    let topo = Topology::new(8, 4, 2, 8);
    let mut table = Table::new(
        "Fig 7: max/avg GPU load vs skewness (DP=8, 32 experts)",
        &["s", "vanilla", "SmartMoE", "FlexMoE", "MicroMoE(rand)", "MicroMoE(w/o AR)", "MicroMoE"],
    );
    for si in 0..=8 {
        let s = si as f64 * 0.25;
        let mut vanilla = VanillaEp::new(topo.clone(), 32);
        let mut smart = SmartMoe::new(topo.clone(), 32);
        smart.replace_every = 8;
        let mut flex = FlexMoe::new(topo.clone(), 32, 1);
        flex.adjust_every = 8;
        let mut rng = Rng::new(99);
        let mut mm_rand = MicroMoe::new(
            topo.clone(),
            random_placement(8, 32, 2, &mut rng),
            SchedulerOptions::default(),
        );
        let mut mm_sym = MicroMoe::new(
            topo.clone(),
            symmetric_placement(&topo, 32),
            SchedulerOptions::default(),
        );
        let mut mm_full = MicroMoe::new(
            topo.clone(),
            symmetric_placement(&topo, 32),
            SchedulerOptions::default(),
        )
        .with_adaptive(
            AdaptiveConfig { check_every: 4, window: 8, slots_per_gpu: 8, ..Default::default() },
            5,
        );
        table.row(vec![
            format!("{s:.2}"),
            format!("{:.3}", mean_imbalance(&mut vanilla, s, batches)),
            format!("{:.3}", mean_imbalance(&mut smart, s, batches)),
            format!("{:.3}", mean_imbalance(&mut flex, s, batches)),
            format!("{:.3}", mean_imbalance(&mut mm_rand, s, batches)),
            format!("{:.3}", mean_imbalance(&mut mm_sym, s, batches)),
            format!("{:.3}", mean_imbalance(&mut mm_full, s, batches)),
        ]);
    }
    table.print();
    println!(
        "\npaper Fig 7: MicroMoE(w/o AR) perfect for s<1 then degrades; \
         full MicroMoE ~1.0 throughout; FlexMoE flat but imperfect; \
         SmartMoE grows with skew."
    );
    let _ = save_json("fig7", &table.to_json());
}
