//! Fig. 7 regenerator: max/avg GPU load vs Zipf skewness for SmartMoE,
//! FlexMoE, MicroMoE (random / w/o AR / full). DP=8, 32 experts, d=2 —
//! exactly the paper's §7.3 setting. Every arm is a policy selected by
//! name through the `MoeSession` registry.

use micromoe::bench_harness::{
    fig7_policy_arms, fig7_zipf_stream, mean_imbalance, save_json, Table,
};
use micromoe::topology::Topology;

fn main() {
    let n = 24;
    let topo = Topology::new(8, 4, 2, 8);
    let mut table = Table::new(
        "Fig 7: max/avg GPU load vs skewness (DP=8, 32 experts)",
        &["s", "vanilla", "SmartMoE", "FlexMoE", "MicroMoE(rand)", "MicroMoE(w/o AR)", "MicroMoE"],
    );
    for si in 0..=8 {
        let s = si as f64 * 0.25;
        let stream = fig7_zipf_stream(s, n);
        // each arm is one registry policy; MicroMoE(rand) only swaps the
        // placement the builder hands the same policy
        let mut arms = fig7_policy_arms(&topo, 32);
        let mut row = vec![format!("{s:.2}")];
        for session in &mut arms {
            row.push(format!("{:.3}", mean_imbalance(session, &stream, n / 3)));
        }
        table.row(row);
    }
    table.print();
    println!(
        "\npaper Fig 7: MicroMoE(w/o AR) perfect for s<1 then degrades; \
         full MicroMoE ~1.0 throughout; FlexMoE flat but imperfect; \
         SmartMoE grows with skew."
    );
    let _ = save_json("fig7", &table.to_json());
}
