//! Fig. 9 regenerator: **measured** MicroEP scheduling time (LP solve +
//! token routing) varying number of experts and GPUs. Unlike the cluster
//! timings, these are real wall-clock measurements of our rust scheduler —
//! the direct analogue of the paper's HiGHS-based numbers (~100 µs small,
//! <1 ms at 64 GPUs / 256 experts).

use micromoe::bench_harness::{bench, fmt_time, save_json, Table};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::ser::Json;

fn sched_time_us(gpus: usize, experts: usize, warm: bool) -> (f64, f64) {
    let p = cayley_graph_placement(gpus, experts);
    let mut s = MicroEpScheduler::new(
        p,
        None,
        SchedulerOptions { warm_start: warm, ..Default::default() },
    );
    let mut rng = Rng::new(7);
    let zipf = Zipf::new(experts, 0.8);
    let mk = |rng: &mut Rng| {
        let mut lm = LoadMatrix::zeros(experts, gpus);
        for g in 0..gpus {
            for _ in 0..2048 {
                lm.add(zipf.sample(rng), g, 1);
            }
        }
        lm
    };
    // prime the warm state
    let lm0 = mk(&mut rng);
    s.schedule(&lm0);
    let mut batches: Vec<LoadMatrix> = (0..8).map(|_| mk(&mut rng)).collect();
    let mut i = 0;
    let r = bench(&format!("sched_{gpus}x{experts}"), 2, 24, || {
        let lm = &mut batches[i % 8];
        i += 1;
        std::hint::black_box(s.schedule(lm));
    });
    (r.summary.p50 * 1e6, r.summary.p95 * 1e6)
}

fn main() {
    let mut table = Table::new(
        "Fig 9: measured scheduling time (LP + routing), warm-started",
        &["GPUs", "experts", "p50", "p95", "p50 cold"],
    );
    let mut json = Vec::new();
    for &gpus in &[8usize, 16, 32, 64] {
        for &experts in &[32usize, 64, 128, 256] {
            if experts < gpus {
                continue;
            }
            let (warm_p50, warm_p95) = sched_time_us(gpus, experts, true);
            let (cold_p50, _) = sched_time_us(gpus, experts, false);
            table.row(vec![
                gpus.to_string(),
                experts.to_string(),
                fmt_time(warm_p50 * 1e-6),
                fmt_time(warm_p95 * 1e-6),
                fmt_time(cold_p50 * 1e-6),
            ]);
            json.push(Json::obj(vec![
                ("gpus", Json::Num(gpus as f64)),
                ("experts", Json::Num(experts as f64)),
                ("warm_p50_us", Json::Num(warm_p50)),
                ("cold_p50_us", Json::Num(cold_p50)),
            ]));
        }
    }
    table.print();
    println!(
        "\npaper Fig 9: ~100 µs minimum, <1 ms at 64 GPUs / 256 experts \
         (HiGHS, one CPU thread)."
    );
    let _ = save_json("fig9", &Json::Arr(json));
}
