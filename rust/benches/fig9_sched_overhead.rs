//! Fig. 9 regenerator: **measured** MicroEP scheduling time (LP solve +
//! token routing) varying number of experts and GPUs. Unlike the cluster
//! timings, these are real wall-clock measurements of our rust scheduler —
//! the direct analogue of the paper's HiGHS-based numbers (~100 µs small,
//! <1 ms at 64 GPUs / 256 experts).
//!
//! Every (pricing × factorization) cell of the revised simplex is
//! measured separately — warm p50/p95, mean warm pivots, mean warm *dual*
//! pivots, and mean bound flips — so the per-commit JSON artifact tracks
//! all the engines' trajectories: devex must keep the pivot counts down,
//! sparse LU must keep the per-pivot cost down as `m` grows, and the
//! long-step dual's bound-flipping ratio test must keep the warm dual
//! pivot count down (its flips show up in `warm_bound_flips`). Beyond the
//! paper's 64-GPU grid, 128/256-GPU shapes are measured for both LPP-1
//! and LPP-4 — the CommAware cells are the per-micro-batch bound-edit
//! path the BFRT exists for.

use micromoe::bench_harness::{bench, fmt_time, save_json, Table};
use micromoe::lp::{FactorKind, Pricing, SolverKind};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::ser::Json;

/// The four revised-simplex cells (the tableau baseline lives in
/// `ablation_solvers`; Fig. 9 tracks the production engines).
fn cells() -> [SolverKind; 4] {
    [
        SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::DenseInverse },
        SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::SparseLu },
        SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::DenseInverse },
        SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::SparseLu },
    ]
}

struct Cell {
    p50_us: f64,
    p95_us: f64,
    /// mean LP pivots per schedule() call over the measured iterations
    pivots: f64,
    /// mean dual-simplex pivots per call (warm-repair work)
    dual_pivots: f64,
    /// mean nonbasic bound flips per call (BFRT batches + primal flips)
    bound_flips: f64,
}

fn sched_time(
    gpus: usize,
    experts: usize,
    mode: &ScheduleMode,
    solver: SolverKind,
    warm: bool,
) -> Cell {
    let p = cayley_graph_placement(gpus, experts);
    let mut s = MicroEpScheduler::new(
        p,
        None,
        SchedulerOptions { warm_start: warm, solver, mode: mode.clone(), ..Default::default() },
    );
    let mut rng = Rng::new(7);
    let zipf = Zipf::new(experts, 0.8);
    let mk = |rng: &mut Rng| {
        let mut lm = LoadMatrix::zeros(experts, gpus);
        for g in 0..gpus {
            for _ in 0..2048 {
                lm.add(zipf.sample(rng), g, 1);
            }
        }
        lm
    };
    // prime the warm state
    let lm0 = mk(&mut rng);
    s.schedule(&lm0);
    let batches: Vec<LoadMatrix> = (0..8).map(|_| mk(&mut rng)).collect();
    let mut i = 0;
    let mut pivots = 0usize;
    let mut dual_pivots = 0usize;
    let mut bound_flips = 0usize;
    let mut solves = 0usize;
    let r = bench(&format!("sched_{gpus}x{experts}_{}", solver.label()), 2, 24, || {
        let sched = s.schedule(&batches[i % 8]);
        pivots += sched.stats.lp_iterations;
        dual_pivots += sched.stats.lp_dual_pivots;
        bound_flips += sched.stats.lp_bound_flips;
        solves += 1;
        i += 1;
        std::hint::black_box(sched);
    });
    let per = |v: usize| v as f64 / solves as f64;
    Cell {
        p50_us: r.summary.p50 * 1e6,
        p95_us: r.summary.p95 * 1e6,
        pivots: per(pivots),
        dual_pivots: per(dual_pivots),
        bound_flips: per(bound_flips),
    }
}

fn main() {
    let lpp1 = ScheduleMode::Compute;
    let lpp4 = ScheduleMode::CommAware { alpha: 0.7 };
    // the paper's grid (LPP-1), then the scale the long-step dual and the
    // Markowitz LU exist for: 128/256-GPU shapes under both objectives —
    // LPP-4 is the per-micro-batch bound-edit path where BFRT batches flips
    let mut cases: Vec<(usize, usize, &str, &ScheduleMode)> = Vec::new();
    for &gpus in &[8usize, 16, 32, 64] {
        for &experts in &[32usize, 64, 128, 256] {
            if experts >= gpus {
                cases.push((gpus, experts, "LPP-1", &lpp1));
            }
        }
    }
    for &(gpus, experts) in &[(128usize, 256usize), (256, 256)] {
        cases.push((gpus, experts, "LPP-1", &lpp1));
    }
    for &(gpus, experts) in &[(64usize, 256usize), (128, 256), (256, 256)] {
        cases.push((gpus, experts, "LPP-4", &lpp4));
    }

    let mut table = Table::new(
        "Fig 9: measured scheduling time (LP + routing) per (pricing × factorization) cell",
        &[
            "mode", "GPUs", "experts", "backend", "warm p50", "warm p95", "warm piv",
            "warm dpiv", "flips", "cold p50",
        ],
    );
    let mut json = Vec::new();
    for (gpus, experts, mode_name, mode) in cases {
        for solver in cells() {
            let warm = sched_time(gpus, experts, mode, solver, true);
            let cold = sched_time(gpus, experts, mode, solver, false);
            table.row(vec![
                mode_name.to_string(),
                gpus.to_string(),
                experts.to_string(),
                solver.label().to_string(),
                fmt_time(warm.p50_us * 1e-6),
                fmt_time(warm.p95_us * 1e-6),
                format!("{:.1}", warm.pivots),
                format!("{:.1}", warm.dual_pivots),
                format!("{:.1}", warm.bound_flips),
                fmt_time(cold.p50_us * 1e-6),
            ]);
            json.push(Json::obj(vec![
                ("mode", Json::Str(mode_name.to_string())),
                ("gpus", Json::Num(gpus as f64)),
                ("experts", Json::Num(experts as f64)),
                ("backend", Json::Str(solver.label().to_string())),
                ("warm_p50_us", Json::Num(warm.p50_us)),
                ("warm_p95_us", Json::Num(warm.p95_us)),
                ("warm_pivots", Json::Num(warm.pivots)),
                ("warm_dual_pivots", Json::Num(warm.dual_pivots)),
                ("warm_bound_flips", Json::Num(warm.bound_flips)),
                ("cold_p50_us", Json::Num(cold.p50_us)),
            ]));
        }
    }
    table.print();
    println!(
        "\npaper Fig 9: ~100 µs minimum, <1 ms at 64 GPUs / 256 experts \
         (HiGHS, one CPU thread). The LPP-4 rows at 128/256 GPUs gate the \
         long-step dual: warm_dual_pivots must sit below the PR-2 baseline \
         with the batched flips showing up in warm_bound_flips."
    );
    let _ = save_json("fig9", &Json::Arr(json));
}
