//! Fig. 14 (App. C.2) regenerator: dispatch time of MicroEP vs vanilla EP
//! with DeepEP and NCCL backends, varying GPU count — same group size for
//! both systems (the appendix's communication-focused comparison), groups
//! spanning nodes beyond 8 GPUs.

use micromoe::balancer::Balancer;
use micromoe::baselines::VanillaEp;
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::{CommBackend, CostModel};
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::ser::Json;
use micromoe::topology::Topology;

fn main() {
    let mut table = Table::new(
        "Fig 14: dispatch A2A time, MicroEP vs EP × DeepEP vs NCCL",
        &["GPUs", "EP+NCCL", "MicroEP+NCCL", "EP+DeepEP", "MicroEP+DeepEP"],
    );
    let mut json = Vec::new();
    for &g in &[8usize, 16, 32] {
        // App. C.2 compares MicroEP and EP at the SAME group size: EP is one
        // EP group spanning all g GPUs; MicroEP merges two EP groups of g/2.
        let topo = Topology::new(g, g / 2, 2, 8);
        let ep_topo = Topology::new(g, g, 1, 8);
        let e = 2 * g.max(8);
        let mut micro = MicroEpScheduler::new(
            symmetric_placement(&topo, e),
            Some(topo.clone()),
            SchedulerOptions::default(),
        );
        let mut ep = VanillaEp::new(ep_topo, e);
        let mut rng = Rng::new(3);
        let zipf = Zipf::new(e, 0.8);
        let mut lm = LoadMatrix::zeros(e, g);
        for gi in 0..g {
            for _ in 0..4096 {
                lm.add(zipf.sample(&mut rng), gi, 1);
            }
        }
        let micro_routes = micro.schedule(&lm).routes;
        let ep_routes = ep.plan(&lm).routes;

        // DeepEP requires Megatron-format pre-processing for MicroEP
        // (App. C.2): charge a fixed conversion overhead on that arm.
        let deepep_preprocess_micro = 120e-6;
        let mut cells = vec![g.to_string()];
        let mut nums = Vec::new();
        for backend in [CommBackend::Nccl, CommBackend::DeepEp] {
            let model = CostModel::h100_testbed().with_backend(backend);
            let t_ep = model.a2a_time_from_routes(&ep_routes, g, &topo);
            let mut t_micro = model.a2a_time_from_routes(&micro_routes, g, &topo);
            if backend == CommBackend::DeepEp {
                t_micro += deepep_preprocess_micro;
            }
            cells.push(fmt_time(t_ep));
            cells.push(fmt_time(t_micro));
            nums.push((t_ep, t_micro));
        }
        // reorder to header: EP+NCCL, MicroEP+NCCL, EP+DeepEP, MicroEP+DeepEP
        table.row(cells);
        json.push(Json::obj(vec![
            ("gpus", Json::Num(g as f64)),
            ("ep_nccl", Json::Num(nums[0].0)),
            ("micro_nccl", Json::Num(nums[0].1)),
            ("ep_deepep", Json::Num(nums[1].0)),
            ("micro_deepep", Json::Num(nums[1].1)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 14: DeepEP beats NCCL; under NCCL MicroEP ≤ EP (locality \
         routing); under DeepEP MicroEP pays a pre-processing overhead; \
         inter-node groups are much slower."
    );
    let _ = save_json("fig14", &Json::Arr(json));
}
