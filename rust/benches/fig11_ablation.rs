//! Fig. 11 regenerator: ablation of MicroMoE's three dispatch
//! optimizations — warm solving (§5.1), locality-aware routing (§5.2),
//! overlap (§5.4) — on dispatch time at the Fig.-8 setting.
//!
//! Scheduling times are *measured* on our LP; A2A volumes feed the
//! calibrated comm model.

use micromoe::balancer::Balancer;
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::CostModel;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::ser::Json;
use micromoe::topology::Topology;

struct Arm {
    name: &'static str,
    warm: bool,
    locality: bool,
    overlap: bool,
}

fn main() {
    let topo = Topology::new(8, 4, 2, 8);
    let model = CostModel::h100_testbed();
    let per_gpu = 8u64 * 2048 * 2;
    let arms = [
        Arm { name: "none", warm: false, locality: false, overlap: false },
        Arm { name: "+warm solving", warm: true, locality: false, overlap: false },
        Arm { name: "+locality routing", warm: true, locality: true, overlap: false },
        Arm { name: "+overlap (full MicroMoE)", warm: true, locality: true, overlap: true },
        Arm { name: "vanilla Megatron-LM", warm: false, locality: false, overlap: true },
    ];

    let mut table = Table::new(
        "Fig 11: dispatch-time ablation (Fig-8 setting)",
        &["configuration", "gather+sched", "A2A (dispatch)", "dispatch total"],
    );
    let mut json = Vec::new();
    for arm in &arms {
        let vanilla = arm.name.starts_with("vanilla");
        let mut sched = MicroEpScheduler::new(
            symmetric_placement(&topo, 32),
            Some(topo.clone()),
            SchedulerOptions {
                warm_start: arm.warm,
                locality_aware: arm.locality,
                ..Default::default()
            },
        );
        let mut vanilla_sys =
            micromoe::baselines::VanillaEp::new(topo.clone(), 32);
        let mut rng = Rng::new(5);
        let zipf = Zipf::new(32, 1.0);
        let rounds = 12;
        let mut sched_t = 0.0;
        let mut a2a_t = 0.0;
        for _ in 0..rounds {
            let mut lm = LoadMatrix::zeros(32, 8);
            for g in 0..8 {
                for _ in 0..per_gpu {
                    lm.add(zipf.sample(&mut rng), g, 1);
                }
            }
            if vanilla {
                let plan = vanilla_sys.plan(&lm);
                a2a_t += model.a2a_time_from_routes(&plan.routes, 8, &topo);
            } else {
                let s = sched.schedule(&lm);
                let gather = model.allgather_time(4.0 * 64.0, 8, false);
                let solve = s.stats.solve_ns as f64 * 1e-9;
                sched_t += gather + if arm.overlap { 0.0 } else { solve };
                a2a_t += model.a2a_time_from_routes(&s.routes, 8, &topo);
            }
        }
        let n = rounds as f64;
        let (s_us, a_us) = (sched_t / n, a2a_t / n);
        table.row(vec![
            arm.name.to_string(),
            fmt_time(s_us),
            fmt_time(a_us),
            fmt_time(s_us + a_us),
        ]);
        json.push(Json::obj(vec![
            ("arm", Json::Str(arm.name.into())),
            ("sched_s", Json::Num(s_us)),
            ("a2a_s", Json::Num(a_us)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 11: warm solving + overlap shrink scheduling; locality \
         routing shrinks A2A; full MicroMoE adds only ~0.4 ms vs Megatron."
    );
    let _ = save_json("fig11", &Json::Arr(json));
}
