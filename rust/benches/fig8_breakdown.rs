//! Fig. 8 regenerator: execution-time breakdown of one MoE layer
//! (prep / dispatch A2A / expert compute / combine A2A) per system at the
//! paper's setting: DP=8, 32 experts, mbs=8, seq=2048, top-2, h=4096, s=1.
//! Systems are policies selected by name through the `MoeSession`
//! registry.
//!
//! Expected shape: compute dominates everywhere; MicroMoE's compute bar is
//! the shortest (perfect balance); MicroMoE's prep is slightly larger but
//! hidden by overlap; DeepSpeed omitted (as in the paper).

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::{fmt_time, mean_layer_breakdown, save_json, Table};
use micromoe::cluster::CostModel;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::LoadMatrix;
use micromoe::topology::Topology;

fn main() {
    let topo = Topology::new(8, 4, 2, 8);
    let model = CostModel::h100_testbed(); // h=4096 defaults
    let per_gpu = 8u64 * 2048 * 2; // mbs·seq·topK assignments per GPU

    let mut rng = Rng::new(5);
    let zipf = Zipf::new(32, 1.0);
    let batches: Vec<LoadMatrix> = (0..16)
        .map(|_| {
            let mut lm = LoadMatrix::zeros(32, 8);
            for g in 0..8 {
                for _ in 0..per_gpu {
                    lm.add(zipf.sample(&mut rng), g, 1);
                }
            }
            lm
        })
        .collect();

    let arms: [(&str, Option<usize>); 5] = [
        ("vanilla-ep", None),
        ("smartmoe", Some(8)),
        ("flexmoe", Some(8)),
        ("micromoe", None),
        ("micromoe-ar", Some(8)),
    ];
    let mut table = Table::new(
        "Fig 8: MoE layer time breakdown (DP=8, E=32, mbs=8, seq=2048, top2, h=4096, s=1)",
        &["system", "prep", "dispatch", "compute", "combine", "total"],
    );
    let mut json_rows = Vec::new();
    for (name, replan) in arms {
        let mut b = MoeSession::builder()
            .topology(topo.clone())
            .experts(32)
            .policy_name(name)
            .seed(if name == "flexmoe" { 1 } else { 3 });
        if let Some(every) = replan {
            b = b.replan_every(every);
        }
        let mut session = b.build().expect("fig8 session");
        // migrations amortize outside the layer: mean_layer_breakdown
        // already pulls prep_extra out of the per-layer numbers
        let (mean, _migration) = mean_layer_breakdown(&mut session, &batches, &model, &topo);
        table.row(vec![
            session.name().to_string(),
            fmt_time(mean.prep),
            fmt_time(mean.dispatch),
            fmt_time(mean.compute),
            fmt_time(mean.combine),
            fmt_time(mean.total()),
        ]);
        json_rows.push(micromoe::ser::Json::obj(vec![
            ("system", micromoe::ser::Json::Str(session.name().into())),
            ("prep", micromoe::ser::Json::Num(mean.prep)),
            ("dispatch", micromoe::ser::Json::Num(mean.dispatch)),
            ("compute", micromoe::ser::Json::Num(mean.compute)),
            ("combine", micromoe::ser::Json::Num(mean.combine)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 8: expert computation dominates; MicroMoE shortest compute; \
         each A2A ≈ 1.3 ms under NCCL."
    );
    let _ = save_json("fig8", &micromoe::ser::Json::Arr(json_rows));
}
