//! Fig. 8 regenerator: execution-time breakdown of one MoE layer
//! (prep / dispatch A2A / expert compute / combine A2A) per system at the
//! paper's setting: DP=8, 32 experts, mbs=8, seq=2048, top-2, h=4096, s=1.
//!
//! Expected shape: compute dominates everywhere; MicroMoE's compute bar is
//! the shortest (perfect balance); MicroMoE's prep is slightly larger but
//! hidden by overlap; DeepSpeed omitted (as in the paper).

use micromoe::adaptive::AdaptiveConfig;
use micromoe::baselines::{FlexMoe, MicroMoe, MoeSystem, SmartMoe, VanillaEp};
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::sim::{moe_layer_time, MoeLayerBreakdown};
use micromoe::cluster::CostModel;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::topology::Topology;

fn main() {
    let topo = Topology::new(8, 4, 2, 8);
    let model = CostModel::h100_testbed(); // h=4096 defaults
    let per_gpu = 8u64 * 2048 * 2; // mbs·seq·topK assignments per GPU

    let mut systems: Vec<Box<dyn MoeSystem>> = vec![
        Box::new(VanillaEp::new(topo.clone(), 32)),
        Box::new({
            let mut s = SmartMoe::new(topo.clone(), 32);
            s.replace_every = 8;
            s
        }),
        Box::new({
            let mut f = FlexMoe::new(topo.clone(), 32, 1);
            f.adjust_every = 8;
            f
        }),
        Box::new(MicroMoe::new(
            topo.clone(),
            symmetric_placement(&topo, 32),
            SchedulerOptions::default(),
        )),
        Box::new(
            MicroMoe::new(
                topo.clone(),
                symmetric_placement(&topo, 32),
                SchedulerOptions::default(),
            )
            .with_adaptive(
                AdaptiveConfig { check_every: 8, window: 8, slots_per_gpu: 8, ..Default::default() },
                3,
            ),
        ),
    ];

    let mut table = Table::new(
        "Fig 8: MoE layer time breakdown (DP=8, E=32, mbs=8, seq=2048, top2, h=4096, s=1)",
        &["system", "prep", "dispatch", "compute", "combine", "total"],
    );
    let mut json_rows = Vec::new();
    for sys in &mut systems {
        let mut rng = Rng::new(5);
        let zipf = Zipf::new(32, 1.0);
        let mut acc = MoeLayerBreakdown::default();
        let rounds = 16;
        for _ in 0..rounds {
            let mut lm = LoadMatrix::zeros(32, 8);
            for g in 0..8 {
                for _ in 0..per_gpu {
                    lm.add(zipf.sample(&mut rng), g, 1);
                }
            }
            let mut plan = sys.plan(&lm);
            plan.prep_extra = 0.0; // migrations amortize outside the layer
            let bd = moe_layer_time(&model, &topo, &plan);
            acc.prep += bd.prep;
            acc.dispatch += bd.dispatch;
            acc.compute += bd.compute;
            acc.combine += bd.combine;
        }
        let n = rounds as f64;
        let mean = MoeLayerBreakdown {
            prep: acc.prep / n,
            dispatch: acc.dispatch / n,
            compute: acc.compute / n,
            combine: acc.combine / n,
        };
        table.row(vec![
            sys.name().to_string(),
            fmt_time(mean.prep),
            fmt_time(mean.dispatch),
            fmt_time(mean.compute),
            fmt_time(mean.combine),
            fmt_time(mean.total()),
        ]);
        json_rows.push(micromoe::ser::Json::obj(vec![
            ("system", micromoe::ser::Json::Str(sys.name().into())),
            ("prep", micromoe::ser::Json::Num(mean.prep)),
            ("dispatch", micromoe::ser::Json::Num(mean.dispatch)),
            ("compute", micromoe::ser::Json::Num(mean.compute)),
            ("combine", micromoe::ser::Json::Num(mean.combine)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 8: expert computation dominates; MicroMoE shortest compute; \
         each A2A ≈ 1.3 ms under NCCL."
    );
    let _ = save_json("fig8", &micromoe::ser::Json::Arr(json_rows));
}
