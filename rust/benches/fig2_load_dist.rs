//! Fig. 2 regenerator: expert load distribution across training
//! iterations and its micro-batch-level fluctuation.
//!
//! Uses the real gate trace recorded by `examples/train_moe.rs`
//! (`artifacts/gate_trace.json`) when present, else a drifting synthetic
//! workload with the same statistics. Prints (a) the per-iteration load
//! share of the hottest experts (the left panel's skew) and (b) the L1
//! distance between consecutive micro-batches (the right panel's
//! fluctuation).

use micromoe::bench_harness::{save_json, Table};
use micromoe::scheduler::LoadMatrix;
use micromoe::ser::Json;
use micromoe::workload::{DriftingWorkload, TraceWorkload, Workload};

fn main() {
    let (mut source, origin): (Box<dyn Workload>, &str) =
        match std::fs::read_to_string("artifacts/gate_trace.json") {
            Ok(text) => {
                let t = TraceWorkload::from_json(&Json::parse(&text).unwrap()).unwrap();
                println!("using real gate trace ({} DP rounds)", t.len());
                (Box::new(t), "real training trace (train_moe)")
            }
            Err(_) => {
                println!("no artifacts/gate_trace.json — synthetic drifting workload");
                (Box::new(DriftingWorkload::new(32, 8, 2000, 1.0, 4, 7)), "synthetic")
            }
        };

    let batches: Vec<LoadMatrix> = (0..24).map(|_| source.next_batch()).collect();
    let e = batches[0].num_experts;

    let mut dist = Table::new(
        &format!("Fig 2 (left): expert load shares over iterations — {origin}"),
        &["iter", "max share", "top-3 share", "min share", "max/avg"],
    );
    for (i, lm) in batches.iter().enumerate().step_by(3) {
        let loads = lm.expert_loads();
        let total = lm.total().max(1) as f64;
        let mut sorted = loads.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top3: u64 = sorted.iter().take(3).sum();
        dist.row(vec![
            i.to_string(),
            format!("{:.3}", sorted[0] as f64 / total),
            format!("{:.3}", top3 as f64 / total),
            format!("{:.4}", *sorted.last().unwrap() as f64 / total),
            format!("{:.2}", sorted[0] as f64 / (total / e as f64)),
        ]);
    }
    dist.print();

    let mut fluct = Table::new(
        "Fig 2 (right): fluctuation between consecutive micro-batches",
        &["pair", "L1 distance (fraction of tokens)"],
    );
    let mut acc = 0.0;
    let pairs = batches.windows(2).take(10).count();
    for (i, w) in batches.windows(2).take(10).enumerate() {
        let (a, b) = (&w[0], &w[1]);
        let mut l1 = 0i64;
        for ei in 0..e {
            l1 += (a.expert_load(ei) as i64 - b.expert_load(ei) as i64).abs();
        }
        let frac = l1 as f64 / (a.total() + b.total()) as f64;
        acc += frac;
        fluct.row(vec![i.to_string(), format!("{frac:.3}")]);
    }
    fluct.print();
    println!(
        "\npaper: 'expert load distribution fluctuates significantly between \
         consecutive micro-batches' — mean fluctuation here {:.3}",
        acc / pairs as f64
    );
    let _ = save_json(
        "fig2",
        &Json::obj(vec![("dist", dist.to_json()), ("fluct", fluct.to_json())]),
    );
}
