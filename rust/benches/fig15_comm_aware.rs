//! Fig. 15 (App. C.3) regenerator: MoE-layer time with increasing levels
//! of locality in communication-aware scheduling — none → GPU-level →
//! GPU+node-level (α₁ = 0.1, α₂ = 1.0), DeepEP backend, 16 GPUs across
//! 2 nodes, 32 experts.

use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::sim::{moe_layer_time, MoeLayerPlan};
use micromoe::cluster::{CommBackend, CostModel};
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{
    LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions,
};
use micromoe::ser::Json;
use micromoe::topology::Topology;

fn main() {
    let topo = Topology::new(16, 8, 2, 8); // 16 GPUs = 2 nodes × 8
    let model = CostModel::h100_testbed()
        .for_hidden_size(2048)
        .with_backend(CommBackend::DeepEp);
    let e = 32;

    let arms: Vec<(&str, SchedulerOptions)> = vec![
        (
            "no locality (LPP 1)",
            SchedulerOptions {
                mode: ScheduleMode::Compute,
                locality_aware: false,
                ..Default::default()
            },
        ),
        (
            "GPU-level (LPP 4, α=1)",
            SchedulerOptions {
                mode: ScheduleMode::CommAware { alpha: 1.0 },
                locality_aware: true,
                ..Default::default()
            },
        ),
        (
            "GPU+node-level (α1=0.1, α2=1.0)",
            SchedulerOptions {
                mode: ScheduleMode::TopoAware { alpha1: 0.1, alpha2: 1.0 },
                locality_aware: true,
                topo_aware_routing: true,
                ..Default::default()
            },
        ),
    ];

    let mut table = Table::new(
        "Fig 15: MoE layer time vs locality levels (16 GPUs / 2 nodes, DeepEP)",
        &["scheduling", "dispatch", "compute", "total", "inter-node tokens"],
    );
    let mut json = Vec::new();
    for (name, opts) in arms {
        let mut sched = MicroEpScheduler::new(
            symmetric_placement(&topo, e),
            Some(topo.clone()),
            opts,
        );
        let mut rng = Rng::new(9);
        let zipf = Zipf::new(e, 0.8);
        let rounds = 8;
        let mut acc_total = 0.0;
        let mut acc_dispatch = 0.0;
        let mut acc_compute = 0.0;
        let mut inter_tokens = 0u64;
        for _ in 0..rounds {
            let mut lm = LoadMatrix::zeros(e, 16);
            for g in 0..16 {
                for _ in 0..4096 {
                    lm.add(zipf.sample(&mut rng), g, 1);
                }
            }
            let s = sched.schedule(&lm);
            inter_tokens += s
                .routes
                .iter()
                .filter(|r| !topo.same_node(r.src, r.dst))
                .map(|r| r.tokens)
                .sum::<u64>();
            let plan = MoeLayerPlan {
                gpu_compute: s.gpu_loads(&sched.placement),
                routes: s.routes,
                sched_time: s.stats.solve_ns as f64 * 1e-9,
                sched_overlapped: true,
                prep_extra: 0.0,
            };
            let bd = moe_layer_time(&model, &topo, &plan);
            acc_dispatch += bd.dispatch;
            acc_compute += bd.compute;
            acc_total += bd.total();
        }
        let n = rounds as f64;
        table.row(vec![
            name.to_string(),
            fmt_time(acc_dispatch / n),
            fmt_time(acc_compute / n),
            fmt_time(acc_total / n),
            format!("{}", inter_tokens / rounds),
        ]);
        json.push(Json::obj(vec![
            ("arm", Json::Str(name.into())),
            ("dispatch_s", Json::Num(acc_dispatch / n)),
            ("total_s", Json::Num(acc_total / n)),
            ("inter_tokens", Json::Num((inter_tokens / rounds) as f64)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 15: overall execution time decreases as more locality \
         levels are considered during scheduling."
    );
    let _ = save_json("fig15", &Json::Arr(json));
}
