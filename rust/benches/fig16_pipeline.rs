//! Fig. 16 (App. C.4) regenerator: dispatch time with pipelined MicroEP,
//! sweeping the fraction of tokens handled by MicroEP (1.0 = no
//! pipelining). 8 GPUs, 128 experts — the large-expert-count regime where
//! scheduling time is worth hiding. DeepEP backend.

use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::{CommBackend, CostModel};
use micromoe::moe::PipelinedMicroEp;
use micromoe::placement::cayley::symmetric_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::{LoadMatrix, SchedulerOptions};
use micromoe::ser::Json;
use micromoe::topology::Topology;

fn main() {
    let topo = Topology::new(8, 4, 2, 8);
    let e = 128;
    let model = CostModel::h100_testbed()
        .for_hidden_size(2048)
        .with_backend(CommBackend::DeepEp);

    let mut table = Table::new(
        "Fig 16: pipelined MicroEP dispatch time vs MicroEP ratio (8 GPUs, 128 experts)",
        &["ratio", "EP A2A", "sched (hidden behind EP A2A)", "MicroEP A2A", "dispatch total"],
    );
    let mut json = Vec::new();
    for ri in [2usize, 4, 6, 8, 10] {
        let ratio = ri as f64 / 10.0;
        let mut pm = PipelinedMicroEp::new(
            symmetric_placement(&topo, e),
            topo.clone(),
            SchedulerOptions::default(),
            ratio,
        );
        let mut rng = Rng::new(11);
        let zipf = Zipf::new(e, 0.8);
        let rounds = 6;
        let mut acc = [0.0f64; 4]; // ep_a2a, sched, micro_a2a, total
        for _ in 0..rounds {
            let mut lm = LoadMatrix::zeros(e, 8);
            for g in 0..8 {
                for _ in 0..8192 {
                    lm.add(zipf.sample(&mut rng), g, 1);
                }
            }
            let (_, bd) = pm.plan(&lm, &model);
            acc[0] += bd.ep_a2a;
            acc[1] += bd.sched;
            acc[2] += bd.micro_a2a;
            acc[3] += bd.total();
        }
        let n = rounds as f64;
        table.row(vec![
            format!("{ratio:.1}"),
            fmt_time(acc[0] / n),
            fmt_time(acc[1] / n),
            fmt_time(acc[2] / n),
            fmt_time(acc[3] / n),
        ]);
        json.push(Json::obj(vec![
            ("ratio", Json::Num(ratio)),
            ("ep_a2a_s", Json::Num(acc[0] / n)),
            ("sched_s", Json::Num(acc[1] / n)),
            ("micro_a2a_s", Json::Num(acc[2] / n)),
            ("total_s", Json::Num(acc[3] / n)),
        ]));
    }
    table.print();
    println!(
        "\npaper Fig 16: pipelining reduces dispatch time by overlapping \
         MicroEP preparation with the EP A2A; dispatch time grows as the \
         MicroEP ratio rises and the EP A2A becomes too short to hide the \
         scheduling."
    );
    let _ = save_json("fig16", &Json::Arr(json));
}
