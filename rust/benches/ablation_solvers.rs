//! Solver ablation (§9 Discussion + the revised-simplex perf claim): the
//! per-micro-batch scheduling solve implemented several ways —
//!
//! * dense full-tableau simplex (cold + warm), the original baseline;
//! * bounded-variable revised simplex (cold + warm), the production path;
//! * binary-search max-flow, the proposed inference path —
//!
//! measured for identical optima across scales. The headline number is the
//! warm p50 ratio tableau/revised in CommAware (LPP-4) mode at 64 GPUs ×
//! 256 experts, where the revised backend's implicit bounds remove ~nx
//! rows and its eta-updated B⁻¹ avoids the O(m·ncols) tableau sweep; the
//! JSON artifact also records warm pivot counts for both backends (the
//! warm-start contract must not regress).

use micromoe::bench_harness::{bench, fmt_time, save_json, Table};
use micromoe::lp::SolverKind;
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::flow::flow_schedule;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::ser::Json;

fn make_batches(g: usize, e: usize, n: usize) -> Vec<LoadMatrix> {
    let mut rng = Rng::new(3);
    let zipf = Zipf::new(e, 0.8);
    (0..n)
        .map(|_| {
            let mut lm = LoadMatrix::zeros(e, g);
            for gi in 0..g {
                for _ in 0..2048 {
                    lm.add(zipf.sample(&mut rng), gi, 1);
                }
            }
            lm
        })
        .collect()
}

struct Measured {
    p50: f64,
    /// mean warm pivots per solve (0 for cold configurations)
    warm_pivots: f64,
}

fn measure(
    g: usize,
    e: usize,
    mode: &ScheduleMode,
    solver: SolverKind,
    warm: bool,
    batches: &[LoadMatrix],
) -> Measured {
    let p = cayley_graph_placement(g, e);
    let mut s = MicroEpScheduler::new(
        p,
        None,
        SchedulerOptions { mode: mode.clone(), solver, warm_start: warm, ..Default::default() },
    );
    s.schedule(&batches[0]); // prime warm state / first build
    let mut pivots = 0usize;
    let mut solves = 0usize;
    let mut i = 0usize;
    let r = bench(&format!("{solver:?}-{}", if warm { "warm" } else { "cold" }), 1, 12, || {
        let sched = s.schedule(&batches[i % batches.len()]);
        pivots += sched.stats.lp_iterations;
        solves += 1;
        std::hint::black_box(&sched);
        i += 1;
    });
    Measured {
        p50: r.summary.p50,
        warm_pivots: if warm { pivots as f64 / solves as f64 } else { 0.0 },
    }
}

fn main() {
    let modes: [(&str, ScheduleMode); 2] = [
        ("LPP-1", ScheduleMode::Compute),
        ("LPP-4", ScheduleMode::CommAware { alpha: 0.7 }),
    ];
    let mut table = Table::new(
        "Solver ablation: dense tableau vs revised simplex vs max-flow",
        &[
            "mode", "GPUs", "experts", "tab cold", "tab warm", "rev cold", "rev warm",
            "warm speedup", "piv tab/rev", "flow", "optima agree",
        ],
    );
    let mut json = Vec::new();
    for (mode_name, mode) in &modes {
        for &(g, e) in &[(8usize, 32usize), (16, 64), (32, 128), (64, 256)] {
            let p = cayley_graph_placement(g, e);
            let batches = make_batches(g, e, 8);

            // optima agreement: revised vs tableau on every batch (and vs
            // max-flow for the LPP-1 integer bound)
            let mut agree = true;
            {
                let opts = |solver: SolverKind| SchedulerOptions {
                    mode: mode.clone(),
                    solver,
                    ..Default::default()
                };
                let mut sr = MicroEpScheduler::new(p.clone(), None, opts(SolverKind::Revised));
                let mut st = MicroEpScheduler::new(p.clone(), None, opts(SolverKind::DenseTableau));
                for lm in &batches {
                    let lr = sr.schedule(lm).stats.lp_objective;
                    let lt = st.schedule(lm).stats.lp_objective;
                    if (lr - lt).abs() > 1e-6 * (1.0 + lr.abs()) {
                        agree = false;
                    }
                    if matches!(mode, ScheduleMode::Compute) {
                        let fl = flow_schedule(&p, lm).max_load;
                        if (lr.ceil() as i64 - fl as i64).abs() > 1 {
                            agree = false;
                        }
                    }
                }
            }

            let tab_cold = measure(g, e, mode, SolverKind::DenseTableau, false, &batches);
            let tab_warm = measure(g, e, mode, SolverKind::DenseTableau, true, &batches);
            let rev_cold = measure(g, e, mode, SolverKind::Revised, false, &batches);
            let rev_warm = measure(g, e, mode, SolverKind::Revised, true, &batches);
            let mut i = 0usize;
            let r_flow = bench("flow", 1, 12, || {
                std::hint::black_box(flow_schedule(&p, &batches[i % 8]));
                i += 1;
            });
            let speedup = tab_warm.p50 / rev_warm.p50;
            let pivot_ratio = if rev_warm.warm_pivots > 0.0 {
                tab_warm.warm_pivots / rev_warm.warm_pivots
            } else {
                f64::INFINITY
            };
            table.row(vec![
                mode_name.to_string(),
                g.to_string(),
                e.to_string(),
                fmt_time(tab_cold.p50),
                fmt_time(tab_warm.p50),
                fmt_time(rev_cold.p50),
                fmt_time(rev_warm.p50),
                format!("{speedup:.2}x"),
                format!("{pivot_ratio:.2}"),
                fmt_time(r_flow.summary.p50),
                agree.to_string(),
            ]);
            json.push(Json::obj(vec![
                ("mode", Json::Str(mode_name.to_string())),
                ("gpus", Json::Num(g as f64)),
                ("experts", Json::Num(e as f64)),
                ("tableau_cold_s", Json::Num(tab_cold.p50)),
                ("tableau_warm_s", Json::Num(tab_warm.p50)),
                ("revised_cold_s", Json::Num(rev_cold.p50)),
                ("revised_warm_s", Json::Num(rev_warm.p50)),
                ("warm_speedup", Json::Num(speedup)),
                ("tableau_warm_pivots", Json::Num(tab_warm.warm_pivots)),
                ("revised_warm_pivots", Json::Num(rev_warm.warm_pivots)),
                ("flow_s", Json::Num(r_flow.summary.p50)),
                ("optima_agree", Json::Bool(agree)),
            ]));
        }
    }
    table.print();
    println!(
        "\nacceptance gate: LPP-4 (CommAware) @ 64 GPUs × 256 experts must show\n\
         revised warm p50 ≥2× faster than the dense tableau, with warm pivot\n\
         counts no worse. §9 Discussion: the flow solver needs no warm state,\n\
         suiting latency-sensitive inference."
    );
    let _ = save_json("ablation_solvers", &Json::Arr(json));
}
