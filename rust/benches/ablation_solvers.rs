//! Solver ablation (§9 Discussion + the revised-simplex perf claims): the
//! per-micro-batch scheduling solve implemented several ways —
//!
//! * dense full-tableau simplex (cold + warm), the original baseline;
//! * bounded-variable revised simplex in every (pricing × factorization)
//!   cell: {Dantzig, devex} × {dense explicit B⁻¹, sparse LU with
//!   Forrest–Tomlin updates};
//! * binary-search max-flow, the proposed inference path —
//!
//! measured for identical optima across scales. Two headline numbers on
//! the CommAware (LPP-4) 64 GPU × 256 expert workload: the warm p50 ratio
//! tableau/revised (implicit bounds remove ~nx rows; no O(m·ncols)
//! tableau sweep), and the warm *pivot* ratio Dantzig/devex (devex's
//! steepest-edge-like entering choices must cut pivots, its candidate
//! list must cut pricing cost). The JSON artifact records warm p50 and
//! pivot counts for every cell so regressions in any engine show up in CI
//! history.

use micromoe::bench_harness::{bench, fmt_ratio, fmt_time, save_json, Table};
use micromoe::lp::{FactorKind, Pricing, SolverKind};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::flow::flow_schedule;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, ScheduleMode, SchedulerOptions};
use micromoe::ser::Json;

/// Every backend cell: the dense tableau plus the four revised
/// (pricing × factorization) combinations.
fn backends() -> [SolverKind; 5] {
    [
        SolverKind::DenseTableau,
        SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::DenseInverse },
        SolverKind::Revised { pricing: Pricing::Dantzig, factor: FactorKind::SparseLu },
        SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::DenseInverse },
        SolverKind::Revised { pricing: Pricing::Devex, factor: FactorKind::SparseLu },
    ]
}

fn make_batches(g: usize, e: usize, n: usize) -> Vec<LoadMatrix> {
    let mut rng = Rng::new(3);
    let zipf = Zipf::new(e, 0.8);
    (0..n)
        .map(|_| {
            let mut lm = LoadMatrix::zeros(e, g);
            for gi in 0..g {
                for _ in 0..2048 {
                    lm.add(zipf.sample(&mut rng), gi, 1);
                }
            }
            lm
        })
        .collect()
}

struct Measured {
    p50: f64,
    /// mean warm pivots per solve (0 for cold configurations)
    warm_pivots: f64,
    /// mean warm dual-simplex pivots per solve (the BFRT's target metric)
    warm_dual_pivots: f64,
    /// mean nonbasic bound flips per solve (BFRT batches + primal flips)
    warm_bound_flips: f64,
    /// mean basis refactorizations per solve
    warm_refactors: f64,
}

fn measure(
    g: usize,
    e: usize,
    mode: &ScheduleMode,
    solver: SolverKind,
    warm: bool,
    batches: &[LoadMatrix],
) -> Measured {
    let p = cayley_graph_placement(g, e);
    let mut s = MicroEpScheduler::new(
        p,
        None,
        SchedulerOptions { mode: mode.clone(), solver, warm_start: warm, ..Default::default() },
    );
    s.schedule(&batches[0]); // prime warm state / first build
    let mut pivots = 0usize;
    let mut dual_pivots = 0usize;
    let mut bound_flips = 0usize;
    let mut refactors = 0usize;
    let mut solves = 0usize;
    let mut i = 0usize;
    let name = format!("{}-{}", solver.label(), if warm { "warm" } else { "cold" });
    let r = bench(&name, 1, 12, || {
        let sched = s.schedule(&batches[i % batches.len()]);
        pivots += sched.stats.lp_iterations;
        dual_pivots += sched.stats.lp_dual_pivots;
        bound_flips += sched.stats.lp_bound_flips;
        refactors += sched.stats.lp_refactors;
        solves += 1;
        std::hint::black_box(&sched);
        i += 1;
    });
    let per = |v: usize| if warm { v as f64 / solves as f64 } else { 0.0 };
    Measured {
        p50: r.summary.p50,
        warm_pivots: per(pivots),
        warm_dual_pivots: per(dual_pivots),
        warm_bound_flips: per(bound_flips),
        warm_refactors: per(refactors),
    }
}

fn main() {
    let modes: [(&str, ScheduleMode); 2] = [
        ("LPP-1", ScheduleMode::Compute),
        ("LPP-4", ScheduleMode::CommAware { alpha: 0.7 }),
    ];
    let mut table = Table::new(
        "Solver ablation: (pricing × factorization) cells vs dense tableau vs max-flow",
        &[
            "mode", "GPUs", "experts", "backend", "cold p50", "warm p50", "warm piv",
            "warm dpiv", "flips", "refac", "vs tab warm", "agree",
        ],
    );
    let mut json = Vec::new();
    // the acceptance-gate cells, filled at 64×256 LPP-4
    let mut gate: Vec<(String, f64, f64)> = Vec::new();
    for (mode_name, mode) in &modes {
        for &(g, e) in &[(8usize, 32usize), (16, 64), (32, 128), (64, 256)] {
            let p = cayley_graph_placement(g, e);
            let batches = make_batches(g, e, 8);

            // optima agreement: every backend pair on every batch (and vs
            // max-flow for the LPP-1 integer bound)
            let mut agree = true;
            {
                let opts = |solver: SolverKind| SchedulerOptions {
                    mode: mode.clone(),
                    solver,
                    ..Default::default()
                };
                let mut scheds: Vec<MicroEpScheduler> = backends()
                    .into_iter()
                    .map(|k| MicroEpScheduler::new(p.clone(), None, opts(k)))
                    .collect();
                for lm in &batches {
                    let objs: Vec<f64> =
                        scheds.iter_mut().map(|s| s.schedule(lm).stats.lp_objective).collect();
                    let base = objs[0];
                    if objs.iter().any(|&o| (o - base).abs() > 1e-6 * (1.0 + base.abs())) {
                        agree = false;
                    }
                    if matches!(mode, ScheduleMode::Compute) {
                        let fl = flow_schedule(&p, lm).max_load;
                        if (base.ceil() as i64 - fl as i64).abs() > 1 {
                            agree = false;
                        }
                    }
                }
            }

            let tab_warm_p50 = {
                let mut tab_warm = f64::NAN;
                for solver in backends() {
                    let cold = measure(g, e, mode, solver, false, &batches);
                    let warm = measure(g, e, mode, solver, true, &batches);
                    if solver == SolverKind::DenseTableau {
                        tab_warm = warm.p50;
                    }
                    table.row(vec![
                        mode_name.to_string(),
                        g.to_string(),
                        e.to_string(),
                        solver.label().to_string(),
                        fmt_time(cold.p50),
                        fmt_time(warm.p50),
                        format!("{:.1}", warm.warm_pivots),
                        format!("{:.1}", warm.warm_dual_pivots),
                        format!("{:.1}", warm.warm_bound_flips),
                        format!("{:.2}", warm.warm_refactors),
                        fmt_ratio(tab_warm, warm.p50), // tableau row: 1.00x
                        agree.to_string(),
                    ]);
                    json.push(Json::obj(vec![
                        ("mode", Json::Str(mode_name.to_string())),
                        ("gpus", Json::Num(g as f64)),
                        ("experts", Json::Num(e as f64)),
                        ("backend", Json::Str(solver.label().to_string())),
                        ("cold_s", Json::Num(cold.p50)),
                        ("warm_s", Json::Num(warm.p50)),
                        ("warm_pivots", Json::Num(warm.warm_pivots)),
                        ("warm_dual_pivots", Json::Num(warm.warm_dual_pivots)),
                        ("warm_bound_flips", Json::Num(warm.warm_bound_flips)),
                        ("warm_refactors", Json::Num(warm.warm_refactors)),
                        ("optima_agree", Json::Bool(agree)),
                    ]));
                    if *mode_name == "LPP-4" && g == 64 {
                        gate.push((solver.label().to_string(), warm.p50, warm.warm_dual_pivots));
                    }
                }
                tab_warm
            };
            let mut i = 0usize;
            let r_flow = bench("flow", 1, 12, || {
                std::hint::black_box(flow_schedule(&p, &batches[i % 8]));
                i += 1;
            });
            json.push(Json::obj(vec![
                ("mode", Json::Str(mode_name.to_string())),
                ("gpus", Json::Num(g as f64)),
                ("experts", Json::Num(e as f64)),
                ("backend", Json::Str("max-flow".to_string())),
                ("cold_s", Json::Num(r_flow.summary.p50)),
                ("optima_agree", Json::Bool(agree)),
            ]));
            table.row(vec![
                mode_name.to_string(),
                g.to_string(),
                e.to_string(),
                "max-flow".to_string(),
                fmt_time(r_flow.summary.p50),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                fmt_ratio(tab_warm_p50, r_flow.summary.p50),
                agree.to_string(),
            ]);
        }
    }
    table.print();
    let cell = |label: &str| gate.iter().find(|(l, _, _)| l == label).cloned();
    if let (Some(dx), Some(dv)) = (cell("dantzig+lu"), cell("devex+lu")) {
        println!(
            "\nacceptance gate (LPP-4 @ 64 GPUs × 256 experts, sparse-LU factors):\n\
             devex warm dual pivots {:.1} vs Dantzig {:.1} ({:.2}x fewer); \
             devex warm p50 {} vs Dantzig {}",
            dv.2,
            dx.2,
            dx.2 / dv.2.max(1e-9),
            fmt_time(dv.1),
            fmt_time(dx.1),
        );
    }
    println!(
        "gate: revised warm p50 must beat the dense tableau ≥2× at 64×256, devex must\n\
         cut warm pivots vs Dantzig, and the long-step dual's flips (warm_bound_flips)\n\
         must keep warm_dual_pivots below the one-flip-per-pivot baseline. §9\n\
         Discussion: the flow solver needs no warm state, suiting latency-sensitive\n\
         inference."
    );
    let _ = save_json("ablation_solvers", &Json::Arr(json));
}
