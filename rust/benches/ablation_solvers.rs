//! Solver ablation (§9 Discussion): the per-micro-batch scheduling solve
//! implemented three ways — cold simplex, warm-started simplex (the
//! training path), and binary-search max-flow (the proposed inference
//! path) — measured for identical optima across scales.

use micromoe::bench_harness::{bench, fmt_time, save_json, Table};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::rng::{Rng, Zipf};
use micromoe::scheduler::flow::flow_schedule;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::ser::Json;

fn main() {
    let mut table = Table::new(
        "Solver ablation: cold LP vs warm LP vs max-flow (same optima)",
        &["GPUs", "experts", "cold LP", "warm LP", "max-flow", "optima agree"],
    );
    let mut json = Vec::new();
    for &(g, e) in &[(8usize, 32usize), (16, 64), (32, 128), (64, 256)] {
        let p = cayley_graph_placement(g, e);
        let mut rng = Rng::new(3);
        let zipf = Zipf::new(e, 0.8);
        let mk = |rng: &mut Rng| {
            let mut lm = LoadMatrix::zeros(e, g);
            for gi in 0..g {
                for _ in 0..2048 {
                    lm.add(zipf.sample(rng), gi, 1);
                }
            }
            lm
        };
        let batches: Vec<LoadMatrix> = (0..8).map(|_| mk(&mut rng)).collect();

        // agreement check on every batch
        let mut agree = true;
        {
            let mut s = MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
            for lm in &batches {
                let lp = s.schedule(lm).stats.lp_objective;
                let fl = flow_schedule(&p, lm).max_load;
                if (lp.ceil() as i64 - fl as i64).abs() > 1 {
                    agree = false;
                }
            }
        }

        let mut cold =
            MicroEpScheduler::new(p.clone(), None, SchedulerOptions { warm_start: false, ..Default::default() });
        let mut i = 0usize;
        let r_cold = bench("cold", 1, 12, || {
            std::hint::black_box(cold.schedule(&batches[i % 8]));
            i += 1;
        });
        let mut warm =
            MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default());
        warm.schedule(&batches[0]);
        let mut i = 0usize;
        let r_warm = bench("warm", 1, 12, || {
            std::hint::black_box(warm.schedule(&batches[i % 8]));
            i += 1;
        });
        let mut i = 0usize;
        let r_flow = bench("flow", 1, 12, || {
            std::hint::black_box(flow_schedule(&p, &batches[i % 8]));
            i += 1;
        });
        table.row(vec![
            g.to_string(),
            e.to_string(),
            fmt_time(r_cold.summary.p50),
            fmt_time(r_warm.summary.p50),
            fmt_time(r_flow.summary.p50),
            agree.to_string(),
        ]);
        json.push(Json::obj(vec![
            ("gpus", Json::Num(g as f64)),
            ("experts", Json::Num(e as f64)),
            ("cold_s", Json::Num(r_cold.summary.p50)),
            ("warm_s", Json::Num(r_warm.summary.p50)),
            ("flow_s", Json::Num(r_flow.summary.p50)),
        ]));
    }
    table.print();
    println!(
        "\n§9 Discussion: 'we can replace the linear programming optimization \
         with … algorithms for reduced computational complexity' — the flow \
         solver needs no warm state, suiting latency-sensitive inference."
    );
    let _ = save_json("ablation_solvers", &Json::Arr(json));
}
