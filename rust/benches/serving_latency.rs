//! Serving-latency regenerator: open-loop batching-window serving
//! (ARCHITECTURE.md §9) comparing the registered policies under three
//! arrival regimes at 64- and 256-GPU scale.
//!
//! Every policy serves the **identical** request trace per (scale, regime)
//! — arrivals are seed-deterministic — so the per-request
//! queue/solve/dispatch percentiles and deadline-miss rates are directly
//! comparable: the LP/flow policies must buy their better-balanced plans
//! (lower modeled dispatch) back against their real solve wall time
//! ([`SolveCost::Wall`] + [`DispatchCost::Modeled`]).
//!
//! Smoke knobs (CI): `SERVING_BENCH_REQUESTS` (default 4000),
//! `SERVING_BENCH_GPUS` (comma list, default `64,256`).

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::cluster::CostModel;
use micromoe::engine::EngineMode;
use micromoe::ser::Json;
use micromoe::serving::{
    ArrivalGen, ArrivalProcess, DispatchCost, Request, ServingConfig, SlaStats, SolveCost,
    TokenModel,
};
use micromoe::topology::Topology;
use micromoe::workload::TopicMix;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_gpus() -> Vec<usize> {
    match std::env::var("SERVING_BENCH_GPUS") {
        Ok(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        Err(_) => vec![64, 256],
    }
}

/// The three arrival regimes, sized so one 500 µs window collects a
/// meaningful micro-batch at every scale.
fn regimes() -> Vec<(&'static str, ArrivalProcess)> {
    vec![
        ("poisson", ArrivalProcess::Poisson { rate_hz: 24_000.0 }),
        (
            "bursty",
            ArrivalProcess::Bursty {
                calm_hz: 12_000.0,
                burst_hz: 96_000.0,
                mean_calm_us: 20_000.0,
                mean_burst_us: 4_000.0,
            },
        ),
        (
            "diurnal",
            ArrivalProcess::Diurnal { base_hz: 18_000.0, amplitude: 0.9, period_us: 200_000.0 },
        ),
    ]
}

fn policies() -> Vec<(&'static str, &'static str, EngineMode)> {
    vec![
        ("vanilla-ep", "vanilla-ep", EngineMode::Barrier),
        ("lpp-barrier", "micromoe", EngineMode::Barrier),
        ("lpp-speculative", "micromoe", EngineMode::speculative()),
        ("max-flow", "least-loaded-inference", EngineMode::Barrier),
    ]
}

fn session(policy: &str, engine: EngineMode, label: &str, gpus: usize, experts: usize) -> MoeSession {
    let topo = Topology::new(gpus, gpus / 2, 2, 8);
    let mut b = MoeSession::builder().topology(topo).experts(experts).policy_name(policy).label(label);
    if !engine.is_barrier() {
        b = b.engine(engine);
    }
    b.build().expect("registered policy builds")
}

fn serve(label: &str, policy: &str, engine: EngineMode, gpus: usize, reqs: &[Request]) -> SlaStats {
    let experts = 2 * gpus;
    let cfg = ServingConfig {
        window_us: 500.0,
        max_batch: 64,
        slo_us: 10_000.0,
        shed_after_us: 20_000.0,
        solve_cost: SolveCost::Wall,
        dispatch_cost: DispatchCost::Modeled {
            model: CostModel::h100_testbed(),
            topo: Topology::new(gpus, gpus / 2, 2, 8),
        },
    };
    let s = session(policy, engine, label, gpus, experts);
    let mut server = s.serve(cfg, TopicMix::new(experts, 1.1, 25, 7));
    server.run(reqs);
    server.sla().clone()
}

fn main() {
    let requests = env_usize("SERVING_BENCH_REQUESTS", 4_000);
    let mut table = Table::new(
        &format!("open-loop serving latency over {requests} requests per (scale, regime)"),
        &["GPUs", "regime", "policy", "e2e p50", "e2e p95", "e2e p99", "solve p95", "miss%", "shed"],
    );
    let mut json = Vec::new();
    for gpus in env_gpus() {
        for (regime, process) in regimes() {
            // one shared trace per (scale, regime): every policy queues and
            // sheds against the same arrivals
            let reqs =
                ArrivalGen::new(process, TokenModel::Fixed(64), 17).take(requests);
            for (label, policy, engine) in policies() {
                let sla = serve(label, policy, engine, gpus, &reqs);
                table.row(vec![
                    gpus.to_string(),
                    regime.to_string(),
                    label.to_string(),
                    fmt_time(sla.e2e.exact(0.50) * 1e-6),
                    fmt_time(sla.e2e.exact(0.95) * 1e-6),
                    fmt_time(sla.e2e.p2_p99() * 1e-6),
                    fmt_time(sla.solve.exact(0.95) * 1e-6),
                    format!("{:.2}", sla.miss_rate() * 100.0),
                    sla.shed.to_string(),
                ]);
                json.push(Json::obj(vec![
                    ("gpus", Json::Num(gpus as f64)),
                    ("regime", Json::Str(regime.to_string())),
                    ("policy", Json::Str(label.to_string())),
                    ("requests", Json::Num(requests as f64)),
                    ("sla", sla.to_json()),
                ]));
            }
        }
    }
    table.print();
    println!(
        "\nserving contract: identical arrivals per (scale, regime); the LP/flow \
         policies must buy their better-balanced dispatch back against real \
         solve wall time. Compare e2e p95/p99 and miss%, not p50."
    );
    let _ = save_json("serving_latency", &Json::Arr(json));
}
