//! Fig. 6 regenerator: end-to-end speedup over Megatron-LM for every
//! Table-2 model × system, under drifting Zipf loads on the calibrated
//! H100 cluster model. Systems are policies selected by name through the
//! `MoeSession` registry.
//!
//! Expected shape (paper): MicroMoE best (up to ~1.48× there), FlexMoE
//! second, SmartMoE mixed (sometimes below Megatron once migrations are
//! charged), DeepSpeed collapsing at 16/32 experts and competitive at 8.

use micromoe::balancer::MoeSession;
use micromoe::bench_harness::{fig6_policy_arms, mean_layer_breakdown, save_json, Table};
use micromoe::cluster::migration::expert_bytes;
use micromoe::cluster::sim::TrainIterationModel;
use micromoe::cluster::CostModel;
use micromoe::config::table2;
use micromoe::scheduler::LoadMatrix;
use micromoe::ser::Json;
use micromoe::workload::{DriftingWorkload, Workload};

fn throughput(
    session: &mut MoeSession,
    batches: &[LoadMatrix],
    model: &CostModel,
    topo: &micromoe::topology::Topology,
    iter_model: &TrainIterationModel,
    tokens_per_iter: u64,
) -> f64 {
    let (mean, migration_per_batch) = mean_layer_breakdown(session, batches, model, topo);
    // migration is a one-off per replacement, amortized per iteration
    let iter_t = iter_model.iteration_time(&mean) + migration_per_batch;
    tokens_per_iter as f64 / iter_t
}

fn main() {
    let skew = 1.0;
    let mut all = Vec::new();
    let mut summary: Vec<(String, f64)> = Vec::new();
    for preset in table2() {
        let topo = preset.topology();
        let g = topo.microep_group_size();
        let e = preset.experts;
        let model = CostModel::h100_testbed().for_hidden_size(preset.hidden);
        let iter_model = TrainIterationModel::paper_default(
            preset.pp_degree,
            preset.layers,
            preset.num_microbatches(),
        );
        let bytes = expert_bytes(preset.hidden, preset.ffn_hidden, true);
        // drifting workload (per-iteration dynamics drive SmartMoE's
        // weakness and AR's value)
        let mut wl = DriftingWorkload::new(
            e,
            g,
            preset.assignments_per_gpu() / 4, // scaled-down token volume
            skew,
            6,
            42,
        );
        let batches: Vec<LoadMatrix> = (0..24).map(|_| wl.next_batch()).collect();

        let mut systems = fig6_policy_arms(&topo, e, Some((&model, bytes)));

        let mut table = Table::new(
            &format!("Fig 6: {} ({} GPUs, {e} experts, s={skew})", preset.name, preset.num_gpus),
            &["system", "tokens/s", "speedup vs Megatron"],
        );
        let mut base = 0.0;
        for session in &mut systems {
            let tput = throughput(
                session,
                &batches,
                &model,
                &topo,
                &iter_model,
                preset.tokens_per_gpu() * g as u64 * preset.num_microbatches() as u64,
            );
            if base == 0.0 {
                base = tput;
            }
            let speedup = tput / base;
            table.row(vec![
                session.name().to_string(),
                format!("{tput:.0}"),
                format!("{speedup:.3}x"),
            ]);
            if session.name() == "MicroMoE" {
                summary.push((preset.name.to_string(), speedup));
            }
        }
        table.print();
        all.push(table.to_json());
    }
    let max = summary.iter().map(|(_, s)| *s).fold(0.0, f64::max);
    let avg = summary.iter().map(|(_, s)| *s).sum::<f64>() / summary.len() as f64;
    println!(
        "\nMicroMoE speedup: max {:.1}% avg {:.1}%  (paper: max 47.6%, avg 36.9%)",
        (max - 1.0) * 100.0,
        (avg - 1.0) * 100.0
    );
    let _ = save_json("fig6", &Json::Arr(all));
}
