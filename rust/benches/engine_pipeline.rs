//! Scheduling-engine bench (Fig.-16-style, beyond the paper): barrier vs
//! pipeline vs pipeline+speculation at 64/128/256 GPUs on a trace-driven
//! (autocorrelated drifting-Zipf) workload.
//!
//! Measures the *critical-path* scheduling time per multi-layer step —
//! the wall time the trainer would actually block on — with a modelled
//! inter-step compute gap during which the speculative engine's forecast
//! pre-solves run off the critical path. Reports per mode: scheduling
//! time per step, token throughput through the scheduler, and (for the
//! speculative engine) the hit rate and the warm-repair pivots per hit
//! against the mean cold-solve pivot count on the same loads — the
//! acceptance numbers for the engine: pipeline ≥ barrier throughput at
//! 128 GPUs, hit rate > 0 on autocorrelated loads, repair pivots per hit
//! below cold pivots.
//!
//! A fourth arm re-runs the pipeline with an enabled Wall
//! [`micromoe::obs::Tracer`] and reports the recording overhead against
//! the (off-tracer) pipeline row — the ISSUE-9 tracing-cost meter.
//!
//! Env knobs (CI smoke): `ENGINE_BENCH_GPUS` (comma list, default
//! `64,128,256`), `ENGINE_BENCH_STEPS` (measured steps, default 8),
//! `ENGINE_BENCH_LAYERS` (default 4), `ENGINE_BENCH_GAP_US` (modelled
//! inter-step compute, default 2000).

use std::time::{Duration, Instant};

use micromoe::balancer::{MoeLayerPlan, MoeSession};
use micromoe::bench_harness::{fmt_time, save_json, Table};
use micromoe::engine::EngineMode;
use micromoe::obs::{TraceConfig, Tracer};
use micromoe::placement::cayley::cayley_graph_placement;
use micromoe::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use micromoe::ser::Json;
use micromoe::topology::Topology;
use micromoe::workload::{DriftingWorkload, Workload};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

const EXPERTS: usize = 256;
const TOKENS_PER_GPU: u64 = 2048;

/// Per-layer drifting-Zipf streams: autocorrelated like a real gate trace
/// (slow hot-set rotation), shared across all modes at one scale.
fn make_rounds(gpus: usize, layers: usize, rounds: usize) -> Vec<Vec<LoadMatrix>> {
    let mut streams: Vec<DriftingWorkload> = (0..layers)
        .map(|l| {
            DriftingWorkload::new(EXPERTS, gpus, TOKENS_PER_GPU, 0.9, 16, 1000 + l as u64)
        })
        .collect();
    (0..rounds)
        .map(|_| streams.iter_mut().map(|w| w.next_batch()).collect())
        .collect()
}

struct ModeResult {
    sched_s_per_step: f64,
    spec_hit_rate: f64,
    repair_pivots_per_hit: f64,
}

/// The per-layer dispatch stage a real consumer runs on every emitted
/// plan (what `MultiLayerSim::step` does with the cost model): derive the
/// all-to-all volumes. On the pipelined engine this overlaps the
/// remaining layers' solves; after a barrier it serializes.
fn dispatch_stage(plan: &MoeLayerPlan) {
    let gpus = plan.gpu_compute.len();
    let mut send = vec![0u64; gpus];
    let mut recv = vec![0u64; gpus];
    for r in &plan.routes {
        if r.src != r.dst {
            send[r.src] += r.tokens;
            recv[r.dst] += r.tokens;
        }
    }
    std::hint::black_box((send, recv));
}

/// Run one mode over the shared rounds through the `MoeSession` facade;
/// round 0 is warmup, the rest are measured. `gap` models the trainer's
/// compute between scheduling rounds (the window speculative pre-solves
/// hide in).
fn run_mode(
    mode: EngineMode,
    gpus: usize,
    layers: usize,
    rounds: &[Vec<LoadMatrix>],
    gap: Duration,
    tracer: Tracer,
) -> ModeResult {
    let placement = cayley_graph_placement(gpus, EXPERTS);
    let mut session = MoeSession::builder()
        .topology(Topology::new(gpus, gpus / 2, 2, 8))
        .placement(placement)
        .engine(mode)
        .tracer(tracer)
        .layers(layers)
        .build()
        .expect("engine bench session");
    let mut measured = 0.0f64;
    for (ri, loads) in rounds.iter().enumerate() {
        let t0 = Instant::now();
        // barrier: every dispatch waits for the slowest solve; engine
        // modes: per-layer dispatch overlaps the later layers' solves
        session.step_with(loads, &mut |_, plan| dispatch_stage(&plan));
        let dt = t0.elapsed().as_secs_f64();
        if ri > 0 {
            measured += dt;
        }
        std::thread::sleep(gap);
    }
    let steps = (rounds.len() - 1) as f64;
    let (hit_rate, rp) = match session.engine_stats() {
        Some(st) if st.spec_issued > 0 => (st.hit_rate(), st.repair_pivots_per_hit()),
        _ => (0.0, 0.0),
    };
    ModeResult {
        sched_s_per_step: measured / steps,
        spec_hit_rate: hit_rate,
        repair_pivots_per_hit: rp,
    }
}

/// Mean cold-solve pivots on the same loads (layer 0's stream) — the
/// baseline the speculative repair pivots must beat.
fn cold_pivots_mean(gpus: usize, rounds: &[Vec<LoadMatrix>]) -> f64 {
    let placement = cayley_graph_placement(gpus, EXPERTS);
    let mut s = MicroEpScheduler::new(
        placement,
        None,
        SchedulerOptions { warm_start: false, ..Default::default() },
    );
    let mut pivots = 0usize;
    let mut n = 0usize;
    for loads in rounds.iter().skip(1) {
        let sched = s.schedule(&loads[0]);
        pivots += sched.stats.lp_iterations;
        n += 1;
    }
    pivots as f64 / n.max(1) as f64
}

fn main() {
    let gpu_list: Vec<usize> = std::env::var("ENGINE_BENCH_GPUS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![64, 128, 256]);
    let steps = env_usize("ENGINE_BENCH_STEPS", 8);
    let layers = env_usize("ENGINE_BENCH_LAYERS", 4);
    let gap = Duration::from_micros(env_usize("ENGINE_BENCH_GAP_US", 2000) as u64);

    let modes: [(&str, EngineMode); 3] = [
        ("barrier", EngineMode::Barrier),
        ("pipeline", EngineMode::pipeline()),
        ("pipeline+spec", EngineMode::speculative()),
    ];

    let mut table = Table::new(
        "Scheduling engine: barrier vs pipeline vs pipeline+speculation \
         (256 experts, drifting-Zipf trace)",
        &[
            "GPUs", "mode", "sched/step", "tokens/s", "vs barrier", "hit rate",
            "piv/hit", "cold piv",
        ],
    );
    let mut json = Vec::new();
    for &gpus in &gpu_list {
        let rounds = make_rounds(gpus, layers, steps + 1);
        let tokens_per_step = (layers * gpus) as f64 * TOKENS_PER_GPU as f64;
        let cold_piv = cold_pivots_mean(gpus, &rounds);
        let mut barrier_thr = 0.0f64;
        let mut pipeline_sched = 0.0f64;
        for (name, mode) in modes.iter().copied() {
            let r = run_mode(mode, gpus, layers, &rounds, gap, Tracer::off());
            let thr = tokens_per_step / r.sched_s_per_step;
            if name == "barrier" {
                barrier_thr = thr;
            }
            if name == "pipeline" {
                pipeline_sched = r.sched_s_per_step;
            }
            let speculative = matches!(mode, EngineMode::Speculative { .. });
            table.row(vec![
                gpus.to_string(),
                name.to_string(),
                fmt_time(r.sched_s_per_step),
                format!("{:.2e}", thr),
                if barrier_thr > 0.0 { format!("{:.2}x", thr / barrier_thr) } else { "-".into() },
                if speculative { format!("{:.0}%", r.spec_hit_rate * 100.0) } else { "-".into() },
                if speculative { format!("{:.1}", r.repair_pivots_per_hit) } else { "-".into() },
                format!("{cold_piv:.1}"),
            ]);
            json.push(Json::obj(vec![
                ("gpus", Json::Num(gpus as f64)),
                ("experts", Json::Num(EXPERTS as f64)),
                ("layers", Json::Num(layers as f64)),
                ("mode", Json::Str(name.to_string())),
                ("sched_s_per_step", Json::Num(r.sched_s_per_step)),
                ("tokens_per_s", Json::Num(thr)),
                ("speedup_vs_barrier", Json::Num(if barrier_thr > 0.0 { thr / barrier_thr } else { 1.0 })),
                ("spec_hit_rate", Json::Num(r.spec_hit_rate)),
                ("repair_pivots_per_hit", Json::Num(r.repair_pivots_per_hit)),
                ("cold_pivots_mean", Json::Num(cold_piv)),
            ]));
        }

        // tracing-overhead arm: the pipeline row above *is* the
        // disabled-tracer baseline (the default tracer is off, and
        // tests/trace_identity.rs pins off == untraced bit-for-bit), so
        // one extra run with an enabled Wall tracer bounds the recording
        // cost from above — the off cost contract is <1% of it
        let wall = Tracer::new(TraceConfig::Wall);
        let r = run_mode(EngineMode::pipeline(), gpus, layers, &rounds, gap, wall.clone());
        let thr = tokens_per_step / r.sched_s_per_step;
        let overhead_pct = if pipeline_sched > 0.0 {
            (r.sched_s_per_step - pipeline_sched) / pipeline_sched * 100.0
        } else {
            0.0
        };
        table.row(vec![
            gpus.to_string(),
            "pipeline+trace".to_string(),
            fmt_time(r.sched_s_per_step),
            format!("{:.2e}", thr),
            if barrier_thr > 0.0 { format!("{:.2}x", thr / barrier_thr) } else { "-".into() },
            "-".into(),
            "-".into(),
            format!("{cold_piv:.1}"),
        ]);
        json.push(Json::obj(vec![
            ("gpus", Json::Num(gpus as f64)),
            ("experts", Json::Num(EXPERTS as f64)),
            ("layers", Json::Num(layers as f64)),
            ("mode", Json::Str("pipeline+trace".to_string())),
            ("sched_s_per_step", Json::Num(r.sched_s_per_step)),
            ("tokens_per_s", Json::Num(thr)),
            ("trace_overhead_pct", Json::num(overhead_pct)),
            ("trace_events", Json::Num(wall.event_count() as f64)),
        ]));
    }
    table.print();
    println!(
        "\nacceptance: pipeline ≥ barrier tokens/s at 128 GPUs (persistent \
         pool, no per-round spawns, dispatch overlaps later solves); \
         pipeline+spec hit rate > 0 with repair pivots per hit well under \
         the cold pivot count — the forecast pre-solve moved the work off \
         the critical path."
    );
    let _ = save_json("engine_pipeline", &Json::Arr(json));
}
