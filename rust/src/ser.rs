//! Minimal JSON substrate (`serde` is unavailable offline).
//!
//! Covers what the system needs: parsing the AOT `manifest.json`, reading
//! golden LP fixtures, and writing metrics/traces for benches and
//! EXPERIMENTS.md. Full RFC 8259 value model, UTF-8 strings with escapes,
//! recursive-descent parser with position-tagged errors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted for stable output).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug, thiserror::Error, PartialEq)]
#[error("json error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset into the input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl Json {
    // ---- constructors ----
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number with the crate-wide non-finite guard: NaN/±inf become
    /// `null`. JSON has no non-finite numbers — a raw `Json::Num(NaN)`
    /// would serialize as the invalid literal `NaN` — and the stats
    /// substrate uses NaN as its "no samples" sentinel, so every emitter
    /// of possibly-empty statistics must construct numbers through this.
    pub fn num(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    /// Array of numbers from f64s.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array of numbers from u64s.
    pub fn arr_u64(xs: &[u64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Array of numbers from usizes.
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors ----
    /// Object member lookup (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["config", "hidden"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---- parsing ----
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- writing ----
    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        newline(out, d + 1);
                        item.write(out, Some(d + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if indent.is_some() && !v.is_empty() {
                    newline(out, indent.unwrap());
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        newline(out, d + 1);
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if indent.is_some() && !m.is_empty() {
                    newline(out, indent.unwrap());
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn newline(out: &mut String, depth: usize) {
    out.push('\n');
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // no surrogate-pair support needed for our data
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{s}'") })
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "preset": "smoke",
          "config": {"hidden": 32, "experts": 4, "lr": 0.001},
          "artifacts": [{"name": "gate", "inputs": [{"shape": [64, 4], "dtype": "float32"}]}]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.path(&["config", "experts"]).unwrap().as_usize(), Some(4));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("gate"));
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert_eq!(e.pos, 6);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::Str("fig7".into())),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn numbers_integral_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn num_guards_non_finite() {
        assert_eq!(Json::num(2.5), Json::Num(2.5));
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(f64::NEG_INFINITY), Json::Null);
        // the guarded form always serializes to valid JSON
        assert_eq!(Json::num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }
}
