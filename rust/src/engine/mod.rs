//! Always-on scheduling engine: persistent worker pool, pipelined
//! multi-layer scheduling, and forecast-driven speculative pre-solves.
//!
//! The paper's claim — optimal load balance *every micro-batch* — only
//! pays off end-to-end if the per-layer LP solves stay off the training
//! critical path. This module is the serving-engine answer to that:
//!
//! * [`pool`] — a persistent pool of solver workers. Each worker **owns**
//!   the [`crate::scheduler::MicroEpScheduler`]s (and their warm-start
//!   bases) of the layers pinned to it for the pool's lifetime; no
//!   per-round thread spawns, no round barrier.
//! * [`pipeline`] — [`ScheduleEngine`], which submits layer commits under
//!   a bounded in-flight window and emits schedules strictly in layer
//!   order as they finish, so layer ℓ−1's routing/dispatch overlaps layer
//!   ℓ's LP solve ([`crate::cluster::sim::MultiLayerSim`] consumes this).
//! * [`forecast`] — [`LoadForecaster`], an EMA + sliding-window predictor
//!   of the next micro-batch's `input_e^g`. In speculative mode the engine
//!   pre-solves each layer against the forecast between steps; when the
//!   actual gate counts land it either warm-repairs the primed basis (a
//!   *hit*, when forecast drift is under threshold) or re-solves from
//!   scratch (a *miss*). Hit/miss/pivot counters surface in
//!   [`crate::stats::EngineStats`].
//!
//! The round-barrier path
//! ([`crate::scheduler::schedule_layers_parallel`]) remains selectable via
//! [`EngineMode::Barrier`] for ablation — `benches/engine_pipeline.rs`
//! measures barrier vs pipeline vs pipeline+speculation.

pub mod forecast;
pub mod pipeline;
pub mod pool;

pub use forecast::{ForecastConfig, LoadForecaster};
pub use pipeline::ScheduleEngine;
pub use pool::WorkerPool;

/// Unrecoverable engine failures. Transient worker deaths are *not* here —
/// the pool respawns dead workers and re-submits their in-flight jobs
/// transparently; these errors surface only when construction is
/// impossible or recovery has been exhausted, and the balancer layer
/// answers them with passthrough plans rather than a crash.
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum EngineError {
    /// [`ScheduleEngine`] was asked to run the round-barrier mode, which
    /// has no engine (use [`crate::scheduler::schedule_layers_parallel`]).
    #[error("ScheduleEngine requires EngineMode::Pipeline or EngineMode::Speculative, not Barrier")]
    BarrierMode,
    /// A worker died repeatedly without making progress; the pool stopped
    /// respawning it.
    #[error("scheduling worker {worker} exceeded {limit} consecutive respawns without progress")]
    RespawnLimit {
        /// Index of the repeatedly-dying worker.
        worker: usize,
        /// The consecutive-respawn cap that was exceeded.
        limit: usize,
    },
    /// The pool's result channel disconnected entirely.
    #[error("all scheduling workers disconnected")]
    PoolDisconnected,
}

/// How multi-layer scheduling executes
/// ([`crate::scheduler::SchedulerOptions::engine`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum EngineMode {
    /// Per-round scoped-thread fan-out with a round barrier
    /// ([`crate::scheduler::schedule_layers_parallel`]) — the PR-1 path,
    /// kept as the ablation baseline and the default.
    #[default]
    Barrier,
    /// Persistent worker pool with pipelined in-order emission
    /// ([`ScheduleEngine`]); bit-identical schedules to the serial loop.
    Pipeline {
        /// Worker threads (0 = one per core, capped at the layer count).
        workers: usize,
        /// Max layers submitted but not yet emitted (0 = 2 × workers).
        inflight: usize,
    },
    /// [`EngineMode::Pipeline`] plus forecast-driven speculative
    /// pre-solves between steps (hit: warm repair on actuals; miss past
    /// the drift threshold: fresh solve).
    Speculative {
        /// Worker threads (0 = one per core, capped at the layer count).
        workers: usize,
        /// Max layers submitted but not yet emitted (0 = 2 × workers).
        inflight: usize,
        /// Forecaster tuning and the hit/miss drift threshold.
        forecast: ForecastConfig,
    },
}

impl EngineMode {
    /// Pipelined engine with automatic sizing.
    pub fn pipeline() -> Self {
        EngineMode::Pipeline { workers: 0, inflight: 0 }
    }

    /// Speculative engine with automatic sizing and default forecasting.
    pub fn speculative() -> Self {
        EngineMode::Speculative { workers: 0, inflight: 0, forecast: ForecastConfig::default() }
    }

    /// Whether this is the round-barrier (non-engine) path.
    pub fn is_barrier(self) -> bool {
        matches!(self, EngineMode::Barrier)
    }
}
