//! Per-expert load forecasting for speculative pre-solves.
//!
//! Pro-Prophet and "Prediction Is All MoE Needs" both observe that expert
//! load is highly autocorrelated across training steps: the gate output of
//! micro-batch *k+1* is usually a small perturbation of micro-batch *k*.
//! [`LoadForecaster`] exploits that with a deliberately cheap predictor —
//! a per-cell exponential moving average blended with a sliding-window
//! mean over the recent `input_e^g` matrices — good enough to place the
//! warm-start basis near the next optimum *before* the real gate counts
//! land, and cheap enough to run per layer per step on the scheduling
//! thread.
//!
//! The speculation contract (driven by
//! [`super::ScheduleEngine`]): after observing step *k* the engine issues a
//! speculative pre-solve on [`LoadForecaster::forecast`]; when step *k+1*'s
//! actual loads arrive, [`LoadForecaster::drift`] — normalized L1 distance
//! between forecast and actuals — decides whether the primed basis is
//! trustworthy (a *hit*: warm-repair the bounds/rhs on the actuals) or not
//! (a *miss*: fall back to a fresh solve). The drift threshold lives in
//! [`ForecastConfig`].
//!
//! The arithmetic is pinned against a numpy transliteration
//! (`python/tools/forecast_reference.py` → `tests/golden_forecast.json`):
//! every operation here is written to match the reference evaluation order
//! exactly, so keep the two in sync when editing.

use crate::scheduler::LoadMatrix;
use crate::stats::VecWindow;

/// Tuning knobs for [`LoadForecaster`] and the speculation state machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ForecastConfig {
    /// EMA smoothing factor in (0, 1]: weight of the newest observation.
    pub ema_alpha: f64,
    /// Sliding-window length (most recent micro-batches averaged).
    pub window: usize,
    /// Weight of the EMA vs the window mean in the blended prediction
    /// (`blend·ema + (1−blend)·window_mean`).
    pub blend: f64,
    /// Normalized-L1 drift (`Σ|forecast − actual| / Σ actual`) above which
    /// a speculative pre-solve counts as a miss and the engine re-solves
    /// from scratch instead of warm-repairing a badly primed basis.
    pub drift_threshold: f64,
    /// Observations required before the first forecast is issued.
    pub min_history: usize,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        // The threshold must clear the multinomial sampling-noise floor:
        // with mean per-cell counts around 8–32 tokens the L1 drift of a
        // *perfect* mean predictor sits near 0.2–0.3 (≈ 0.8/√count), so
        // 0.5 accepts stationary workloads and rejects hot-set rotations.
        ForecastConfig {
            ema_alpha: 0.4,
            window: 4,
            blend: 0.5,
            drift_threshold: 0.5,
            min_history: 2,
        }
    }
}

/// EMA + sliding-window forecaster over `input_e^g` matrices (one instance
/// per MoE layer; layers' gate distributions are unrelated).
#[derive(Clone, Debug)]
pub struct LoadForecaster {
    cfg: ForecastConfig,
    experts: usize,
    gpus: usize,
    /// per-cell EMA, expert-major (matches [`LoadMatrix`] layout)
    ema: Vec<f64>,
    window: VecWindow,
    observed: usize,
}

/// Round half up — `numpy.round` rounds half to even, so both this and the
/// python reference use `floor(x + 0.5)` to keep integer forecasts
/// bit-identical across the two implementations.
fn round_half_up(v: f64) -> u64 {
    (v + 0.5).floor().max(0.0) as u64
}

impl LoadForecaster {
    /// Forecaster for `experts × gpus` load matrices.
    pub fn new(experts: usize, gpus: usize, cfg: ForecastConfig) -> Self {
        assert!(experts > 0 && gpus > 0);
        assert!(cfg.ema_alpha > 0.0 && cfg.ema_alpha <= 1.0, "alpha in (0,1]");
        assert!((0.0..=1.0).contains(&cfg.blend), "blend in [0,1]");
        assert!(cfg.window > 0 && cfg.drift_threshold >= 0.0);
        LoadForecaster {
            cfg,
            experts,
            gpus,
            ema: vec![0.0; experts * gpus],
            window: VecWindow::new(cfg.window),
            observed: 0,
        }
    }

    /// The configuration this forecaster was built with.
    pub fn config(&self) -> ForecastConfig {
        self.cfg
    }

    /// Micro-batches observed so far.
    pub fn observed(&self) -> usize {
        self.observed
    }

    /// Fold in one micro-batch's actual gate counts.
    pub fn observe(&mut self, loads: &LoadMatrix) {
        assert_eq!(loads.num_experts, self.experts, "expert count changed");
        assert_eq!(loads.num_gpus, self.gpus, "gpu count changed");
        let mut row = Vec::with_capacity(self.ema.len());
        for e in 0..self.experts {
            for g in 0..self.gpus {
                row.push(loads.get(e, g) as f64);
            }
        }
        if self.observed == 0 {
            self.ema.copy_from_slice(&row);
        } else {
            let a = self.cfg.ema_alpha;
            for (m, &x) in self.ema.iter_mut().zip(&row) {
                *m = a * x + (1.0 - a) * *m;
            }
        }
        self.window.push(row);
        self.observed += 1;
    }

    /// Unrounded per-cell prediction for the next micro-batch, expert-major
    /// (`None` until `min_history` observations have been folded in).
    pub fn forecast_dense(&self) -> Option<Vec<f64>> {
        if self.observed < self.cfg.min_history.max(1) {
            return None;
        }
        let wmean = self.window.mean()?;
        let b = self.cfg.blend;
        Some(
            self.ema
                .iter()
                .zip(&wmean)
                .map(|(&m, &w)| b * m + (1.0 - b) * w)
                .collect(),
        )
    }

    /// Integer forecast of the next `input_e^g` matrix (`None` until
    /// `min_history`). This is what the engine pre-solves against.
    pub fn forecast(&self) -> Option<LoadMatrix> {
        let dense = self.forecast_dense()?;
        let mut lm = LoadMatrix::zeros(self.experts, self.gpus);
        for e in 0..self.experts {
            for g in 0..self.gpus {
                lm.set(e, g, round_half_up(dense[e * self.gpus + g]));
            }
        }
        Some(lm)
    }

    /// Normalized L1 distance between a forecast and the actual loads:
    /// `Σ_{e,g} |pred − actual| / max(1, Σ actual)`. 0 = perfect forecast;
    /// 2.0 = completely disjoint load of equal volume.
    pub fn drift(pred: &LoadMatrix, actual: &LoadMatrix) -> f64 {
        assert_eq!(pred.num_experts, actual.num_experts);
        assert_eq!(pred.num_gpus, actual.num_gpus);
        let mut num = 0u64;
        for e in 0..actual.num_experts {
            for g in 0..actual.num_gpus {
                num += pred.get(e, g).abs_diff(actual.get(e, g));
            }
        }
        num as f64 / actual.total().max(1) as f64
    }

    /// Whether a forecast is close enough to the actuals to trust the
    /// speculatively primed basis (a speculation *hit*).
    pub fn is_hit(&self, pred: &LoadMatrix, actual: &LoadMatrix) -> bool {
        Self::drift(pred, actual) <= self.cfg.drift_threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lm_of(rows: Vec<Vec<u64>>) -> LoadMatrix {
        LoadMatrix::from_rows(rows)
    }

    #[test]
    fn no_forecast_before_min_history() {
        let mut f = LoadForecaster::new(2, 2, ForecastConfig::default());
        assert!(f.forecast().is_none());
        f.observe(&lm_of(vec![vec![1, 2], vec![3, 4]]));
        assert!(f.forecast().is_none(), "min_history = 2");
        f.observe(&lm_of(vec![vec![1, 2], vec![3, 4]]));
        assert!(f.forecast().is_some());
    }

    #[test]
    fn stationary_loads_forecast_exactly() {
        let mut f = LoadForecaster::new(2, 3, ForecastConfig::default());
        let lm = lm_of(vec![vec![10, 20, 30], vec![5, 0, 7]]);
        for _ in 0..5 {
            f.observe(&lm);
        }
        let pred = f.forecast().unwrap();
        assert_eq!(pred, lm);
        assert_eq!(LoadForecaster::drift(&pred, &lm), 0.0);
        assert!(f.is_hit(&pred, &lm));
    }

    #[test]
    fn drift_is_normalized_l1() {
        let a = lm_of(vec![vec![10, 0], vec![0, 10]]);
        let b = lm_of(vec![vec![0, 10], vec![10, 0]]);
        // disjoint equal-volume loads: |10-0|·4 / 20 = 2.0
        assert!((LoadForecaster::drift(&a, &b) - 2.0).abs() < 1e-12);
        // empty actuals: denominator clamps to 1
        let z = LoadMatrix::zeros(2, 2);
        assert!((LoadForecaster::drift(&a, &z) - 20.0).abs() < 1e-12);
        assert_eq!(LoadForecaster::drift(&z, &z), 0.0);
    }

    #[test]
    fn ema_tracks_level_shift_faster_than_window_alone() {
        let cfg = ForecastConfig { ema_alpha: 0.5, window: 4, blend: 1.0, ..Default::default() };
        let mut f = LoadForecaster::new(1, 1, cfg);
        for _ in 0..4 {
            f.observe(&lm_of(vec![vec![100]]));
        }
        for _ in 0..3 {
            f.observe(&lm_of(vec![vec![200]]));
        }
        // EMA after three 200s from 100: 100→150→175→187.5
        let dense = f.forecast_dense().unwrap();
        assert!((dense[0] - 187.5).abs() < 1e-9, "{}", dense[0]);
    }

    #[test]
    fn blend_mixes_ema_and_window_mean() {
        let cfg = ForecastConfig {
            ema_alpha: 1.0, // EMA == latest observation
            window: 2,
            blend: 0.5,
            ..Default::default()
        };
        let mut f = LoadForecaster::new(1, 1, cfg);
        f.observe(&lm_of(vec![vec![10]]));
        f.observe(&lm_of(vec![vec![30]]));
        // ema = 30, window mean = 20 → 0.5·30 + 0.5·20 = 25
        let dense = f.forecast_dense().unwrap();
        assert!((dense[0] - 25.0).abs() < 1e-12);
        assert_eq!(f.forecast().unwrap().get(0, 0), 25);
    }

    #[test]
    fn rounding_is_half_up() {
        assert_eq!(round_half_up(2.5), 3);
        assert_eq!(round_half_up(2.49), 2);
        assert_eq!(round_half_up(0.0), 0);
        assert_eq!(round_half_up(-0.4), 0);
    }
}
