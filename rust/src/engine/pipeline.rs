//! The pipelined scheduling engine: stage overlap + speculation driver.
//!
//! [`ScheduleEngine`] replaces the round-barrier
//! [`crate::scheduler::schedule_layers_parallel`] path for multi-layer
//! scheduling. Per step it:
//!
//! 1. submits layer commits to the persistent [`super::WorkerPool`] under a
//!    **bounded in-flight window** (at most `inflight` layers submitted but
//!    not yet emitted — backpressure that keeps queue memory and staleness
//!    bounded),
//! 2. **emits schedules strictly in layer order** as they complete, so the
//!    caller processes layer ℓ−1's routing/dispatch while layers ℓ… are
//!    still solving in the pool (the stage overlap
//!    [`crate::cluster::sim::MultiLayerSim`] exploits), and
//! 3. in speculative mode, folds the step's actual loads into the
//!    per-layer [`super::LoadForecaster`]s and issues **speculative
//!    pre-solves** for the *next* step — the pool warms each layer's basis
//!    against the forecast while the trainer is busy with compute, so the
//!    next commit is a cheap warm repair (a *hit*) unless the forecast
//!    drifted past the threshold (a *miss*, re-solved from scratch).
//!
//! Determinism: layer → worker pinning plus per-worker FIFO queues mean
//! every layer's solver sees an identical job sequence regardless of
//! worker count, and the in-order emission makes the output sequence
//! identical to the serial loop. Speculation changes which basis a solve
//! starts from (so it is *not* bit-identical to the non-speculative path)
//! but remains deterministic for a fixed load history.

use std::sync::Arc;

use crate::placement::Placement;
use crate::scheduler::{LoadMatrix, Schedule, SchedulerOptions};
use crate::stats::EngineStats;
use crate::topology::Topology;

use super::forecast::LoadForecaster;
use super::pool::WorkerPool;
use super::{EngineError, EngineMode};

/// Speculation verdict for one layer of one step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SpecDecision {
    /// No pre-solve was issued for this layer (warmup, or pipeline mode).
    None,
    /// Forecast within the drift threshold: trust the primed basis and
    /// warm-repair on the actuals.
    Hit,
    /// Forecast drifted: the primed basis is not worth repairing from —
    /// solve the actuals from scratch.
    Miss,
}

/// Always-on multi-layer scheduling engine (persistent pool + pipelined
/// emission + optional forecast-driven speculation).
pub struct ScheduleEngine {
    pool: WorkerPool,
    layers: usize,
    inflight: usize,
    /// per-layer forecasters; empty in pipeline mode (each carries the
    /// forecast config, including the drift threshold)
    forecasters: Vec<LoadForecaster>,
    /// forecast a pre-solve was issued against, per layer (next step's);
    /// shares the allocation the pool pre-solved
    pending: Vec<Option<Arc<LoadMatrix>>>,
    /// commit-step counter, stamped into every commit job — authoritative
    /// for fault injection, so `(step, layer)` slots stay deterministic
    /// across worker respawns and job replays
    step: usize,
    stats: EngineStats,
    /// clone of `opts.trace` (the pool owns the options); emits one
    /// [`crate::obs::Span::Engine`] per in-order emission, so engine-span
    /// counts match [`EngineStats::schedules`]
    trace: crate::obs::Tracer,
}

impl ScheduleEngine {
    /// Build the engine for `layers` MoE layers over one shared placement.
    /// `opts.engine` selects the mode and sizing; [`EngineMode::Barrier`]
    /// is the one mode this engine does not implement (use
    /// [`crate::scheduler::schedule_layers_parallel`] for that) and yields
    /// [`EngineError::BarrierMode`].
    pub fn new(
        placement: Placement,
        topo: Option<Topology>,
        opts: SchedulerOptions,
        layers: usize,
    ) -> Result<Self, EngineError> {
        assert!(layers > 0, "engine needs at least one layer");
        let (workers, inflight, forecast_cfg) = match opts.engine {
            EngineMode::Barrier => return Err(EngineError::BarrierMode),
            EngineMode::Pipeline { workers, inflight } => (workers, inflight, None),
            EngineMode::Speculative { workers, inflight, forecast } => {
                (workers, inflight, Some(forecast))
            }
        };
        let experts = placement.num_experts;
        let gpus = placement.num_gpus;
        let trace = opts.trace.clone();
        let pool = WorkerPool::new(placement, topo, opts, layers, workers);
        let inflight = if inflight == 0 { 2 * pool.workers() } else { inflight }.clamp(1, layers);
        let forecasters = match forecast_cfg {
            Some(cfg) => (0..layers).map(|_| LoadForecaster::new(experts, gpus, cfg)).collect(),
            None => Vec::new(),
        };
        Ok(ScheduleEngine {
            pool,
            layers,
            inflight,
            forecasters,
            pending: (0..layers).map(|_| None).collect(),
            step: 0,
            stats: EngineStats::default(),
            trace,
        })
    }

    /// MoE layers scheduled per step.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// In-flight window bound (max submitted-but-unemitted layers).
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Whether speculative pre-solves are enabled.
    pub fn speculative(&self) -> bool {
        !self.forecasters.is_empty()
    }

    /// Cumulative engine counters (steps, hits/misses, pivot meters).
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Prime every layer's warm basis by submitting speculative pre-solves
    /// on the given expected loads (`expected[l]` for layer `l`). No
    /// schedule is returned; the pool's results are metered as
    /// off-critical-path pre-solve work when they drain during later
    /// steps. Unlike the automatic speculation loop this never registers a
    /// pending forecast, so it cannot produce hits or misses — it only
    /// moves each layer's warm-start state toward the expected optimum.
    /// Works in pipeline mode too, where it is the only source of
    /// speculative jobs. Best-effort: priming is an optimization, so a
    /// worker already past its respawn limit is ignored here and surfaces
    /// on the next [`Self::schedule_step`] instead.
    pub fn prime(&mut self, expected: &[LoadMatrix]) {
        assert_eq!(expected.len(), self.layers, "one expected load matrix per layer");
        for (l, lm) in expected.iter().enumerate() {
            let _ = self.pool.submit_speculate(l, Arc::new(lm.clone()));
        }
    }

    /// Schedule one micro-batch for every layer; `loads[l]` is layer `l`'s
    /// `input_e^g`. Returns schedules in layer order. Errs only when a
    /// worker exceeds the pool's respawn limit (transient worker deaths
    /// are recovered internally); the step is then incomplete and the
    /// caller decides the fallback (the balancer layer emits passthrough
    /// plans).
    pub fn schedule_step(&mut self, loads: &[LoadMatrix]) -> Result<Vec<Schedule>, EngineError> {
        let mut out: Vec<Option<Schedule>> = (0..self.layers).map(|_| None).collect();
        self.schedule_step_with(loads, |layer, s| out[layer] = Some(s))?;
        Ok(out.into_iter().map(|s| s.expect("every layer emitted")).collect())
    }

    /// Like [`Self::schedule_step`], but hands each schedule to `sink` in
    /// layer order *as soon as it is available* — the caller's per-layer
    /// stage (routing/dispatch timing, tensor permutation, …) overlaps the
    /// remaining layers' LP solves. On `Err`, every schedule already
    /// handed to `sink` stays valid; the remaining layers were never
    /// emitted.
    pub fn schedule_step_with<F>(
        &mut self,
        loads: &[LoadMatrix],
        mut sink: F,
    ) -> Result<(), EngineError>
    where
        F: FnMut(usize, Schedule),
    {
        assert_eq!(loads.len(), self.layers, "one load matrix per layer");
        self.stats.steps += 1;
        let step = self.step;
        self.step += 1;

        // ---- speculation verdicts for this step's commits ----
        let decisions: Vec<SpecDecision> = (0..self.layers)
            .map(|l| match self.pending[l].take() {
                Some(pred) => {
                    if self.forecasters[l].is_hit(&pred, &loads[l]) {
                        SpecDecision::Hit
                    } else {
                        SpecDecision::Miss
                    }
                }
                None => SpecDecision::None,
            })
            .collect();

        // ---- bounded-window submission, deterministic in-order emission ----
        let mut stash: Vec<Option<Schedule>> = (0..self.layers).map(|_| None).collect();
        let mut submitted = 0usize;
        let mut emitted = 0usize;
        while emitted < self.layers {
            while submitted < self.layers && submitted - emitted < self.inflight {
                let cold = decisions[submitted] == SpecDecision::Miss;
                self.pool.submit_commit(step, submitted, Arc::new(loads[submitted].clone()), cold)?;
                submitted += 1;
            }
            let r = self.pool.recv()?;
            if r.speculative {
                // a pre-solve issued at the end of the previous step; its
                // work happened off the critical path — just meter it
                self.stats.spec_presolve_pivots += r.schedule.stats.lp_iterations as u64;
                continue;
            }
            stash[r.layer] = Some(r.schedule);
            while emitted < self.layers {
                let Some(s) = stash[emitted].take() else { break };
                self.stats.schedules += 1;
                match decisions[emitted] {
                    SpecDecision::Hit => {
                        self.stats.spec_hits += 1;
                        self.stats.hit_repair_pivots += s.stats.lp_iterations as u64;
                    }
                    SpecDecision::Miss => {
                        self.stats.spec_misses += 1;
                        self.stats.miss_solve_pivots += s.stats.lp_iterations as u64;
                    }
                    SpecDecision::None => {}
                }
                self.trace.record(
                    s.stats.solve_ns as f64 / 1_000.0,
                    crate::obs::Span::Engine {
                        step,
                        layer: emitted,
                        worker: emitted % self.pool.workers(),
                        outcome: match decisions[emitted] {
                            SpecDecision::Hit => crate::obs::SpanOutcome::Hit,
                            SpecDecision::Miss => crate::obs::SpanOutcome::Miss,
                            SpecDecision::None => crate::obs::SpanOutcome::Fresh,
                        },
                        inflight: submitted - emitted,
                        pivots: s.stats.lp_iterations,
                    },
                );
                sink(emitted, s);
                emitted += 1;
            }
        }

        // ---- learn this step's actuals, pre-solve the next step ----
        if !self.forecasters.is_empty() {
            for (l, lm) in loads.iter().enumerate() {
                self.forecasters[l].observe(lm);
                if let Some(pred) = self.forecasters[l].forecast() {
                    let pred = Arc::new(pred);
                    self.pool.submit_speculate(l, Arc::clone(&pred))?;
                    self.pending[l] = Some(pred);
                    self.stats.spec_issued += 1;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;
    use crate::scheduler::MicroEpScheduler;

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    fn pipeline_opts(workers: usize, inflight: usize) -> SchedulerOptions {
        SchedulerOptions {
            engine: EngineMode::Pipeline { workers, inflight },
            ..Default::default()
        }
    }

    #[test]
    fn pipeline_matches_serial_schedulers() {
        let p = cayley_graph_placement(8, 16);
        let layers = 4;
        let mut engine =
            ScheduleEngine::new(p.clone(), None, pipeline_opts(2, 2), layers).unwrap();
        let mut serial: Vec<MicroEpScheduler> = (0..layers)
            .map(|_| MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default()))
            .collect();
        for round in 0..3 {
            let loads: Vec<LoadMatrix> =
                (0..layers).map(|l| random_lm(round * 10 + l as u64, 16, 8, 1200)).collect();
            let got = engine.schedule_step(&loads).unwrap();
            let want: Vec<Schedule> =
                serial.iter_mut().zip(&loads).map(|(s, lm)| s.schedule(lm)).collect();
            for (l, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.replica_loads, b.replica_loads, "round {round} layer {l}");
                assert_eq!(a.routes, b.routes, "round {round} layer {l}");
            }
        }
        let st = engine.stats();
        assert_eq!(st.steps, 3);
        assert_eq!(st.schedules, 3 * layers as u64);
        assert_eq!(st.spec_issued, 0, "pipeline mode must not speculate");
    }

    #[test]
    fn emission_is_in_layer_order() {
        let p = cayley_graph_placement(4, 8);
        let layers = 6;
        let mut engine =
            ScheduleEngine::new(p, None, pipeline_opts(3, 2), layers).unwrap();
        let loads: Vec<LoadMatrix> =
            (0..layers).map(|l| random_lm(l as u64, 8, 4, 600)).collect();
        let mut order = Vec::new();
        engine.schedule_step_with(&loads, |l, _| order.push(l)).unwrap();
        assert_eq!(order, (0..layers).collect::<Vec<_>>());
    }

    #[test]
    fn speculation_hits_on_stationary_loads() {
        let p = cayley_graph_placement(8, 16);
        let layers = 2;
        let opts = SchedulerOptions {
            engine: EngineMode::speculative(),
            ..Default::default()
        };
        let mut engine = ScheduleEngine::new(p, None, opts, layers).unwrap();
        let lm = random_lm(3, 16, 8, 2000);
        let loads = vec![lm.clone(), lm.clone()];
        for _ in 0..5 {
            let scheds = engine.schedule_step(&loads).unwrap();
            for s in &scheds {
                let total: u64 =
                    s.replica_loads.iter().map(|v| v.iter().sum::<u64>()).sum();
                assert_eq!(total, lm.total());
            }
        }
        let st = engine.stats();
        assert!(st.spec_issued > 0, "no speculations issued");
        assert!(st.spec_hits > 0, "stationary loads must hit: {st:?}");
        assert_eq!(st.spec_misses, 0, "stationary loads must never miss: {st:?}");
        // a hit's warm repair on identical loads is (near-)free
        assert!(
            st.repair_pivots_per_hit() <= 2.0,
            "stationary repairs should be trivial: {st:?}"
        );
    }

    #[test]
    fn speculation_misses_on_load_jumps() {
        let p = cayley_graph_placement(4, 8);
        let opts = SchedulerOptions {
            engine: EngineMode::speculative(),
            ..Default::default()
        };
        let mut engine = ScheduleEngine::new(p, None, opts, 1).unwrap();
        // concentrate all load on a rotating expert: every step is a jump
        for step in 0..6 {
            let mut lm = LoadMatrix::zeros(8, 4);
            lm.set(step % 8, 0, 4000);
            engine.schedule_step(&[lm]).unwrap();
        }
        let st = engine.stats();
        assert!(st.spec_issued > 0);
        assert!(st.spec_misses > 0, "rotating hot expert must miss: {st:?}");
    }
}
