//! Persistent scheduling worker pool.
//!
//! The PR-1 parallel path ([`crate::scheduler::schedule_layers_parallel`])
//! re-spawns scoped threads every round — measurable overhead once
//! per-layer solves drop under ~100 µs — and its round barrier couples
//! every layer to the slowest one. This pool fixes the ownership story
//! instead: each worker thread **owns** the [`MicroEpScheduler`]s (and
//! therefore the warm-start bases) of the layers assigned to it, for the
//! lifetime of the pool. Layer `l` is pinned to worker `l % workers`, and
//! each worker drains its job queue in FIFO order, so a layer's solver
//! sees exactly the same job sequence regardless of how many workers
//! exist — the §5.3 determinism property extends to the pool for free,
//! which `tests/integration_scheduler.rs` pins across 1/2/8 workers.
//!
//! Jobs are either *commits* (solve + route the actual micro-batch loads)
//! or *speculative pre-solves* (prime the warm basis with forecast loads;
//! the schedule itself is discarded by the engine). Results flow back over
//! one shared channel and are re-ordered by the engine
//! ([`super::ScheduleEngine`]), never here.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::placement::Placement;
use crate::scheduler::{LoadMatrix, MicroEpScheduler, Schedule, SchedulerOptions};
use crate::topology::Topology;

/// One unit of work for a layer-owning worker. Loads travel as `Arc`s so
/// the engine can share one allocation between the pool and its own
/// bookkeeping (forecasts) instead of deep-copying per consumer.
enum Job {
    /// Solve + route actual loads; `cold` forces a from-scratch solve
    /// (speculation miss: the primed basis is too far off to repair).
    Commit {
        layer: usize,
        loads: Arc<LoadMatrix>,
        cold: bool,
    },
    /// Speculative pre-solve on forecast loads: primes the layer's warm
    /// basis; the engine meters the pivots and drops the schedule.
    Speculate { layer: usize, loads: Arc<LoadMatrix> },
}

/// A completed job, tagged for re-ordering by the engine.
pub(crate) struct JobResult {
    /// Layer the schedule belongs to.
    pub layer: usize,
    /// Whether this was a speculative pre-solve (schedule is discarded).
    pub speculative: bool,
    /// The produced schedule.
    pub schedule: Schedule,
}

/// Always-on pool of solver workers, each owning the warm-start state of
/// its layers across steps (no per-round spawns).
pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    results: Receiver<JobResult>,
    handles: Vec<JoinHandle<()>>,
    layers: usize,
}

impl WorkerPool {
    /// Spawn `workers` threads (0 = one per core), each constructing and
    /// owning one [`MicroEpScheduler`] per layer it is pinned to. Worker
    /// count is capped at the layer count — extra threads could never
    /// receive work.
    pub fn new(
        placement: Placement,
        topo: Option<Topology>,
        opts: SchedulerOptions,
        layers: usize,
        workers: usize,
    ) -> Self {
        assert!(layers > 0, "pool needs at least one layer");
        let workers = if workers == 0 {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        } else {
            workers
        }
        .clamp(1, layers);
        let (res_tx, results) = channel::<JobResult>();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            let res_tx = res_tx.clone();
            let placement = placement.clone();
            let topo = topo.clone();
            let opts = opts.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sched-worker-{w}"))
                .spawn(move || {
                    // One warm scheduler per owned layer, alive across steps
                    // — the whole point of the persistent pool. Built inside
                    // the thread so solver state never crosses threads.
                    let mut scheds: Vec<Option<MicroEpScheduler>> = (0..layers)
                        .map(|l| {
                            (l % workers == w).then(|| {
                                MicroEpScheduler::new(
                                    placement.clone(),
                                    topo.clone(),
                                    opts.clone(),
                                )
                            })
                        })
                        .collect();
                    while let Ok(job) = rx.recv() {
                        let (layer, speculative, schedule) = match job {
                            Job::Commit { layer, loads, cold } => {
                                let s = scheds[layer].as_mut().expect("job routed to owner");
                                let schedule =
                                    if cold { s.schedule_cold(&loads) } else { s.schedule(&loads) };
                                (layer, false, schedule)
                            }
                            Job::Speculate { layer, loads } => {
                                let s = scheds[layer].as_mut().expect("job routed to owner");
                                (layer, true, s.schedule(&loads))
                            }
                        };
                        if res_tx.send(JobResult { layer, speculative, schedule }).is_err() {
                            break; // engine gone: shut down
                        }
                    }
                })
                .expect("spawn scheduler worker");
            handles.push(handle);
        }
        WorkerPool { senders, results, handles, layers }
    }

    /// Worker threads actually running (after the layer-count cap).
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Layers this pool schedules.
    pub fn layers(&self) -> usize {
        self.layers
    }

    pub(crate) fn submit_commit(&self, layer: usize, loads: Arc<LoadMatrix>, cold: bool) {
        assert!(layer < self.layers);
        self.senders[layer % self.senders.len()]
            .send(Job::Commit { layer, loads, cold })
            .expect("worker thread alive");
    }

    pub(crate) fn submit_speculate(&self, layer: usize, loads: Arc<LoadMatrix>) {
        assert!(layer < self.layers);
        self.senders[layer % self.senders.len()]
            .send(Job::Speculate { layer, loads })
            .expect("worker thread alive");
    }

    /// Blocking receive of the next finished job (any layer, any kind).
    pub(crate) fn recv(&self) -> JobResult {
        self.results.recv().expect("a worker owes a result")
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channels lets each worker drain what it has and
        // exit; results they still send land in the buffered channel and
        // are dropped with it.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    #[test]
    fn pool_caps_workers_at_layer_count() {
        let p = cayley_graph_placement(4, 8);
        let pool = WorkerPool::new(p, None, SchedulerOptions::default(), 2, 16);
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.layers(), 2);
    }

    #[test]
    fn pool_solves_and_reports_every_layer() {
        let p = cayley_graph_placement(4, 8);
        let layers = 3;
        let pool = WorkerPool::new(p, None, SchedulerOptions::default(), layers, 2);
        let loads: Vec<LoadMatrix> =
            (0..layers).map(|l| random_lm(l as u64, 8, 4, 500)).collect();
        for (l, lm) in loads.iter().enumerate() {
            pool.submit_commit(l, Arc::new(lm.clone()), false);
        }
        let mut seen = vec![false; layers];
        for _ in 0..layers {
            let r = pool.recv();
            assert!(!r.speculative);
            assert!(!seen[r.layer], "layer {} reported twice", r.layer);
            seen[r.layer] = true;
            let total: u64 =
                r.schedule.replica_loads.iter().map(|v| v.iter().sum::<u64>()).sum();
            assert_eq!(total, loads[r.layer].total(), "layer {}", r.layer);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn dropping_pool_with_queued_work_does_not_hang() {
        let p = cayley_graph_placement(4, 8);
        let pool = WorkerPool::new(p, None, SchedulerOptions::default(), 2, 2);
        for l in 0..2 {
            pool.submit_speculate(l, Arc::new(random_lm(9 + l as u64, 8, 4, 300)));
        }
        drop(pool); // must join cleanly with results unread
    }
}
