//! Persistent scheduling worker pool — now fault-tolerant.
//!
//! The PR-1 parallel path ([`crate::scheduler::schedule_layers_parallel`])
//! re-spawns scoped threads every round — measurable overhead once
//! per-layer solves drop under ~100 µs — and its round barrier couples
//! every layer to the slowest one. This pool fixes the ownership story
//! instead: each worker thread **owns** the [`MicroEpScheduler`]s (and
//! therefore the warm-start bases) of the layers assigned to it, for the
//! lifetime of the pool. Layer `l` is pinned to worker `l % workers`, and
//! each worker drains its job queue in FIFO order, so a layer's solver
//! sees exactly the same job sequence regardless of how many workers
//! exist — the §5.3 determinism property extends to the pool for free,
//! which `tests/integration_scheduler.rs` pins across 1/2/8 workers.
//!
//! Jobs are either *commits* (solve + route the actual micro-batch loads)
//! or *speculative pre-solves* (prime the warm basis with forecast loads;
//! the schedule itself is discarded by the engine). Results flow back over
//! one shared channel and are re-ordered by the engine
//! ([`super::ScheduleEngine`]), never here.
//!
//! # Worker-respawn state machine
//!
//! A worker thread can die (a solver panic, or an injected
//! [`crate::faults::Fault::WorkerPanic`] in the chaos suite). The pool
//! keeps a per-worker FIFO of **unacknowledged jobs** — submitted, result
//! not yet received — so death is recoverable without engine cooperation:
//!
//! 1. **detect** — [`WorkerPool::recv`] polls with a short timeout; on a
//!    quiet tick it scans worker handles for `is_finished()`. A dead
//!    worker is also caught eagerly when a submit's channel send fails.
//! 2. **respawn** — the dead thread is joined (reaping its panic payload),
//!    a fresh thread is spawned over a new job channel, and it rebuilds
//!    its layers' schedulers from scratch — warm bases are lost, so the
//!    next solve on those layers runs the cold rung.
//! 3. **replay** — the worker's unacknowledged jobs are re-submitted in
//!    order. The front job is the one it died on; its injected panic (if
//!    any) is *disarmed* on replay so a one-shot fault cannot live-lock
//!    the pool, while `persistent` faults re-fire by design.
//! 4. **give up** — more than [`MAX_RESPAWNS`] consecutive respawns of the
//!    same worker without a single result in between returns
//!    [`EngineError::RespawnLimit`]; the balancer layer answers with
//!    passthrough plans. Any received result resets the worker's counter.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::faults::Fault;
use crate::placement::Placement;
use crate::scheduler::{LoadMatrix, MicroEpScheduler, Schedule, SchedulerOptions};
use crate::topology::Topology;

use super::EngineError;

/// Consecutive respawns of one worker (without a result in between) before
/// the pool gives up with [`EngineError::RespawnLimit`].
pub const MAX_RESPAWNS: usize = 3;

/// How often a blocked [`WorkerPool::recv`] wakes to scan for dead
/// workers. Purely a liveness knob: results are handled the moment they
/// arrive, this only bounds how long a silent worker death can stall the
/// drain loop.
const DEATH_POLL: Duration = Duration::from_millis(25);

/// One unit of work for a layer-owning worker. Loads travel as `Arc`s so
/// the engine can share one allocation between the pool and its own
/// bookkeeping (forecasts) instead of deep-copying per consumer — and so
/// the pool's in-flight replay queue can hold a clone for free.
#[derive(Clone)]
enum Job {
    /// Solve + route actual loads; `cold` forces a from-scratch solve
    /// (speculation miss: the primed basis is too far off to repair).
    Commit {
        /// Engine-stamped step index — authoritative for fault lookup, so
        /// injections stay deterministic across respawns and replay.
        step: usize,
        layer: usize,
        loads: Arc<LoadMatrix>,
        cold: bool,
        /// Whether an injected `WorkerPanic` at this `(step, layer)` may
        /// fire. Cleared when the job is replayed after a respawn (unless
        /// the fault is `persistent`).
        armed: bool,
    },
    /// Speculative pre-solve on forecast loads: primes the layer's warm
    /// basis; the engine meters the pivots and drops the schedule. Never
    /// consults the fault plan and never advances the fault step cursor.
    Speculate { layer: usize, loads: Arc<LoadMatrix> },
}

impl Job {
    fn layer(&self) -> usize {
        match self {
            Job::Commit { layer, .. } | Job::Speculate { layer, .. } => *layer,
        }
    }

    fn disarm(&mut self) {
        if let Job::Commit { armed, .. } = self {
            *armed = false;
        }
    }
}

/// A completed job, tagged for re-ordering by the engine.
pub(crate) struct JobResult {
    /// Layer the schedule belongs to.
    pub layer: usize,
    /// Whether this was a speculative pre-solve (schedule is discarded).
    pub speculative: bool,
    /// The produced schedule.
    pub schedule: Schedule,
}

/// Always-on pool of solver workers, each owning the warm-start state of
/// its layers across steps (no per-round spawns). Survives worker death by
/// respawning and replaying unacknowledged jobs (see the module docs).
pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    results: Receiver<JobResult>,
    /// Kept so the results channel never disconnects and respawned workers
    /// can be handed a fresh clone.
    res_tx: Sender<JobResult>,
    handles: Vec<Option<JoinHandle<()>>>,
    /// Per-worker FIFO of submitted-but-unacknowledged jobs. Workers
    /// process and answer strictly in order, so the front entry is always
    /// the job the next result (or death) belongs to.
    inflight: Vec<VecDeque<Job>>,
    /// Consecutive respawns per worker since its last delivered result.
    respawns: Vec<usize>,
    layers: usize,
    // ---- retained construction state for respawns ----
    placement: Placement,
    topo: Option<Topology>,
    opts: SchedulerOptions,
}

fn spawn_worker(
    w: usize,
    workers: usize,
    layers: usize,
    placement: Placement,
    topo: Option<Topology>,
    opts: SchedulerOptions,
    rx: Receiver<Job>,
    res_tx: Sender<JobResult>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sched-worker-{w}"))
        .spawn(move || {
            // One warm scheduler per owned layer, alive across steps — the
            // whole point of the persistent pool. Built inside the thread
            // so solver state never crosses threads; a respawned worker
            // therefore restarts its layers cold.
            let faults = opts.faults.clone();
            let mut scheds: Vec<Option<MicroEpScheduler>> = (0..layers)
                .map(|l| {
                    (l % workers == w).then(|| {
                        let mut s =
                            MicroEpScheduler::new(placement.clone(), topo.clone(), opts.clone());
                        s.set_layer(l);
                        s
                    })
                })
                .collect();
            while let Ok(job) = rx.recv() {
                let (layer, speculative, schedule) = match job {
                    Job::Commit { step, layer, loads, cold, armed } => {
                        if let Some(plan) = &faults {
                            if let Some(Fault::WorkerPanic { persistent }) = plan.at(step, layer) {
                                if armed || persistent {
                                    panic!("injected worker panic at step {step} layer {layer}");
                                }
                            }
                        }
                        let s = scheds[layer].as_mut().expect("job routed to owner");
                        let schedule = if cold {
                            s.schedule_cold_at(step, &loads)
                        } else {
                            s.schedule_at(step, &loads)
                        };
                        (layer, false, schedule)
                    }
                    Job::Speculate { layer, loads } => {
                        let s = scheds[layer].as_mut().expect("job routed to owner");
                        (layer, true, s.speculate(&loads))
                    }
                };
                if res_tx.send(JobResult { layer, speculative, schedule }).is_err() {
                    break; // engine gone: shut down
                }
            }
        })
        .expect("spawn scheduler worker")
}

impl WorkerPool {
    /// Spawn `workers` threads (0 = one per core), each constructing and
    /// owning one [`MicroEpScheduler`] per layer it is pinned to. Worker
    /// count is capped at the layer count — extra threads could never
    /// receive work.
    pub fn new(
        placement: Placement,
        topo: Option<Topology>,
        opts: SchedulerOptions,
        layers: usize,
        workers: usize,
    ) -> Self {
        assert!(layers > 0, "pool needs at least one layer");
        let workers = if workers == 0 {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        } else {
            workers
        }
        .clamp(1, layers);
        let (res_tx, results) = channel::<JobResult>();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            handles.push(Some(spawn_worker(
                w,
                workers,
                layers,
                placement.clone(),
                topo.clone(),
                opts.clone(),
                rx,
                res_tx.clone(),
            )));
        }
        WorkerPool {
            senders,
            results,
            res_tx,
            handles,
            inflight: (0..workers).map(|_| VecDeque::new()).collect(),
            respawns: vec![0; workers],
            layers,
            placement,
            topo,
            opts,
        }
    }

    /// Worker threads actually running (after the layer-count cap).
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Layers this pool schedules.
    pub fn layers(&self) -> usize {
        self.layers
    }

    pub(crate) fn submit_commit(
        &mut self,
        step: usize,
        layer: usize,
        loads: Arc<LoadMatrix>,
        cold: bool,
    ) -> Result<(), EngineError> {
        assert!(layer < self.layers);
        self.dispatch(Job::Commit { step, layer, loads, cold, armed: true })
    }

    pub(crate) fn submit_speculate(
        &mut self,
        layer: usize,
        loads: Arc<LoadMatrix>,
    ) -> Result<(), EngineError> {
        assert!(layer < self.layers);
        self.dispatch(Job::Speculate { layer, loads })
    }

    fn dispatch(&mut self, job: Job) -> Result<(), EngineError> {
        let w = job.layer() % self.senders.len();
        // Queue before sending: if the worker is already dead the job is
        // part of its in-flight set and the respawn replays it.
        self.inflight[w].push_back(job.clone());
        if self.senders[w].send(job).is_err() {
            self.respawn(w)?;
        }
        Ok(())
    }

    /// Blocking receive of the next finished job (any layer, any kind).
    /// Transparently respawns dead workers and replays their in-flight
    /// jobs; errs only once a worker exceeds the consecutive-respawn cap.
    pub(crate) fn recv(&mut self) -> Result<JobResult, EngineError> {
        loop {
            match self.results.recv_timeout(DEATH_POLL) {
                Ok(r) => {
                    let w = r.layer % self.senders.len();
                    // Workers answer in FIFO order: this result
                    // acknowledges the front of w's in-flight queue.
                    self.inflight[w].pop_front();
                    self.respawns[w] = 0;
                    return Ok(r);
                }
                Err(RecvTimeoutError::Timeout) => self.reap_dead()?,
                // Unreachable while we hold `res_tx`, but fail typed
                // rather than looping forever if that invariant breaks.
                Err(RecvTimeoutError::Disconnected) => return Err(EngineError::PoolDisconnected),
            }
        }
    }

    /// Respawn every worker whose thread has exited.
    fn reap_dead(&mut self) -> Result<(), EngineError> {
        for w in 0..self.handles.len() {
            if self.handles[w].as_ref().is_some_and(|h| h.is_finished()) {
                self.respawn(w)?;
            }
        }
        Ok(())
    }

    /// Replace worker `w`'s thread and replay its unacknowledged jobs. The
    /// replayed front job is disarmed so a one-shot injected panic cannot
    /// re-fire; the new worker rebuilds its schedulers cold.
    fn respawn(&mut self, w: usize) -> Result<(), EngineError> {
        self.respawns[w] += 1;
        if self.respawns[w] > MAX_RESPAWNS {
            return Err(EngineError::RespawnLimit { worker: w, limit: MAX_RESPAWNS });
        }
        if let Some(h) = self.handles[w].take() {
            // The thread is already dead or unwinding (its job receiver is
            // gone / is_finished fired), so this join is immediate; it
            // also swallows the panic payload.
            let _ = h.join();
        }
        log::warn!(
            "scheduling worker {w} died with {} job(s) in flight; respawning (attempt {}/{})",
            self.inflight[w].len(),
            self.respawns[w],
            MAX_RESPAWNS
        );
        // mark the discontinuity: replayed jobs re-solve under fresh span
        // ids on the shared tracer, so the trace stays globally consistent
        self.opts.trace.record(
            0.0,
            crate::obs::Span::WorkerRespawn { worker: w, attempt: self.respawns[w] },
        );
        let workers = self.senders.len();
        let (tx, rx) = channel::<Job>();
        self.handles[w] = Some(spawn_worker(
            w,
            workers,
            self.layers,
            self.placement.clone(),
            self.topo.clone(),
            self.opts.clone(),
            rx,
            self.res_tx.clone(),
        ));
        self.senders[w] = tx;
        for (i, queued) in self.inflight[w].iter().enumerate() {
            let mut job = queued.clone();
            if i == 0 {
                job.disarm();
            }
            if self.senders[w].send(job).is_err() {
                // Died again before the replay finished queueing — counted
                // by the recursion, bounded by MAX_RESPAWNS.
                return self.respawn(w);
            }
        }
        Ok(())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channels lets each worker drain what it has and
        // exit; results they still send land in the buffered channel and
        // are dropped with it.
        self.senders.clear();
        for h in self.handles.iter_mut().filter_map(Option::take) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultPlan;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;
    use crate::stats::DegradationRung;

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    #[test]
    fn pool_caps_workers_at_layer_count() {
        let p = cayley_graph_placement(4, 8);
        let pool = WorkerPool::new(p, None, SchedulerOptions::default(), 2, 16);
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.layers(), 2);
    }

    #[test]
    fn pool_solves_and_reports_every_layer() {
        let p = cayley_graph_placement(4, 8);
        let layers = 3;
        let mut pool = WorkerPool::new(p, None, SchedulerOptions::default(), layers, 2);
        let loads: Vec<LoadMatrix> =
            (0..layers).map(|l| random_lm(l as u64, 8, 4, 500)).collect();
        for (l, lm) in loads.iter().enumerate() {
            pool.submit_commit(0, l, Arc::new(lm.clone()), false).unwrap();
        }
        let mut seen = vec![false; layers];
        for _ in 0..layers {
            let r = pool.recv().unwrap();
            assert!(!r.speculative);
            assert!(!seen[r.layer], "layer {} reported twice", r.layer);
            seen[r.layer] = true;
            let total: u64 =
                r.schedule.replica_loads.iter().map(|v| v.iter().sum::<u64>()).sum();
            assert_eq!(total, loads[r.layer].total(), "layer {}", r.layer);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn dropping_pool_with_queued_work_does_not_hang() {
        let p = cayley_graph_placement(4, 8);
        let mut pool = WorkerPool::new(p, None, SchedulerOptions::default(), 2, 2);
        for l in 0..2 {
            pool.submit_speculate(l, Arc::new(random_lm(9 + l as u64, 8, 4, 300))).unwrap();
        }
        drop(pool); // must join cleanly with results unread
    }

    #[test]
    fn worker_death_respawns_and_replays() {
        let p = cayley_graph_placement(4, 8);
        let layers = 2;
        let opts = SchedulerOptions {
            faults: Some(Arc::new(FaultPlan::with_faults(vec![(
                1,
                0,
                Fault::WorkerPanic { persistent: false },
            )]))),
            ..Default::default()
        };
        let mut pool = WorkerPool::new(p, None, opts, layers, 2);
        let loads: Vec<LoadMatrix> =
            (0..layers).map(|l| random_lm(40 + l as u64, 8, 4, 600)).collect();
        for step in 0..3 {
            for (l, lm) in loads.iter().enumerate() {
                pool.submit_commit(step, l, Arc::new(lm.clone()), false).unwrap();
            }
            let mut rungs = vec![None; layers];
            for _ in 0..layers {
                let r = pool.recv().unwrap();
                let total: u64 =
                    r.schedule.replica_loads.iter().map(|v| v.iter().sum::<u64>()).sum();
                assert_eq!(total, loads[r.layer].total(), "step {step} layer {}", r.layer);
                rungs[r.layer] = Some(r.schedule.stats.rung);
            }
            if step == 1 {
                // The replayed job ran on a fresh worker: cold rung.
                assert_eq!(rungs[0], Some(DegradationRung::ColdLp), "step {step}");
            } else if step == 2 {
                // Recovered: back to warm repairs on the respawned worker.
                assert_eq!(rungs[0], Some(DegradationRung::WarmLp), "step {step}");
            }
        }
    }

    #[test]
    fn persistent_panic_exhausts_respawn_limit() {
        let p = cayley_graph_placement(4, 8);
        let opts = SchedulerOptions {
            faults: Some(Arc::new(FaultPlan::with_faults(vec![(
                0,
                0,
                Fault::WorkerPanic { persistent: true },
            )]))),
            ..Default::default()
        };
        let mut pool = WorkerPool::new(p, None, opts, 1, 1);
        pool.submit_commit(0, 0, Arc::new(random_lm(7, 8, 4, 400)), false).unwrap();
        let err = pool.recv().expect_err("persistent panic must exhaust the respawn limit");
        assert_eq!(err, EngineError::RespawnLimit { worker: 0, limit: MAX_RESPAWNS });
    }
}
