//! MoE-layer execution strategies: scheduling-overlap (§5.4) and the
//! pipelined MicroEP dispatch (Appendix A.2, Fig. 16).
//!
//! Pipelining splits each micro-batch's tokens into an **EP part**
//! (dispatched immediately with static even-split routing — footnote 4:
//! "more like FlexMoE") and a **MicroEP part** (LP-scheduled). The MicroEP
//! scheduling runs while the EP part's all-to-all is in flight; the LP
//! additionally sees the EP part's per-GPU loads as a fixed base so total
//! compute still balances.

use crate::cluster::sim::MoeLayerPlan;
use crate::cluster::CostModel;
use crate::placement::Placement;
use crate::scheduler::rounding::round_preserving_sum;
use crate::scheduler::routing::route_tokens;
use crate::scheduler::{LoadMatrix, MicroEpScheduler, Route, SchedulerOptions};
use crate::topology::Topology;

/// Pipelined-dispatch timing (Fig. 16's stacked bars).
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelinedDispatch {
    /// all-gather of load info
    pub gather: f64,
    /// EP-part all-to-all (overlaps the MicroEP scheduling)
    pub ep_a2a: f64,
    /// MicroEP scheduling time (CPU)
    pub sched: f64,
    /// MicroEP-part all-to-all
    pub micro_a2a: f64,
    /// extra kernel-launch/synchronization cost of splitting the A2A
    pub split_overhead: f64,
}

impl PipelinedDispatch {
    /// Wall time: gather, then max(EP A2A, scheduling), then MicroEP A2A.
    pub fn total(&self) -> f64 {
        self.gather + self.ep_a2a.max(self.sched) + self.micro_a2a + self.split_overhead
    }
}

/// A MicroEP scheduler wrapped with the App.-A.2 pipelining split.
pub struct PipelinedMicroEp {
    /// The LP scheduler handling the MicroEP token share.
    pub scheduler: MicroEpScheduler,
    placement: Placement,
    topo: Topology,
    /// fraction of tokens handled by MicroEP (1.0 = no pipelining)
    pub microep_ratio: f64,
    /// fixed overhead per extra all-to-all launch
    pub split_overhead: f64,
}

impl PipelinedMicroEp {
    /// Wrap a scheduler; `microep_ratio` of each batch goes through the LP.
    pub fn new(
        placement: Placement,
        topo: Topology,
        opts: SchedulerOptions,
        microep_ratio: f64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&microep_ratio));
        let scheduler = MicroEpScheduler::new(placement.clone(), Some(topo.clone()), opts);
        PipelinedMicroEp {
            scheduler,
            placement,
            topo,
            microep_ratio,
            split_overhead: 20e-6,
        }
    }

    /// Split loads into (EP part, MicroEP part) by ratio per (e, g) cell.
    pub fn split_loads(&self, loads: &LoadMatrix) -> (LoadMatrix, LoadMatrix) {
        let e_count = loads.num_experts;
        let g_count = loads.num_gpus;
        let mut ep = LoadMatrix::zeros(e_count, g_count);
        let mut micro = LoadMatrix::zeros(e_count, g_count);
        for e in 0..e_count {
            for g in 0..g_count {
                let n = loads.get(e, g);
                let m = (n as f64 * self.microep_ratio).round() as u64;
                micro.set(e, g, m);
                ep.set(e, g, n - m);
            }
        }
        (ep, micro)
    }

    /// Static even-split routing for the EP part (FlexMoE-like, footnote 4).
    fn route_ep_part(&self, ep: &LoadMatrix) -> (Vec<u64>, Vec<Route>) {
        let budgets: Vec<Vec<u64>> = (0..self.placement.num_experts)
            .map(|e| {
                let total = ep.expert_load(e);
                let k = self.placement.replica_count(e);
                round_preserving_sum(&vec![total as f64 / k as f64; k], total)
            })
            .collect();
        let routes = route_tokens(&self.placement, ep, &budgets, true, Some(&self.topo));
        let mut gpu = vec![0u64; ep.num_gpus];
        for (e, grp) in self.placement.replicas.iter().enumerate() {
            for (r, &g) in grp.iter().enumerate() {
                gpu[g] += budgets[e][r];
            }
        }
        (gpu, routes)
    }

    /// Plan one micro-batch; returns the combined plan plus the pipelined
    /// dispatch-time breakdown under `model`.
    pub fn plan(&mut self, loads: &LoadMatrix, model: &CostModel) -> (MoeLayerPlan, PipelinedDispatch) {
        let g_count = loads.num_gpus;
        let (ep, micro) = self.split_loads(loads);

        let (ep_gpu, ep_routes) = self.route_ep_part(&ep);
        let sched = self.scheduler.schedule_with_base(&micro, &ep_gpu);
        let micro_gpu = sched.gpu_loads(&self.placement);

        let gather = model.allgather_time(4.0 * 64.0, g_count, g_count > self.topo.gpus_per_node);
        let ep_a2a = model.a2a_time_from_routes(&ep_routes, g_count, &self.topo);
        let micro_a2a = model.a2a_time_from_routes(&sched.routes, g_count, &self.topo);
        let breakdown = PipelinedDispatch {
            gather,
            ep_a2a,
            sched: sched.stats.solve_ns as f64 * 1e-9,
            micro_a2a,
            split_overhead: if self.microep_ratio < 1.0 && self.microep_ratio > 0.0 {
                self.split_overhead
            } else {
                0.0
            },
        };

        let mut gpu_compute = vec![0u64; g_count];
        for g in 0..g_count {
            gpu_compute[g] = ep_gpu[g] + micro_gpu[g];
        }
        let mut routes = ep_routes;
        routes.extend(sched.routes);
        let plan = MoeLayerPlan {
            gpu_compute,
            routes,
            sched_time: breakdown.sched,
            sched_overlapped: true, // pipelining is the overlap mechanism
            prep_extra: 0.0,
        };
        (plan, breakdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::cayley::symmetric_placement;
    use crate::rng::{Rng, Zipf};
    use crate::stats::imbalance_ratio;

    fn setup(ratio: f64) -> PipelinedMicroEp {
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 32);
        PipelinedMicroEp::new(p, topo, SchedulerOptions::default(), ratio)
    }

    fn loads(seed: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let z = Zipf::new(32, 1.0);
        let mut lm = LoadMatrix::zeros(32, 8);
        for g in 0..8 {
            for _ in 0..2000 {
                lm.add(z.sample(&mut rng), g, 1);
            }
        }
        lm
    }

    #[test]
    fn split_conserves_tokens() {
        let p = setup(0.4);
        let lm = loads(1);
        let (ep, micro) = p.split_loads(&lm);
        for e in 0..32 {
            for g in 0..8 {
                assert_eq!(ep.get(e, g) + micro.get(e, g), lm.get(e, g));
            }
        }
    }

    #[test]
    fn ratio_zero_is_pure_ep() {
        let mut p = setup(0.0);
        let lm = loads(2);
        let (plan, bd) = p.plan(&lm, &CostModel::h100_testbed());
        assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total());
        assert_eq!(bd.micro_a2a, 0.0);
        assert_eq!(bd.split_overhead, 0.0);
    }

    #[test]
    fn ratio_one_is_pure_microep() {
        let mut p = setup(1.0);
        let lm = loads(3);
        let (plan, bd) = p.plan(&lm, &CostModel::h100_testbed());
        assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total());
        assert_eq!(bd.ep_a2a, 0.0);
        // full MicroEP at 32 experts: near-perfect balance. (s=1.0 puts the
        // hot expert at ~24.6% mass — the 2-replica capacity edge of 25% —
        // so sampling noise can cost a few percent; Fig. 7 degrades past
        // s=1 for exactly this reason.)
        let l: Vec<f64> = plan.gpu_compute.iter().map(|&x| x as f64).collect();
        assert!(imbalance_ratio(&l) < 1.06, "imb {}", imbalance_ratio(&l));
    }

    #[test]
    fn partial_ratio_still_balances_total_mild_skew() {
        // At mild skew the LP (seeing the EP part as base load) keeps the
        // combined compute near balanced. Note: under *heavy* skew the
        // even-split EP prefix pins hot-expert load on the replica GPUs and
        // no MicroEP share can repair it — exactly the trade-off App. A.2
        // warns about ("recommend pipelining … with minimal system
        // overhead"); see fig16 bench.
        let mut p = setup(0.5);
        let mut rng = Rng::new(4);
        let z = Zipf::new(32, 0.4);
        let mut lm = LoadMatrix::zeros(32, 8);
        for g in 0..8 {
            for _ in 0..2000 {
                lm.add(z.sample(&mut rng), g, 1);
            }
        }
        let (plan, _) = p.plan(&lm, &CostModel::h100_testbed());
        let l: Vec<f64> = plan.gpu_compute.iter().map(|&x| x as f64).collect();
        assert!(imbalance_ratio(&l) < 1.15, "imbalance {}", imbalance_ratio(&l));
        assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total());
    }

    #[test]
    fn combined_max_never_exceeds_lp_bound_plus_rounding() {
        // the LP objective with base loads is a certified optimum: the
        // realized combined max may exceed it only by rounding slack
        let mut p = setup(0.5);
        let lm = loads(4);
        let (ep, micro) = p.split_loads(&lm);
        // reproduce the base the planner feeds the LP
        let (base, _) = {
            use crate::scheduler::rounding::round_preserving_sum;
            let place = p.scheduler.placement.clone();
            let budgets: Vec<Vec<u64>> = (0..place.num_experts)
                .map(|e| {
                    let total = ep.expert_load(e);
                    let k = place.replica_count(e);
                    round_preserving_sum(&vec![total as f64 / k as f64; k], total)
                })
                .collect();
            let mut b = vec![0u64; 8];
            for (e, grp) in place.replicas.iter().enumerate() {
                for (r, &g) in grp.iter().enumerate() {
                    b[g] += budgets[e][r];
                }
            }
            (b, budgets)
        };
        let topo = Topology::new(8, 4, 2, 8);
        let mut fresh = MicroEpScheduler::new(
            p.scheduler.placement.clone(),
            Some(topo),
            SchedulerOptions::default(),
        );
        let bound = fresh.schedule_with_base(&micro, &base).stats.lp_objective;
        let (plan, _) = p.plan(&lm, &CostModel::h100_testbed());
        let max = *plan.gpu_compute.iter().max().unwrap() as f64;
        // per-GPU rounding slack < resident replicas (≤ 8 here) per part
        assert!(max <= bound + 16.0, "max {max} vs LP bound {bound}");
    }

    #[test]
    fn scheduling_overlaps_ep_a2a() {
        let mut p = setup(0.5);
        let lm = loads(5);
        let (_, bd) = p.plan(&lm, &CostModel::h100_testbed());
        // total charges max(ep_a2a, sched), not their sum
        let serial = bd.gather + bd.ep_a2a + bd.sched + bd.micro_a2a + bd.split_overhead;
        assert!(bd.total() <= serial);
        assert!(bd.total() >= bd.gather + bd.micro_a2a);
    }

    #[test]
    fn dispatch_time_varies_with_ratio() {
        // Fig. 16's mechanism: moderate ratios hide scheduling behind the
        // EP A2A; ratio 1.0 exposes it fully when sched > a2a
        let model = CostModel::h100_testbed();
        let lm = loads(6);
        let t_half = setup(0.5).plan(&lm, &model).1;
        let t_full = setup(1.0).plan(&lm, &model).1;
        // at ratio 0.5 some scheduling is hidden behind ep_a2a
        assert!(t_half.ep_a2a > 0.0);
        assert!(t_full.ep_a2a == 0.0);
    }
}
