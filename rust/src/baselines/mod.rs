//! Load-balancing systems under one interface: the paper's baselines
//! (§7.1) plus MicroMoE itself, all implementing the unified
//! [`crate::balancer::Balancer`] trait so Fig. 6/7/8 comparisons are
//! apples-to-apples — one step loop, swappable policy (the former
//! `MoeSystem` trait is folded into `Balancer`; the per-layer
//! [`crate::balancer::Balancer::plan`] shorthand replaces its old method).
//!
//! * [`vanilla_ep::VanillaEp`] — Megatron-LM: fixed placement, tokens go to
//!   their expert's replica inside the source GPU's EP group.
//! * [`deepspeed::DeepSpeedPad`] — DeepSpeed/GShard capacity padding: every
//!   expert padded to the max expert load.
//! * [`smartmoe::SmartMoe`] — periodic expert-placement re-optimization
//!   from long-term load statistics (within EP groups).
//! * [`flexmoe::FlexMoe`] — popularity-proportional replica counts with
//!   even load split across replicas, DP-group-wide.
//! * [`micromoe::MicroMoe`] — MicroEP token scheduling (± adaptive
//!   replacement), the paper's system.
//!
//! Each is registered by name in the [`crate::balancer::MoeSession`]
//! policy registry; construct them there unless a test needs the struct.

pub mod deepspeed;
pub mod flexmoe;
pub mod micromoe;
pub mod smartmoe;
pub mod vanilla_ep;

pub use deepspeed::DeepSpeedPad;
pub use flexmoe::FlexMoe;
pub use micromoe::MicroMoe;
pub use smartmoe::SmartMoe;
pub use vanilla_ep::VanillaEp;

#[cfg(test)]
pub(crate) mod testutil {
    use crate::rng::{Rng, Zipf};
    use crate::scheduler::LoadMatrix;

    /// Zipf loads with per-GPU sources, for baseline tests.
    pub fn zipf_loads(
        experts: usize,
        gpus: usize,
        tokens_per_gpu: u64,
        s: f64,
        seed: u64,
    ) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let z = Zipf::new(experts, s);
        let mut lm = LoadMatrix::zeros(experts, gpus);
        for g in 0..gpus {
            for _ in 0..tokens_per_gpu {
                lm.add(z.sample(&mut rng), g, 1);
            }
        }
        lm
    }

    /// Σ tokens crossing GPUs in a plan.
    pub fn cross_traffic(plan: &crate::cluster::sim::MoeLayerPlan) -> u64 {
        plan.routes.iter().filter(|r| r.src != r.dst).map(|r| r.tokens).sum()
    }
}
