//! Load-balancing systems under one interface: the paper's baselines
//! (§7.1) plus MicroMoE itself, all planning against the same cluster
//! model so Fig. 6/7/8 comparisons are apples-to-apples.
//!
//! * [`vanilla_ep::VanillaEp`] — Megatron-LM: fixed placement, tokens go to
//!   their expert's replica inside the source GPU's EP group.
//! * [`deepspeed::DeepSpeedPad`] — DeepSpeed/GShard capacity padding: every
//!   expert padded to the max expert load.
//! * [`smartmoe::SmartMoe`] — periodic expert-placement re-optimization
//!   from long-term load statistics (within EP groups).
//! * [`flexmoe::FlexMoe`] — popularity-proportional replica counts with
//!   even load split across replicas, DP-group-wide.
//! * [`micromoe::MicroMoe`] — MicroEP token scheduling (± adaptive
//!   replacement), the paper's system.

pub mod deepspeed;
pub mod flexmoe;
pub mod micromoe;
pub mod smartmoe;
pub mod vanilla_ep;

use crate::cluster::sim::MoeLayerPlan;
use crate::scheduler::LoadMatrix;

/// A load-balancing system planning one MoE layer per micro-batch.
pub trait MoeSystem {
    /// Display name for tables and legends.
    fn name(&self) -> &'static str;
    /// Decide token→GPU assignment (and implied communication) for one
    /// micro-batch of gate outputs.
    fn plan(&mut self, loads: &LoadMatrix) -> MoeLayerPlan;
}

pub use deepspeed::DeepSpeedPad;
pub use flexmoe::FlexMoe;
pub use micromoe::MicroMoe;
pub use smartmoe::SmartMoe;
pub use vanilla_ep::VanillaEp;

#[cfg(test)]
pub(crate) mod testutil {
    use crate::rng::{Rng, Zipf};
    use crate::scheduler::LoadMatrix;

    /// Zipf loads with per-GPU sources, for baseline tests.
    pub fn zipf_loads(
        experts: usize,
        gpus: usize,
        tokens_per_gpu: u64,
        s: f64,
        seed: u64,
    ) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let z = Zipf::new(experts, s);
        let mut lm = LoadMatrix::zeros(experts, gpus);
        for g in 0..gpus {
            for _ in 0..tokens_per_gpu {
                lm.add(z.sample(&mut rng), g, 1);
            }
        }
        lm
    }

    /// Σ tokens crossing GPUs in a plan.
    pub fn cross_traffic(plan: &crate::cluster::sim::MoeLayerPlan) -> u64 {
        plan.routes.iter().filter(|r| r.src != r.dst).map(|r| r.tokens).sum()
    }
}
