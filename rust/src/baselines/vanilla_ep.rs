//! Vanilla expert parallelism (Megatron-LM baseline, Fig. 3a).
//!
//! Each EP group holds one replica of every expert at a fixed rank; a token
//! on GPU `g` assigned to expert `e` must go to `e`'s replica inside
//! `g`'s own EP group. GPU load is therefore fully determined by the gate —
//! no scheduling space, and the straggler bounds the layer (§2.3).

use crate::balancer::{step_layers, Balancer, MoeLayerPlan, StepInput, StepOutput};
use crate::scheduler::{LoadMatrix, Route};
use crate::topology::Topology;

/// Megatron-LM vanilla EP: fixed contiguous placement, tokens routed to
/// the replica inside the source GPU's EP group.
pub struct VanillaEp {
    topo: Topology,
    num_experts: usize,
    experts_per_gpu: usize,
}

impl VanillaEp {
    /// Contiguous expert→rank layout over the topology.
    pub fn new(topo: Topology, num_experts: usize) -> Self {
        let experts_per_gpu = topo.experts_per_gpu(num_experts);
        VanillaEp { topo, num_experts, experts_per_gpu }
    }

    /// Home GPU of expert `e` for a token originating on `src`.
    pub fn home_gpu(&self, e: usize, src: usize) -> usize {
        let rank = e / self.experts_per_gpu;
        self.topo.ep_group_of(src) * self.topo.ep_degree + rank
    }

    fn plan_layer(&mut self, loads: &LoadMatrix) -> MoeLayerPlan {
        let g_count = loads.num_gpus;
        let mut gpu_compute = vec![0u64; g_count];
        let mut routes = Vec::new();
        for e in 0..self.num_experts {
            for src in 0..g_count {
                let n = loads.get(e, src);
                if n == 0 {
                    continue;
                }
                let dst = self.home_gpu(e, src);
                gpu_compute[dst] += n;
                routes.push(Route { expert: e, src, dst, tokens: n });
            }
        }
        MoeLayerPlan {
            gpu_compute,
            routes,
            sched_time: 0.0,
            sched_overlapped: true,
            prep_extra: 0.0,
        }
    }
}

impl Balancer for VanillaEp {
    fn name(&self) -> &str {
        "Megatron-LM (vanilla EP)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        step_layers(input.loads, |lm| self.plan_layer(lm))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::zipf_loads;
    use super::*;

    fn sys() -> VanillaEp {
        // DP=8, EP=4, d=2: one MicroEP scope of 8 GPUs, 2 EP groups
        VanillaEp::new(Topology::new(8, 4, 2, 8), 16)
    }

    #[test]
    fn tokens_stay_in_their_ep_group() {
        let mut s = sys();
        let lm = zipf_loads(16, 8, 500, 1.0, 1);
        let plan = s.plan(&lm);
        for r in &plan.routes {
            assert_eq!(
                s.topo.ep_group_of(r.src),
                s.topo.ep_group_of(r.dst),
                "route escaped its EP group: {r:?}"
            );
        }
    }

    #[test]
    fn compute_conserves_tokens() {
        let mut s = sys();
        let lm = zipf_loads(16, 8, 500, 1.2, 2);
        let plan = s.plan(&lm);
        assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total());
    }

    #[test]
    fn expert_rank_mapping() {
        let s = sys(); // 16 experts / EP degree 4 = 4 per GPU
        assert_eq!(s.home_gpu(0, 0), 0);
        assert_eq!(s.home_gpu(5, 0), 1);
        assert_eq!(s.home_gpu(15, 2), 3);
        // from the second EP group (GPUs 4..8)
        assert_eq!(s.home_gpu(0, 5), 4);
        assert_eq!(s.home_gpu(15, 7), 7);
    }

    #[test]
    fn skew_creates_straggler() {
        let mut s = sys();
        // all tokens to expert 0 -> GPUs 0 and 4 take everything
        let mut lm = LoadMatrix::zeros(16, 8);
        for g in 0..8 {
            lm.set(0, g, 100);
        }
        let plan = s.plan(&lm);
        assert_eq!(plan.gpu_compute[0], 400);
        assert_eq!(plan.gpu_compute[4], 400);
        assert_eq!(plan.gpu_compute[1], 0);
    }

    #[test]
    fn local_tokens_do_not_travel() {
        let mut s = sys();
        let mut lm = LoadMatrix::zeros(16, 8);
        lm.set(0, 0, 50); // expert 0 lives on GPU 0 of EP group 0
        let plan = s.plan(&lm);
        assert_eq!(plan.routes.len(), 1);
        assert_eq!(plan.routes[0].src, plan.routes[0].dst);
    }
}
