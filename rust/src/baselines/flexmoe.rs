//! FlexMoE baseline (§7.1): popularity-proportional replica counts with
//! *even* load split across replicas.
//!
//! The key contrast with MicroEP (§6.4 "Algorithms"): FlexMoE computes a
//! replica's load as `load_e / count_e` — all replicas of an expert are
//! equal — whereas MicroEP's LP may assign uneven loads. FlexMoE adapts
//! counts when the popularity EMA drifts, paying migration, and places
//! replicas across the whole DP group like MicroMoE's asymmetric mode.

use crate::balancer::{step_layers, Balancer, MoeLayerPlan, StepInput, StepOutput};
use crate::cluster::{migration, CostModel};
use crate::placement::asymmetric::greedy_replica_counts;
use crate::placement::{random::random_placement, Placement};
use crate::rng::Rng;
use crate::scheduler::rounding::round_preserving_sum;
use crate::scheduler::routing::route_tokens;
use crate::scheduler::LoadMatrix;
use crate::stats::Ema;
use crate::topology::Topology;

/// FlexMoE-style baseline: popularity-proportional replica counts with
/// even load split, re-planned when the EMA popularity drifts.
pub struct FlexMoe {
    topo: Topology,
    num_experts: usize,
    slots_per_gpu: usize,
    placement: Placement,
    ema: Vec<Ema>,
    batch: usize,
    /// Re-planning cadence in micro-batches.
    pub adjust_every: usize,
    /// relative EMA change that triggers re-planning
    pub drift_threshold: f64,
    last_counts: Vec<usize>,
    rng: Rng,
    cost: Option<(CostModel, u64)>,
    /// Re-plans performed so far (for tests/metrics).
    pub adjustments: usize,
}

impl FlexMoe {
    /// Baseline starting from uniform replica counts.
    pub fn new(topo: Topology, num_experts: usize, seed: u64) -> Self {
        let slots_per_gpu = topo.slots_per_gpu(num_experts);
        let g = topo.microep_group_size();
        let mut rng = Rng::new(seed);
        // start from uniform replica counts (d replicas each)
        let placement = random_placement(g, num_experts, topo.d, &mut rng);
        let last_counts = vec![topo.d; num_experts];
        FlexMoe {
            topo,
            num_experts,
            slots_per_gpu,
            placement,
            ema: (0..num_experts).map(|_| Ema::new(0.1)).collect(),
            batch: 0,
            adjust_every: 16,
            drift_threshold: 0.25,
            last_counts,
            rng,
            cost: None,
            adjustments: 0,
        }
    }

    /// Charge replica movements against this cost model.
    pub fn with_migration_cost(mut self, model: CostModel, bytes_per_expert: u64) -> Self {
        self.cost = Some((model, bytes_per_expert));
        self
    }

    /// Current replica placement.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    fn maybe_adjust(&mut self, num_gpus: usize) -> f64 {
        let loads: Vec<f64> = self.ema.iter().map(|e| e.get().unwrap_or(1.0).max(0.0)).collect();
        let counts =
            greedy_replica_counts(&loads, num_gpus * self.slots_per_gpu, num_gpus);
        // only pay migration when counts actually drifted
        let drift: f64 = counts
            .iter()
            .zip(&self.last_counts)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum::<f64>()
            / self.num_experts as f64;
        if drift < self.drift_threshold {
            return 0.0;
        }
        // place counts: heaviest experts spread first, fill GPU slots evenly
        let new_placement = place_counts(num_gpus, &counts, self.slots_per_gpu, &mut self.rng);
        let mut prep = 0.0;
        if let Some((model, bytes)) = &self.cost {
            let moves = migration::placement_diff(&self.placement, &new_placement, &self.topo);
            prep = migration::migration_time(&moves, *bytes, model, &self.topo, num_gpus);
        }
        self.placement = new_placement;
        self.last_counts = counts;
        self.adjustments += 1;
        prep
    }
}

/// Deterministic slot-balanced placement of given replica counts.
fn place_counts(
    num_gpus: usize,
    counts: &[usize],
    slots_per_gpu: usize,
    rng: &mut Rng,
) -> Placement {
    let mut remaining = vec![slots_per_gpu; num_gpus];
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_by_key(|&e| std::cmp::Reverse(counts[e]));
    let mut replicas = vec![Vec::new(); counts.len()];
    for &e in &order {
        let mut chosen: Vec<usize> = Vec::with_capacity(counts[e]);
        for _ in 0..counts[e] {
            // most-free GPU not already chosen; random tie-break
            let best = (0..num_gpus)
                .filter(|g| !chosen.contains(g) && remaining[*g] > 0)
                .max_by_key(|&g| (remaining[g], rng.below(1024)));
            let g = best.expect("ran out of slots placing replica counts");
            chosen.push(g);
            remaining[g] -= 1;
        }
        chosen.sort_unstable();
        replicas[e] = chosen;
    }
    Placement::from_replicas(num_gpus, replicas)
}

impl FlexMoe {
    fn plan_layer(&mut self, loads: &LoadMatrix) -> MoeLayerPlan {
        for e in 0..self.num_experts {
            self.ema[e].update(loads.expert_load(e) as f64);
        }
        self.batch += 1;
        let mut prep_extra = 0.0;
        if self.batch % self.adjust_every == 0 {
            prep_extra = self.maybe_adjust(loads.num_gpus);
        }

        // FlexMoE's defining rule: replica load = load_e / count_e (even)
        let budgets: Vec<Vec<u64>> = (0..self.num_experts)
            .map(|e| {
                let total = loads.expert_load(e);
                let k = self.placement.replica_count(e);
                round_preserving_sum(&vec![total as f64 / k as f64; k], total)
            })
            .collect();
        let routes = route_tokens(&self.placement, loads, &budgets, true, None);
        let mut gpu_compute = vec![0u64; loads.num_gpus];
        for (e, grp) in self.placement.replicas.iter().enumerate() {
            for (r, &g) in grp.iter().enumerate() {
                gpu_compute[g] += budgets[e][r];
            }
        }
        MoeLayerPlan {
            gpu_compute,
            routes,
            sched_time: 0.0,
            sched_overlapped: true,
            prep_extra,
        }
    }
}

impl Balancer for FlexMoe {
    fn name(&self) -> &str {
        "FlexMoE (adaptive replicas)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        step_layers(input.loads, |lm| self.plan_layer(lm))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::zipf_loads;
    use super::*;
    use crate::stats::imbalance_ratio;

    fn topo() -> Topology {
        Topology::new(8, 4, 2, 8)
    }

    #[test]
    fn even_split_across_replicas() {
        let mut s = FlexMoe::new(topo(), 16, 1);
        let lm = zipf_loads(16, 8, 500, 1.0, 2);
        let plan = s.plan(&lm);
        assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total());
        // per-expert inbound volumes differ by at most 1 across replicas
        for e in 0..16 {
            let grp = s.placement.replicas[e].clone();
            let mut per_replica = vec![0u64; grp.len()];
            for r in &plan.routes {
                if r.expert == e {
                    let idx = grp.iter().position(|&g| g == r.dst).unwrap();
                    per_replica[idx] += r.tokens;
                }
            }
            let max = *per_replica.iter().max().unwrap();
            let min = *per_replica.iter().min().unwrap();
            assert!(max - min <= 1, "expert {e}: {per_replica:?}");
        }
    }

    #[test]
    fn adapts_replica_counts_to_skew() {
        let mut s = FlexMoe::new(topo(), 16, 3);
        s.adjust_every = 4;
        for seed in 0..32 {
            s.plan(&zipf_loads(16, 8, 2000, 1.8, 100 + seed));
        }
        // the hottest expert should have gained replicas
        let max_count = (0..16).map(|e| s.placement.replica_count(e)).max().unwrap();
        assert!(max_count > 2, "counts never adapted");
        assert!(s.adjustments > 0);
    }

    #[test]
    fn balances_better_than_vanilla_under_skew() {
        let t = topo();
        let mut flex = FlexMoe::new(t.clone(), 16, 4);
        flex.adjust_every = 2;
        let mut van = super::super::vanilla_ep::VanillaEp::new(t, 16);
        let mut flex_imb = 0.0;
        let mut van_imb = 0.0;
        for seed in 0..24 {
            let lm = zipf_loads(16, 8, 2000, 1.2, 500 + seed);
            let fp = flex.plan(&lm);
            let vp = van.plan(&lm);
            if seed >= 8 {
                // after counts settle
                flex_imb += imbalance_ratio(
                    &fp.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                );
                van_imb += imbalance_ratio(
                    &vp.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                );
            }
        }
        assert!(
            flex_imb < van_imb,
            "FlexMoE {flex_imb} should beat vanilla {van_imb}"
        );
    }

    #[test]
    fn slot_budget_respected_after_adjustments() {
        let mut s = FlexMoe::new(topo(), 16, 5);
        s.adjust_every = 2;
        for seed in 0..20 {
            s.plan(&zipf_loads(16, 8, 1000, 1.5, 900 + seed));
            for g in 0..8 {
                assert!(s.placement.slots_used(g) <= s.slots_per_gpu + 1);
            }
            s.placement.check_consistency().unwrap();
        }
    }
}
