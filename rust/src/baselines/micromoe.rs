//! MicroMoE — the paper's system as a plan-producing
//! [`crate::balancer::Balancer`] (the `"micromoe-ar"` registry policy).
//!
//! Composes the MicroEP LP scheduler (§5) with a placement (symmetric
//! Cayley by default) and, optionally, adaptive replacement (§6.4). The
//! `(w/o AR)` evaluation arm is this struct with `adaptive = None`;
//! "MicroMoE (random)" is the random placement. One internal scheduler is
//! shared across a step's layers (adaptive replacement is a per-system,
//! not per-layer, decision); for per-layer warm state use the
//! `"micromoe"` policy ([`crate::balancer::LppBalancer`]).

use crate::adaptive::{AdaptiveConfig, ReplacementManager};
use crate::balancer::{step_layers, Balancer, MoeLayerPlan, StepInput, StepOutput};
use crate::cluster::{migration, CostModel};
use crate::placement::Placement;
use crate::scheduler::{LoadMatrix, MicroEpScheduler, SchedulerOptions};
use crate::topology::Topology;

/// The paper's system: MicroEP token scheduling, optionally with
/// adaptive expert replacement.
pub struct MicroMoe {
    topo: Topology,
    scheduler: MicroEpScheduler,
    opts: SchedulerOptions,
    /// §5.4: scheduling overlaps the token-permute op
    pub overlap: bool,
    adaptive: Option<ReplacementManager>,
    cost: Option<(CostModel, u64)>,
    /// Legend label override (e.g. for ablation arms).
    pub name_override: Option<&'static str>,
    /// Adaptive replacements performed so far.
    pub replacements: usize,
}

impl MicroMoe {
    /// MicroEP system over a fixed placement (no adaptive replacement).
    pub fn new(topo: Topology, placement: Placement, opts: SchedulerOptions) -> Self {
        let scheduler = MicroEpScheduler::new(placement, Some(topo.clone()), opts.clone());
        MicroMoe {
            topo,
            scheduler,
            opts,
            overlap: true,
            adaptive: None,
            cost: None,
            name_override: None,
            replacements: 0,
        }
    }

    /// Enable adaptive replacement (the full "MicroMoE" arm).
    pub fn with_adaptive(mut self, cfg: AdaptiveConfig, seed: u64) -> Self {
        self.adaptive = Some(ReplacementManager::new(cfg, seed));
        self
    }

    /// Charge replacement migrations against this cost model.
    pub fn with_migration_cost(mut self, model: CostModel, bytes_per_expert: u64) -> Self {
        self.cost = Some((model, bytes_per_expert));
        self
    }

    /// Current replica placement.
    pub fn placement(&self) -> &Placement {
        &self.scheduler.placement
    }

    fn plan_layer(&mut self, loads: &LoadMatrix) -> MoeLayerPlan {
        let mut prep_extra = 0.0;
        if let Some(mgr) = &mut self.adaptive {
            mgr.observe(&loads.expert_loads());
            if let Some(decision) = mgr.maybe_replace(&self.scheduler.placement) {
                if let Some((model, bytes)) = &self.cost {
                    let moves = migration::placement_diff(
                        &self.scheduler.placement,
                        &decision.placement,
                        &self.topo,
                    );
                    prep_extra = migration::migration_time(
                        &moves,
                        *bytes,
                        model,
                        &self.topo,
                        loads.num_gpus,
                    );
                }
                self.scheduler = MicroEpScheduler::new(
                    decision.placement,
                    Some(self.topo.clone()),
                    self.opts.clone(),
                );
                self.replacements += 1;
            }
        }
        let sched = self.scheduler.schedule(loads);
        MoeLayerPlan {
            gpu_compute: sched.gpu_loads(&self.scheduler.placement),
            routes: sched.routes,
            sched_time: sched.stats.solve_ns as f64 * 1e-9,
            sched_overlapped: self.overlap,
            prep_extra,
        }
    }
}

impl Balancer for MicroMoe {
    fn name(&self) -> &str {
        self.name_override.unwrap_or(match self.adaptive {
            Some(_) => "MicroMoE",
            None => "MicroMoE (w/o AR)",
        })
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        step_layers(input.loads, |lm| self.plan_layer(lm))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{cross_traffic, zipf_loads};
    use super::*;
    use crate::placement::cayley::symmetric_placement;
    use crate::stats::imbalance_ratio;

    fn topo() -> Topology {
        Topology::new(8, 4, 2, 8)
    }

    fn micromoe_no_ar() -> MicroMoe {
        let t = topo();
        let p = symmetric_placement(&t, 16);
        MicroMoe::new(t, p, SchedulerOptions::default())
    }

    #[test]
    fn near_perfect_balance_at_moderate_skew() {
        // paper Fig. 7 config: DP=8, 32 experts — perfect balance for s<1
        let t = topo();
        let p = symmetric_placement(&t, 32);
        let mut s = MicroMoe::new(t, p, SchedulerOptions::default());
        for seed in 0..8 {
            let lm = zipf_loads(32, 8, 2000, 0.8, seed);
            let plan = s.plan(&lm);
            let loads: Vec<f64> = plan.gpu_compute.iter().map(|&x| x as f64).collect();
            let imb = imbalance_ratio(&loads);
            assert!(imb < 1.02, "seed {seed}: imbalance {imb}");
        }
    }

    #[test]
    fn beats_vanilla_ep_imbalance() {
        let mut mm = micromoe_no_ar();
        let mut van = super::super::vanilla_ep::VanillaEp::new(topo(), 16);
        for seed in 0..6 {
            let lm = zipf_loads(16, 8, 2000, 1.0, 40 + seed);
            let a = mm.plan(&lm);
            let b = van.plan(&lm);
            let ia = imbalance_ratio(&a.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>());
            let ib = imbalance_ratio(&b.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>());
            assert!(ia <= ib + 1e-9, "seed {seed}: micromoe {ia} vs vanilla {ib}");
        }
    }

    #[test]
    fn adaptive_replaces_under_sustained_skew() {
        let t = topo();
        let p = symmetric_placement(&t, 16);
        let mut s = MicroMoe::new(t, p, SchedulerOptions::default())
            .with_adaptive(
                AdaptiveConfig { check_every: 4, window: 8, slots_per_gpu: 4, ..Default::default() },
                11,
            )
            .with_migration_cost(CostModel::h100_testbed(), 1 << 22);
        let mut migration_charged = false;
        for seed in 0..40 {
            let plan = s.plan(&zipf_loads(16, 8, 3000, 2.0, 7)); // static heavy skew
            if plan.prep_extra > 0.0 {
                migration_charged = true;
            }
            let _ = seed;
        }
        assert!(s.replacements > 0, "AR never triggered under s=2.0");
        assert!(migration_charged, "migration never charged");
    }

    #[test]
    fn ar_improves_balance_under_heavy_skew() {
        let t = topo();
        let p = symmetric_placement(&t, 16);
        let mut no_ar = MicroMoe::new(t.clone(), p.clone(), SchedulerOptions::default());
        let mut with_ar = MicroMoe::new(t, p, SchedulerOptions::default()).with_adaptive(
            AdaptiveConfig { check_every: 4, window: 8, slots_per_gpu: 4, ..Default::default() },
            13,
        );
        let (mut i_no, mut i_ar) = (0.0, 0.0);
        for batch in 0..48 {
            let lm = zipf_loads(16, 8, 3000, 2.0, 3); // stationary heavy skew
            let a = no_ar.plan(&lm);
            let b = with_ar.plan(&lm);
            if batch >= 24 {
                i_no += imbalance_ratio(&a.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>());
                i_ar += imbalance_ratio(&b.gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>());
            }
        }
        assert!(
            i_ar < i_no,
            "AR {i_ar} should improve on static symmetric {i_no} at s=2.0"
        );
    }

    #[test]
    fn sched_time_is_reported() {
        let mut s = micromoe_no_ar();
        let plan = s.plan(&zipf_loads(16, 8, 1000, 0.5, 1));
        assert!(plan.sched_time > 0.0);
        assert!(plan.sched_overlapped);
    }

    #[test]
    fn locality_cuts_cross_traffic() {
        let t = topo();
        let p = symmetric_placement(&t, 16);
        let mut with_loc = MicroMoe::new(
            t.clone(),
            p.clone(),
            SchedulerOptions { locality_aware: true, ..Default::default() },
        );
        let mut without = MicroMoe::new(
            t,
            p,
            SchedulerOptions { locality_aware: false, ..Default::default() },
        );
        let mut tw = 0u64;
        let mut to = 0u64;
        for seed in 0..6 {
            let lm = zipf_loads(16, 8, 1500, 0.7, 70 + seed);
            tw += cross_traffic(&with_loc.plan(&lm));
            to += cross_traffic(&without.plan(&lm));
        }
        assert!(tw < to, "locality {tw} !< plain {to}");
    }
}
