//! SmartMoE baseline (§7.1): offline/online expert-placement optimization
//! within EP groups, from *long-term* load statistics.
//!
//! Every `replace_every` micro-batches, experts are re-assigned to EP ranks
//! by LPT (longest-processing-time greedy) on the EMA of expert loads —
//! identical placement across EP groups, no token scheduling. The paper's
//! Fig. 6/7 point: long-horizon placement cannot track per-micro-batch
//! fluctuations, so SmartMoE sometimes loses even to vanilla Megatron once
//! migration overhead is charged.

use crate::balancer::{step_layers, Balancer, MoeLayerPlan, StepInput, StepOutput};
use crate::cluster::{migration, CostModel};
use crate::scheduler::{LoadMatrix, Route};
use crate::stats::Ema;
use crate::topology::Topology;

/// SmartMoE-style baseline: periodic expert→rank re-optimization from
/// long-term (EMA) load statistics, within EP groups.
pub struct SmartMoe {
    topo: Topology,
    num_experts: usize,
    experts_per_gpu: usize,
    /// expert -> EP rank
    rank_of: Vec<usize>,
    ema: Vec<Ema>,
    batch: usize,
    /// Re-optimization cadence in micro-batches.
    pub replace_every: usize,
    /// charge migrations using this model (None = free migrations)
    cost: Option<(CostModel, u64)>, // (model, bytes per expert)
    /// Expert migrations performed so far.
    pub migrations: usize,
}

impl SmartMoe {
    /// Baseline starting from the contiguous vanilla-EP layout.
    pub fn new(topo: Topology, num_experts: usize) -> Self {
        let experts_per_gpu = topo.experts_per_gpu(num_experts);
        SmartMoe {
            topo,
            num_experts,
            experts_per_gpu,
            rank_of: (0..num_experts).map(|e| e / experts_per_gpu).collect(),
            ema: (0..num_experts).map(|_| Ema::new(0.05)).collect(),
            batch: 0,
            replace_every: 64,
            cost: None,
            migrations: 0,
        }
    }

    /// Charge migrations against this cost model.
    pub fn with_migration_cost(mut self, model: CostModel, bytes_per_expert: u64) -> Self {
        self.cost = Some((model, bytes_per_expert));
        self
    }

    /// LPT re-assignment of experts to EP ranks using EMA loads.
    fn reoptimize(&mut self) -> usize {
        let mut order: Vec<usize> = (0..self.num_experts).collect();
        let loads: Vec<f64> = self.ema.iter().map(|e| e.get().unwrap_or(0.0)).collect();
        order.sort_by(|&a, &b| loads[b].partial_cmp(&loads[a]).unwrap());
        let ranks = self.topo.ep_degree;
        let mut rank_load = vec![0.0f64; ranks];
        let mut rank_slots = vec![self.experts_per_gpu; ranks];
        let mut new_rank = vec![0usize; self.num_experts];
        for &e in &order {
            // least-loaded rank with a free slot
            let r = (0..ranks)
                .filter(|&r| rank_slots[r] > 0)
                .min_by(|&a, &b| rank_load[a].partial_cmp(&rank_load[b]).unwrap())
                .expect("slot accounting broke");
            new_rank[e] = r;
            rank_load[r] += loads[e];
            rank_slots[r] -= 1;
        }
        let moved = (0..self.num_experts).filter(|&e| new_rank[e] != self.rank_of[e]).count();
        self.rank_of = new_rank;
        moved
    }

    fn home_gpu(&self, e: usize, src: usize) -> usize {
        self.topo.ep_group_of(src) * self.topo.ep_degree + self.rank_of[e]
    }

    fn plan_layer(&mut self, loads: &LoadMatrix) -> MoeLayerPlan {
        // update long-term statistics
        for e in 0..self.num_experts {
            self.ema[e].update(loads.expert_load(e) as f64);
        }
        self.batch += 1;

        let mut prep_extra = 0.0;
        if self.batch % self.replace_every == 0 {
            let moved = self.reoptimize();
            if moved > 0 {
                self.migrations += 1;
                if let Some((model, bytes)) = &self.cost {
                    // every moved expert copies to d EP groups
                    let copies = moved * self.topo.num_ep_groups();
                    let fake_moves: Vec<migration::Move> = (0..copies)
                        .map(|i| migration::Move {
                            expert: i % self.num_experts,
                            dst: i % loads.num_gpus,
                            src: (i + 1) % loads.num_gpus,
                        })
                        .collect();
                    prep_extra = migration::migration_time(
                        &fake_moves,
                        *bytes,
                        model,
                        &self.topo,
                        loads.num_gpus,
                    );
                }
            }
        }

        let g_count = loads.num_gpus;
        let mut gpu_compute = vec![0u64; g_count];
        let mut routes = Vec::new();
        for e in 0..self.num_experts {
            for src in 0..g_count {
                let n = loads.get(e, src);
                if n == 0 {
                    continue;
                }
                let dst = self.home_gpu(e, src);
                gpu_compute[dst] += n;
                routes.push(Route { expert: e, src, dst, tokens: n });
            }
        }
        MoeLayerPlan {
            gpu_compute,
            routes,
            sched_time: 0.0,
            sched_overlapped: true,
            prep_extra,
        }
    }
}

impl Balancer for SmartMoe {
    fn name(&self) -> &str {
        "SmartMoE (expert placement)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        step_layers(input.loads, |lm| self.plan_layer(lm))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::zipf_loads;
    use super::*;
    use crate::stats::imbalance_ratio;

    #[test]
    fn reoptimization_improves_static_skew() {
        // stable skew: SmartMoE should converge to a better placement
        let topo = Topology::new(8, 4, 2, 8);
        let mut s = SmartMoe::new(topo, 16);
        s.replace_every = 8;
        let mut before = 0.0;
        let mut after = 0.0;
        for batch in 0..64 {
            let lm = zipf_loads(16, 8, 1000, 1.2, 42); // same dist every batch
            let plan = s.plan(&lm);
            let loads: Vec<f64> = plan.gpu_compute.iter().map(|&l| l as f64).collect();
            let imb = imbalance_ratio(&loads);
            if batch == 0 {
                before = imb;
            }
            after = imb;
        }
        assert!(after < before, "LPT never helped: {before} -> {after}");
    }

    #[test]
    fn conserves_tokens() {
        let topo = Topology::new(8, 4, 2, 8);
        let mut s = SmartMoe::new(topo, 16);
        let lm = zipf_loads(16, 8, 700, 0.8, 7);
        let plan = s.plan(&lm);
        assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total());
    }

    #[test]
    fn respects_slot_capacity() {
        let topo = Topology::new(8, 4, 2, 8);
        let mut s = SmartMoe::new(topo, 16);
        s.replace_every = 1;
        for seed in 0..10 {
            let lm = zipf_loads(16, 8, 500, 1.5, seed);
            s.plan(&lm);
            // each rank holds exactly experts_per_gpu experts
            let mut per_rank = vec![0usize; 4];
            for e in 0..16 {
                per_rank[s.rank_of[e]] += 1;
            }
            assert_eq!(per_rank, vec![4; 4]);
        }
    }

    #[test]
    fn migration_cost_charged_on_replacement() {
        let topo = Topology::new(8, 4, 2, 8);
        let mut s = SmartMoe::new(topo, 16)
            .with_migration_cost(CostModel::h100_testbed(), 1 << 24);
        s.replace_every = 4;
        let mut charged = false;
        for seed in 0..16 {
            // alternate between two skews so placements keep moving
            let skew = if seed % 2 == 0 { 2.0 } else { 0.2 };
            let plan = s.plan(&zipf_loads(16, 8, 500, skew, seed));
            if plan.prep_extra > 0.0 {
                charged = true;
            }
        }
        assert!(charged, "migration never charged");
    }

    #[test]
    fn tokens_stay_in_ep_group() {
        let topo = Topology::new(8, 4, 2, 8);
        let mut s = SmartMoe::new(topo.clone(), 16);
        let lm = zipf_loads(16, 8, 300, 1.0, 9);
        let plan = s.plan(&lm);
        for r in &plan.routes {
            assert_eq!(topo.ep_group_of(r.src), topo.ep_group_of(r.dst));
        }
    }
}
