//! DeepSpeed-style capacity padding (§7.2's analysis of DeepSpeed).
//!
//! GShard-lineage implementations pad every expert's batch to the *maximum*
//! expert load so tensor shapes are static: with skewed loads each GPU
//! computes `experts_per_gpu × max_e load_e` rows regardless of its real
//! load, wasting compute and memory — which is why DeepSpeed collapses at
//! 16/32 experts in Fig. 6 and is omitted from Fig. 8.

use crate::balancer::{step_layers, Balancer, MoeLayerPlan, StepInput, StepOutput};
use crate::scheduler::{LoadMatrix, Route};
use crate::topology::Topology;

/// DeepSpeed/GShard-style capacity padding: vanilla EP routing with
/// every expert padded to the max expert load.
pub struct DeepSpeedPad {
    inner: super::vanilla_ep::VanillaEp,
    topo: Topology,
    num_experts: usize,
}

impl DeepSpeedPad {
    /// Padding baseline over the vanilla-EP layout.
    pub fn new(topo: Topology, num_experts: usize) -> Self {
        DeepSpeedPad {
            inner: super::vanilla_ep::VanillaEp::new(topo.clone(), num_experts),
            topo,
            num_experts,
        }
    }
}

impl DeepSpeedPad {
    fn plan_layer(&mut self, loads: &LoadMatrix) -> MoeLayerPlan {
        let mut plan = self.inner.plan(loads);
        // per EP group: pad every expert to the group's max expert load
        let experts_per_gpu = self.num_experts / self.topo.ep_degree;
        for grp in 0..self.topo.num_ep_groups() {
            let gpus = self.topo.ep_gpus(grp);
            // max over experts of tokens arriving from this EP group
            let mut max_load = 0u64;
            for e in 0..self.num_experts {
                let l: u64 = gpus.clone().map(|g| loads.get(e, g)).sum();
                max_load = max_load.max(l);
            }
            let padded = max_load * experts_per_gpu as u64;
            for g in gpus {
                plan.gpu_compute[g] = padded;
            }
        }
        // padding also inflates the all-to-all: slots are exchanged at
        // capacity, not at actual counts
        let mut pad_routes: Vec<Route> = Vec::with_capacity(plan.routes.len());
        for grp in 0..self.topo.num_ep_groups() {
            let gpus: Vec<usize> = self.topo.ep_gpus(grp).collect();
            let mut max_load = 0u64;
            for e in 0..self.num_experts {
                let l: u64 = gpus.iter().map(|&g| loads.get(e, g)).sum();
                max_load = max_load.max(l);
            }
            // each src sends capacity/|group| slots per expert to its home
            let per_src = max_load.div_ceil(gpus.len() as u64);
            for e in 0..self.num_experts {
                for &src in &gpus {
                    let dst = self.inner.home_gpu(e, src);
                    pad_routes.push(Route { expert: e, src, dst, tokens: per_src });
                }
            }
        }
        plan.routes = pad_routes;
        plan
    }
}

impl Balancer for DeepSpeedPad {
    fn name(&self) -> &str {
        "DeepSpeed (capacity padding)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        step_layers(input.loads, |lm| self.plan_layer(lm))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::zipf_loads;
    use super::*;

    fn sys() -> DeepSpeedPad {
        DeepSpeedPad::new(Topology::new(8, 4, 2, 8), 16)
    }

    #[test]
    fn all_gpus_compute_padded_amount() {
        let mut s = sys();
        let lm = zipf_loads(16, 8, 500, 1.5, 3);
        let plan = s.plan(&lm);
        // within each EP group, all GPUs equal
        for grp in [0usize, 1] {
            let gpus: Vec<usize> = (grp * 4..(grp + 1) * 4).collect();
            let first = plan.gpu_compute[gpus[0]];
            for &g in &gpus {
                assert_eq!(plan.gpu_compute[g], first);
            }
        }
    }

    #[test]
    fn padding_never_below_actual() {
        let mut pad = sys();
        let mut van = super::super::vanilla_ep::VanillaEp::new(
            Topology::new(8, 4, 2, 8),
            16,
        );
        let lm = zipf_loads(16, 8, 500, 1.0, 4);
        let p = pad.plan(&lm);
        let v = van.plan(&lm);
        for g in 0..8 {
            assert!(p.gpu_compute[g] >= v.gpu_compute[g], "gpu {g}");
        }
    }

    #[test]
    fn uniform_loads_minimal_waste() {
        let mut s = sys();
        let lm = zipf_loads(16, 8, 4000, 0.0, 5);
        let plan = s.plan(&lm);
        let padded: u64 = plan.gpu_compute.iter().sum();
        // waste < 35% under uniform loads (statistical max ≈ mean)
        assert!(
            (padded as f64) < 1.35 * lm.total() as f64,
            "padded {padded} vs actual {}",
            lm.total()
        );
    }

    #[test]
    fn skew_explodes_padding() {
        let mut s = sys();
        let lm = zipf_loads(16, 8, 1000, 2.0, 6);
        let plan = s.plan(&lm);
        let padded: u64 = plan.gpu_compute.iter().sum();
        assert!(
            (padded as f64) > 3.0 * lm.total() as f64,
            "padding should blow up under skew: {padded} vs {}",
            lm.total()
        );
    }
}
