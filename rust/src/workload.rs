//! Workload generators: synthetic gate outputs (`input_e^g` matrices) with
//! controllable skew and dynamics, plus trace replay from real training.
//!
//! * [`ZipfWorkload`] — §7.3's evaluation workload: token→expert assignment
//!   follows a Zipfian distribution with skewness `s` over a per-generator
//!   expert popularity ranking.
//! * [`DriftingWorkload`] — the Fig.-2 phenomenon: popularity ranks rotate
//!   and per-micro-batch noise fluctuates, so the hot expert set changes
//!   over time (what adaptive replacement reacts to).
//! * [`TopicMix`] — the serving tier's per-token view of the same drift
//!   model: expert popularity sampled one token at a time, rotated per
//!   batching window instead of per fixed-shape batch.
//! * [`TraceWorkload`] — replays `(micro_batch, expert, gpu) -> count`
//!   traces recorded from the real e2e training run (Fig. 2's data).

use crate::rng::{Rng, Zipf};
use crate::scheduler::LoadMatrix;
use crate::ser::Json;

/// Common interface: produce the next micro-batch's load matrix.
pub trait Workload {
    /// Generate the next micro-batch's `input_e^g` matrix.
    fn next_batch(&mut self) -> LoadMatrix;
    /// Experts in every generated matrix.
    fn num_experts(&self) -> usize;
    /// Source GPUs in every generated matrix.
    fn num_gpus(&self) -> usize;
}

/// Zipfian token→expert assignment, independent per source GPU.
pub struct ZipfWorkload {
    /// Experts in the popularity ranking.
    pub experts: usize,
    /// Source GPUs per batch.
    pub gpus: usize,
    /// Tokens emitted per GPU per batch.
    pub tokens_per_gpu: u64,
    zipf: Zipf,
    /// rank→expert mapping (which expert is the i-th hottest)
    rank_of: Vec<usize>,
    rng: Rng,
}

impl ZipfWorkload {
    /// Workload with skew `s` and a seeded random popularity ranking.
    pub fn new(experts: usize, gpus: usize, tokens_per_gpu: u64, s: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut rank_of: Vec<usize> = (0..experts).collect();
        rng.shuffle(&mut rank_of);
        ZipfWorkload { experts, gpus, tokens_per_gpu, zipf: Zipf::new(experts, s), rank_of, rng }
    }
}

impl Workload for ZipfWorkload {
    fn next_batch(&mut self) -> LoadMatrix {
        let mut lm = LoadMatrix::zeros(self.experts, self.gpus);
        for g in 0..self.gpus {
            for _ in 0..self.tokens_per_gpu {
                let rank = self.zipf.sample(&mut self.rng);
                lm.add(self.rank_of[rank], g, 1);
            }
        }
        lm
    }

    fn num_experts(&self) -> usize {
        self.experts
    }

    fn num_gpus(&self) -> usize {
        self.gpus
    }
}

/// Zipf workload whose popularity ranking drifts: every `rotate_every`
/// micro-batches the top ranks permute, modelling inter-iteration dynamics.
pub struct DriftingWorkload {
    inner: ZipfWorkload,
    rotate_every: usize,
    batch: usize,
}

impl DriftingWorkload {
    /// Drifting workload rotating its hot set every `rotate_every` batches.
    pub fn new(
        experts: usize,
        gpus: usize,
        tokens_per_gpu: u64,
        s: f64,
        rotate_every: usize,
        seed: u64,
    ) -> Self {
        DriftingWorkload {
            inner: ZipfWorkload::new(experts, gpus, tokens_per_gpu, s, seed),
            rotate_every: rotate_every.max(1),
            batch: 0,
        }
    }
}

impl Workload for DriftingWorkload {
    fn next_batch(&mut self) -> LoadMatrix {
        if self.batch > 0 && self.batch % self.rotate_every == 0 {
            // rotate the hottest third of the ranking
            let k = (self.inner.experts / 3).max(2).min(self.inner.experts);
            self.inner.rank_of[..k].rotate_left(1);
            // and occasionally swap a hot rank with a random cold one
            let hot = self.inner.rng.below(k as u64) as usize;
            let cold = k + self.inner.rng.below((self.inner.experts - k).max(1) as u64) as usize;
            if cold < self.inner.experts {
                self.inner.rank_of.swap(hot, cold);
            }
        }
        self.batch += 1;
        self.inner.next_batch()
    }

    fn num_experts(&self) -> usize {
        self.inner.experts
    }

    fn num_gpus(&self) -> usize {
        self.inner.gpus
    }
}

/// Per-token drifting expert popularity for the serving tier: the same
/// Zipf-over-drifting-ranks model as [`DriftingWorkload`], but sampled one
/// token at a time so the batching-window server can assemble load
/// matrices from whatever requests fell inside a window, instead of
/// consuming fixed-shape batches. Rotation ticks per *window* (via
/// [`TopicMix::next_window`]), mirroring `DriftingWorkload`'s per-batch
/// rotation of the hottest third of the ranking.
pub struct TopicMix {
    experts: usize,
    zipf: Zipf,
    rank_of: Vec<usize>,
    rng: Rng,
    rotate_every: usize,
    window: usize,
}

impl TopicMix {
    /// Mix over `experts` with Zipf skew `s`, rotating the hot set every
    /// `rotate_every` windows (0 disables drift), from a seeded ranking.
    pub fn new(experts: usize, s: f64, rotate_every: usize, seed: u64) -> Self {
        assert!(experts > 0);
        let mut rng = Rng::new(seed);
        let mut rank_of: Vec<usize> = (0..experts).collect();
        rng.shuffle(&mut rank_of);
        TopicMix { experts, zipf: Zipf::new(experts, s), rank_of, rng, rotate_every, window: 0 }
    }

    /// Experts in the popularity ranking.
    pub fn num_experts(&self) -> usize {
        self.experts
    }

    /// Advance to the next batching window, applying the drift rotation on
    /// the same cadence and with the same permutation moves as
    /// [`DriftingWorkload`].
    pub fn next_window(&mut self) {
        if self.rotate_every > 0 && self.window > 0 && self.window % self.rotate_every == 0 {
            let k = (self.experts / 3).max(2).min(self.experts);
            self.rank_of[..k].rotate_left(1);
            let hot = self.rng.below(k as u64) as usize;
            let cold = k + self.rng.below((self.experts - k).max(1) as u64) as usize;
            if cold < self.experts {
                self.rank_of.swap(hot, cold);
            }
        }
        self.window += 1;
    }

    /// Sample the expert one token routes to under the current ranking.
    pub fn sample_expert(&mut self) -> usize {
        let rank = self.zipf.sample(&mut self.rng);
        self.rank_of[rank]
    }

    /// Spread `tokens` tokens emitted by source GPU `gpu` over the experts
    /// of `lm` (one Zipf draw per token).
    pub fn scatter(&mut self, lm: &mut LoadMatrix, gpu: usize, tokens: u64) {
        for _ in 0..tokens {
            let e = self.sample_expert();
            lm.add(e, gpu, 1);
        }
    }
}

/// Replays recorded load matrices (loops at the end).
pub struct TraceWorkload {
    batches: Vec<LoadMatrix>,
    cursor: usize,
}

impl TraceWorkload {
    /// Replay the given batches in order, looping at the end.
    pub fn new(batches: Vec<LoadMatrix>) -> Self {
        assert!(!batches.is_empty());
        TraceWorkload { batches, cursor: 0 }
    }

    /// Number of recorded batches.
    pub fn len(&self) -> usize {
        self.batches.len()
    }

    /// Whether the trace is empty (never true: construction asserts).
    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }

    /// Parse from the JSON trace format written by the e2e trainer:
    /// `{"experts": E, "gpus": G, "batches": [[[count; G]; E], ...]}`.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let e = j.get("experts").and_then(Json::as_usize).ok_or("missing experts")?;
        let g = j.get("gpus").and_then(Json::as_usize).ok_or("missing gpus")?;
        let batches = j.get("batches").and_then(Json::as_arr).ok_or("missing batches")?;
        let mut out = Vec::with_capacity(batches.len());
        for (bi, b) in batches.iter().enumerate() {
            let rows = b.as_arr().ok_or(format!("batch {bi} not an array"))?;
            if rows.len() != e {
                return Err(format!("batch {bi}: {} rows != {e}", rows.len()));
            }
            let mut lm = LoadMatrix::zeros(e, g);
            for (ei, row) in rows.iter().enumerate() {
                let cells = row.as_arr().ok_or("row not an array")?;
                if cells.len() != g {
                    return Err(format!("batch {bi} row {ei}: width {} != {g}", cells.len()));
                }
                for (gi, c) in cells.iter().enumerate() {
                    lm.set(ei, gi, c.as_f64().ok_or("non-numeric count")? as u64);
                }
            }
            out.push(lm);
        }
        Ok(TraceWorkload::new(out))
    }

    /// Serialize back to the JSON trace format.
    pub fn to_json(&self) -> Json {
        let e = self.batches[0].num_experts;
        let g = self.batches[0].num_gpus;
        let batches: Vec<Json> = self
            .batches
            .iter()
            .map(|lm| {
                Json::Arr(
                    (0..e)
                        .map(|ei| Json::arr_u64(&(0..g).map(|gi| lm.get(ei, gi)).collect::<Vec<_>>()))
                        .collect(),
                )
            })
            .collect();
        Json::obj(vec![
            ("experts", Json::Num(e as f64)),
            ("gpus", Json::Num(g as f64)),
            ("batches", Json::Arr(batches)),
        ])
    }
}

impl Workload for TraceWorkload {
    fn next_batch(&mut self) -> LoadMatrix {
        let b = self.batches[self.cursor].clone();
        self.cursor = (self.cursor + 1) % self.batches.len();
        b
    }

    fn num_experts(&self) -> usize {
        self.batches[0].num_experts
    }

    fn num_gpus(&self) -> usize {
        self.batches[0].num_gpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::imbalance_ratio;

    #[test]
    fn zipf_token_conservation() {
        let mut w = ZipfWorkload::new(16, 8, 100, 1.0, 42);
        let lm = w.next_batch();
        assert_eq!(lm.total(), 800);
        for g in 0..8 {
            assert_eq!(lm.gpu_input(g), 100);
        }
    }

    #[test]
    fn zero_skew_is_roughly_uniform() {
        let mut w = ZipfWorkload::new(8, 4, 10_000, 0.0, 1);
        let lm = w.next_batch();
        let loads: Vec<f64> = lm.expert_loads().iter().map(|&l| l as f64).collect();
        assert!(imbalance_ratio(&loads) < 1.1, "{loads:?}");
    }

    #[test]
    fn high_skew_concentrates() {
        let mut w = ZipfWorkload::new(8, 4, 10_000, 2.0, 1);
        let lm = w.next_batch();
        let loads = lm.expert_loads();
        let max = *loads.iter().max().unwrap();
        assert!(max as f64 > 0.5 * lm.total() as f64);
    }

    #[test]
    fn drifting_changes_hot_expert() {
        let mut w = DriftingWorkload::new(8, 4, 5_000, 1.5, 1, 7);
        let hot_of = |lm: &LoadMatrix| -> usize {
            let loads = lm.expert_loads();
            loads.iter().enumerate().max_by_key(|&(_, &l)| l).unwrap().0
        };
        let first = hot_of(&w.next_batch());
        let mut changed = false;
        for _ in 0..30 {
            if hot_of(&w.next_batch()) != first {
                changed = true;
                break;
            }
        }
        assert!(changed, "hot expert never drifted");
    }

    #[test]
    fn topic_mix_conserves_and_drifts() {
        let mut mix = TopicMix::new(8, 1.5, 1, 7);
        let hot_of = |mix: &mut TopicMix| -> usize {
            let mut lm = LoadMatrix::zeros(8, 2);
            mix.next_window();
            mix.scatter(&mut lm, 0, 2_500);
            mix.scatter(&mut lm, 1, 2_500);
            assert_eq!(lm.total(), 5_000);
            let loads = lm.expert_loads();
            loads.iter().enumerate().max_by_key(|&(_, &l)| l).unwrap().0
        };
        let first = hot_of(&mut mix);
        let mut changed = false;
        for _ in 0..30 {
            if hot_of(&mut mix) != first {
                changed = true;
                break;
            }
        }
        assert!(changed, "hot expert never drifted across windows");
    }

    #[test]
    fn trace_roundtrip_json() {
        let mut w = ZipfWorkload::new(4, 2, 50, 1.0, 3);
        let batches: Vec<LoadMatrix> = (0..3).map(|_| w.next_batch()).collect();
        let t = TraceWorkload::new(batches.clone());
        let j = t.to_json();
        let mut t2 = TraceWorkload::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        for b in &batches {
            assert_eq!(&t2.next_batch(), b);
        }
    }

    #[test]
    fn trace_loops() {
        let lm = LoadMatrix::from_rows(vec![vec![1, 2], vec![3, 4]]);
        let mut t = TraceWorkload::new(vec![lm.clone()]);
        assert_eq!(t.next_batch(), lm);
        assert_eq!(t.next_batch(), lm);
    }

    #[test]
    fn from_json_rejects_malformed() {
        let j = Json::parse(r#"{"experts": 2, "gpus": 2, "batches": [[[1,2]]]}"#).unwrap();
        assert!(TraceWorkload::from_json(&j).is_err());
    }
}
