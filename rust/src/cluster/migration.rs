//! Expert-parameter migration cost model (§7.5 Fig. 10).
//!
//! Adaptive replacement re-initializes expert placements; the cost is
//! moving expert parameters *and optimizer states* between GPUs. With
//! BF16 params, FP32 Adam moments and an FP32 master copy (Megatron's
//! distributed-optimizer layout), each expert parameter costs
//! 2 + 4 + 4 + 4 = 14 bytes to relocate.

use super::CostModel;
use crate::placement::Placement;
use crate::topology::Topology;

/// Bytes per expert for a two-matrix FFN expert (h×f and f×h).
pub fn expert_bytes(hidden: usize, ffn: usize, with_optimizer: bool) -> u64 {
    let params = 2 * hidden as u64 * ffn as u64;
    let per_param = if with_optimizer { 14 } else { 2 };
    params * per_param
}

/// A replica movement: expert `e` appears on `dst` where it wasn't before.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Move {
    /// Expert being copied.
    pub expert: usize,
    /// Destination GPU gaining the replica.
    pub dst: usize,
    /// chosen source replica (nearest surviving one)
    pub src: usize,
}

/// Diff two placements into the replica copies required. The result is
/// deterministically ordered by `(expert, src, dst)` so downstream
/// consumers (controller decisions, trace spans, golden fixtures) see a
/// stable move list regardless of replica-group iteration order.
pub fn placement_diff(old: &Placement, new: &Placement, topo: &Topology) -> Vec<Move> {
    assert_eq!(old.num_experts, new.num_experts);
    let mut moves = Vec::new();
    for e in 0..new.num_experts {
        for &dst in &new.replicas[e] {
            if !old.hosts(dst, e) {
                // prefer an intra-node source if one exists
                let src = old.replicas[e]
                    .iter()
                    .copied()
                    .min_by_key(|&s| (!topo.same_node(s, dst) as usize, s))
                    .expect("expert had no replica in old placement");
                moves.push(Move { expert: e, dst, src });
            }
        }
    }
    moves.sort_unstable_by_key(|m| (m.expert, m.src, m.dst));
    moves
}

/// Total migration time: per-GPU send/recv volumes over the right link
/// tiers, bottlenecked by the busiest GPU (copies proceed in parallel).
pub fn migration_time(
    moves: &[Move],
    bytes_per_expert: u64,
    model: &CostModel,
    topo: &Topology,
    num_gpus: usize,
) -> f64 {
    if moves.is_empty() {
        return 0.0;
    }
    let mut si = vec![0u64; num_gpus];
    let mut ri = vec![0u64; num_gpus];
    let mut sj = vec![0u64; num_gpus];
    let mut rj = vec![0u64; num_gpus];
    for m in moves {
        if topo.same_node(m.src, m.dst) {
            si[m.src] += bytes_per_expert;
            ri[m.dst] += bytes_per_expert;
        } else {
            sj[m.src] += bytes_per_expert;
            rj[m.dst] += bytes_per_expert;
        }
    }
    // Migration runs through the framework's re-init path (broadcast +
    // optimizer-state reshuffle), not a raw memcpy: the paper's Fig. 10
    // shows hundreds of ms for Table-2 models, implying ~10% of line rate.
    const MIGRATION_EFF: f64 = 0.10;
    // training suspension + process-group re-initialization
    const REINIT_OVERHEAD: f64 = 50e-3;
    let mut worst: f64 = 0.0;
    for g in 0..num_gpus {
        let t = si[g].max(ri[g]) as f64 / (model.nvlink_bw * MIGRATION_EFF)
            + sj[g].max(rj[g]) as f64 / (model.ib_bw * MIGRATION_EFF);
        worst = worst.max(t);
    }
    worst + model.inter_lat + REINIT_OVERHEAD
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::Placement;

    fn topo4() -> Topology {
        Topology::new(4, 2, 2, 2)
    }

    #[test]
    fn expert_bytes_gpt13b_scale() {
        // GPT 32×1.3B: h=2048, f=8192 -> 2·h·f = 33.5M params
        let b = expert_bytes(2048, 8192, true);
        assert_eq!(b, 2 * 2048 * 8192 * 14);
    }

    #[test]
    fn no_moves_for_identical_placements() {
        let p = Placement::from_replicas(4, vec![vec![0, 1], vec![2, 3]]);
        assert!(placement_diff(&p, &p, &topo4()).is_empty());
        assert_eq!(migration_time(&[], 1, &CostModel::h100_testbed(), &topo4(), 4), 0.0);
    }

    #[test]
    fn diff_finds_new_replicas() {
        let old = Placement::from_replicas(4, vec![vec![0, 1], vec![2, 3]]);
        let new = Placement::from_replicas(4, vec![vec![0, 2], vec![2, 3]]);
        let moves = placement_diff(&old, &new, &topo4());
        assert_eq!(moves.len(), 1);
        assert_eq!(moves[0].expert, 0);
        assert_eq!(moves[0].dst, 2);
    }

    #[test]
    fn prefers_intra_node_source() {
        // expert replicas on {0, 2}; new replica on 3. node(3) = {2,3},
        // so src must be 2.
        let old = Placement::from_replicas(4, vec![vec![0, 2]]);
        let new = Placement::from_replicas(4, vec![vec![0, 2, 3]]);
        let moves = placement_diff(&old, &new, &topo4());
        assert_eq!(moves[0].src, 2);
    }

    #[test]
    fn migration_magnitude_matches_fig10() {
        // Fig. 10: hundreds of ms for Table-2 models. Take GPT 16×3.2B
        // (h=4096, f=16384) and move half of 16 experts across nodes.
        let model = CostModel::h100_testbed();
        let topo = Topology::new(8, 4, 2, 4);
        let old = Placement::from_replicas(
            8,
            (0..16).map(|e| vec![e % 8, (e + 4) % 8]).collect(),
        );
        let new = Placement::from_replicas(
            8,
            (0..16).map(|e| vec![(e + 1) % 8, (e + 5) % 8]).collect(),
        );
        let moves = placement_diff(&old, &new, &topo);
        let t = migration_time(&moves, expert_bytes(4096, 16384, true), &model, &topo, 8);
        assert!((0.05..2.0).contains(&t), "migration {t}s out of Fig-10 range");
    }

    #[test]
    fn diff_is_sorted_by_expert_src_dst() {
        // experts placed so the raw scan order (per-expert dst order)
        // differs from the pinned (expert, src, dst) order
        let old = Placement::from_replicas(4, vec![vec![3], vec![2], vec![1]]);
        let new =
            Placement::from_replicas(4, vec![vec![3, 2, 0], vec![2, 0], vec![1, 3]]);
        let moves = placement_diff(&old, &new, &topo4());
        let keys: Vec<_> = moves.iter().map(|m| (m.expert, m.src, m.dst)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "moves must come out ordered by (expert, src, dst)");
        assert_eq!(moves.len(), 4);
    }

    #[test]
    fn migration_time_monotone_in_bytes() {
        let model = CostModel::h100_testbed();
        let topo = topo4();
        let moves = vec![
            Move { expert: 0, dst: 1, src: 0 },
            Move { expert: 1, dst: 3, src: 0 },
        ];
        let mut prev = 0.0;
        for bytes in [1u64 << 20, 1 << 24, 1 << 28, 1 << 32] {
            let t = migration_time(&moves, bytes, &model, &topo, 4);
            assert!(t > prev, "time must strictly grow with bytes: {t} vs {prev}");
            prev = t;
        }
    }

    #[test]
    fn migration_time_monotone_in_bandwidth() {
        let topo = topo4();
        let moves = vec![
            Move { expert: 0, dst: 1, src: 0 }, // intra-node (NVLink tier)
            Move { expert: 1, dst: 3, src: 0 }, // inter-node (IB tier)
        ];
        let b = expert_bytes(4096, 16384, true);
        let base = CostModel::h100_testbed();
        let t0 = migration_time(&moves, b, &base, &topo, 4);
        // doubling either link tier's bandwidth must strictly shrink the
        // total (each tier carries traffic in this move set)
        let mut fast_nv = base.clone();
        fast_nv.nvlink_bw *= 2.0;
        assert!(migration_time(&moves, b, &fast_nv, &topo, 4) < t0);
        let mut fast_ib = base.clone();
        fast_ib.ib_bw *= 2.0;
        assert!(migration_time(&moves, b, &fast_ib, &topo, 4) < t0);
    }

    #[test]
    fn more_moves_cost_more() {
        let model = CostModel::h100_testbed();
        let topo = topo4();
        let b = expert_bytes(1024, 4096, true);
        let one = vec![Move { expert: 0, dst: 3, src: 0 }];
        let many: Vec<Move> =
            (0..8).map(|e| Move { expert: e, dst: 3, src: 0 }).collect();
        assert!(
            migration_time(&many, b, &model, &topo, 4)
                > migration_time(&one, b, &model, &topo, 4)
        );
    }
}
