//! MoE-layer and training-iteration timing simulation.
//!
//! [`moe_layer_time`] produces the Fig.-8 breakdown (prep / dispatch A2A /
//! expert compute / combine A2A) for one micro-batch given a system's plan;
//! [`TrainIterationModel`] composes layer times into end-to-end iteration
//! time with pipeline-parallel bubbles and gradient sync (Fig. 6).

use super::CostModel;
use crate::balancer::MoeSession;
use crate::placement::Placement;
use crate::scheduler::{LoadMatrix, SchedulerOptions};
use crate::stats::EngineStats;
use crate::topology::Topology;

pub use crate::balancer::MoeLayerPlan;

/// Fig.-8 execution-time breakdown of one MoE layer (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MoeLayerBreakdown {
    /// all-gather of load info + (non-overlapped) scheduling + extras
    pub prep: f64,
    /// Dispatch all-to-all.
    pub dispatch: f64,
    /// Max per-GPU expert FFN time.
    pub compute: f64,
    /// Combine all-to-all.
    pub combine: f64,
}

impl MoeLayerBreakdown {
    /// Sum of all phases.
    pub fn total(&self) -> f64 {
        self.prep + self.dispatch + self.compute + self.combine
    }
}

/// Time one MoE layer under the cost model.
pub fn moe_layer_time(
    model: &CostModel,
    topo: &Topology,
    plan: &MoeLayerPlan,
) -> MoeLayerBreakdown {
    let g = plan.gpu_compute.len();
    // load-info all-gather: E×G u32 counts ≈ tiny; dominated by latency
    let crosses = g > topo.gpus_per_node;
    let info_bytes = 4.0 * 64.0; // per-rank expert-count vector (capped)
    let gather = if plan.sched_time > 0.0 {
        model.allgather_time(info_bytes, g, crosses)
    } else {
        0.0
    };
    let sched = if plan.sched_overlapped { 0.0 } else { plan.sched_time };
    let prep = gather + sched + plan.prep_extra;

    let dispatch = model.a2a_time_from_routes(&plan.routes, g, topo);
    // combine moves the same volumes in reverse; max(send,recv) symmetric
    let combine = dispatch;

    let compute = plan
        .gpu_compute
        .iter()
        .map(|&t| model.ffn_time(t))
        .fold(0.0, f64::max);

    MoeLayerBreakdown { prep, dispatch, compute, combine }
}

/// Multi-layer MoE timing over the unified policy API: a
/// [`MoeSession`] owns one warm scheduler per layer (exactly like the
/// per-layer solver replicas a real deployment keeps) and the sim times
/// every emitted plan under the cost model. On a training pipeline every
/// layer's gate output is available once the previous forward finishes, so
/// the solves are embarrassingly parallel — this is the wall-clock win
/// that keeps scheduling off the critical path even when a stage holds
/// many MoE layers. [`SchedulerOptions::engine`] selects the execution
/// backend of the default `micromoe` policy: the round-barrier fan-out
/// (default) or the persistent engine (pipelined / speculative); arbitrary
/// policies plug in through [`MultiLayerSim::with_session`].
pub struct MultiLayerSim {
    /// Cluster cost model used to time each layer.
    pub model: CostModel,
    /// Topology (node boundaries for the all-to-all model).
    pub topo: Topology,
    session: MoeSession,
    /// §5.4: scheduling overlaps the token-permute op
    pub overlap: bool,
}

impl MultiLayerSim {
    /// `layers` independent per-layer schedulers over one shared placement
    /// (the `micromoe` policy), executed by the backend `opts.engine`
    /// selects.
    pub fn new(
        model: CostModel,
        topo: Topology,
        placement: Placement,
        opts: SchedulerOptions,
        layers: usize,
    ) -> Self {
        let session = MoeSession::builder()
            .topology(topo.clone())
            .placement(placement)
            .options(opts)
            .layers(layers)
            .build()
            .expect("sim session over an explicit placement");
        MultiLayerSim::with_session(model, topo, session)
    }

    /// Time an arbitrary policy session under this cost model.
    pub fn with_session(model: CostModel, topo: Topology, session: MoeSession) -> Self {
        MultiLayerSim { model, topo, session, overlap: true }
    }

    /// Number of MoE layers simulated.
    pub fn layers(&self) -> usize {
        self.session.layers()
    }

    /// The policy session driving the per-layer solves.
    pub fn session(&self) -> &MoeSession {
        &self.session
    }

    /// Engine counters (hit/miss/pivot meters) when the session's policy
    /// runs the persistent engine; `None` on the barrier path.
    pub fn engine_stats(&self) -> Option<EngineStats> {
        self.session.engine_stats()
    }

    /// Schedule one micro-batch for every layer and time each layer under
    /// the cost model. `loads[l]` is layer `l`'s `input_e^g`. On the
    /// engine backend each layer's timing is computed as its plan is
    /// emitted, while later layers are still solving in the pool.
    pub fn step(&mut self, loads: &[LoadMatrix]) -> Vec<MoeLayerBreakdown> {
        let MultiLayerSim { model, topo, session, overlap } = self;
        let (model, topo, overlap) = (&*model, &*topo, *overlap);
        let mut out = Vec::with_capacity(loads.len());
        session.step_with(loads, &mut |_, mut plan| {
            plan.sched_overlapped = overlap;
            out.push(moe_layer_time(model, topo, &plan));
        });
        out
    }
}

/// End-to-end iteration model (Fig. 6): GPipe-style schedule.
#[derive(Clone, Debug)]
pub struct TrainIterationModel {
    /// Pipeline-parallel degree.
    pub pp_degree: usize,
    /// MoE layers per pipeline stage.
    pub layers_per_stage: usize,
    /// Micro-batches per iteration (per DP group).
    pub num_microbatches: usize,
    /// per-micro-batch attention + dense time per layer (s)
    pub attn_time: f64,
    /// per-iteration gradient sync (s)
    pub grad_sync: f64,
    /// backward/forward compute ratio (≈2 for matmul-dominated layers)
    pub bwd_factor: f64,
}

impl TrainIterationModel {
    /// Paper testbed defaults: PP = nodes, DP = 8 (§7.1).
    pub fn paper_default(pp: usize, layers: usize, num_microbatches: usize) -> Self {
        TrainIterationModel {
            pp_degree: pp,
            layers_per_stage: layers / pp.max(1),
            num_microbatches,
            attn_time: 0.8e-3,
            grad_sync: 5e-3,
            bwd_factor: 2.0,
        }
    }

    /// Iteration time from the mean per-micro-batch MoE-layer breakdown.
    ///
    /// fwd stage time = layers·(attn + moe_total); bwd multiplies compute
    /// by `bwd_factor` and repeats both all-to-alls. GPipe bubble:
    /// (m + p − 1)/m scaling of the per-micro-batch pipeline.
    pub fn iteration_time(&self, moe: &MoeLayerBreakdown) -> f64 {
        let fwd_stage =
            self.layers_per_stage as f64 * (self.attn_time + moe.total());
        let bwd_stage = self.layers_per_stage as f64
            * (self.attn_time * self.bwd_factor
                + moe.prep
                + self.bwd_factor * moe.compute
                + moe.dispatch
                + moe.combine);
        let per_mb = fwd_stage + bwd_stage;
        let m = self.num_microbatches as f64;
        let p = self.pp_degree as f64;
        per_mb * (m + p - 1.0) + self.grad_sync
    }

    /// Throughput in tokens/s given tokens per micro-batch (per DP group).
    pub fn throughput(&self, moe: &MoeLayerBreakdown, tokens_per_mb: u64) -> f64 {
        let t = self.iteration_time(moe);
        (tokens_per_mb * self.num_microbatches as u64) as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{MicroEpScheduler, Route};

    fn flat_plan(per_gpu: u64, g: usize) -> MoeLayerPlan {
        MoeLayerPlan {
            gpu_compute: vec![per_gpu; g],
            routes: Vec::new(),
            sched_time: 0.0,
            sched_overlapped: false,
            prep_extra: 0.0,
        }
    }

    #[test]
    fn compute_dominated_by_straggler() {
        let m = CostModel::h100_testbed();
        let topo = Topology::new(8, 4, 2, 8);
        let mut plan = flat_plan(1000, 8);
        let balanced = moe_layer_time(&m, &topo, &plan);
        plan.gpu_compute[3] = 8000; // straggler
        let skewed = moe_layer_time(&m, &topo, &plan);
        assert!(skewed.compute > balanced.compute * 4.0);
    }

    #[test]
    fn overlap_hides_scheduling() {
        let m = CostModel::h100_testbed();
        let topo = Topology::new(8, 4, 2, 8);
        let mut plan = flat_plan(1000, 8);
        plan.sched_time = 500e-6;
        let visible = moe_layer_time(&m, &topo, &plan);
        plan.sched_overlapped = true;
        let hidden = moe_layer_time(&m, &topo, &plan);
        assert!(visible.prep > hidden.prep + 400e-6);
        assert_eq!(visible.compute, hidden.compute);
    }

    #[test]
    fn combine_mirrors_dispatch() {
        let m = CostModel::h100_testbed();
        let topo = Topology::new(4, 2, 2, 8);
        let plan = MoeLayerPlan {
            gpu_compute: vec![100; 4],
            routes: vec![Route { expert: 0, src: 0, dst: 1, tokens: 5000 }],
            sched_time: 0.0,
            sched_overlapped: false,
            prep_extra: 0.0,
        };
        let b = moe_layer_time(&m, &topo, &plan);
        assert_eq!(b.dispatch, b.combine);
        assert!(b.dispatch > 0.0);
    }

    #[test]
    fn iteration_time_has_pipeline_bubble() {
        let moe = MoeLayerBreakdown { prep: 0.0, dispatch: 1e-3, compute: 2e-3, combine: 1e-3 };
        let flat = TrainIterationModel::paper_default(1, 8, 8).iteration_time(&moe);
        let piped = TrainIterationModel::paper_default(4, 8, 8).iteration_time(&moe);
        // 4 stages: fewer layers per stage but (m+p-1) bubble
        let per_stage_ratio = (8.0 + 4.0 - 1.0) / (8.0 + 1.0 - 1.0) / 4.0;
        let expected = flat * per_stage_ratio;
        assert!((piped - expected).abs() / expected < 0.2, "{piped} vs {expected}");
    }

    #[test]
    fn throughput_decreases_with_straggler() {
        let model = TrainIterationModel::paper_default(2, 8, 8);
        let good = MoeLayerBreakdown { prep: 0.0, dispatch: 1e-3, compute: 2e-3, combine: 1e-3 };
        let bad = MoeLayerBreakdown { compute: 6e-3, ..good };
        assert!(model.throughput(&good, 8192) > 1.5 * model.throughput(&bad, 8192));
    }

    #[test]
    fn multi_layer_sim_times_every_layer() {
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let mut sim = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo,
            p,
            SchedulerOptions::default(),
            4,
        );
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            let loads: Vec<LoadMatrix> = (0..4)
                .map(|_| {
                    let mut lm = LoadMatrix::zeros(16, 8);
                    for _ in 0..1200 {
                        lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
                    }
                    lm
                })
                .collect();
            let breakdowns = sim.step(&loads);
            assert_eq!(breakdowns.len(), 4);
            for b in &breakdowns {
                assert!(b.compute > 0.0);
                assert!(b.total().is_finite());
            }
        }
    }

    #[test]
    fn engine_backend_matches_barrier_breakdowns() {
        use crate::engine::EngineMode;
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let layers = 3;
        let mut barrier = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo.clone(),
            p.clone(),
            SchedulerOptions::default(),
            layers,
        );
        let mut engine = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo,
            p,
            SchedulerOptions {
                engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
                ..Default::default()
            },
            layers,
        );
        assert!(barrier.engine_stats().is_none());
        let mut rng = Rng::new(31);
        for round in 0..3 {
            let loads: Vec<LoadMatrix> = (0..layers)
                .map(|_| {
                    let mut lm = LoadMatrix::zeros(16, 8);
                    for _ in 0..1000 {
                        lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
                    }
                    lm
                })
                .collect();
            let a = barrier.step(&loads);
            let b = engine.step(&loads);
            // pipelined schedules are bit-identical to the barrier path, so
            // the load-derived phases must match exactly (prep only differs
            // through measured wall time, which both paths hide via overlap)
            for (l, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.dispatch, y.dispatch, "round {round} layer {l}");
                assert_eq!(x.compute, y.compute, "round {round} layer {l}");
                assert_eq!(x.combine, y.combine, "round {round} layer {l}");
            }
        }
        let st = engine.engine_stats().unwrap();
        assert_eq!(st.steps, 3);
        assert_eq!(st.schedules, 3 * layers as u64);
    }

    #[test]
    fn speculative_backend_hits_on_repeated_loads() {
        use crate::engine::EngineMode;
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let mut sim = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo,
            p,
            SchedulerOptions { engine: EngineMode::speculative(), ..Default::default() },
            2,
        );
        let mut rng = Rng::new(5);
        let mut lm = LoadMatrix::zeros(16, 8);
        for _ in 0..2000 {
            lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
        }
        let loads = vec![lm.clone(), lm];
        for _ in 0..5 {
            let b = sim.step(&loads);
            assert_eq!(b.len(), 2);
        }
        let st = sim.engine_stats().unwrap();
        assert!(st.spec_issued > 0 && st.spec_hits > 0, "{st:?}");
    }

    #[test]
    fn multi_layer_sim_matches_single_layer_plan() {
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let model = CostModel::h100_testbed();
        let mut sim = MultiLayerSim::new(
            model.clone(),
            topo.clone(),
            p.clone(),
            SchedulerOptions::default(),
            2,
        );
        let mut reference =
            MicroEpScheduler::new(p.clone(), Some(topo.clone()), SchedulerOptions::default());
        let mut rng = Rng::new(21);
        let mut lm = LoadMatrix::zeros(16, 8);
        for _ in 0..1000 {
            lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
        }
        // identical loads on both layers: identical, deterministic plans
        let loads = vec![lm.clone(), lm.clone()];
        let breakdowns = sim.step(&loads);
        let s = reference.schedule(&lm);
        let plan = MoeLayerPlan {
            gpu_compute: s.gpu_loads(&p),
            routes: s.routes,
            sched_time: 0.0,
            sched_overlapped: true,
            prep_extra: 0.0,
        };
        let expect = moe_layer_time(&model, &topo, &plan);
        for b in &breakdowns {
            assert_eq!(b.dispatch, expect.dispatch);
            assert_eq!(b.compute, expect.compute);
        }
    }
}
