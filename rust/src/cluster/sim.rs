//! MoE-layer and training-iteration timing simulation.
//!
//! [`moe_layer_time`] produces the Fig.-8 breakdown (prep / dispatch A2A /
//! expert compute / combine A2A) for one micro-batch given a system's plan;
//! [`TrainIterationModel`] composes layer times into end-to-end iteration
//! time with pipeline-parallel bubbles and gradient sync (Fig. 6).

use super::CostModel;
use crate::engine::ScheduleEngine;
use crate::placement::Placement;
use crate::scheduler::{
    schedule_layers_parallel, LoadMatrix, MicroEpScheduler, Route, Schedule, SchedulerOptions,
};
use crate::stats::EngineStats;
use crate::topology::Topology;

/// What a load-balancing system decided for one MoE layer of one
/// micro-batch (produced by [`crate::baselines::MoeSystem::plan`]).
#[derive(Clone, Debug)]
pub struct MoeLayerPlan {
    /// tokens to compute per GPU (FFN input rows, already top-K expanded)
    pub gpu_compute: Vec<u64>,
    /// token movements (src != dst entries cost communication)
    pub routes: Vec<Route>,
    /// CPU scheduling time for this micro-batch (s); 0 for static systems
    pub sched_time: f64,
    /// whether scheduling hides under the permute op (§5.4)
    pub sched_overlapped: bool,
    /// extra prep charged to this layer (backend pre-processing,
    /// amortized migration, padding setup …)
    pub prep_extra: f64,
}

/// Fig.-8 execution-time breakdown of one MoE layer (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MoeLayerBreakdown {
    /// all-gather of load info + (non-overlapped) scheduling + extras
    pub prep: f64,
    /// Dispatch all-to-all.
    pub dispatch: f64,
    /// Max per-GPU expert FFN time.
    pub compute: f64,
    /// Combine all-to-all.
    pub combine: f64,
}

impl MoeLayerBreakdown {
    /// Sum of all phases.
    pub fn total(&self) -> f64 {
        self.prep + self.dispatch + self.compute + self.combine
    }
}

/// Time one MoE layer under the cost model.
pub fn moe_layer_time(
    model: &CostModel,
    topo: &Topology,
    plan: &MoeLayerPlan,
) -> MoeLayerBreakdown {
    let g = plan.gpu_compute.len();
    // load-info all-gather: E×G u32 counts ≈ tiny; dominated by latency
    let crosses = g > topo.gpus_per_node;
    let info_bytes = 4.0 * 64.0; // per-rank expert-count vector (capped)
    let gather = if plan.sched_time > 0.0 {
        model.allgather_time(info_bytes, g, crosses)
    } else {
        0.0
    };
    let sched = if plan.sched_overlapped { 0.0 } else { plan.sched_time };
    let prep = gather + sched + plan.prep_extra;

    let dispatch = model.a2a_time_from_routes(&plan.routes, g, topo);
    // combine moves the same volumes in reverse; max(send,recv) symmetric
    let combine = dispatch;

    let compute = plan
        .gpu_compute
        .iter()
        .map(|&t| model.ffn_time(t))
        .fold(0.0, f64::max);

    MoeLayerBreakdown { prep, dispatch, compute, combine }
}

/// How a [`MultiLayerSim`] executes its per-layer solves.
enum SimBackend {
    /// Per-round scoped-thread fan-out ([`schedule_layers_parallel`]) —
    /// the PR-1 path, kept selectable for ablation.
    Barrier(Vec<MicroEpScheduler>),
    /// Persistent pipelined engine ([`ScheduleEngine`]): no per-round
    /// spawns, layer ℓ−1's dispatch timing overlaps layer ℓ's solve, and
    /// (in speculative mode) forecast-driven pre-solves between steps.
    Engine(ScheduleEngine),
}

/// Multi-layer MoE timing: one independent [`MicroEpScheduler`] per layer
/// (each owns its own warm-start basis, exactly like the per-layer solver
/// replicas a real deployment keeps). On a training pipeline every layer's
/// gate output is available once the previous forward finishes, so the
/// solves are embarrassingly parallel — this is the wall-clock win that
/// keeps scheduling off the critical path even when a stage holds many
/// MoE layers. [`SchedulerOptions::engine`] selects the execution backend:
/// the round-barrier fan-out (default) or the persistent
/// [`ScheduleEngine`] (pipelined / speculative).
pub struct MultiLayerSim {
    /// Cluster cost model used to time each layer.
    pub model: CostModel,
    /// Topology (node boundaries for the all-to-all model).
    pub topo: Topology,
    placement: Placement,
    backend: SimBackend,
    layers: usize,
    /// §5.4: scheduling overlaps the token-permute op
    pub overlap: bool,
}

/// Time one layer's schedule under the cost model.
fn time_one(
    model: &CostModel,
    topo: &Topology,
    placement: &Placement,
    overlap: bool,
    s: Schedule,
) -> MoeLayerBreakdown {
    let plan = MoeLayerPlan {
        gpu_compute: s.gpu_loads(placement),
        routes: s.routes,
        sched_time: s.stats.solve_ns as f64 * 1e-9,
        sched_overlapped: overlap,
        prep_extra: 0.0,
    };
    moe_layer_time(model, topo, &plan)
}

impl MultiLayerSim {
    /// `layers` independent schedulers over one shared placement, executed
    /// by the backend `opts.engine` selects.
    pub fn new(
        model: CostModel,
        topo: Topology,
        placement: Placement,
        opts: SchedulerOptions,
        layers: usize,
    ) -> Self {
        assert!(layers > 0);
        let backend = if opts.engine.is_barrier() {
            SimBackend::Barrier(
                (0..layers)
                    .map(|_| {
                        MicroEpScheduler::new(placement.clone(), Some(topo.clone()), opts.clone())
                    })
                    .collect(),
            )
        } else {
            SimBackend::Engine(ScheduleEngine::new(
                placement.clone(),
                Some(topo.clone()),
                opts,
                layers,
            ))
        };
        MultiLayerSim { model, topo, placement, backend, layers, overlap: true }
    }

    /// Number of MoE layers simulated.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Engine counters (hit/miss/pivot meters) when the engine backend is
    /// active; `None` on the barrier path.
    pub fn engine_stats(&self) -> Option<EngineStats> {
        match &self.backend {
            SimBackend::Engine(e) => Some(e.stats()),
            SimBackend::Barrier(_) => None,
        }
    }

    /// Schedule one micro-batch for every layer and time each layer under
    /// the cost model. `loads[l]` is layer `l`'s `input_e^g`. On the
    /// engine backend each layer's timing is computed as its schedule is
    /// emitted, while later layers are still solving in the pool.
    pub fn step(&mut self, loads: &[LoadMatrix]) -> Vec<MoeLayerBreakdown> {
        assert_eq!(loads.len(), self.layers, "one load matrix per layer");
        let MultiLayerSim { model, topo, placement, backend, overlap, .. } = self;
        let (model, topo, placement, overlap) = (&*model, &*topo, &*placement, *overlap);
        match backend {
            SimBackend::Barrier(scheds) => schedule_layers_parallel(scheds, loads)
                .into_iter()
                .map(|s| time_one(model, topo, placement, overlap, s))
                .collect(),
            SimBackend::Engine(engine) => {
                let mut out = Vec::with_capacity(loads.len());
                engine.schedule_step_with(loads, |_, s| {
                    out.push(time_one(model, topo, placement, overlap, s));
                });
                out
            }
        }
    }
}

/// End-to-end iteration model (Fig. 6): GPipe-style schedule.
#[derive(Clone, Debug)]
pub struct TrainIterationModel {
    /// Pipeline-parallel degree.
    pub pp_degree: usize,
    /// MoE layers per pipeline stage.
    pub layers_per_stage: usize,
    /// Micro-batches per iteration (per DP group).
    pub num_microbatches: usize,
    /// per-micro-batch attention + dense time per layer (s)
    pub attn_time: f64,
    /// per-iteration gradient sync (s)
    pub grad_sync: f64,
    /// backward/forward compute ratio (≈2 for matmul-dominated layers)
    pub bwd_factor: f64,
}

impl TrainIterationModel {
    /// Paper testbed defaults: PP = nodes, DP = 8 (§7.1).
    pub fn paper_default(pp: usize, layers: usize, num_microbatches: usize) -> Self {
        TrainIterationModel {
            pp_degree: pp,
            layers_per_stage: layers / pp.max(1),
            num_microbatches,
            attn_time: 0.8e-3,
            grad_sync: 5e-3,
            bwd_factor: 2.0,
        }
    }

    /// Iteration time from the mean per-micro-batch MoE-layer breakdown.
    ///
    /// fwd stage time = layers·(attn + moe_total); bwd multiplies compute
    /// by `bwd_factor` and repeats both all-to-alls. GPipe bubble:
    /// (m + p − 1)/m scaling of the per-micro-batch pipeline.
    pub fn iteration_time(&self, moe: &MoeLayerBreakdown) -> f64 {
        let fwd_stage =
            self.layers_per_stage as f64 * (self.attn_time + moe.total());
        let bwd_stage = self.layers_per_stage as f64
            * (self.attn_time * self.bwd_factor
                + moe.prep
                + self.bwd_factor * moe.compute
                + moe.dispatch
                + moe.combine);
        let per_mb = fwd_stage + bwd_stage;
        let m = self.num_microbatches as f64;
        let p = self.pp_degree as f64;
        per_mb * (m + p - 1.0) + self.grad_sync
    }

    /// Throughput in tokens/s given tokens per micro-batch (per DP group).
    pub fn throughput(&self, moe: &MoeLayerBreakdown, tokens_per_mb: u64) -> f64 {
        let t = self.iteration_time(moe);
        (tokens_per_mb * self.num_microbatches as u64) as f64 / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_plan(per_gpu: u64, g: usize) -> MoeLayerPlan {
        MoeLayerPlan {
            gpu_compute: vec![per_gpu; g],
            routes: Vec::new(),
            sched_time: 0.0,
            sched_overlapped: false,
            prep_extra: 0.0,
        }
    }

    #[test]
    fn compute_dominated_by_straggler() {
        let m = CostModel::h100_testbed();
        let topo = Topology::new(8, 4, 2, 8);
        let mut plan = flat_plan(1000, 8);
        let balanced = moe_layer_time(&m, &topo, &plan);
        plan.gpu_compute[3] = 8000; // straggler
        let skewed = moe_layer_time(&m, &topo, &plan);
        assert!(skewed.compute > balanced.compute * 4.0);
    }

    #[test]
    fn overlap_hides_scheduling() {
        let m = CostModel::h100_testbed();
        let topo = Topology::new(8, 4, 2, 8);
        let mut plan = flat_plan(1000, 8);
        plan.sched_time = 500e-6;
        let visible = moe_layer_time(&m, &topo, &plan);
        plan.sched_overlapped = true;
        let hidden = moe_layer_time(&m, &topo, &plan);
        assert!(visible.prep > hidden.prep + 400e-6);
        assert_eq!(visible.compute, hidden.compute);
    }

    #[test]
    fn combine_mirrors_dispatch() {
        let m = CostModel::h100_testbed();
        let topo = Topology::new(4, 2, 2, 8);
        let plan = MoeLayerPlan {
            gpu_compute: vec![100; 4],
            routes: vec![Route { expert: 0, src: 0, dst: 1, tokens: 5000 }],
            sched_time: 0.0,
            sched_overlapped: false,
            prep_extra: 0.0,
        };
        let b = moe_layer_time(&m, &topo, &plan);
        assert_eq!(b.dispatch, b.combine);
        assert!(b.dispatch > 0.0);
    }

    #[test]
    fn iteration_time_has_pipeline_bubble() {
        let moe = MoeLayerBreakdown { prep: 0.0, dispatch: 1e-3, compute: 2e-3, combine: 1e-3 };
        let flat = TrainIterationModel::paper_default(1, 8, 8).iteration_time(&moe);
        let piped = TrainIterationModel::paper_default(4, 8, 8).iteration_time(&moe);
        // 4 stages: fewer layers per stage but (m+p-1) bubble
        let per_stage_ratio = (8.0 + 4.0 - 1.0) / (8.0 + 1.0 - 1.0) / 4.0;
        let expected = flat * per_stage_ratio;
        assert!((piped - expected).abs() / expected < 0.2, "{piped} vs {expected}");
    }

    #[test]
    fn throughput_decreases_with_straggler() {
        let model = TrainIterationModel::paper_default(2, 8, 8);
        let good = MoeLayerBreakdown { prep: 0.0, dispatch: 1e-3, compute: 2e-3, combine: 1e-3 };
        let bad = MoeLayerBreakdown { compute: 6e-3, ..good };
        assert!(model.throughput(&good, 8192) > 1.5 * model.throughput(&bad, 8192));
    }

    #[test]
    fn multi_layer_sim_times_every_layer() {
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let mut sim = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo,
            p,
            SchedulerOptions::default(),
            4,
        );
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            let loads: Vec<LoadMatrix> = (0..4)
                .map(|_| {
                    let mut lm = LoadMatrix::zeros(16, 8);
                    for _ in 0..1200 {
                        lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
                    }
                    lm
                })
                .collect();
            let breakdowns = sim.step(&loads);
            assert_eq!(breakdowns.len(), 4);
            for b in &breakdowns {
                assert!(b.compute > 0.0);
                assert!(b.total().is_finite());
            }
        }
    }

    #[test]
    fn engine_backend_matches_barrier_breakdowns() {
        use crate::engine::EngineMode;
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let layers = 3;
        let mut barrier = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo.clone(),
            p.clone(),
            SchedulerOptions::default(),
            layers,
        );
        let mut engine = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo,
            p,
            SchedulerOptions {
                engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
                ..Default::default()
            },
            layers,
        );
        assert!(barrier.engine_stats().is_none());
        let mut rng = Rng::new(31);
        for round in 0..3 {
            let loads: Vec<LoadMatrix> = (0..layers)
                .map(|_| {
                    let mut lm = LoadMatrix::zeros(16, 8);
                    for _ in 0..1000 {
                        lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
                    }
                    lm
                })
                .collect();
            let a = barrier.step(&loads);
            let b = engine.step(&loads);
            // pipelined schedules are bit-identical to the barrier path, so
            // the load-derived phases must match exactly (prep only differs
            // through measured wall time, which both paths hide via overlap)
            for (l, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.dispatch, y.dispatch, "round {round} layer {l}");
                assert_eq!(x.compute, y.compute, "round {round} layer {l}");
                assert_eq!(x.combine, y.combine, "round {round} layer {l}");
            }
        }
        let st = engine.engine_stats().unwrap();
        assert_eq!(st.steps, 3);
        assert_eq!(st.schedules, 3 * layers as u64);
    }

    #[test]
    fn speculative_backend_hits_on_repeated_loads() {
        use crate::engine::EngineMode;
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let mut sim = MultiLayerSim::new(
            CostModel::h100_testbed(),
            topo,
            p,
            SchedulerOptions { engine: EngineMode::speculative(), ..Default::default() },
            2,
        );
        let mut rng = Rng::new(5);
        let mut lm = LoadMatrix::zeros(16, 8);
        for _ in 0..2000 {
            lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
        }
        let loads = vec![lm.clone(), lm];
        for _ in 0..5 {
            let b = sim.step(&loads);
            assert_eq!(b.len(), 2);
        }
        let st = sim.engine_stats().unwrap();
        assert!(st.spec_issued > 0 && st.spec_hits > 0, "{st:?}");
    }

    #[test]
    fn multi_layer_sim_matches_single_layer_plan() {
        use crate::placement::cayley::symmetric_placement;
        use crate::rng::Rng;
        let topo = Topology::new(8, 4, 2, 8);
        let p = symmetric_placement(&topo, 16);
        let model = CostModel::h100_testbed();
        let mut sim = MultiLayerSim::new(
            model.clone(),
            topo.clone(),
            p.clone(),
            SchedulerOptions::default(),
            2,
        );
        let mut reference =
            MicroEpScheduler::new(p.clone(), Some(topo.clone()), SchedulerOptions::default());
        let mut rng = Rng::new(21);
        let mut lm = LoadMatrix::zeros(16, 8);
        for _ in 0..1000 {
            lm.add(rng.below(16) as usize, rng.below(8) as usize, 1);
        }
        // identical loads on both layers: identical, deterministic plans
        let loads = vec![lm.clone(), lm.clone()];
        let breakdowns = sim.step(&loads);
        let s = reference.schedule(&lm);
        let plan = MoeLayerPlan {
            gpu_compute: s.gpu_loads(&p),
            routes: s.routes,
            sched_time: 0.0,
            sched_overlapped: true,
            prep_extra: 0.0,
        };
        let expect = moe_layer_time(&model, &topo, &plan);
        for b in &breakdowns {
            assert_eq!(b.dispatch, expect.dispatch);
            assert_eq!(b.compute, expect.compute);
        }
    }
}
