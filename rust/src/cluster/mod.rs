//! Simulated GPU cluster — the substitute for the paper's 4×8 H100 testbed
//! (DESIGN.md §Offline-environment substitutions).
//!
//! The model encodes exactly the two behaviours the paper's evaluation
//! depends on:
//!
//! 1. **Compute ∝ tokens** (§2.3): per-GPU FFN time is affine in the token
//!    count assigned to that GPU, `t = t_fixed + tokens · t_token`, and an
//!    MoE layer waits for the slowest GPU (all-to-all synchronization).
//! 2. **α-β communication** with link tiers: NVLink intra-node, InfiniBand
//!    inter-node, and a backend efficiency/latency profile for NCCL vs
//!    DeepEP (App. C.2).
//!
//! Constants default to H100-testbed values fitted to the paper's reported
//! numbers (≈1.3 ms per all-to-all in the Fig. 8 setting) and can be
//! re-calibrated from real PJRT CPU timings via
//! [`CostModel::calibrate_compute`] (used by the e2e example).

pub mod migration;
pub mod sim;

use crate::topology::Topology;

/// All-to-all backend profiles (App. C.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommBackend {
    /// Default Megatron path: higher software latency, lower achieved bw.
    Nccl,
    /// DeepEP: near-line-rate with small fixed cost.
    DeepEp,
}

impl CommBackend {
    /// (per-op software latency seconds, achieved-bandwidth efficiency)
    fn profile(self) -> (f64, f64) {
        match self {
            CommBackend::Nccl => (60e-6, 0.30),
            CommBackend::DeepEp => (15e-6, 0.75),
        }
    }
}

/// Cluster cost model.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// fixed per-layer FFN launch overhead (s)
    pub t_fixed: f64,
    /// per-token FFN compute time (s/token) — both matmuls, fwd only
    pub t_token: f64,
    /// bytes moved per token in an all-to-all (hidden · dtype width)
    pub bytes_per_token: f64,
    /// NVLink per-GPU bandwidth (B/s)
    pub nvlink_bw: f64,
    /// InfiniBand per-GPU bandwidth (B/s)
    pub ib_bw: f64,
    /// per-hop latency within a node (s)
    pub intra_lat: f64,
    /// per-hop latency across nodes (s)
    pub inter_lat: f64,
    /// All-to-all backend profile.
    pub backend: CommBackend,
}

impl CostModel {
    /// H100 testbed defaults for the Fig.-8 model shape
    /// (hidden=4096, bf16, top-2): calibrated so one all-to-all in the
    /// Fig. 8 setting costs ≈1.3 ms under NCCL, as the paper reports.
    pub fn h100_testbed() -> Self {
        CostModel {
            // 16k tokens/GPU × top2 ≈ 4096 assignments/GPU/expert-layer at
            // DP=8; H100 bf16 ~1 PFLOP/s peak, MoE FFN ≈ 16·h² flops/token
            // at 40% MXU efficiency.
            t_fixed: 30e-6,
            t_token: 16.0 * 4096.0 * 4096.0 / (1e15 * 0.40),
            bytes_per_token: 4096.0 * 2.0,
            nvlink_bw: 900e9,
            ib_bw: 100e9, // 2×400 Gbps shared by 8 GPUs
            intra_lat: 8e-6,
            inter_lat: 25e-6,
            backend: CommBackend::Nccl,
        }
    }

    /// Scale compute constants for a model's hidden size (t_token ∝ h²)
    /// and bytes/token (∝ h).
    pub fn for_hidden_size(mut self, hidden: usize) -> Self {
        let h = hidden as f64;
        self.t_token = 16.0 * h * h / (1e15 * 0.40);
        self.bytes_per_token = h * 2.0;
        self
    }

    /// Same model with a different all-to-all backend.
    pub fn with_backend(mut self, backend: CommBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Re-fit (t_fixed, t_token) from two measured (tokens, seconds) points
    /// — used with real PJRT timings of the expert-FFN artifact.
    pub fn calibrate_compute(&mut self, small: (u64, f64), large: (u64, f64)) {
        assert!(large.0 > small.0);
        let slope = (large.1 - small.1) / (large.0 - small.0) as f64;
        self.t_token = slope.max(1e-12);
        self.t_fixed = (small.1 - slope * small.0 as f64).max(0.0);
    }

    /// FFN compute time for `tokens` on one GPU.
    pub fn ffn_time(&self, tokens: u64) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        self.t_fixed + tokens as f64 * self.t_token
    }

    /// One all-to-all phase (dispatch or combine) given per-GPU send/recv
    /// token volumes split by link tier. The phase completes when the
    /// busiest GPU finishes moving `max(send, recv)` bytes on each tier.
    pub fn a2a_time(
        &self,
        send_intra: &[u64],
        recv_intra: &[u64],
        send_inter: &[u64],
        recv_inter: &[u64],
    ) -> f64 {
        let (sw_lat, eff) = self.backend.profile();
        let g = send_intra.len();
        // an all-to-all with nothing to move is skipped entirely
        let total: u64 = send_intra.iter().chain(send_inter).chain(recv_intra).chain(recv_inter).sum();
        if total == 0 {
            return 0.0;
        }
        let mut worst: f64 = 0.0;
        let mut any_inter = false;
        for i in 0..g {
            let intra_bytes = send_intra[i].max(recv_intra[i]) as f64 * self.bytes_per_token;
            let inter_bytes = send_inter[i].max(recv_inter[i]) as f64 * self.bytes_per_token;
            if send_inter[i] > 0 || recv_inter[i] > 0 {
                any_inter = true;
            }
            let t = intra_bytes / (self.nvlink_bw * eff) + inter_bytes / (self.ib_bw * eff);
            worst = worst.max(t);
        }
        let lat = sw_lat + if any_inter { self.inter_lat } else { self.intra_lat };
        lat + worst
    }

    /// All-to-all with volumes already split by tier from routes.
    pub fn a2a_time_from_routes(
        &self,
        routes: &[crate::scheduler::Route],
        num_gpus: usize,
        topo: &Topology,
    ) -> f64 {
        let mut si = vec![0u64; num_gpus];
        let mut ri = vec![0u64; num_gpus];
        let mut sj = vec![0u64; num_gpus];
        let mut rj = vec![0u64; num_gpus];
        for r in routes {
            if r.src == r.dst {
                continue;
            }
            if topo.same_node(r.src, r.dst) {
                si[r.src] += r.tokens;
                ri[r.dst] += r.tokens;
            } else {
                sj[r.src] += r.tokens;
                rj[r.dst] += r.tokens;
            }
        }
        self.a2a_time(&si, &ri, &sj, &rj)
    }

    /// All-gather of `bytes` per rank over `group` ranks (ring model) —
    /// the scheduler's load-information collection step (§5.3).
    pub fn allgather_time(&self, bytes_per_rank: f64, group: usize, crosses_nodes: bool) -> f64 {
        let (sw_lat, eff) = self.backend.profile();
        let bw = if crosses_nodes { self.ib_bw } else { self.nvlink_bw } * eff;
        let hop = if crosses_nodes { self.inter_lat } else { self.intra_lat };
        let steps = group.saturating_sub(1) as f64;
        sw_lat + steps * (hop + bytes_per_rank / bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ffn_time_affine_in_tokens() {
        let m = CostModel::h100_testbed();
        let t1 = m.ffn_time(1000);
        let t2 = m.ffn_time(2000);
        let t3 = m.ffn_time(3000);
        assert!((t3 - t2 - (t2 - t1)).abs() < 1e-12, "not affine");
        assert_eq!(m.ffn_time(0), 0.0);
    }

    #[test]
    fn a2a_matches_paper_magnitude() {
        // Fig. 8 setting: DP=8, mbs=8, seq=2048, top2, h=4096 -> each GPU
        // sends ~(7/8)·32768 assignments. Paper: ~1.3 ms per A2A (NCCL).
        let m = CostModel::h100_testbed();
        let per_gpu = 8 * 2048 * 2; // assignments per source GPU
        let cross = (per_gpu as f64 * 7.0 / 8.0) as u64;
        let t = m.a2a_time(&[cross; 8], &[cross; 8], &[0; 8], &[0; 8]);
        assert!(
            (0.5e-3..3e-3).contains(&t),
            "A2A {t} s out of paper's magnitude (~1.3ms)"
        );
    }

    #[test]
    fn deepep_faster_than_nccl() {
        let nccl = CostModel::h100_testbed();
        let deep = CostModel::h100_testbed().with_backend(CommBackend::DeepEp);
        let v = [4096u64; 8];
        let z = [0u64; 8];
        assert!(deep.a2a_time(&v, &v, &z, &z) < nccl.a2a_time(&v, &v, &z, &z));
    }

    #[test]
    fn inter_node_slower_than_intra() {
        let m = CostModel::h100_testbed();
        let v = [4096u64; 8];
        let z = [0u64; 8];
        let intra = m.a2a_time(&v, &v, &z, &z);
        let inter = m.a2a_time(&z, &z, &v, &v);
        assert!(inter > intra * 2.0, "intra {intra} inter {inter}");
    }

    #[test]
    fn calibration_fits_line() {
        let mut m = CostModel::h100_testbed();
        // synthetic measurements: t = 1ms + tokens * 2us
        m.calibrate_compute((100, 1e-3 + 100.0 * 2e-6), (1000, 1e-3 + 1000.0 * 2e-6));
        assert!((m.t_token - 2e-6).abs() < 1e-12);
        assert!((m.t_fixed - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn a2a_bottleneck_is_max_gpu() {
        let m = CostModel::h100_testbed();
        let balanced = m.a2a_time(&[100, 100], &[100, 100], &[0, 0], &[0, 0]);
        let skewed = m.a2a_time(&[200, 0], &[0, 200], &[0, 0], &[0, 0]);
        assert!(skewed > balanced);
    }

    #[test]
    fn allgather_scales_with_group() {
        let m = CostModel::h100_testbed();
        let t8 = m.allgather_time(1024.0, 8, false);
        let t16 = m.allgather_time(1024.0, 16, false);
        assert!(t16 > t8);
    }

    #[test]
    fn routes_split_by_tier() {
        let topo = Topology::new(4, 2, 2, 2); // nodes {0,1}, {2,3}
        let m = CostModel::h100_testbed();
        use crate::scheduler::Route;
        let routes = vec![
            Route { expert: 0, src: 0, dst: 1, tokens: 1000 }, // intra
            Route { expert: 0, src: 0, dst: 2, tokens: 1000 }, // inter
            Route { expert: 1, src: 3, dst: 3, tokens: 999 },  // local, free
        ];
        let t = m.a2a_time_from_routes(&routes, 4, &topo);
        let only_intra =
            m.a2a_time(&[1000, 0, 0, 0], &[0, 1000, 0, 0], &[0; 4], &[0; 4]);
        assert!(t > only_intra);
    }
}
