//! Small statistics substrate: summaries, histograms, moving averages and
//! time-series tooling shared by the adaptive-replacement predictor, the
//! bench harness, the serving tier's SLO accounting, and the experiment
//! reports.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median (interpolated).
    pub p50: f64,
    /// 95th percentile (interpolated).
    pub p95: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample. An empty sample yields the stats-wide empty
    /// sentinel — `n == 0` with every moment `NaN`, the
    /// [`LatencyTrack::max`] convention — rather than panicking; JSON
    /// emitters route the fields through [`crate::ser::Json::num`], which
    /// maps non-finite to `null`.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                max: f64::NAN,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Percentile of an already-sorted slice (nearest-rank with
/// interpolation); `NaN` on an empty slice (the stats-wide empty-sample
/// sentinel, like [`Summary::of`] and [`LatencyTrack::max`]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// P² (Jain–Chlamtac 1985) streaming quantile estimator: one quantile in
/// O(1) memory with five piecewise-parabolic markers, so the serving tier
/// can report p50/p95/p99 over unbounded request streams without keeping
/// every latency sample. The first five observations are buffered and
/// answered exactly; from the sixth on, marker heights are adjusted by the
/// parabolic (or, when non-monotone, linear) P² update.
///
/// The estimator is transliterated op-for-op in
/// `python/tools/serving_reference.py`; keep the update arithmetic and its
/// evaluation order in sync with that reference — the golden-serving
/// fixture pins both implementations to identical marker trajectories.
#[derive(Clone, Debug, PartialEq)]
pub struct P2Quantile {
    p: f64,
    count: u64,
    /// First five observations, kept for the exact small-sample answer.
    warmup: Vec<f64>,
    /// Marker heights q0..q4.
    q: [f64; 5],
    /// Marker positions (1-based observation counts), n0..n4.
    pos: [f64; 5],
    /// Desired marker positions n'0..n'4.
    desired: [f64; 5],
    /// Per-observation desired-position increments dn0..dn4.
    dn: [f64; 5],
}

impl P2Quantile {
    /// Estimator for quantile `p` in (0, 1).
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0, "quantile must be in (0, 1), got {p}");
        P2Quantile {
            p,
            count: 0,
            warmup: Vec::with_capacity(5),
            q: [0.0; 5],
            pos: [0.0; 5],
            desired: [0.0; 5],
            dn: [0.0; 5],
        }
    }

    /// Quantile being tracked.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold in one observation.
    pub fn observe(&mut self, x: f64) {
        self.count += 1;
        if self.count <= 5 {
            self.warmup.push(x);
            if self.count == 5 {
                let mut init = self.warmup.clone();
                init.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for i in 0..5 {
                    self.q[i] = init[i];
                    self.pos[i] = (i + 1) as f64;
                }
                let p = self.p;
                self.desired = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0];
                self.dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0];
            }
            return;
        }
        // cell index k: which marker interval x falls into (extremes clamp)
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x < self.q[1] {
            0
        } else if x < self.q[2] {
            1
        } else if x < self.q[3] {
            2
        } else if x <= self.q[4] {
            3
        } else {
            self.q[4] = x;
            3
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.dn[i];
        }
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = if d >= 0.0 { 1.0 } else { -1.0 };
                let cand = self.parabolic(i, s);
                self.q[i] = if self.q[i - 1] < cand && cand < self.q[i + 1] {
                    cand
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
    }

    /// Piecewise-parabolic (P²) height prediction for marker `i` moving by
    /// `s` (±1).
    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (q, n) = (&self.q, &self.pos);
        q[i] + s / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + s) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    /// Linear fallback when the parabolic prediction is non-monotone.
    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + s * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate: exact (interpolated) over the warmup buffer while
    /// five or fewer observations are held, the middle marker height after;
    /// `NaN` before the first observation.
    pub fn estimate(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.count <= 5 {
            let mut sorted = self.warmup.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            return percentile(&sorted, self.p);
        }
        self.q[2]
    }
}

/// splitmix64 finalizer — the stateless hash behind the reservoir's
/// Algorithm R replacement index, so sampling needs no carried RNG state
/// (the track keeps its derived `PartialEq`, and long serving runs stay
/// bit-reproducible across runs and worker counts).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Latency accumulator used by the serving tier's SLO accounting: a
/// bounded reservoir of raw samples (exact while the stream fits under the
/// cap — true percentiles and conservation checks; a deterministic uniform
/// reservoir past it, so unbounded serving runs can't grow memory without
/// bound) alongside P² streaming estimators for p50/p95/p99, so reports
/// can show both the ground truth and what an O(1)-memory production meter
/// would have said.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyTrack {
    samples: Vec<f64>,
    /// Reservoir capacity (samples kept at most).
    cap: usize,
    /// Samples ever recorded (drives count/mean; `samples` holds at most
    /// `cap` of them).
    seen: u64,
    sum: f64,
    max: f64,
    p2_50: P2Quantile,
    p2_95: P2Quantile,
    p2_99: P2Quantile,
}

impl Default for LatencyTrack {
    fn default() -> Self {
        LatencyTrack::new()
    }
}

impl LatencyTrack {
    /// Default reservoir capacity: large enough that every existing bench
    /// and test keeps exact quantiles, small enough to bound a week-long
    /// serving run to ~512 KiB of samples per track.
    pub const DEFAULT_RESERVOIR: usize = 65_536;

    /// Empty track with the default reservoir capacity.
    pub fn new() -> Self {
        LatencyTrack::with_capacity(Self::DEFAULT_RESERVOIR)
    }

    /// Empty track keeping at most `cap` raw samples (`cap > 0`). The
    /// moment counters ([`LatencyTrack::count`], [`LatencyTrack::mean`],
    /// [`LatencyTrack::max`]) and the P² estimators always cover the full
    /// stream; only [`LatencyTrack::exact`] degrades to a reservoir
    /// estimate once the stream outgrows the cap.
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "reservoir needs room for at least one sample");
        LatencyTrack {
            samples: Vec::new(),
            cap,
            seen: 0,
            sum: 0.0,
            // NaN, not 0.0: an empty track has no largest sample, and a
            // fabricated zero would read as a real zero-latency maximum in
            // SLO artifacts. `f64::max` recovers on the first record.
            max: f64::NAN,
            p2_50: P2Quantile::new(0.50),
            p2_95: P2Quantile::new(0.95),
            p2_99: P2Quantile::new(0.99),
        }
    }

    /// Reservoir capacity this track was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record one latency sample (any unit; the serving tier uses µs).
    pub fn record(&mut self, x: f64) {
        self.sum += x;
        // IEEE maxNum semantics: NaN.max(x) == x, so the empty-track NaN
        // sentinel is replaced by the first real sample
        self.max = self.max.max(x);
        self.p2_50.observe(x);
        self.p2_95.observe(x);
        self.p2_99.observe(x);
        // Vitter's Algorithm R, with the replacement index drawn from a
        // stateless splitmix64 hash of the sample ordinal: sample i
        // replaces slot j = hash(i) mod (i+1) iff j < cap.
        if (self.seen as usize) < self.cap {
            self.samples.push(x);
        } else {
            let j = splitmix64(self.seen) % (self.seen + 1);
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
        self.seen += 1;
    }

    /// Samples recorded over the whole stream (not just those retained).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    /// Whether nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Mean over the whole stream (`NaN` when empty).
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.sum / self.seen as f64
        }
    }

    /// Largest sample (`NaN` when empty, like [`LatencyTrack::mean`] —
    /// JSON emitters route it through the same NaN→null guard).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Interpolated quantile `q` in [0, 1] over the retained samples
    /// (`NaN` when empty). Exact while the stream fits in the reservoir
    /// (`count() <= capacity()`); past the cap it is the quantile of a
    /// uniform sample of the stream — an unbiased estimate, no longer the
    /// exact order statistic.
    pub fn exact(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&sorted, q)
    }

    /// P² streaming p50 estimate (`NaN` when empty).
    pub fn p2_p50(&self) -> f64 {
        self.p2_50.estimate()
    }

    /// P² streaming p95 estimate (`NaN` when empty).
    pub fn p2_p95(&self) -> f64 {
        self.p2_95.estimate()
    }

    /// P² streaming p99 estimate (`NaN` when empty).
    pub fn p2_p99(&self) -> f64 {
        self.p2_99.estimate()
    }

    /// Retained raw samples: the full stream in arrival order while under
    /// the reservoir cap, a uniform reservoir of it past the cap.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Exponential moving average (the paper's §6.4 "moving averages" predictor
/// is realized as EMA + a windowed simple MA; both live here).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// EMA with smoothing factor `alpha` in [0, 1].
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    /// Fold in an observation, returning the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    /// Current average (`None` before the first observation).
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Fixed-window moving average over vectors (per-expert load series).
#[derive(Clone, Debug)]
pub struct VecWindow {
    window: usize,
    buf: std::collections::VecDeque<Vec<f64>>,
}

impl VecWindow {
    /// Window holding the `window` most recent vectors.
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        VecWindow { window, buf: std::collections::VecDeque::new() }
    }

    /// Append a vector, evicting the oldest when full.
    pub fn push(&mut self, xs: Vec<f64>) {
        if self.buf.len() == self.window {
            self.buf.pop_front();
        }
        self.buf.push_back(xs);
    }

    /// Vectors currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Element-wise mean over the window.
    pub fn mean(&self) -> Option<Vec<f64>> {
        let first = self.buf.front()?;
        let mut acc = vec![0.0; first.len()];
        for xs in &self.buf {
            for (a, x) in acc.iter_mut().zip(xs) {
                *a += x;
            }
        }
        let n = self.buf.len() as f64;
        for a in &mut acc {
            *a /= n;
        }
        Some(acc)
    }
}

/// Simple linear-scale histogram for latency collections.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    /// Samples below the range.
    pub underflow: u64,
    /// Samples at/above the range end.
    pub overflow: u64,
}

impl Histogram {
    /// Histogram over `[lo, hi)` with `bins` equal-width bins.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    /// Count a sample (out-of-range goes to underflow/overflow).
    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.counts.len();
            let bin = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.counts[bin.min(n - 1)] += 1;
        }
    }

    /// Per-bin counts.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total samples, including out-of-range.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Cumulative counters of the pipelined scheduling engine
/// ([`crate::engine::ScheduleEngine`]): how many speculative pre-solves
/// were issued, how often the forecast was close enough to trust (hits vs
/// misses), and where the LP pivots went. `hit_repair_pivots` vs a cold
/// solve's pivot count is the speculation win: the pre-solve already moved
/// the basis next to the optimum off the critical path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Multi-layer steps executed.
    pub steps: u64,
    /// Per-layer schedules produced (`steps × layers`).
    pub schedules: u64,
    /// Speculative pre-solves issued.
    pub spec_issued: u64,
    /// Pre-solves whose forecast stayed under the drift threshold (the
    /// commit warm-repaired the primed basis).
    pub spec_hits: u64,
    /// Pre-solves whose forecast drifted past the threshold (the commit
    /// re-solved from scratch).
    pub spec_misses: u64,
    /// LP pivots spent committing hits (the on-critical-path repair work).
    pub hit_repair_pivots: u64,
    /// LP pivots spent committing misses (fresh solves).
    pub miss_solve_pivots: u64,
    /// LP pivots spent in speculative pre-solves (off the critical path).
    /// Metered as pre-solve results drain during *later* steps, so
    /// pre-solves still in flight when stats are read — e.g. the final
    /// step's, which are issued but never judged — are not yet counted;
    /// expect `spec_issued ≥ spec_hits + spec_misses`.
    pub spec_presolve_pivots: u64,
}

impl EngineStats {
    /// Hits over issued-and-judged speculations (0 when none were judged).
    pub fn hit_rate(&self) -> f64 {
        let judged = self.spec_hits + self.spec_misses;
        if judged == 0 {
            0.0
        } else {
            self.spec_hits as f64 / judged as f64
        }
    }

    /// Mean LP pivots per speculation hit (0 when there were no hits).
    pub fn repair_pivots_per_hit(&self) -> f64 {
        if self.spec_hits == 0 {
            0.0
        } else {
            self.hit_repair_pivots as f64 / self.spec_hits as f64
        }
    }
}

/// Which rung of the robustness ladder produced a schedule: warm LP →
/// cold LP → greedy least-loaded fallback → vanilla-EP passthrough
/// (see `ARCHITECTURE.md` §8). Lower rungs are better-balanced; the
/// ladder only descends when a rung fails or runs out of
/// [`crate::lp::SolveBudget`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DegradationRung {
    /// Warm-started LP repair succeeded (rung 0, the steady-state path).
    WarmLp,
    /// Cold LP solve succeeded (rung 1; also every first solve).
    #[default]
    ColdLp,
    /// Both LP attempts failed or exhausted their budget: deterministic
    /// greedy least-loaded water-fill over the replicas (rung 2).
    Greedy,
    /// Engine-level last resort: vanilla-EP passthrough plan (rung 3),
    /// used when the scheduling workers themselves are unrecoverable.
    Passthrough,
}

/// Degradation-ladder counters: how often each rung produced the plan,
/// why solve budgets ran out, and how far fallback plans were from the
/// LP-quality balance. Aggregated per step in [`StepStats`] and over a
/// balancer's lifetime in [`BalancerStats`]; the chaos suite asserts
/// these match an injected [`crate::faults::FaultPlan`] exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DegradationStats {
    /// Plans produced by a successful warm LP repair (rung 0).
    pub warm_lp: u64,
    /// Plans produced by a successful cold LP solve (rung 1).
    pub cold_lp: u64,
    /// Plans produced by the greedy least-loaded fallback (rung 2).
    pub greedy: u64,
    /// Plans produced by vanilla-EP passthrough (rung 3).
    pub passthrough: u64,
    /// Solve attempts that exhausted their pivot cap.
    pub budget_pivots: u64,
    /// Solve attempts that exhausted their refactorization cap.
    pub budget_refactors: u64,
    /// Solve attempts that blew their wall-clock deadline.
    pub budget_wall: u64,
    /// Sum over fallback plans of `(plan max load − LP lower bound) /
    /// LP lower bound` — the imbalance price paid for degrading. Divide
    /// by `greedy + passthrough` for the mean excess.
    pub fallback_excess_sum: f64,
}

impl DegradationStats {
    /// Record one schedule's rung, optional budget-exhaustion reason, and
    /// (for fallback rungs) its imbalance excess over the LP lower bound.
    pub fn record(
        &mut self,
        rung: DegradationRung,
        budget: Option<crate::lp::BudgetReason>,
        fallback_excess: f64,
    ) {
        match rung {
            DegradationRung::WarmLp => self.warm_lp += 1,
            DegradationRung::ColdLp => self.cold_lp += 1,
            DegradationRung::Greedy => self.greedy += 1,
            DegradationRung::Passthrough => self.passthrough += 1,
        }
        match budget {
            Some(crate::lp::BudgetReason::Pivots) => self.budget_pivots += 1,
            Some(crate::lp::BudgetReason::Refactors) => self.budget_refactors += 1,
            Some(crate::lp::BudgetReason::WallClock) => self.budget_wall += 1,
            None => {}
        }
        if matches!(rung, DegradationRung::Greedy | DegradationRung::Passthrough)
            && fallback_excess.is_finite()
        {
            self.fallback_excess_sum += fallback_excess;
        }
    }

    /// Fold another accumulator into this one.
    pub fn absorb(&mut self, other: &DegradationStats) {
        self.warm_lp += other.warm_lp;
        self.cold_lp += other.cold_lp;
        self.greedy += other.greedy;
        self.passthrough += other.passthrough;
        self.budget_pivots += other.budget_pivots;
        self.budget_refactors += other.budget_refactors;
        self.budget_wall += other.budget_wall;
        self.fallback_excess_sum += other.fallback_excess_sum;
    }

    /// Total plans recorded across all rungs.
    pub fn total(&self) -> u64 {
        self.warm_lp + self.cold_lp + self.greedy + self.passthrough
    }

    /// Fraction of plans produced by an LP rung (1.0 when none recorded —
    /// an empty run has not degraded).
    pub fn lp_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            1.0
        } else {
            (self.warm_lp + self.cold_lp) as f64 / total as f64
        }
    }

    /// Plans produced below the LP rungs (the silent-fallback detector the
    /// `session_sweep` CI column watches).
    pub fn fallbacks(&self) -> u64 {
        self.greedy + self.passthrough
    }
}

/// Two-level (Dantzig–Wolfe-style) decomposition meters from
/// [`crate::scheduler::ScheduleMode::Decomposed`] solves: how many
/// master/subproblem outer iterations each layer took, where the simplex
/// pivots went (per-block subproblems vs the one global LP the exact modes
/// solve), how far the final coordination gap sat from the LP lower bound,
/// and how many block subproblems degraded to the greedy water-fill
/// (block-level degradation — the layer keeps its LP rung). Zero for every
/// non-decomposed mode. Aggregated per step in [`StepStats`] and over a
/// balancer's lifetime in [`BalancerStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DecomposeStats {
    /// Decomposed layer solves recorded.
    pub solves: u64,
    /// Master/subproblem outer iterations summed over solves.
    pub outer_iters: u64,
    /// Simplex pivots spent inside per-block subproblem solves.
    pub subproblem_pivots: u64,
    /// Sum over solves of the final master gap — `(max block level − LP
    /// lower bound) / LP lower bound`. Divide by [`DecomposeStats::solves`]
    /// for the mean gap.
    pub master_gap_sum: f64,
    /// Largest final master gap observed over any solve.
    pub master_gap_max: f64,
    /// Block subproblems that degraded to the greedy water-fill (budget
    /// exhaustion or a numerical failure confined to that block).
    pub blocks_degraded: u64,
}

impl DecomposeStats {
    /// Fold another accumulator into this one.
    pub fn absorb(&mut self, other: &DecomposeStats) {
        self.solves += other.solves;
        self.outer_iters += other.outer_iters;
        self.subproblem_pivots += other.subproblem_pivots;
        self.master_gap_sum += other.master_gap_sum;
        self.master_gap_max = self.master_gap_max.max(other.master_gap_max);
        self.blocks_degraded += other.blocks_degraded;
    }

    /// Mean final master gap per decomposed solve (0 when none recorded).
    pub fn mean_gap(&self) -> f64 {
        if self.solves == 0 {
            0.0
        } else {
            self.master_gap_sum / self.solves as f64
        }
    }
}

/// Placement-controller meters from the slow loop of the two-timescale
/// system ([`crate::control`]): how many control ticks evaluated the
/// placement, how many produced a decision, what the decisions moved
/// (replica copies, bytes, charged downtime), and how the predicted Eq.-3
/// density gain compared with the realized one. Zero for sessions without
/// a controller. Aggregated per step in [`StepStats`] and over a
/// balancer's lifetime in [`BalancerStats`]; the chaos suite and the
/// trace-reconciliation test pin `moves` against the placement-change
/// spans exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ControlStats {
    /// Control ticks that ran the detector/decider (every N steps).
    pub ticks: u64,
    /// Ticks that committed a placement change (decisions taken).
    pub decisions: u64,
    /// Replica copies executed across all decisions.
    pub moves: u64,
    /// Expert-parameter bytes migrated across all decisions.
    pub bytes: u64,
    /// Migration downtime charged into step prep time, seconds.
    pub downtime: f64,
    /// Sum of predicted Eq.-3 density improvements at decision time.
    pub predicted_gain: f64,
    /// Sum of realized density improvements, measured on the first
    /// post-migration load matrix against the old placement.
    pub realized_gain: f64,
}

impl ControlStats {
    /// Fold another accumulator into this one.
    pub fn absorb(&mut self, other: &ControlStats) {
        self.ticks += other.ticks;
        self.decisions += other.decisions;
        self.moves += other.moves;
        self.bytes += other.bytes;
        self.downtime += other.downtime;
        self.predicted_gain += other.predicted_gain;
        self.realized_gain += other.realized_gain;
    }

    /// Mean realized/predicted gain ratio (1.0 when nothing was predicted —
    /// an idle controller has not mispredicted).
    pub fn gain_accuracy(&self) -> f64 {
        if self.predicted_gain <= 0.0 {
            1.0
        } else {
            self.realized_gain / self.predicted_gain
        }
    }
}

/// Unified per-step scheduling diagnostics reported by every
/// [`crate::balancer::Balancer`] in its
/// [`crate::balancer::StepOutput`]. Static systems (vanilla EP, padding)
/// leave the LP counters at zero; LP-backed policies fill them from the
/// per-layer [`crate::scheduler::ScheduleStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepStats {
    /// Layer plans produced this step.
    pub layers: usize,
    /// Layers whose solve took the warm-start path.
    pub warm_layers: usize,
    /// Simplex pivots spent across the step's layers.
    pub lp_pivots: u64,
    /// Dual-simplex pivots alone (the warm-repair work).
    pub lp_dual_pivots: u64,
    /// Nonbasic bound flips across the step's layers.
    pub lp_bound_flips: u64,
    /// Basis refactorizations across the step's layers.
    pub lp_refactors: u64,
    /// Total scheduling wall time (LP + routing) across layers, seconds.
    pub sched_seconds: f64,
    /// Extra prep charged by the policy (migrations, padding setup), seconds.
    pub prep_seconds: f64,
    /// Max per-GPU compute load over all of the step's layers, tokens.
    pub max_gpu_load: u64,
    /// Degradation-ladder counters for the step's layers. Static policies
    /// (vanilla EP, padding) leave this at zero — they have no ladder.
    pub degradation: DegradationStats,
    /// Decomposition meters for the step's layers; zero unless the policy
    /// runs [`crate::scheduler::ScheduleMode::Decomposed`].
    pub decompose: DecomposeStats,
    /// Placement-controller meters for the step; zero unless the session
    /// runs the [`crate::control`] slow loop.
    pub control: ControlStats,
}

/// Cumulative counters over a [`crate::balancer::Balancer`]'s lifetime
/// (what [`crate::balancer::MoeSession::stats`] accumulates for any
/// policy, and LP-backed policies also keep internally).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BalancerStats {
    /// Multi-layer steps executed.
    pub steps: u64,
    /// Layer plans produced in total.
    pub layers: u64,
    /// Layers whose solve took the warm-start path.
    pub warm_layers: u64,
    /// Simplex pivots spent in total.
    pub lp_pivots: u64,
    /// Dual-simplex pivots alone.
    pub lp_dual_pivots: u64,
    /// Nonbasic bound flips in total.
    pub lp_bound_flips: u64,
    /// Basis refactorizations in total.
    pub lp_refactors: u64,
    /// Total scheduling wall time, seconds.
    pub sched_seconds: f64,
    /// Total extra prep charged by the policy, seconds.
    pub prep_seconds: f64,
    /// Max per-GPU compute load ever observed, tokens.
    pub max_gpu_load: u64,
    /// Cumulative degradation-ladder counters.
    pub degradation: DegradationStats,
    /// Cumulative decomposition meters (decomposed-mode policies only).
    pub decompose: DecomposeStats,
    /// Cumulative placement-controller meters (controller sessions only).
    pub control: ControlStats,
}

impl BalancerStats {
    /// Fold one step's diagnostics into the cumulative counters.
    pub fn absorb(&mut self, step: &StepStats) {
        self.steps += 1;
        self.layers += step.layers as u64;
        self.warm_layers += step.warm_layers as u64;
        self.lp_pivots += step.lp_pivots;
        self.lp_dual_pivots += step.lp_dual_pivots;
        self.lp_bound_flips += step.lp_bound_flips;
        self.lp_refactors += step.lp_refactors;
        self.sched_seconds += step.sched_seconds;
        self.prep_seconds += step.prep_seconds;
        self.max_gpu_load = self.max_gpu_load.max(step.max_gpu_load);
        self.degradation.absorb(&step.degradation);
        self.decompose.absorb(&step.decompose);
        self.control.absorb(&step.control);
    }

    /// Mean scheduling seconds per executed step (0 before the first).
    pub fn sched_seconds_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.sched_seconds / self.steps as f64
        }
    }
}

/// max/avg imbalance of a load vector (Fig. 7's y-axis).
pub fn imbalance_ratio(loads: &[f64]) -> f64 {
    let max = loads.iter().cloned().fold(f64::MIN, f64::max);
    let avg = loads.iter().sum::<f64>() / loads.len() as f64;
    if avg <= 0.0 {
        1.0
    } else {
        max / avg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn ema_tracks_change() {
        let mut e = Ema::new(0.3);
        e.update(0.0);
        let v = e.update(10.0);
        assert!((v - 3.0).abs() < 1e-12);
    }

    #[test]
    fn vec_window_mean() {
        let mut w = VecWindow::new(2);
        w.push(vec![1.0, 2.0]);
        w.push(vec![3.0, 4.0]);
        assert_eq!(w.mean().unwrap(), vec![2.0, 3.0]);
        w.push(vec![5.0, 6.0]); // evicts first
        assert_eq!(w.mean().unwrap(), vec![4.0, 5.0]);
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.counts(), &[1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn engine_stats_rates() {
        let mut s = EngineStats { spec_issued: 5, spec_hits: 3, spec_misses: 1, ..Default::default() };
        s.hit_repair_pivots = 6;
        assert!((s.hit_rate() - 0.75).abs() < 1e-12, "judged = hits + misses");
        assert!((s.repair_pivots_per_hit() - 2.0).abs() < 1e-12);
        let empty = EngineStats::default();
        assert_eq!(empty.hit_rate(), 0.0);
        assert_eq!(empty.repair_pivots_per_hit(), 0.0);
    }

    #[test]
    fn balancer_stats_absorb_accumulates() {
        let mut b = BalancerStats::default();
        let s1 = StepStats {
            layers: 4,
            warm_layers: 3,
            lp_pivots: 10,
            sched_seconds: 0.5,
            max_gpu_load: 100,
            ..Default::default()
        };
        let s2 = StepStats { layers: 4, lp_pivots: 2, max_gpu_load: 80, ..Default::default() };
        b.absorb(&s1);
        b.absorb(&s2);
        assert_eq!(b.steps, 2);
        assert_eq!(b.layers, 8);
        assert_eq!(b.warm_layers, 3);
        assert_eq!(b.lp_pivots, 12);
        assert_eq!(b.max_gpu_load, 100);
        assert!((b.sched_seconds_per_step() - 0.25).abs() < 1e-12);
        assert_eq!(BalancerStats::default().sched_seconds_per_step(), 0.0);
    }

    #[test]
    fn degradation_stats_record_and_absorb() {
        use crate::lp::BudgetReason;
        let mut d = DegradationStats::default();
        d.record(DegradationRung::WarmLp, None, 0.0);
        d.record(DegradationRung::ColdLp, Some(BudgetReason::Pivots), 0.0);
        d.record(DegradationRung::Greedy, Some(BudgetReason::WallClock), 0.25);
        // non-finite excess must not poison the sum
        d.record(DegradationRung::Greedy, None, f64::NAN);
        assert_eq!(d.warm_lp, 1);
        assert_eq!(d.cold_lp, 1);
        assert_eq!(d.greedy, 2);
        assert_eq!(d.budget_pivots, 1);
        assert_eq!(d.budget_wall, 1);
        assert!((d.fallback_excess_sum - 0.25).abs() < 1e-12);
        assert_eq!(d.total(), 4);
        assert_eq!(d.fallbacks(), 2);
        assert!((d.lp_rate() - 0.5).abs() < 1e-12);
        assert_eq!(DegradationStats::default().lp_rate(), 1.0);

        let mut sum = DegradationStats::default();
        sum.absorb(&d);
        sum.absorb(&d);
        assert_eq!(sum.greedy, 4);
        assert_eq!(sum.total(), 8);

        // StepStats absorption carries the ladder into BalancerStats
        let mut b = BalancerStats::default();
        b.absorb(&StepStats { degradation: d, ..Default::default() });
        assert_eq!(b.degradation, d);
    }

    #[test]
    fn p2_small_samples_are_exact() {
        let mut p2 = P2Quantile::new(0.5);
        assert!(p2.estimate().is_nan());
        for x in [5.0, 1.0, 3.0] {
            p2.observe(x);
        }
        assert!((p2.estimate() - 3.0).abs() < 1e-12, "exact median of 3 samples");
        assert_eq!(p2.count(), 3);
        assert!((p2.p() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn p2_tracks_uniform_stream_quantiles() {
        // a 1..=1000 permutation-free ramp: exact quantiles are known
        for (p, want) in [(0.5, 500.0), (0.95, 950.0), (0.99, 990.0)] {
            let mut p2 = P2Quantile::new(p);
            for i in 1..=1000 {
                p2.observe(i as f64);
            }
            let got = p2.estimate();
            assert!(
                (got - want).abs() / want < 0.05,
                "p{p}: got {got}, want ~{want}"
            );
        }
    }

    #[test]
    fn empty_latency_track_has_no_fabricated_max() {
        let t = LatencyTrack::new();
        assert!(t.is_empty());
        // every moment of an empty track is NaN — not a fake 0.0 maximum
        assert!(t.max().is_nan());
        assert!(t.mean().is_nan());
        assert!(t.exact(0.5).is_nan());
        // the first real sample replaces the sentinel outright
        let mut t = t;
        t.record(-3.0);
        assert_eq!(t.max(), -3.0);
        t.record(7.0);
        assert_eq!(t.max(), 7.0);
    }

    #[test]
    fn decompose_stats_absorb_and_mean_gap() {
        let a = DecomposeStats {
            solves: 2,
            outer_iters: 5,
            subproblem_pivots: 40,
            master_gap_sum: 0.02,
            master_gap_max: 0.015,
            blocks_degraded: 1,
        };
        let b = DecomposeStats {
            solves: 1,
            outer_iters: 3,
            subproblem_pivots: 10,
            master_gap_sum: 0.04,
            master_gap_max: 0.04,
            blocks_degraded: 0,
        };
        let mut sum = DecomposeStats::default();
        assert_eq!(sum.mean_gap(), 0.0);
        sum.absorb(&a);
        sum.absorb(&b);
        assert_eq!(sum.solves, 3);
        assert_eq!(sum.outer_iters, 8);
        assert_eq!(sum.subproblem_pivots, 50);
        assert_eq!(sum.blocks_degraded, 1);
        assert_eq!(sum.master_gap_max, 0.04);
        assert!((sum.mean_gap() - 0.02).abs() < 1e-12);

        // StepStats absorption carries the meters into BalancerStats
        let mut bal = BalancerStats::default();
        bal.absorb(&StepStats { decompose: a, ..Default::default() });
        assert_eq!(bal.decompose, a);
    }

    #[test]
    fn control_stats_absorb_and_gain_accuracy() {
        let a = ControlStats {
            ticks: 4,
            decisions: 2,
            moves: 5,
            bytes: 1_000,
            downtime: 0.25,
            predicted_gain: 40.0,
            realized_gain: 30.0,
        };
        let b = ControlStats {
            ticks: 1,
            decisions: 1,
            moves: 2,
            bytes: 500,
            downtime: 0.05,
            predicted_gain: 10.0,
            realized_gain: 15.0,
        };
        let mut sum = ControlStats::default();
        assert_eq!(sum.gain_accuracy(), 1.0, "idle controller has not mispredicted");
        sum.absorb(&a);
        sum.absorb(&b);
        assert_eq!(sum.ticks, 5);
        assert_eq!(sum.decisions, 3);
        assert_eq!(sum.moves, 7);
        assert_eq!(sum.bytes, 1_500);
        assert!((sum.downtime - 0.30).abs() < 1e-12);
        assert!((sum.gain_accuracy() - 0.9).abs() < 1e-12);

        // StepStats absorption carries the meters into BalancerStats
        let mut bal = BalancerStats::default();
        bal.absorb(&StepStats { control: a, ..Default::default() });
        assert_eq!(bal.control, a);
    }

    #[test]
    fn latency_track_exact_and_p2_agree_on_ramp() {
        let mut t = LatencyTrack::new();
        assert!(t.is_empty());
        assert!(t.mean().is_nan());
        for i in 0..2000 {
            t.record((i % 1000) as f64);
        }
        assert_eq!(t.count(), 2000);
        assert_eq!(t.max(), 999.0);
        assert!((t.mean() - 499.5).abs() < 1e-9);
        for (exact, p2) in
            [(t.exact(0.50), t.p2_p50()), (t.exact(0.95), t.p2_p95()), (t.exact(0.99), t.p2_p99())]
        {
            assert!(
                (exact - p2).abs() / exact.max(1.0) < 0.05,
                "exact {exact} vs p2 {p2}"
            );
        }
    }

    #[test]
    fn imbalance_of_uniform_is_one() {
        assert!((imbalance_ratio(&[4.0, 4.0, 4.0]) - 1.0).abs() < 1e-12);
        assert!((imbalance_ratio(&[8.0, 0.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_and_percentile_use_nan_sentinel() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        for v in [s.mean, s.std, s.min, s.p50, s.p95, s.max] {
            assert!(v.is_nan(), "empty-summary moments are the NaN sentinel");
        }
        assert!(percentile(&[], 0.5).is_nan());
        assert!(percentile(&[], 0.0).is_nan());
        // the q-range contract still holds on empty input
        assert!(std::panic::catch_unwind(|| percentile(&[], 1.5)).is_err());
    }

    #[test]
    fn reservoir_bounds_samples_but_not_the_moments() {
        let mut t = LatencyTrack::with_capacity(64);
        assert_eq!(t.capacity(), 64);
        for i in 0..1000 {
            t.record(i as f64);
        }
        assert_eq!(t.samples().len(), 64, "reservoir caps retained samples");
        assert_eq!(t.count(), 1000, "count covers the whole stream");
        assert_eq!(t.max(), 999.0);
        assert!((t.mean() - 499.5).abs() < 1e-9, "mean covers the whole stream");
        // the reservoir quantile still estimates the stream's median
        let est = t.exact(0.5);
        assert!((est - 499.5).abs() < 150.0, "reservoir median far off: {est}");
        // P² markers are unaffected by the reservoir
        assert!((t.p2_p50() - 500.0).abs() < 50.0, "p2 p50: {}", t.p2_p50());
    }

    #[test]
    fn reservoir_is_deterministic_and_exact_under_cap() {
        let fill = |cap: usize| {
            let mut t = LatencyTrack::with_capacity(cap);
            for i in 0..300 {
                t.record(((i * 7919) % 1000) as f64);
            }
            t
        };
        // same stream, same cap → bit-identical tracks (derived PartialEq)
        assert_eq!(fill(128), fill(128));
        // under the cap the track is the exact stream in arrival order
        let exact = fill(512);
        assert_eq!(exact.samples().len(), 300);
        assert_eq!(exact.samples()[0], 0.0);
        assert_eq!(exact.count(), 300);
    }

    // The P² edge-case goldens below are the Python reference's outputs
    // (python/tools/serving_reference.py, P2Quantile/percentile): each
    // expected value was produced by feeding the identical stream to the
    // transliterated estimator. Keep them in sync with that file.

    fn p2_over(stream: &[f64], p: f64) -> f64 {
        let mut q = P2Quantile::new(p);
        for &x in stream {
            q.observe(x);
        }
        q.estimate()
    }

    #[test]
    fn p2_under_five_observations_matches_exact_percentile() {
        // fewer than 5 observations: the warmup buffer answers exactly
        let stream = [7.0, 1.0, 4.0];
        // reference: p2=4.0, 6.699999999999999, 6.9399999999999995
        assert!((p2_over(&stream, 0.50) - 4.0).abs() < 1e-12);
        assert!((p2_over(&stream, 0.95) - 6.699999999999999).abs() < 1e-12);
        assert!((p2_over(&stream, 0.99) - 6.9399999999999995).abs() < 1e-12);
        let mut sorted = stream.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.5, 0.95, 0.99] {
            assert!((p2_over(&stream, p) - percentile(&sorted, p)).abs() < 1e-12);
        }
    }

    #[test]
    fn p2_duplicate_heavy_stream_matches_reference() {
        // 90% duplicates of 5.0 with a 10% spread — marker collisions
        // stress the parabolic/linear update's monotonicity guard
        let stream: Vec<f64> = (0..500)
            .map(|i| if i % 10 != 0 { 5.0 } else { (i % 100) as f64 })
            .collect();
        // reference: p2 = 5.0003071711622455 / 41.67689047416763 /
        // 84.07637171085906
        assert!((p2_over(&stream, 0.50) - 5.0003071711622455).abs() < 1e-9);
        assert!((p2_over(&stream, 0.95) - 41.67689047416763).abs() < 1e-9);
        assert!((p2_over(&stream, 0.99) - 84.07637171085906).abs() < 1e-9);
    }

    #[test]
    fn p2_adversarial_monotone_streams_match_reference() {
        // sorted input is the estimator's worst case: every observation
        // lands in the top cell and drags the desired positions
        let up: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let down: Vec<f64> = (1..=200).rev().map(|i| i as f64).collect();
        // reference (up): p2 = 100.0 / 190.0 / 197.0
        assert!((p2_over(&up, 0.50) - 100.0).abs() < 1e-9);
        assert!((p2_over(&up, 0.95) - 190.0).abs() < 1e-9);
        assert!((p2_over(&up, 0.99) - 197.0).abs() < 1e-9);
        // reference (down): p2 = 101.0 / 191.0 / 198.0
        assert!((p2_over(&down, 0.50) - 101.0).abs() < 1e-9);
        assert!((p2_over(&down, 0.95) - 191.0).abs() < 1e-9);
        assert!((p2_over(&down, 0.99) - 198.0).abs() < 1e-9);
        // and both stay within a few percent of the exact quantiles
        for (p, want) in [(0.50, 100.5), (0.95, 190.05), (0.99, 198.01)] {
            assert!((p2_over(&up, p) - want).abs() / want < 0.05);
            assert!((p2_over(&down, p) - want).abs() / want < 0.05);
        }
    }
}
