//! The [`Tracer`]: typed span recording behind a zero-cost-when-disabled
//! handle.
//!
//! A `Tracer` is a cheap clonable handle (`Option<Arc<..>>`). Disabled —
//! the [`TraceConfig::Off`] default — it holds no allocation and every
//! record call is a branch on a `None`; the schedulers, the engine pool,
//! and the serving tier can therefore carry one unconditionally (inside
//! [`crate::scheduler::SchedulerOptions`]) without paying for it. Enabled,
//! all clones share one event buffer and one span-id counter, so spans
//! recorded by pool workers survive worker respawn/replay with globally
//! unique ids.
//!
//! # Clock domains
//!
//! Each tracer lives on one clock ([`TraceConfig::Wall`] or
//! [`TraceConfig::Virtual`]):
//!
//! * **Wall** — timestamps are µs since tracer creation; [`Tracer::record`]
//!   places a span so it *ends* now (`ts = now − dur`).
//! * **Virtual** — timestamps are the serving tier's deterministic µs
//!   clock, advanced explicitly via [`Tracer::set_virtual_us`];
//!   [`Tracer::record`] places a span *starting* at the current virtual
//!   time (wall-measured durations keep their length but carry no virtual
//!   start of their own), and [`Tracer::record_at`] places a span at an
//!   explicit virtual interval (what [`crate::serving::MoeServer`] uses
//!   for its windows).
//!
//! Every event remembers which domain stamped it ([`ClockDomain`]), and
//! the Chrome export keeps the domains on separate process lanes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::stats::DegradationRung;

/// Whether a [`Tracer`] records at all, and on which clock.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceConfig {
    /// No tracing: recording is a no-op and allocates nothing (default).
    #[default]
    Off,
    /// Record on the wall clock (µs since tracer creation).
    Wall,
    /// Record on the serving tier's virtual µs clock
    /// ([`Tracer::set_virtual_us`]).
    Virtual,
}

/// Which clock stamped an event's timestamps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockDomain {
    /// Wall-clock µs since tracer creation.
    Wall,
    /// Serving-tier virtual µs.
    Virtual,
}

/// Speculation verdict attribute of an engine emission span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    /// A speculative pre-solve was judged close enough: warm repair.
    Hit,
    /// The pre-solve's forecast drifted: re-solved from scratch.
    Miss,
    /// No pre-solve was pending (warmup, or pipeline mode).
    Fresh,
}

impl SpanOutcome {
    /// Attribute string used in exports.
    pub fn name(&self) -> &'static str {
        match self {
            SpanOutcome::Hit => "hit",
            SpanOutcome::Miss => "miss",
            SpanOutcome::Fresh => "fresh",
        }
    }
}

/// Export name of a degradation rung (span attribute vocabulary).
pub fn rung_name(rung: DegradationRung) -> &'static str {
    match rung {
        DegradationRung::WarmLp => "warm-lp",
        DegradationRung::ColdLp => "cold-lp",
        DegradationRung::Greedy => "greedy",
        DegradationRung::Passthrough => "passthrough",
    }
}

/// Typed span payloads — the trace vocabulary of the whole stack.
#[derive(Clone, Debug, PartialEq)]
pub enum Span {
    /// One committed per-layer schedule solve (LP / greedy / passthrough).
    /// Emitted once per committed plan — speculative pre-solves and
    /// non-committing probes are excluded, so solve-span rung counts match
    /// [`crate::stats::DegradationStats`] exactly.
    Solve {
        /// Commit-step index of the producing scheduler.
        step: usize,
        /// MoE layer the schedule belongs to.
        layer: usize,
        /// Schedule mode name ([`crate::scheduler::ScheduleMode::name`]).
        mode: &'static str,
        /// Degradation-ladder rung that produced the plan.
        rung: DegradationRung,
        /// Whether the solve took the warm-start path.
        warm: bool,
        /// Primal simplex pivots.
        pivots: usize,
        /// Dual simplex pivots (warm-repair work).
        dual_pivots: usize,
        /// Nonbasic bound flips.
        flips: usize,
        /// Basis refactorizations.
        refactors: usize,
    },
    /// One in-order schedule emission by the pipelined engine. Emitted
    /// once per emitted layer, so engine-span counts match
    /// [`crate::stats::EngineStats::schedules`] and the hit/miss tags
    /// match its speculation counters.
    Engine {
        /// Engine step index.
        step: usize,
        /// Emitted layer.
        layer: usize,
        /// Pool worker pinned to the layer (`layer % workers`).
        worker: usize,
        /// Speculation verdict for this layer's commit.
        outcome: SpanOutcome,
        /// Layers submitted but not yet emitted at emission time.
        inflight: usize,
        /// LP pivots the commit spent on the critical path.
        pivots: usize,
    },
    /// One outer round of one block of a Dantzig–Wolfe decomposed solve.
    DecomposeRound {
        /// Outer master/subproblem iteration (0-based).
        round: usize,
        /// Node-block index.
        block: usize,
        /// Master coordination gap after this round.
        gap: f64,
        /// The block's capacity-feedback weight κ_b after this round.
        kappa: f64,
    },
    /// One formed serving batching window (including windows emptied by
    /// admission shedding), so window-span counts match
    /// [`crate::serving::SlaStats::windows`].
    ServingWindow {
        /// Window index in arrival order.
        index: usize,
        /// Requests admitted into the window's batch.
        admitted: usize,
        /// Requests shed while forming the batch.
        shed: usize,
        /// Served requests that missed their deadline.
        deadline_miss: usize,
    },
    /// A pool worker died and was respawned (replayed jobs re-solve under
    /// fresh span ids; this marks the discontinuity).
    WorkerRespawn {
        /// Worker index.
        worker: usize,
        /// Consecutive respawn attempt (1-based).
        attempt: usize,
    },
    /// One committed placement change by the slow control loop
    /// ([`crate::control`]): the session migrated expert replicas and
    /// rebuilt the affected layers' warm scheduler bases. Emitted once per
    /// decision, so placement-change span counts and their `moves` sums
    /// reconcile exactly with [`crate::stats::ControlStats`].
    PlacementChange {
        /// Step index at which the change was applied.
        step: usize,
        /// Control tick that produced the decision (1-based).
        tick: usize,
        /// Replica copies executed ([`crate::cluster::migration::Move`]s).
        moves: usize,
        /// Expert-parameter bytes migrated.
        bytes: u64,
        /// Predicted Eq.-3 density improvement at decision time.
        predicted_gain: f64,
        /// Migration downtime charged into the step, seconds.
        downtime: f64,
    },
}

impl Span {
    /// Export name of the span kind.
    pub fn name(&self) -> &'static str {
        match self {
            Span::Solve { .. } => "solve",
            Span::Engine { .. } => "engine",
            Span::DecomposeRound { .. } => "decompose_round",
            Span::ServingWindow { .. } => "serving_window",
            Span::WorkerRespawn { .. } => "worker_respawn",
            Span::PlacementChange { .. } => "placement_change",
        }
    }

    /// Chrome-trace lane (`tid`) the span renders on: solves by layer,
    /// engine emissions by worker, decompose rounds by block, serving and
    /// respawn markers on their own lanes.
    pub fn lane(&self) -> u64 {
        match self {
            Span::Solve { layer, .. } => *layer as u64,
            Span::Engine { worker, .. } => 100 + *worker as u64,
            Span::DecomposeRound { block, .. } => 200 + *block as u64,
            Span::ServingWindow { .. } => 300,
            Span::WorkerRespawn { worker, .. } => 100 + *worker as u64,
            Span::PlacementChange { .. } => 400,
        }
    }
}

/// One recorded span instance.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Globally unique span id (monotone across clones and respawns).
    pub id: u64,
    /// Start timestamp, µs in the event's clock domain.
    pub ts_us: f64,
    /// Duration, µs.
    pub dur_us: f64,
    /// Clock that stamped `ts_us`.
    pub domain: ClockDomain,
    /// Typed payload.
    pub span: Span,
}

#[derive(Debug)]
struct TracerInner {
    clock: ClockDomain,
    epoch: Instant,
    /// Current virtual time, stored as f64 bits (µs).
    virtual_us: AtomicU64,
    next_id: AtomicU64,
    events: Mutex<Vec<TraceEvent>>,
}

/// Shared tracing handle — see the module docs. `Default` is disabled;
/// clones of one enabled tracer share the same buffer and id counter.
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    inner: Option<Arc<TracerInner>>,
}

/// Two tracers are equal when both are disabled or both are clones of the
/// same enabled tracer — so [`crate::scheduler::SchedulerOptions`] keeps
/// its derived `PartialEq` (and `default() == default()` holds).
impl PartialEq for Tracer {
    fn eq(&self, other: &Self) -> bool {
        match (&self.inner, &other.inner) {
            (None, None) => true,
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

impl Tracer {
    /// Build a tracer; [`TraceConfig::Off`] yields the no-op handle.
    pub fn new(cfg: TraceConfig) -> Tracer {
        let clock = match cfg {
            TraceConfig::Off => return Tracer { inner: None },
            TraceConfig::Wall => ClockDomain::Wall,
            TraceConfig::Virtual => ClockDomain::Virtual,
        };
        Tracer {
            inner: Some(Arc::new(TracerInner {
                clock,
                epoch: Instant::now(),
                virtual_us: AtomicU64::new(0f64.to_bits()),
                next_id: AtomicU64::new(0),
                events: Mutex::new(Vec::new()),
            })),
        }
    }

    /// The disabled no-op handle (same as `Tracer::default()`).
    pub fn off() -> Tracer {
        Tracer { inner: None }
    }

    /// Whether recording does anything.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The config this tracer was built with.
    pub fn config(&self) -> TraceConfig {
        match &self.inner {
            None => TraceConfig::Off,
            Some(i) => match i.clock {
                ClockDomain::Wall => TraceConfig::Wall,
                ClockDomain::Virtual => TraceConfig::Virtual,
            },
        }
    }

    /// Advance the virtual clock (serving tier); no-op when disabled.
    pub fn set_virtual_us(&self, us: f64) {
        if let Some(i) = &self.inner {
            i.virtual_us.store(us.to_bits(), Ordering::Relaxed);
        }
    }

    /// Current virtual time, µs (0 when disabled or never set).
    pub fn virtual_us(&self) -> f64 {
        match &self.inner {
            None => 0.0,
            Some(i) => f64::from_bits(i.virtual_us.load(Ordering::Relaxed)),
        }
    }

    /// Record a span of `dur_us` µs ending now (wall domain) or starting
    /// at the current virtual time (virtual domain). No-op when disabled.
    pub fn record(&self, dur_us: f64, span: Span) {
        let Some(i) = &self.inner else { return };
        let (ts, domain) = match i.clock {
            ClockDomain::Wall => {
                let now = i.epoch.elapsed().as_secs_f64() * 1e6;
                ((now - dur_us).max(0.0), ClockDomain::Wall)
            }
            ClockDomain::Virtual => {
                (f64::from_bits(i.virtual_us.load(Ordering::Relaxed)), ClockDomain::Virtual)
            }
        };
        self.push(i, ts, dur_us, domain, span);
    }

    /// Record a span at an explicit virtual interval, whatever the
    /// tracer's own clock — the serving tier's windows always live on the
    /// virtual timeline. No-op when disabled.
    pub fn record_at(&self, ts_us: f64, dur_us: f64, span: Span) {
        let Some(i) = &self.inner else { return };
        self.push(i, ts_us, dur_us, ClockDomain::Virtual, span);
    }

    fn push(&self, i: &TracerInner, ts_us: f64, dur_us: f64, domain: ClockDomain, span: Span) {
        let id = i.next_id.fetch_add(1, Ordering::Relaxed);
        let ev = TraceEvent { id, ts_us, dur_us, domain, span };
        i.events.lock().expect("trace buffer poisoned").push(ev);
    }

    /// Snapshot of every recorded event (empty when disabled). Order is
    /// buffer-arrival order; concurrent recorders interleave, so assert on
    /// span *sets*, not sequence.
    pub fn events(&self) -> Vec<TraceEvent> {
        match &self.inner {
            None => Vec::new(),
            Some(i) => i.events.lock().expect("trace buffer poisoned").clone(),
        }
    }

    /// Recorded event count without cloning the buffer.
    pub fn event_count(&self) -> usize {
        match &self.inner {
            None => 0,
            Some(i) => i.events.lock().expect("trace buffer poisoned").len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve_span(step: usize) -> Span {
        Span::Solve {
            step,
            layer: 0,
            mode: "compute",
            rung: DegradationRung::WarmLp,
            warm: true,
            pivots: 3,
            dual_pivots: 2,
            flips: 1,
            refactors: 0,
        }
    }

    #[test]
    fn disabled_tracer_is_inert_and_equal_to_default() {
        let t = Tracer::off();
        assert!(!t.enabled());
        assert_eq!(t.config(), TraceConfig::Off);
        t.record(5.0, solve_span(0));
        t.record_at(1.0, 2.0, solve_span(1));
        t.set_virtual_us(99.0);
        assert_eq!(t.event_count(), 0);
        assert!(t.events().is_empty());
        assert_eq!(t, Tracer::default());
        assert_eq!(Tracer::new(TraceConfig::Off), Tracer::default());
    }

    #[test]
    fn clones_share_buffer_and_ids() {
        let t = Tracer::new(TraceConfig::Wall);
        let c = t.clone();
        assert_eq!(t, c, "clones compare equal (same buffer)");
        assert_ne!(t, Tracer::new(TraceConfig::Wall), "distinct tracers differ");
        t.record(1.0, solve_span(0));
        c.record(1.0, solve_span(1));
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].id, 0);
        assert_eq!(evs[1].id, 1);
        assert!(evs.iter().all(|e| e.domain == ClockDomain::Wall));
    }

    #[test]
    fn wall_spans_end_at_record_time() {
        let t = Tracer::new(TraceConfig::Wall);
        t.record(1e12, solve_span(0)); // longer than the tracer has lived
        let e = &t.events()[0];
        assert_eq!(e.ts_us, 0.0, "start clamps to the epoch");
        assert_eq!(e.dur_us, 1e12);
    }

    #[test]
    fn virtual_clock_stamps_records() {
        let t = Tracer::new(TraceConfig::Virtual);
        t.set_virtual_us(1500.0);
        assert_eq!(t.virtual_us(), 1500.0);
        t.record(40.0, solve_span(0));
        t.record_at(2000.0, 500.0, Span::ServingWindow {
            index: 0,
            admitted: 4,
            shed: 1,
            deadline_miss: 0,
        });
        let evs = t.events();
        assert_eq!(evs[0].ts_us, 1500.0);
        assert_eq!(evs[0].domain, ClockDomain::Virtual);
        assert_eq!(evs[1].ts_us, 2000.0);
        assert_eq!(evs[1].dur_us, 500.0);
    }

    #[test]
    fn span_names_and_lanes() {
        assert_eq!(solve_span(0).name(), "solve");
        assert_eq!(solve_span(0).lane(), 0);
        let e = Span::Engine {
            step: 0,
            layer: 3,
            worker: 1,
            outcome: SpanOutcome::Hit,
            inflight: 2,
            pivots: 7,
        };
        assert_eq!(e.name(), "engine");
        assert_eq!(e.lane(), 101);
        assert_eq!(SpanOutcome::Miss.name(), "miss");
        assert_eq!(rung_name(DegradationRung::Passthrough), "passthrough");
        let d = Span::DecomposeRound { round: 0, block: 2, gap: 0.01, kappa: 1.0 };
        assert_eq!(d.lane(), 202);
        let w = Span::ServingWindow { index: 0, admitted: 0, shed: 0, deadline_miss: 0 };
        assert_eq!(w.name(), "serving_window");
        assert_eq!(w.lane(), 300);
        let r = Span::WorkerRespawn { worker: 2, attempt: 1 };
        assert_eq!(r.name(), "worker_respawn");
        assert_eq!(r.lane(), 102);
        let p = Span::PlacementChange {
            step: 8,
            tick: 2,
            moves: 3,
            bytes: 1 << 20,
            predicted_gain: 12.5,
            downtime: 0.06,
        };
        assert_eq!(p.name(), "placement_change");
        assert_eq!(p.lane(), 400);
    }
}
