//! Observability spine: structured tracing + unified metrics.
//!
//! Everything the stack already measures — [`crate::stats::BalancerStats`],
//! [`crate::stats::EngineStats`], [`crate::stats::DegradationStats`],
//! [`crate::stats::DecomposeStats`], [`crate::serving::SlaStats`] — is
//! *aggregate*: totals with no timeline and no per-event attribution. This
//! module adds the missing event layer and one export surface:
//!
//! * [`trace`] — a zero-cost-when-disabled [`Tracer`] recording typed
//!   spans ([`Span::Solve`], [`Span::Engine`], [`Span::DecomposeRound`],
//!   [`Span::ServingWindow`]) on either the wall clock or the serving
//!   tier's virtual µs clock ([`TraceConfig`]);
//! * [`export`] — Chrome-trace (`chrome://tracing` / Perfetto) JSON and
//!   Prometheus text exposition;
//! * [`registry`] — the [`MetricsHub`] folding every stats struct into one
//!   named-metric namespace with JSON snapshots and per-step diffs.
//!
//! The contract threaded through the stack: tracing **observes, never
//! steers**. A session traced with `TraceConfig::Off` (the default) is
//! bit-identical to one built before this module existed, and a traced run
//! produces the same schedules as an untraced one — pinned by
//! `tests/trace_identity.rs` and the `engine_pipeline` bench's overhead
//! column. See `ARCHITECTURE.md` §11 for the span taxonomy and the
//! wall-vs-virtual clock-domain rules.

pub mod export;
pub mod registry;
pub mod trace;

pub use export::{chrome_trace, prometheus};
pub use registry::{MetricKind, MetricsHub};
pub use trace::{ClockDomain, Span, SpanOutcome, TraceConfig, TraceEvent, Tracer};
