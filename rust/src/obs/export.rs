//! Trace and metrics exporters: Chrome-trace JSON and Prometheus text.
//!
//! [`chrome_trace`] turns a [`Tracer`]'s events into the Chrome Trace
//! Event Format (`chrome://tracing`, Perfetto's legacy JSON importer):
//! complete (`"ph": "X"`) events with µs timestamps, the wall and virtual
//! clock domains separated onto two named processes (`pid` 0/1) so the two
//! timelines never interleave, and span attributes under `args`.
//!
//! [`prometheus`] renders a [`MetricsHub`] in the Prometheus text
//! exposition format (`# TYPE` headers, one sample per line, metric names
//! prefixed `micromoe_`).

use std::collections::BTreeMap;

use crate::ser::Json;

use super::registry::{MetricKind, MetricsHub};
use super::trace::{rung_name, ClockDomain, Span, TraceEvent, Tracer};

fn pid(domain: ClockDomain) -> f64 {
    match domain {
        ClockDomain::Wall => 0.0,
        ClockDomain::Virtual => 1.0,
    }
}

fn args(span: &Span) -> Json {
    match span {
        Span::Solve { step, layer, mode, rung, warm, pivots, dual_pivots, flips, refactors } => {
            Json::obj(vec![
                ("step", Json::Num(*step as f64)),
                ("layer", Json::Num(*layer as f64)),
                ("mode", Json::Str((*mode).to_string())),
                ("rung", Json::Str(rung_name(*rung).to_string())),
                ("warm", Json::Bool(*warm)),
                ("pivots", Json::Num(*pivots as f64)),
                ("dual_pivots", Json::Num(*dual_pivots as f64)),
                ("flips", Json::Num(*flips as f64)),
                ("refactors", Json::Num(*refactors as f64)),
            ])
        }
        Span::Engine { step, layer, worker, outcome, inflight, pivots } => Json::obj(vec![
            ("step", Json::Num(*step as f64)),
            ("layer", Json::Num(*layer as f64)),
            ("worker", Json::Num(*worker as f64)),
            ("outcome", Json::Str(outcome.name().to_string())),
            ("inflight", Json::Num(*inflight as f64)),
            ("pivots", Json::Num(*pivots as f64)),
        ]),
        Span::DecomposeRound { round, block, gap, kappa } => Json::obj(vec![
            ("round", Json::Num(*round as f64)),
            ("block", Json::Num(*block as f64)),
            ("gap", Json::num(*gap)),
            ("kappa", Json::num(*kappa)),
        ]),
        Span::ServingWindow { index, admitted, shed, deadline_miss } => Json::obj(vec![
            ("index", Json::Num(*index as f64)),
            ("admitted", Json::Num(*admitted as f64)),
            ("shed", Json::Num(*shed as f64)),
            ("deadline_miss", Json::Num(*deadline_miss as f64)),
        ]),
        Span::WorkerRespawn { worker, attempt } => Json::obj(vec![
            ("worker", Json::Num(*worker as f64)),
            ("attempt", Json::Num(*attempt as f64)),
        ]),
        Span::PlacementChange { step, tick, moves, bytes, predicted_gain, downtime } => {
            Json::obj(vec![
                ("step", Json::Num(*step as f64)),
                ("tick", Json::Num(*tick as f64)),
                ("moves", Json::Num(*moves as f64)),
                ("bytes", Json::Num(*bytes as f64)),
                ("predicted_gain", Json::num(*predicted_gain)),
                ("downtime", Json::num(*downtime)),
            ])
        }
    }
}

fn event_json(e: &TraceEvent) -> Json {
    Json::obj(vec![
        ("name", Json::Str(e.span.name().to_string())),
        ("cat", Json::Str("micromoe".to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(pid(e.domain))),
        ("tid", Json::Num(e.span.lane() as f64)),
        ("ts", Json::num(e.ts_us)),
        ("dur", Json::num(e.dur_us)),
        ("id", Json::Num(e.id as f64)),
        ("args", args(&e.span)),
    ])
}

fn process_meta(domain: ClockDomain, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str("process_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid(domain))),
        ("tid", Json::Num(0.0)),
        ("args", Json::obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

/// Export every recorded event as a Chrome-trace JSON document. Events are
/// sorted by (domain, start, id) so the artifact is stable for a fixed
/// event set even when pool workers raced to record. A disabled tracer
/// yields a valid document with only the process-name metadata.
pub fn chrome_trace(tracer: &Tracer) -> Json {
    let mut events = tracer.events();
    events.sort_by(|a, b| {
        (pid(a.domain), a.ts_us, a.id)
            .partial_cmp(&(pid(b.domain), b.ts_us, b.id))
            .expect("trace timestamps are comparable")
    });
    let mut out = vec![
        process_meta(ClockDomain::Wall, "micromoe (wall clock)"),
        process_meta(ClockDomain::Virtual, "micromoe (virtual clock)"),
    ];
    out.extend(events.iter().map(event_json));
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

fn format_value(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string() // valid in the Prometheus text format
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Render a [`MetricsHub`] in the Prometheus text exposition format.
pub fn prometheus(hub: &MetricsHub) -> String {
    let mut out = String::new();
    // group samples under one # TYPE header per metric name
    let mut by_name: BTreeMap<String, (MetricKind, f64)> = BTreeMap::new();
    for (name, kind, value) in hub.iter() {
        by_name.insert(name.to_string(), (kind, value));
    }
    for (name, (kind, value)) in by_name {
        let kind = match kind {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        };
        out.push_str(&format!("# TYPE micromoe_{name} {kind}\n"));
        out.push_str(&format!("micromoe_{name} {}\n", format_value(value)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{SpanOutcome, TraceConfig};
    use crate::stats::DegradationRung;

    fn traced() -> Tracer {
        let t = Tracer::new(TraceConfig::Wall);
        t.record(10.0, Span::Solve {
            step: 0,
            layer: 1,
            mode: "compute",
            rung: DegradationRung::ColdLp,
            warm: false,
            pivots: 12,
            dual_pivots: 0,
            flips: 3,
            refactors: 1,
        });
        t.record(2.0, Span::Engine {
            step: 0,
            layer: 1,
            worker: 1,
            outcome: SpanOutcome::Fresh,
            inflight: 2,
            pivots: 12,
        });
        t.record_at(500.0, 250.0, Span::ServingWindow {
            index: 0,
            admitted: 3,
            shed: 0,
            deadline_miss: 1,
        });
        t
    }

    #[test]
    fn chrome_trace_is_valid_and_complete() {
        let j = chrome_trace(&traced());
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process metadata + 3 spans
        assert_eq!(evs.len(), 5);
        let spans: Vec<&Json> =
            evs.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")).collect();
        assert_eq!(spans.len(), 3);
        for s in &spans {
            assert!(s.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(s.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(s.get("args").is_some());
        }
        // the serving window landed on the virtual process
        let sw = spans
            .iter()
            .find(|s| s.get("name").unwrap().as_str() == Some("serving_window"))
            .unwrap();
        assert_eq!(sw.get("pid").unwrap().as_f64(), Some(1.0));
        assert_eq!(sw.path(&["args", "deadline_miss"]).unwrap().as_f64(), Some(1.0));
        // round-trips through the parser (i.e. no NaN leaked into the text)
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn disabled_tracer_exports_empty_document() {
        let j = chrome_trace(&Tracer::off());
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.iter().all(|e| e.get("ph").unwrap().as_str() == Some("M")));
    }

    #[test]
    fn prometheus_renders_types_and_nan() {
        let mut hub = MetricsHub::new();
        hub.set_counter("balancer_steps", 4.0);
        hub.set_gauge("serving_e2e_p99_us", f64::NAN);
        let text = prometheus(&hub);
        assert!(text.contains("# TYPE micromoe_balancer_steps counter\n"));
        assert!(text.contains("micromoe_balancer_steps 4\n"));
        assert!(text.contains("# TYPE micromoe_serving_e2e_p99_us gauge\n"));
        assert!(text.contains("micromoe_serving_e2e_p99_us NaN\n"));
    }
}
