//! The [`MetricsHub`]: one named-metric namespace over every stats struct.
//!
//! The stack meters itself in four unrelated structs —
//! [`crate::stats::BalancerStats`] (with its nested degradation and
//! decompose rollups), [`crate::stats::EngineStats`], and the serving
//! tier's [`crate::serving::SlaStats`]. The hub folds any subset of them
//! into one flat `name → value` namespace (Prometheus-safe snake_case
//! names), so exports ([`super::export::prometheus`]), JSON snapshots, and
//! per-step diffs all read from a single source.
//!
//! Typical per-step use: snapshot the hub, absorb the fresh stats, then
//! [`MetricsHub::diff`] against the snapshot — counters report their
//! delta, gauges their new value.

use std::collections::BTreeMap;

use crate::ser::Json;
use crate::serving::SlaStats;
use crate::stats::{BalancerStats, EngineStats, LatencyTrack};

/// Prometheus-style metric kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone cumulative count; diffs report the delta.
    Counter,
    /// Point-in-time value; diffs report the new value.
    Gauge,
}

/// Unified named-metric registry — see the module docs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsHub {
    metrics: BTreeMap<String, (MetricKind, f64)>,
}

impl MetricsHub {
    /// Empty hub.
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    /// Set (or overwrite) a counter.
    pub fn set_counter(&mut self, name: &str, value: f64) {
        self.metrics.insert(name.to_string(), (MetricKind::Counter, value));
    }

    /// Set (or overwrite) a gauge.
    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.metrics.insert(name.to_string(), (MetricKind::Gauge, value));
    }

    /// Current value of a metric.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.metrics.get(name).map(|(_, v)| *v)
    }

    /// Metrics registered.
    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    /// Whether nothing has been registered.
    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    /// Iterate `(name, kind, value)` in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, MetricKind, f64)> {
        self.metrics.iter().map(|(k, (kind, v))| (k.as_str(), *kind, *v))
    }

    /// Fold a balancer's cumulative counters (including its degradation
    /// ladder and decomposition rollups) under `balancer_*`,
    /// `degradation_*`, and `decompose_*`.
    pub fn absorb_balancer(&mut self, b: &BalancerStats) {
        self.set_counter("balancer_steps", b.steps as f64);
        self.set_counter("balancer_layers", b.layers as f64);
        self.set_counter("balancer_warm_layers", b.warm_layers as f64);
        self.set_counter("balancer_lp_pivots", b.lp_pivots as f64);
        self.set_counter("balancer_lp_dual_pivots", b.lp_dual_pivots as f64);
        self.set_counter("balancer_lp_bound_flips", b.lp_bound_flips as f64);
        self.set_counter("balancer_lp_refactors", b.lp_refactors as f64);
        self.set_counter("balancer_sched_seconds", b.sched_seconds);
        self.set_counter("balancer_prep_seconds", b.prep_seconds);
        self.set_gauge("balancer_max_gpu_load", b.max_gpu_load as f64);
        let d = &b.degradation;
        self.set_counter("degradation_warm_lp", d.warm_lp as f64);
        self.set_counter("degradation_cold_lp", d.cold_lp as f64);
        self.set_counter("degradation_greedy", d.greedy as f64);
        self.set_counter("degradation_passthrough", d.passthrough as f64);
        self.set_counter("degradation_budget_pivots", d.budget_pivots as f64);
        self.set_counter("degradation_budget_refactors", d.budget_refactors as f64);
        self.set_counter("degradation_budget_wall", d.budget_wall as f64);
        self.set_counter("degradation_fallback_excess_sum", d.fallback_excess_sum);
        self.set_gauge("degradation_lp_rate", d.lp_rate());
        let dc = &b.decompose;
        self.set_counter("decompose_solves", dc.solves as f64);
        self.set_counter("decompose_outer_iters", dc.outer_iters as f64);
        self.set_counter("decompose_subproblem_pivots", dc.subproblem_pivots as f64);
        self.set_counter("decompose_blocks_degraded", dc.blocks_degraded as f64);
        self.set_gauge("decompose_master_gap_mean", dc.mean_gap());
        self.set_gauge("decompose_master_gap_max", dc.master_gap_max);
    }

    /// Fold the pipelined engine's counters under `engine_*`.
    pub fn absorb_engine(&mut self, e: &EngineStats) {
        self.set_counter("engine_steps", e.steps as f64);
        self.set_counter("engine_schedules", e.schedules as f64);
        self.set_counter("engine_spec_issued", e.spec_issued as f64);
        self.set_counter("engine_spec_hits", e.spec_hits as f64);
        self.set_counter("engine_spec_misses", e.spec_misses as f64);
        self.set_counter("engine_hit_repair_pivots", e.hit_repair_pivots as f64);
        self.set_counter("engine_miss_solve_pivots", e.miss_solve_pivots as f64);
        self.set_counter("engine_spec_presolve_pivots", e.spec_presolve_pivots as f64);
        self.set_gauge("engine_hit_rate", e.hit_rate());
    }

    fn absorb_track(&mut self, prefix: &str, t: &LatencyTrack) {
        self.set_counter(&format!("{prefix}_count"), t.count() as f64);
        self.set_gauge(&format!("{prefix}_mean_us"), t.mean());
        self.set_gauge(&format!("{prefix}_max_us"), t.max());
        self.set_gauge(&format!("{prefix}_p50_us"), t.p2_p50());
        self.set_gauge(&format!("{prefix}_p95_us"), t.p2_p95());
        self.set_gauge(&format!("{prefix}_p99_us"), t.p2_p99());
    }

    /// Fold the serving tier's SLO accounting under `serving_*`, with the
    /// four latency tracks exposed as P² quantile gauges (summary-style:
    /// `serving_e2e_p99_us` etc.; empty tracks read `NaN`, which the JSON
    /// snapshot maps to `null`).
    pub fn absorb_sla(&mut self, s: &SlaStats) {
        self.set_counter("serving_arrived", s.arrived as f64);
        self.set_counter("serving_served", s.served as f64);
        self.set_counter("serving_shed", s.shed as f64);
        self.set_counter("serving_deadline_misses", s.deadline_misses as f64);
        self.set_counter("serving_windows", s.windows as f64);
        self.set_counter("serving_empty_windows", s.empty_windows as f64);
        self.set_gauge("serving_miss_rate", s.miss_rate());
        self.set_gauge("serving_shed_rate", s.shed_rate());
        self.absorb_track("serving_queue", &s.queue);
        self.absorb_track("serving_solve", &s.solve);
        self.absorb_track("serving_dispatch", &s.dispatch);
        self.absorb_track("serving_e2e", &s.e2e);
    }

    /// Full snapshot: one JSON object, `name → value`, non-finite values
    /// mapped to `null` (the [`Json::num`] guard).
    pub fn snapshot(&self) -> Json {
        Json::Obj(self.metrics.iter().map(|(k, (_, v))| (k.clone(), Json::num(*v))).collect())
    }

    /// What changed since `prev`: counters report `now − before`, gauges
    /// their new value; unchanged metrics (including still-NaN gauges) are
    /// omitted, and metrics absent from `prev` count from zero.
    pub fn diff(&self, prev: &MetricsHub) -> Json {
        let mut out = BTreeMap::new();
        for (name, (kind, now)) in &self.metrics {
            let before = prev.get(name).unwrap_or(0.0);
            if *now == before || (now.is_nan() && before.is_nan()) {
                continue;
            }
            let value = match kind {
                MetricKind::Counter => Json::num(now - before),
                MetricKind::Gauge => Json::num(*now),
            };
            out.insert(name.clone(), value);
        }
        Json::Obj(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{DegradationRung, StepStats};

    #[test]
    fn absorb_balancer_names_every_rollup() {
        let mut b = BalancerStats::default();
        let mut step = StepStats { layers: 2, lp_pivots: 9, max_gpu_load: 70, ..Default::default() };
        step.degradation.record(DegradationRung::Greedy, None, 0.5);
        b.absorb(&step);
        let mut hub = MetricsHub::new();
        hub.absorb_balancer(&b);
        assert_eq!(hub.get("balancer_steps"), Some(1.0));
        assert_eq!(hub.get("balancer_lp_pivots"), Some(9.0));
        assert_eq!(hub.get("degradation_greedy"), Some(1.0));
        assert_eq!(hub.get("degradation_fallback_excess_sum"), Some(0.5));
        assert_eq!(hub.get("decompose_solves"), Some(0.0));
        assert!(!hub.is_empty());
    }

    #[test]
    fn absorb_engine_and_sla() {
        let mut hub = MetricsHub::new();
        hub.absorb_engine(&EngineStats { spec_hits: 3, spec_misses: 1, ..Default::default() });
        assert_eq!(hub.get("engine_spec_hits"), Some(3.0));
        assert_eq!(hub.get("engine_hit_rate"), Some(0.75));
        let mut sla = SlaStats::default();
        sla.arrived = 2;
        sla.e2e.record(120.0);
        hub.absorb_sla(&sla);
        assert_eq!(hub.get("serving_arrived"), Some(2.0));
        assert_eq!(hub.get("serving_e2e_count"), Some(1.0));
        assert_eq!(hub.get("serving_e2e_max_us"), Some(120.0));
        // empty queue track: NaN gauge, null in the snapshot
        assert!(hub.get("serving_queue_p99_us").unwrap().is_nan());
        let snap = hub.snapshot();
        assert_eq!(snap.get("serving_queue_p99_us"), Some(&Json::Null));
        assert_eq!(snap.get("serving_e2e_count"), Some(&Json::Num(1.0)));
    }

    #[test]
    fn diff_reports_counter_deltas_and_gauge_values() {
        let mut before = MetricsHub::new();
        before.set_counter("balancer_steps", 2.0);
        before.set_gauge("balancer_max_gpu_load", 50.0);
        before.set_gauge("serving_e2e_p99_us", f64::NAN);
        let mut after = before.clone();
        after.set_counter("balancer_steps", 5.0);
        after.set_gauge("balancer_max_gpu_load", 80.0);
        after.set_counter("engine_steps", 1.0);
        let d = after.diff(&before);
        assert_eq!(d.get("balancer_steps").unwrap().as_f64(), Some(3.0));
        assert_eq!(d.get("balancer_max_gpu_load").unwrap().as_f64(), Some(80.0));
        assert_eq!(d.get("engine_steps").unwrap().as_f64(), Some(1.0));
        // still-NaN gauge is not noise
        assert!(d.get("serving_e2e_p99_us").is_none());
        // no change at all → empty diff
        assert_eq!(after.diff(&after), Json::Obj(Default::default()));
    }
}
