//! Model/experiment configuration: Table-2 presets and a small
//! `key = value` config-file parser (no serde/toml crates offline).

use std::collections::HashMap;

use crate::topology::Topology;

/// One row of Table 2 (model hyperparameters used in §7.2 / Fig. 6/10).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPreset {
    /// Preset display name (Table-2 row label).
    pub name: &'static str,
    /// Transformer layer count.
    pub layers: usize,
    /// Attention heads.
    pub heads: usize,
    /// Model (hidden) dimension.
    pub hidden: usize,
    /// Expert FFN inner dimension.
    pub ffn_hidden: usize,
    /// Sequence length in tokens.
    pub seq: usize,
    /// Experts per MoE layer.
    pub experts: usize,
    /// Gate top-K.
    pub topk: usize,
    /// Sequences per micro-batch.
    pub micro_batch: usize,
    /// Sequences per global batch.
    pub global_batch: usize,
    /// Total GPUs for this preset.
    pub num_gpus: usize,
    /// Pipeline-parallel degree.
    pub pp_degree: usize,
    /// Expert-parallel degree.
    pub ep_degree: usize,
}

impl ModelPreset {
    /// Tokens per GPU per micro-batch (gate inputs).
    pub fn tokens_per_gpu(&self) -> u64 {
        (self.micro_batch * self.seq) as u64
    }

    /// Gate assignments per GPU per micro-batch (top-K expanded).
    pub fn assignments_per_gpu(&self) -> u64 {
        self.tokens_per_gpu() * self.topk as u64
    }

    /// DP degree on this preset's GPU count.
    pub fn dp_degree(&self) -> usize {
        self.num_gpus / self.pp_degree
    }

    /// Number of micro-batches per iteration (per DP group).
    pub fn num_microbatches(&self) -> usize {
        self.global_batch / (self.micro_batch * self.dp_degree())
    }

    /// Paper-§7.1 topology: DP = 8, EP = 4, d = 2, 8 GPUs/node.
    pub fn topology(&self) -> Topology {
        Topology::new(self.dp_degree(), self.ep_degree, 2, 8)
    }

    /// Per-expert parameter count (two FFN matrices).
    pub fn expert_params(&self) -> u64 {
        2 * self.hidden as u64 * self.ffn_hidden as u64
    }
}

/// The five Table-2 models.
pub fn table2() -> Vec<ModelPreset> {
    vec![
        ModelPreset {
            name: "GPT 32x1.3B",
            layers: 24, heads: 16, hidden: 2048, ffn_hidden: 8192, seq: 2048,
            experts: 32, topk: 2, micro_batch: 4, global_batch: 512,
            num_gpus: 16, pp_degree: 2, ep_degree: 4,
        },
        ModelPreset {
            name: "GPT 16x3.2B",
            layers: 16, heads: 32, hidden: 4096, ffn_hidden: 16384, seq: 2048,
            experts: 16, topk: 2, micro_batch: 2, global_batch: 512,
            num_gpus: 16, pp_degree: 2, ep_degree: 4,
        },
        ModelPreset {
            name: "GPT 8x6.7B",
            layers: 32, heads: 32, hidden: 4096, ffn_hidden: 16384, seq: 2048,
            experts: 8, topk: 2, micro_batch: 2, global_batch: 512,
            num_gpus: 32, pp_degree: 4, ep_degree: 4,
        },
        ModelPreset {
            name: "Mixtral 16x2B",
            layers: 32, heads: 32, hidden: 2048, ffn_hidden: 8192, seq: 4096,
            experts: 16, topk: 2, micro_batch: 2, global_batch: 256,
            num_gpus: 16, pp_degree: 2, ep_degree: 4,
        },
        ModelPreset {
            name: "Mixtral 8x7B",
            layers: 32, heads: 32, hidden: 4096, ffn_hidden: 14336, seq: 4096,
            experts: 8, topk: 2, micro_batch: 1, global_batch: 256,
            num_gpus: 32, pp_degree: 4, ep_degree: 4,
        },
    ]
}

/// Look up a Table-2 preset by (case-insensitive) name.
pub fn preset(name: &str) -> Option<ModelPreset> {
    table2().into_iter().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// Minimal `key = value` config file: `#` comments, blank lines, string /
/// number / bool values. Flat namespace (sections become `section.key`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigFile {
    values: HashMap<String, String>,
}

impl ConfigFile {
    /// Parse config text; `Err` carries the offending line number.
    pub fn parse(text: &str) -> Result<ConfigFile, String> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected key = value", lineno + 1));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(ConfigFile { values })
    }

    /// Read and parse a config file from disk.
    pub fn load(path: &std::path::Path) -> Result<ConfigFile, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        ConfigFile::parse(&text)
    }

    /// String value at `key` (`section.key` for sectioned files).
    pub fn str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// `key` parsed as f64.
    pub fn f64(&self, key: &str) -> Option<f64> {
        self.str(key)?.parse().ok()
    }

    /// `key` parsed as usize.
    pub fn usize(&self, key: &str) -> Option<usize> {
        self.str(key)?.parse().ok()
    }

    /// `key` parsed as bool (`true/1/yes` vs `false/0/no`).
    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.str(key)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let t = table2();
        assert_eq!(t.len(), 5);
        let gpt13 = &t[0];
        assert_eq!(gpt13.experts, 32);
        assert_eq!(gpt13.hidden, 2048);
        assert_eq!(gpt13.dp_degree(), 8);
        assert_eq!(gpt13.num_microbatches(), 16); // 512 / (4·8)
        let mix7 = &t[4];
        assert_eq!(mix7.ffn_hidden, 14336);
        assert_eq!(mix7.dp_degree(), 8);
    }

    #[test]
    fn topology_matches_section71() {
        for p in table2() {
            let topo = p.topology();
            assert_eq!(topo.dp_degree, 8);
            assert_eq!(topo.ep_degree, 4);
            assert_eq!(topo.num_ep_groups(), 2);
            assert_eq!(topo.num_microep_groups(), 1); // d = 2
        }
    }

    #[test]
    fn preset_lookup_case_insensitive() {
        assert!(preset("gpt 32x1.3b").is_some());
        assert!(preset("nope").is_none());
    }

    #[test]
    fn config_file_parsing() {
        let cfg = ConfigFile::parse(
            "# comment\nseed = 42\n[sim]\nskew = 1.5  # inline\nname = \"fig7\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(cfg.usize("seed"), Some(42));
        assert_eq!(cfg.f64("sim.skew"), Some(1.5));
        assert_eq!(cfg.str("sim.name"), Some("fig7"));
        assert_eq!(cfg.bool("sim.flag"), Some(true));
        assert_eq!(cfg.str("missing"), None);
    }

    #[test]
    fn config_rejects_garbage() {
        assert!(ConfigFile::parse("not a kv line").is_err());
    }
}
