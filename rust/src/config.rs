//! Model/experiment configuration: Table-2 presets, a small `key = value`
//! config-file parser (no serde/toml crates offline), and the
//! JSON-loadable [`PolicySpec`] the [`crate::balancer::MoeSession`]
//! registry resolves — benches and the CLI select policies by name string.

use std::collections::{BTreeMap, HashMap};

use crate::control::ControlSpec;
use crate::engine::{EngineMode, ForecastConfig};
use crate::lp::{FactorKind, Pricing, SolveBudget, SolverKind};
use crate::scheduler::{ScheduleMode, SchedulerOptions};
use crate::ser::Json;
use crate::topology::Topology;

/// One row of Table 2 (model hyperparameters used in §7.2 / Fig. 6/10).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelPreset {
    /// Preset display name (Table-2 row label).
    pub name: &'static str,
    /// Transformer layer count.
    pub layers: usize,
    /// Attention heads.
    pub heads: usize,
    /// Model (hidden) dimension.
    pub hidden: usize,
    /// Expert FFN inner dimension.
    pub ffn_hidden: usize,
    /// Sequence length in tokens.
    pub seq: usize,
    /// Experts per MoE layer.
    pub experts: usize,
    /// Gate top-K.
    pub topk: usize,
    /// Sequences per micro-batch.
    pub micro_batch: usize,
    /// Sequences per global batch.
    pub global_batch: usize,
    /// Total GPUs for this preset.
    pub num_gpus: usize,
    /// Pipeline-parallel degree.
    pub pp_degree: usize,
    /// Expert-parallel degree.
    pub ep_degree: usize,
}

impl ModelPreset {
    /// Tokens per GPU per micro-batch (gate inputs).
    pub fn tokens_per_gpu(&self) -> u64 {
        (self.micro_batch * self.seq) as u64
    }

    /// Gate assignments per GPU per micro-batch (top-K expanded).
    pub fn assignments_per_gpu(&self) -> u64 {
        self.tokens_per_gpu() * self.topk as u64
    }

    /// DP degree on this preset's GPU count.
    pub fn dp_degree(&self) -> usize {
        self.num_gpus / self.pp_degree
    }

    /// Number of micro-batches per iteration (per DP group).
    pub fn num_microbatches(&self) -> usize {
        self.global_batch / (self.micro_batch * self.dp_degree())
    }

    /// Paper-§7.1 topology: DP = 8, EP = 4, d = 2, 8 GPUs/node.
    pub fn topology(&self) -> Topology {
        Topology::new(self.dp_degree(), self.ep_degree, 2, 8)
    }

    /// Per-expert parameter count (two FFN matrices).
    pub fn expert_params(&self) -> u64 {
        2 * self.hidden as u64 * self.ffn_hidden as u64
    }
}

/// The five Table-2 models.
pub fn table2() -> Vec<ModelPreset> {
    vec![
        ModelPreset {
            name: "GPT 32x1.3B",
            layers: 24, heads: 16, hidden: 2048, ffn_hidden: 8192, seq: 2048,
            experts: 32, topk: 2, micro_batch: 4, global_batch: 512,
            num_gpus: 16, pp_degree: 2, ep_degree: 4,
        },
        ModelPreset {
            name: "GPT 16x3.2B",
            layers: 16, heads: 32, hidden: 4096, ffn_hidden: 16384, seq: 2048,
            experts: 16, topk: 2, micro_batch: 2, global_batch: 512,
            num_gpus: 16, pp_degree: 2, ep_degree: 4,
        },
        ModelPreset {
            name: "GPT 8x6.7B",
            layers: 32, heads: 32, hidden: 4096, ffn_hidden: 16384, seq: 2048,
            experts: 8, topk: 2, micro_batch: 2, global_batch: 512,
            num_gpus: 32, pp_degree: 4, ep_degree: 4,
        },
        ModelPreset {
            name: "Mixtral 16x2B",
            layers: 32, heads: 32, hidden: 2048, ffn_hidden: 8192, seq: 4096,
            experts: 16, topk: 2, micro_batch: 2, global_batch: 256,
            num_gpus: 16, pp_degree: 2, ep_degree: 4,
        },
        ModelPreset {
            name: "Mixtral 8x7B",
            layers: 32, heads: 32, hidden: 4096, ffn_hidden: 14336, seq: 4096,
            experts: 8, topk: 2, micro_batch: 1, global_batch: 256,
            num_gpus: 32, pp_degree: 4, ep_degree: 4,
        },
    ]
}

/// Look up a Table-2 preset by (case-insensitive) name.
pub fn preset(name: &str) -> Option<ModelPreset> {
    table2().into_iter().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// Which load-balancing policy a [`crate::balancer::MoeSession`] runs,
/// selected by registry name
/// ([`crate::balancer::registered_policies`]) with its knobs — the
/// JSON-round-trippable unit benches and the CLI configure policies with.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicySpec {
    /// Registry name (`"micromoe"`, `"micromoe-ar"`, `"vanilla-ep"`,
    /// `"deepspeed-pad"`, `"smartmoe"`, `"flexmoe"`,
    /// `"least-loaded-inference"`).
    pub name: String,
    /// Scheduler options (mode, warm start, solver, engine) — consumed by
    /// the LP-backed policies.
    pub options: SchedulerOptions,
    /// RNG seed for stochastic policies (FlexMoE placement, AR search).
    pub seed: u64,
    /// Re-plan cadence in micro-batches for the periodic policies
    /// (SmartMoE / FlexMoE / adaptive replacement); `None` = policy default.
    pub replan_every: Option<usize>,
    /// Slow-loop placement controller ([`crate::control`]); only the
    /// `"micromoe"` policy on the barrier engine accepts one.
    pub control: Option<ControlSpec>,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec {
            name: "micromoe".to_string(),
            options: SchedulerOptions::default(),
            seed: 0,
            replan_every: None,
            control: None,
        }
    }
}

impl PolicySpec {
    /// Serialize to the JSON object [`PolicySpec::from_json`] accepts.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("policy", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("options", scheduler_options_to_json(&self.options)),
        ];
        if let Some(every) = self.replan_every {
            pairs.push(("replan_every", Json::Num(every as f64)));
        }
        if let Some(c) = &self.control {
            pairs.push(("control", control_spec_to_json(c)));
        }
        Json::obj(pairs)
    }

    /// Parse from JSON, rejecting unknown fields. Only `"policy"` is
    /// required; everything else defaults.
    pub fn from_json(j: &Json) -> Result<PolicySpec, String> {
        let m = as_obj(j, "policy spec")?;
        for key in m.keys() {
            if !matches!(key.as_str(), "policy" | "seed" | "replan_every" | "options" | "control")
            {
                return Err(format!("policy spec: unknown field '{key}'"));
            }
        }
        let name = m
            .get("policy")
            .ok_or("policy spec: missing 'policy'")?
            .as_str()
            .ok_or("policy spec: 'policy' must be a string")?
            .to_string();
        let seed = match m.get("seed") {
            Some(v) => uint_field(v, "seed")?,
            None => 0,
        };
        let replan_every = match m.get("replan_every") {
            Some(v) => Some(uint_field(v, "replan_every")? as usize),
            None => None,
        };
        let options = match m.get("options") {
            Some(v) => scheduler_options_from_json(v)?,
            None => SchedulerOptions::default(),
        };
        let control = match m.get("control") {
            Some(v) => Some(control_spec_from_json(v)?),
            None => None,
        };
        Ok(PolicySpec { name, options, seed, replan_every, control })
    }

    /// Parse a complete JSON document ([`PolicySpec::from_json`]).
    pub fn parse(text: &str) -> Result<PolicySpec, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        PolicySpec::from_json(&j)
    }
}

fn as_obj<'a>(j: &'a Json, what: &str) -> Result<&'a BTreeMap<String, Json>, String> {
    match j {
        Json::Obj(m) => Ok(m),
        _ => Err(format!("{what}: expected a JSON object")),
    }
}

fn get_bool(m: &BTreeMap<String, Json>, key: &str, default: bool) -> Result<bool, String> {
    match m.get(key) {
        Some(v) => v.as_bool().ok_or_else(|| format!("'{key}' must be a bool")),
        None => Ok(default),
    }
}

/// Strict non-negative integer: fractions and negatives are rejected, not
/// silently truncated, and values past 2^53 are rejected because the JSON
/// substrate carries numbers as f64 (they would round-trip corrupted).
fn uint_field(v: &Json, key: &str) -> Result<u64, String> {
    let x = v.as_f64().ok_or_else(|| format!("'{key}' must be a number"))?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(format!("'{key}' must be a non-negative integer, got {x}"));
    }
    if x > (1u64 << 53) as f64 {
        return Err(format!("'{key}' exceeds 2^53 and cannot round-trip through JSON"));
    }
    Ok(x as u64)
}

fn get_usize(m: &BTreeMap<String, Json>, key: &str, default: usize) -> Result<usize, String> {
    match m.get(key) {
        Some(v) => uint_field(v, key).map(|x| x as usize),
        None => Ok(default),
    }
}

fn opt_usize(m: &BTreeMap<String, Json>, key: &str) -> Result<Option<usize>, String> {
    match m.get(key) {
        Some(v) => uint_field(v, key).map(|x| Some(x as usize)),
        None => Ok(None),
    }
}

fn req_f64(m: &BTreeMap<String, Json>, key: &str) -> Result<f64, String> {
    m.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing or non-numeric '{key}'"))
}

fn get_f64(m: &BTreeMap<String, Json>, key: &str, default: f64) -> Result<f64, String> {
    match m.get(key) {
        Some(v) => v.as_f64().ok_or_else(|| format!("'{key}' must be a number")),
        None => Ok(default),
    }
}

/// Serialize [`SchedulerOptions`] to the JSON object
/// [`scheduler_options_from_json`] accepts. Mode-, solver-, and
/// engine-dependent fields are emitted only when applicable, mirroring the
/// parser's rejection of inapplicable fields.
pub fn scheduler_options_to_json(o: &SchedulerOptions) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    match o.mode {
        ScheduleMode::Compute => pairs.push(("mode", Json::Str("compute".into()))),
        ScheduleMode::CommAware { alpha } => {
            pairs.push(("mode", Json::Str("comm-aware".into())));
            pairs.push(("alpha", Json::Num(alpha)));
        }
        ScheduleMode::TopoAware { alpha1, alpha2 } => {
            pairs.push(("mode", Json::Str("topo-aware".into())));
            pairs.push(("alpha1", Json::Num(alpha1)));
            pairs.push(("alpha2", Json::Num(alpha2)));
        }
        ScheduleMode::Decomposed { nodes_per_block, max_outer_iters, tol } => {
            pairs.push(("mode", Json::Str("decomposed".into())));
            pairs.push(("nodes_per_block", Json::Num(nodes_per_block as f64)));
            pairs.push(("max_outer_iters", Json::Num(max_outer_iters as f64)));
            pairs.push(("tol", Json::Num(tol)));
        }
    }
    pairs.push(("warm_start", Json::Bool(o.warm_start)));
    pairs.push(("locality_aware", Json::Bool(o.locality_aware)));
    pairs.push(("topo_aware_routing", Json::Bool(o.topo_aware_routing)));
    match o.solver {
        SolverKind::Revised { pricing, factor } => {
            pairs.push(("solver", Json::Str("revised".into())));
            pairs.push((
                "pricing",
                Json::Str(match pricing {
                    Pricing::Dantzig => "dantzig".into(),
                    Pricing::Devex => "devex".into(),
                }),
            ));
            pairs.push((
                "factor",
                Json::Str(match factor {
                    FactorKind::Auto => "auto".into(),
                    FactorKind::DenseInverse => "dense-inverse".into(),
                    FactorKind::SparseLu => "sparse-lu".into(),
                }),
            ));
        }
        SolverKind::DenseTableau => pairs.push(("solver", Json::Str("dense-tableau".into()))),
    }
    match o.engine {
        EngineMode::Barrier => pairs.push(("engine", Json::Str("barrier".into()))),
        EngineMode::Pipeline { workers, inflight } => {
            pairs.push(("engine", Json::Str("pipeline".into())));
            pairs.push(("workers", Json::Num(workers as f64)));
            pairs.push(("inflight", Json::Num(inflight as f64)));
        }
        EngineMode::Speculative { workers, inflight, forecast } => {
            pairs.push(("engine", Json::Str("speculative".into())));
            pairs.push(("workers", Json::Num(workers as f64)));
            pairs.push(("inflight", Json::Num(inflight as f64)));
            pairs.push((
                "forecast",
                Json::obj(vec![
                    ("ema_alpha", Json::Num(forecast.ema_alpha)),
                    ("window", Json::Num(forecast.window as f64)),
                    ("blend", Json::Num(forecast.blend)),
                    ("drift_threshold", Json::Num(forecast.drift_threshold)),
                    ("min_history", Json::Num(forecast.min_history as f64)),
                ]),
            ));
        }
    }
    // budget caps: emitted only when set, so an unlimited (default) budget
    // round-trips as absence. Fault plans are deliberately *not*
    // serializable — chaos harnesses are built in code, never from config.
    if let Some(p) = o.budget.max_pivots {
        pairs.push(("budget_max_pivots", Json::Num(p as f64)));
    }
    if let Some(r) = o.budget.max_refactors {
        pairs.push(("budget_max_refactors", Json::Num(r as f64)));
    }
    if let Some(w) = o.budget.max_wall {
        pairs.push(("budget_max_wall_us", Json::Num(w.as_micros() as f64)));
    }
    Json::obj(pairs)
}

/// Serialize a [`ControlSpec`] to the JSON object
/// [`control_spec_from_json`] accepts. Every knob is emitted (the spec has
/// no mode-dependent fields), so a round-trip compares exactly.
pub fn control_spec_to_json(c: &ControlSpec) -> Json {
    Json::obj(vec![
        ("interval", Json::Num(c.interval as f64)),
        ("ema_alpha", Json::Num(c.ema_alpha)),
        ("hot_enter", Json::Num(c.hot_enter)),
        ("hot_exit", Json::Num(c.hot_exit)),
        ("cold_enter", Json::Num(c.cold_enter)),
        ("cold_exit", Json::Num(c.cold_exit)),
        ("dwell", Json::Num(c.dwell as f64)),
        ("budget_seconds", Json::Num(c.budget_seconds)),
        ("max_moves", Json::Num(c.max_moves as f64)),
        ("min_gain", Json::Num(c.min_gain)),
        ("bytes_per_expert", Json::Num(c.bytes_per_expert as f64)),
        ("slot_headroom", Json::Num(c.slot_headroom as f64)),
    ])
}

/// Parse a [`ControlSpec`] from JSON: unknown fields are rejected, absent
/// fields take the [`ControlSpec::default`] values, and the result must
/// pass [`ControlSpec::validate`] (threshold ordering, positive periods).
pub fn control_spec_from_json(j: &Json) -> Result<ControlSpec, String> {
    let m = as_obj(j, "control")?;
    for key in m.keys() {
        if !matches!(
            key.as_str(),
            "interval"
                | "ema_alpha"
                | "hot_enter"
                | "hot_exit"
                | "cold_enter"
                | "cold_exit"
                | "dwell"
                | "budget_seconds"
                | "max_moves"
                | "min_gain"
                | "bytes_per_expert"
                | "slot_headroom"
        ) {
            return Err(format!("control: unknown field '{key}'"));
        }
    }
    let d = ControlSpec::default();
    let spec = ControlSpec {
        interval: get_usize(m, "interval", d.interval)?,
        ema_alpha: get_f64(m, "ema_alpha", d.ema_alpha)?,
        hot_enter: get_f64(m, "hot_enter", d.hot_enter)?,
        hot_exit: get_f64(m, "hot_exit", d.hot_exit)?,
        cold_enter: get_f64(m, "cold_enter", d.cold_enter)?,
        cold_exit: get_f64(m, "cold_exit", d.cold_exit)?,
        dwell: get_usize(m, "dwell", d.dwell)?,
        budget_seconds: get_f64(m, "budget_seconds", d.budget_seconds)?,
        max_moves: get_usize(m, "max_moves", d.max_moves)?,
        min_gain: get_f64(m, "min_gain", d.min_gain)?,
        bytes_per_expert: match m.get("bytes_per_expert") {
            Some(v) => uint_field(v, "bytes_per_expert")?,
            None => d.bytes_per_expert,
        },
        slot_headroom: get_usize(m, "slot_headroom", d.slot_headroom)?,
    };
    spec.validate().map_err(|e| format!("control: {e}"))?;
    Ok(spec)
}

fn forecast_from_json(j: &Json) -> Result<ForecastConfig, String> {
    let m = as_obj(j, "forecast")?;
    for key in m.keys() {
        if !matches!(
            key.as_str(),
            "ema_alpha" | "window" | "blend" | "drift_threshold" | "min_history"
        ) {
            return Err(format!("forecast: unknown field '{key}'"));
        }
    }
    let d = ForecastConfig::default();
    Ok(ForecastConfig {
        ema_alpha: get_f64(m, "ema_alpha", d.ema_alpha)?,
        window: get_usize(m, "window", d.window)?,
        blend: get_f64(m, "blend", d.blend)?,
        drift_threshold: get_f64(m, "drift_threshold", d.drift_threshold)?,
        min_history: get_usize(m, "min_history", d.min_history)?,
    })
}

/// Parse [`SchedulerOptions`] from JSON. Unknown fields are rejected, and
/// so are fields inapplicable to the selected mode/solver/engine (e.g.
/// `alpha` with `"mode": "compute"`, `pricing` with the dense tableau) —
/// nothing silently fails to round-trip.
pub fn scheduler_options_from_json(j: &Json) -> Result<SchedulerOptions, String> {
    let m = as_obj(j, "options")?;
    let mode_name = match m.get("mode") {
        Some(v) => v.as_str().ok_or("options: 'mode' must be a string")?,
        None => "compute",
    };
    let solver_name = match m.get("solver") {
        Some(v) => v.as_str().ok_or("options: 'solver' must be a string")?,
        None => "revised",
    };
    let engine_name = match m.get("engine") {
        Some(v) => v.as_str().ok_or("options: 'engine' must be a string")?,
        None => "barrier",
    };

    let mut allowed: Vec<&str> = vec![
        "mode",
        "warm_start",
        "locality_aware",
        "topo_aware_routing",
        "solver",
        "engine",
        "budget_max_pivots",
        "budget_max_refactors",
        "budget_max_wall_us",
    ];
    match mode_name {
        "comm-aware" => allowed.push("alpha"),
        "topo-aware" => allowed.extend(["alpha1", "alpha2"]),
        "decomposed" => allowed.extend(["nodes_per_block", "max_outer_iters", "tol"]),
        _ => {}
    }
    if solver_name == "revised" {
        allowed.extend(["pricing", "factor"]);
    }
    match engine_name {
        "pipeline" => allowed.extend(["workers", "inflight"]),
        "speculative" => allowed.extend(["workers", "inflight", "forecast"]),
        _ => {}
    }
    for key in m.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(format!(
                "options: unknown or inapplicable field '{key}' (mode {mode_name}, \
                 solver {solver_name}, engine {engine_name})"
            ));
        }
    }

    let mode = match mode_name {
        "compute" => ScheduleMode::Compute,
        "comm-aware" => ScheduleMode::CommAware { alpha: req_f64(m, "alpha")? },
        "topo-aware" => {
            ScheduleMode::TopoAware { alpha1: req_f64(m, "alpha1")?, alpha2: req_f64(m, "alpha2")? }
        }
        "decomposed" => ScheduleMode::Decomposed {
            nodes_per_block: get_usize(m, "nodes_per_block", 1)?,
            max_outer_iters: get_usize(m, "max_outer_iters", 4)?,
            tol: get_f64(m, "tol", 1e-2)?,
        },
        other => return Err(format!("options: unknown mode '{other}'")),
    };
    let solver = match solver_name {
        "revised" => {
            let pricing = match m.get("pricing").map(|v| v.as_str()) {
                None => Pricing::default(),
                Some(Some("devex")) => Pricing::Devex,
                Some(Some("dantzig")) => Pricing::Dantzig,
                Some(other) => return Err(format!("options: bad pricing {other:?}")),
            };
            let factor = match m.get("factor").map(|v| v.as_str()) {
                None => FactorKind::default(),
                Some(Some("auto")) => FactorKind::Auto,
                Some(Some("dense-inverse")) => FactorKind::DenseInverse,
                Some(Some("sparse-lu")) => FactorKind::SparseLu,
                Some(other) => return Err(format!("options: bad factor {other:?}")),
            };
            SolverKind::Revised { pricing, factor }
        }
        "dense-tableau" => SolverKind::DenseTableau,
        other => return Err(format!("options: unknown solver '{other}'")),
    };
    let engine = match engine_name {
        "barrier" => EngineMode::Barrier,
        "pipeline" => EngineMode::Pipeline {
            workers: get_usize(m, "workers", 0)?,
            inflight: get_usize(m, "inflight", 0)?,
        },
        "speculative" => EngineMode::Speculative {
            workers: get_usize(m, "workers", 0)?,
            inflight: get_usize(m, "inflight", 0)?,
            forecast: match m.get("forecast") {
                Some(v) => forecast_from_json(v)?,
                None => ForecastConfig::default(),
            },
        },
        other => return Err(format!("options: unknown engine '{other}'")),
    };
    let budget = SolveBudget {
        max_pivots: opt_usize(m, "budget_max_pivots")?,
        max_refactors: opt_usize(m, "budget_max_refactors")?,
        max_wall: opt_usize(m, "budget_max_wall_us")?
            .map(|us| std::time::Duration::from_micros(us as u64)),
    };
    Ok(SchedulerOptions {
        mode,
        warm_start: get_bool(m, "warm_start", true)?,
        locality_aware: get_bool(m, "locality_aware", true)?,
        topo_aware_routing: get_bool(m, "topo_aware_routing", false)?,
        solver,
        engine,
        budget,
        // fault plans are code-only (chaos tests); config never carries one
        faults: None,
        // tracers are handles, not data — wired in code via
        // MoeSessionBuilder::trace, never through config
        trace: crate::obs::Tracer::default(),
    })
}

/// Minimal `key = value` config file: `#` comments, blank lines, string /
/// number / bool values. Flat namespace (sections become `section.key`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigFile {
    values: HashMap<String, String>,
}

impl ConfigFile {
    /// Parse config text; `Err` carries the offending line number.
    pub fn parse(text: &str) -> Result<ConfigFile, String> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected key = value", lineno + 1));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(ConfigFile { values })
    }

    /// Read and parse a config file from disk.
    pub fn load(path: &std::path::Path) -> Result<ConfigFile, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        ConfigFile::parse(&text)
    }

    /// String value at `key` (`section.key` for sectioned files).
    pub fn str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// `key` parsed as f64.
    pub fn f64(&self, key: &str) -> Option<f64> {
        self.str(key)?.parse().ok()
    }

    /// `key` parsed as usize.
    pub fn usize(&self, key: &str) -> Option<usize> {
        self.str(key)?.parse().ok()
    }

    /// `key` parsed as bool (`true/1/yes` vs `false/0/no`).
    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.str(key)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let t = table2();
        assert_eq!(t.len(), 5);
        let gpt13 = &t[0];
        assert_eq!(gpt13.experts, 32);
        assert_eq!(gpt13.hidden, 2048);
        assert_eq!(gpt13.dp_degree(), 8);
        assert_eq!(gpt13.num_microbatches(), 16); // 512 / (4·8)
        let mix7 = &t[4];
        assert_eq!(mix7.ffn_hidden, 14336);
        assert_eq!(mix7.dp_degree(), 8);
    }

    #[test]
    fn topology_matches_section71() {
        for p in table2() {
            let topo = p.topology();
            assert_eq!(topo.dp_degree, 8);
            assert_eq!(topo.ep_degree, 4);
            assert_eq!(topo.num_ep_groups(), 2);
            assert_eq!(topo.num_microep_groups(), 1); // d = 2
        }
    }

    #[test]
    fn preset_lookup_case_insensitive() {
        assert!(preset("gpt 32x1.3b").is_some());
        assert!(preset("nope").is_none());
    }

    #[test]
    fn config_file_parsing() {
        let cfg = ConfigFile::parse(
            "# comment\nseed = 42\n[sim]\nskew = 1.5  # inline\nname = \"fig7\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(cfg.usize("seed"), Some(42));
        assert_eq!(cfg.f64("sim.skew"), Some(1.5));
        assert_eq!(cfg.str("sim.name"), Some("fig7"));
        assert_eq!(cfg.bool("sim.flag"), Some(true));
        assert_eq!(cfg.str("missing"), None);
    }

    #[test]
    fn config_rejects_garbage() {
        assert!(ConfigFile::parse("not a kv line").is_err());
    }

    fn roundtrip_opts(o: &SchedulerOptions) -> SchedulerOptions {
        let j = scheduler_options_to_json(o);
        // through text too, so formatting quirks can't hide
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        scheduler_options_from_json(&j2).unwrap()
    }

    #[test]
    fn scheduler_options_default_roundtrip() {
        let o = SchedulerOptions::default();
        assert_eq!(roundtrip_opts(&o), o);
        // and an empty object parses to the default (default-equivalence)
        let from_empty = scheduler_options_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(from_empty, o);
    }

    #[test]
    fn scheduler_options_every_variant_roundtrips() {
        let variants = vec![
            SchedulerOptions {
                mode: ScheduleMode::CommAware { alpha: 0.25 },
                warm_start: false,
                ..Default::default()
            },
            SchedulerOptions {
                mode: ScheduleMode::TopoAware { alpha1: 0.1, alpha2: 1.5 },
                topo_aware_routing: true,
                locality_aware: false,
                ..Default::default()
            },
            SchedulerOptions {
                mode: ScheduleMode::Decomposed {
                    nodes_per_block: 2,
                    max_outer_iters: 6,
                    tol: 1e-3,
                },
                ..Default::default()
            },
            SchedulerOptions { solver: SolverKind::DenseTableau, ..Default::default() },
            SchedulerOptions {
                solver: SolverKind::Revised {
                    pricing: Pricing::Dantzig,
                    factor: FactorKind::SparseLu,
                },
                ..Default::default()
            },
            SchedulerOptions {
                engine: EngineMode::Pipeline { workers: 4, inflight: 3 },
                ..Default::default()
            },
            SchedulerOptions {
                budget: SolveBudget {
                    max_pivots: Some(5000),
                    max_refactors: None,
                    max_wall: Some(std::time::Duration::from_micros(1500)),
                },
                ..Default::default()
            },
            SchedulerOptions {
                engine: EngineMode::Speculative {
                    workers: 2,
                    inflight: 0,
                    forecast: ForecastConfig {
                        ema_alpha: 0.125,
                        window: 6,
                        blend: 0.75,
                        drift_threshold: 0.375,
                        min_history: 3,
                    },
                },
                ..Default::default()
            },
        ];
        for o in variants {
            assert_eq!(roundtrip_opts(&o), o, "{o:?}");
        }
    }

    #[test]
    fn scheduler_options_reject_unknown_and_inapplicable_fields() {
        for bad in [
            r#"{"bogus": 1}"#,
            // alpha only exists in comm-aware mode
            r#"{"mode": "compute", "alpha": 0.5}"#,
            // block sizing only exists in decomposed mode
            r#"{"mode": "compute", "nodes_per_block": 2}"#,
            r#"{"mode": "topo-aware", "alpha1": 0.1, "alpha2": 1.0, "tol": 0.01}"#,
            // pricing only exists on the revised solver
            r#"{"solver": "dense-tableau", "pricing": "devex"}"#,
            // workers only exist on the engine modes
            r#"{"engine": "barrier", "workers": 4}"#,
            // forecast only exists in speculative mode
            r#"{"engine": "pipeline", "forecast": {}}"#,
            r#"{"engine": "speculative", "forecast": {"bogus": 1}}"#,
            r#"{"mode": "warp"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(scheduler_options_from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn policy_spec_roundtrips() {
        let specs = vec![
            PolicySpec::default(),
            PolicySpec {
                name: "flexmoe".into(),
                seed: 7,
                replan_every: Some(4),
                ..Default::default()
            },
            PolicySpec {
                name: "micromoe".into(),
                options: SchedulerOptions {
                    engine: EngineMode::speculative(),
                    ..Default::default()
                },
                ..Default::default()
            },
            PolicySpec {
                name: "micromoe".into(),
                control: Some(ControlSpec::default()),
                ..Default::default()
            },
            PolicySpec {
                name: "micromoe".into(),
                seed: 11,
                control: Some(ControlSpec {
                    interval: 32,
                    ema_alpha: 0.5,
                    hot_enter: 3.0,
                    hot_exit: 2.0,
                    cold_enter: 0.25,
                    cold_exit: 0.5,
                    dwell: 2,
                    budget_seconds: 0.25,
                    max_moves: 4,
                    min_gain: 0.05,
                    bytes_per_expert: 1 << 24,
                    slot_headroom: 2,
                }),
                ..Default::default()
            },
        ];
        for spec in specs {
            let parsed = PolicySpec::parse(&spec.to_json().to_string_pretty()).unwrap();
            assert_eq!(parsed, spec, "{spec:?}");
        }
    }

    #[test]
    fn integer_fields_reject_fractions_and_negatives() {
        for bad in [
            r#"{"policy": "flexmoe", "replan_every": 0.5}"#,
            r#"{"policy": "flexmoe", "seed": -1}"#,
            r#"{"policy": "micromoe", "options": {"engine": "pipeline", "workers": 1.5}}"#,
            r#"{"policy": "micromoe", "options": {"engine": "pipeline", "workers": -2}}"#,
            // past 2^53 an f64-carried integer silently loses precision
            r#"{"policy": "flexmoe", "seed": 11400714819323198485}"#,
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn control_spec_rejects_unknown_fields_and_invalid_bands() {
        for bad in [
            r#"{"policy": "micromoe", "control": {"bogus": 1}}"#,
            // inverted hysteresis band fails ControlSpec::validate
            r#"{"policy": "micromoe", "control": {"hot_enter": 1.0, "hot_exit": 1.5}}"#,
            r#"{"policy": "micromoe", "control": {"interval": 0}}"#,
            r#"{"policy": "micromoe", "control": {"dwell": 0.5}}"#,
            r#"{"policy": "micromoe", "control": {"bytes_per_expert": -4}}"#,
            r#"{"policy": "micromoe", "control": 7}"#,
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "accepted: {bad}");
        }
        // absent fields default: an empty control object is the default spec
        let spec = PolicySpec::parse(r#"{"policy": "micromoe", "control": {}}"#).unwrap();
        assert_eq!(spec.control, Some(ControlSpec::default()));
    }

    #[test]
    fn policy_spec_rejects_unknown_fields_and_requires_name() {
        assert!(PolicySpec::parse(r#"{"policy": "micromoe", "bogus": 1}"#).is_err());
        assert!(PolicySpec::parse(r#"{"seed": 3}"#).is_err());
        // name alone is enough; everything else defaults
        let spec = PolicySpec::parse(r#"{"policy": "vanilla-ep"}"#).unwrap();
        assert_eq!(spec.name, "vanilla-ep");
        assert_eq!(spec.options, SchedulerOptions::default());
        assert_eq!(spec.replan_every, None);
    }
}
