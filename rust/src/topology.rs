//! Parallelism topology algebra: DP / EP / PP / EDP / MicroEP groups.
//!
//! Rank conventions follow Megatron-LM's order (§2.2): within one PP stage,
//! GPUs are numbered `0..dp_degree`; the DP group is partitioned into
//! `dp_degree / ep_degree` EP groups of consecutive ranks; EDP groups link
//! the same EP rank across EP groups. MicroEP merges `d` consecutive EP
//! groups into one scheduling domain (§4).

/// Static description of one PP stage's GPU pool and its grouping.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// Number of GPUs in the DP group (== DP degree).
    pub dp_degree: usize,
    /// Experts-per-group parallelism degree; divides `dp_degree`.
    pub ep_degree: usize,
    /// MicroEP merge factor `d`, with `1 < d <= dp_degree / ep_degree`
    /// (d == 1 degenerates to vanilla EP).
    pub d: usize,
    /// GPUs per node (NVLink island size) for topology-aware scheduling.
    pub gpus_per_node: usize,
}

impl Topology {
    /// Validated topology; asserts EP | DP and d | EDP-degree.
    pub fn new(dp_degree: usize, ep_degree: usize, d: usize, gpus_per_node: usize) -> Self {
        assert!(ep_degree > 0 && dp_degree % ep_degree == 0, "EP must divide DP");
        let edp = dp_degree / ep_degree;
        assert!(d >= 1 && edp % d == 0, "d={d} must divide EDP degree {edp}");
        assert!(gpus_per_node > 0);
        Topology { dp_degree, ep_degree, d, gpus_per_node }
    }

    /// Number of EP groups inside the DP group.
    pub fn num_ep_groups(&self) -> usize {
        self.dp_degree / self.ep_degree
    }

    /// Number of MicroEP groups (each merges `d` EP groups).
    pub fn num_microep_groups(&self) -> usize {
        self.num_ep_groups() / self.d
    }

    /// GPUs in one MicroEP group.
    pub fn microep_group_size(&self) -> usize {
        self.d * self.ep_degree
    }

    /// EP group index of a GPU.
    pub fn ep_group_of(&self, gpu: usize) -> usize {
        gpu / self.ep_degree
    }

    /// EP rank (position within its EP group) of a GPU.
    pub fn ep_rank_of(&self, gpu: usize) -> usize {
        gpu % self.ep_degree
    }

    /// MicroEP group index of a GPU.
    pub fn microep_group_of(&self, gpu: usize) -> usize {
        gpu / self.microep_group_size()
    }

    /// The GPUs of MicroEP group `m` (consecutive ranks).
    pub fn microep_gpus(&self, m: usize) -> std::ops::Range<usize> {
        let s = self.microep_group_size();
        m * s..(m + 1) * s
    }

    /// The GPUs of EP group `k`.
    pub fn ep_gpus(&self, k: usize) -> std::ops::Range<usize> {
        k * self.ep_degree..(k + 1) * self.ep_degree
    }

    /// Vanilla-EP EDP group of EP rank `r` (same rank across EP groups).
    pub fn edp_group_of_rank(&self, r: usize) -> Vec<usize> {
        (0..self.num_ep_groups()).map(|k| k * self.ep_degree + r).collect()
    }

    /// Node index of a GPU.
    pub fn node_of(&self, gpu: usize) -> usize {
        gpu / self.gpus_per_node
    }

    /// Whether two GPUs share a node (NVLink vs IB path).
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Experts per GPU when `num_experts` are spread over an EP group.
    pub fn experts_per_gpu(&self, num_experts: usize) -> usize {
        assert!(num_experts % self.ep_degree == 0, "experts must divide over EP group");
        num_experts / self.ep_degree
    }

    /// Replica slots per GPU inside a MicroEP group (uniform-count case):
    /// each of the d merged EP groups contributes one full expert set.
    pub fn slots_per_gpu(&self, num_experts: usize) -> usize {
        self.experts_per_gpu(num_experts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_testbed() -> Topology {
        // §7.1: DP=8, EP=4 -> 2 EP groups; d=2 -> 1 MicroEP group; 8 GPUs/node
        Topology::new(8, 4, 2, 8)
    }

    #[test]
    fn paper_config_groups() {
        let t = paper_testbed();
        assert_eq!(t.num_ep_groups(), 2);
        assert_eq!(t.num_microep_groups(), 1);
        assert_eq!(t.microep_group_size(), 8);
        assert_eq!(t.microep_gpus(0), 0..8);
    }

    #[test]
    fn ep_group_membership() {
        let t = paper_testbed();
        assert_eq!(t.ep_group_of(0), 0);
        assert_eq!(t.ep_group_of(3), 0);
        assert_eq!(t.ep_group_of(4), 1);
        assert_eq!(t.ep_gpus(1), 4..8);
        assert_eq!(t.ep_rank_of(5), 1);
    }

    #[test]
    fn vanilla_edp_groups_link_same_rank() {
        let t = paper_testbed();
        assert_eq!(t.edp_group_of_rank(0), vec![0, 4]);
        assert_eq!(t.edp_group_of_rank(3), vec![3, 7]);
    }

    #[test]
    fn deepseek_like_config() {
        // DeepSeek-V3 pretraining shape (§4): EP=64, DP=128 -> 2 EP groups
        let t = Topology::new(128, 64, 2, 8);
        assert_eq!(t.num_ep_groups(), 2);
        assert_eq!(t.microep_group_size(), 128);
        assert_eq!(t.slots_per_gpu(256), 4);
    }

    #[test]
    fn multiple_microep_groups() {
        // DP=16, EP=4 -> 4 EP groups; d=2 -> 2 MicroEP groups of 8 GPUs
        let t = Topology::new(16, 4, 2, 8);
        assert_eq!(t.num_microep_groups(), 2);
        assert_eq!(t.microep_gpus(0), 0..8);
        assert_eq!(t.microep_gpus(1), 8..16);
        assert_eq!(t.microep_group_of(9), 1);
    }

    #[test]
    fn node_locality() {
        let t = Topology::new(16, 4, 2, 8);
        assert!(t.same_node(0, 7));
        assert!(!t.same_node(7, 8));
        assert_eq!(t.node_of(15), 1);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn invalid_d_rejected() {
        Topology::new(8, 4, 3, 8); // edp=2, d=3 invalid
    }
}
