//! The MicroMoE policies behind the [`Balancer`] trait: the per-layer
//! warm-started LPP scheduler fan-out and the persistent pipelined /
//! speculative engine.
//!
//! Both wrap existing machinery without changing its numerics, so they are
//! bit-identical to the pre-trait entry points (pinned by
//! `tests/trait_equivalence.rs`):
//!
//! * [`LppBalancer`] — one [`MicroEpScheduler`] per layer (each owns its
//!   warm-start basis), executed through the round-barrier
//!   [`schedule_layers_parallel`] fan-out. Supports every
//!   [`crate::scheduler::ScheduleMode`].
//! * [`EngineBalancer`] — the always-on [`ScheduleEngine`]: persistent
//!   worker pool, bounded in-flight window with in-order emission, and (in
//!   speculative mode) forecast-driven pre-solves between steps.

use super::{
    fold_plan, fold_schedule, schedule_to_plan, step_layers, Balancer, MoeLayerPlan, StepInput,
    StepOutput,
};
use crate::engine::{EngineError, ScheduleEngine};
use crate::placement::Placement;
use crate::scheduler::flow::flow_schedule;
use crate::scheduler::{
    schedule_layers_parallel, LoadMatrix, MicroEpScheduler, Route, SchedulerOptions,
};
use crate::stats::{BalancerStats, DegradationRung, EngineStats, StepStats};
use crate::topology::Topology;

/// The MicroMoE LPP scheduler as a multi-layer [`Balancer`]: per-layer
/// warm-started [`MicroEpScheduler`]s driven through the round-barrier
/// fan-out (the `EngineMode::Barrier` arm of the `"micromoe"` policy).
pub struct LppBalancer {
    placement: Placement,
    scheds: Vec<MicroEpScheduler>,
    overlap: bool,
    stats: BalancerStats,
}

impl LppBalancer {
    /// One scheduler per layer over a shared placement. `overlap` marks the
    /// emitted plans as §5.4-overlapped (scheduling hides under permute).
    pub fn new(
        placement: Placement,
        topo: Option<Topology>,
        opts: SchedulerOptions,
        layers: usize,
        overlap: bool,
    ) -> Self {
        assert!(layers > 0, "balancer needs at least one layer");
        let scheds = (0..layers)
            .map(|_| MicroEpScheduler::new(placement.clone(), topo.clone(), opts.clone()))
            .collect();
        LppBalancer { placement, scheds, overlap, stats: BalancerStats::default() }
    }

    /// MoE layers scheduled per step.
    pub fn layers(&self) -> usize {
        self.scheds.len()
    }
}

impl Balancer for LppBalancer {
    fn name(&self) -> &str {
        "MicroMoE (w/o AR)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        assert_eq!(input.loads.len(), self.scheds.len(), "one load matrix per layer");
        let schedules = schedule_layers_parallel(&mut self.scheds, input.loads);
        let mut stats = StepStats::default();
        let layers: Vec<MoeLayerPlan> = schedules
            .into_iter()
            .map(|s| {
                fold_schedule(&mut stats, &s.stats);
                let plan = schedule_to_plan(s, &self.placement, self.overlap);
                fold_plan(&mut stats, &plan);
                plan
            })
            .collect();
        self.stats.absorb(&stats);
        StepOutput { layers, stats }
    }

    fn warm_hint(&mut self, expected: &[LoadMatrix]) {
        assert_eq!(expected.len(), self.scheds.len(), "one expected load matrix per layer");
        // prime each layer's warm basis with a discarded solve
        for (s, lm) in self.scheds.iter_mut().zip(expected) {
            let _ = s.schedule(lm);
        }
    }

    fn stats(&self) -> BalancerStats {
        self.stats
    }
}

/// The pipelined / speculative scheduling engine as a [`Balancer`] (the
/// `EngineMode::{Pipeline, Speculative}` arms of the `"micromoe"` policy).
/// Owns the persistent worker pool and, in speculative mode, the per-layer
/// load forecasters.
pub struct EngineBalancer {
    engine: ScheduleEngine,
    placement: Placement,
    overlap: bool,
    stats: BalancerStats,
    /// clone of `opts.trace` + the mode's export name (the engine owns the
    /// options): passthrough plans emitted on engine failure still get a
    /// solve span, keeping trace rung counts equal to
    /// [`crate::stats::DegradationStats`]
    trace: crate::obs::Tracer,
    mode_name: &'static str,
}

impl EngineBalancer {
    /// Engine over a shared placement; `opts.engine` must be `Pipeline` or
    /// `Speculative` (the barrier mode belongs to [`LppBalancer`] and
    /// yields [`EngineError::BarrierMode`]).
    pub fn new(
        placement: Placement,
        topo: Option<Topology>,
        opts: SchedulerOptions,
        layers: usize,
        overlap: bool,
    ) -> Result<Self, EngineError> {
        let trace = opts.trace.clone();
        let mode_name = opts.mode.name();
        let engine = ScheduleEngine::new(placement.clone(), topo, opts, layers)?;
        Ok(EngineBalancer {
            engine,
            placement,
            overlap,
            stats: BalancerStats::default(),
            trace,
            mode_name,
        })
    }

    /// MoE layers scheduled per step.
    pub fn layers(&self) -> usize {
        self.engine.layers()
    }

    /// Worker threads in the persistent pool.
    pub fn workers(&self) -> usize {
        self.engine.workers()
    }
}

impl Balancer for EngineBalancer {
    fn name(&self) -> &str {
        if self.engine.speculative() {
            "MicroMoE (speculative engine)"
        } else {
            "MicroMoE (pipelined engine)"
        }
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        let mut layers: Vec<MoeLayerPlan> = Vec::with_capacity(input.loads.len());
        let stats = self.step_with(input, &mut |_, plan| layers.push(plan));
        StepOutput { layers, stats }
    }

    fn step_with(
        &mut self,
        input: &StepInput,
        sink: &mut dyn FnMut(usize, MoeLayerPlan),
    ) -> StepStats {
        // index of the step being scheduled (absorb() advances the counter
        // only after the step completes)
        let step = self.stats.steps as usize;
        let EngineBalancer { engine, placement, overlap, trace, mode_name, .. } = self;
        let overlap = *overlap;
        let mut stats = StepStats::default();
        let mut emitted = vec![false; input.loads.len()];
        let result = engine.schedule_step_with(input.loads, |l, s| {
            emitted[l] = true;
            fold_schedule(&mut stats, &s.stats);
            let plan = schedule_to_plan(s, placement, overlap);
            fold_plan(&mut stats, &plan);
            sink(l, plan);
        });
        if let Err(e) = result {
            // The ladder's last rung: the engine is past recovery (respawn
            // limit), but the step must still cover every layer — emit
            // vanilla-EP passthrough plans for whatever was not scheduled.
            log::error!("scheduling engine failed ({e}); passthrough for the remaining layers");
            for (l, lm) in input.loads.iter().enumerate() {
                if emitted[l] {
                    continue;
                }
                let plan = passthrough_plan(placement, lm, overlap);
                stats.degradation.record(DegradationRung::Passthrough, None, 0.0);
                trace.record(
                    0.0,
                    crate::obs::Span::Solve {
                        step,
                        layer: l,
                        mode: *mode_name,
                        rung: DegradationRung::Passthrough,
                        warm: false,
                        pivots: 0,
                        dual_pivots: 0,
                        flips: 0,
                        refactors: 0,
                    },
                );
                fold_plan(&mut stats, &plan);
                sink(l, plan);
            }
        }
        self.stats.absorb(&stats);
        stats
    }

    fn warm_hint(&mut self, expected: &[LoadMatrix]) {
        self.engine.prime(expected);
    }

    fn stats(&self) -> BalancerStats {
        self.stats
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        Some(self.engine.stats())
    }
}

/// The degradation ladder's terminal rung: a plan that needs no solver at
/// all. Every expert's tokens go to its first replica's host GPU —
/// vanilla-EP semantics over the current placement, always feasible, no
/// balancing.
fn passthrough_plan(placement: &Placement, loads: &LoadMatrix, overlap: bool) -> MoeLayerPlan {
    let mut gpu_compute = vec![0u64; placement.num_gpus];
    let mut routes = Vec::new();
    for (e, grp) in placement.replicas.iter().enumerate() {
        let dst = *grp.first().expect("every expert has a replica");
        for src in 0..placement.num_gpus {
            let n = loads.get(e, src);
            if n == 0 {
                continue;
            }
            gpu_compute[dst] += n;
            routes.push(Route { expert: e, src, dst, tokens: n });
        }
    }
    MoeLayerPlan {
        gpu_compute,
        routes,
        sched_time: 0.0,
        sched_overlapped: overlap,
        prep_extra: 0.0,
    }
}

/// The serving tier's stateless per-window policy (registry name
/// `"least-loaded-inference"`): the promoted form of the seed
/// `examples/inference_router.rs` logic. Each batch is solved from scratch
/// with the exact max-flow scheduler ([`flow_schedule`] — binary-searched
/// Dinic, the paper's §9 "replace the LP" suggestion for latency-sensitive
/// inference), then lowered to routes by a deterministic locality-first
/// fill: every expert's tokens stay on their source GPU's own replica
/// while it has flow capacity, and spill to the remaining replicas in
/// placement order. No warm state, no history — exactly what an
/// already-imbalanced inference deployment re-balancing per batching
/// window needs (*Least-Loaded Expert Parallelism*, PAPERS.md).
pub struct LeastLoadedInference {
    placement: Placement,
    layers: usize,
    overlap: bool,
    stats: BalancerStats,
}

impl LeastLoadedInference {
    /// Stateless flow policy over a placement; `layers` load matrices are
    /// expected per step (serving uses 1).
    pub fn new(placement: Placement, layers: usize, overlap: bool) -> Self {
        assert!(layers > 0, "balancer needs at least one layer");
        LeastLoadedInference { placement, layers, overlap, stats: BalancerStats::default() }
    }

    /// The whole policy for one batch, as a pure function — the
    /// trait-equivalence suite pins the registry policy bit-identical to
    /// direct calls of this (flow solve + locality-first route lowering).
    pub fn plan_one(placement: &Placement, loads: &LoadMatrix, overlap: bool) -> MoeLayerPlan {
        let t0 = std::time::Instant::now();
        let fs = flow_schedule(placement, loads);
        let mut gpu_compute = vec![0u64; placement.num_gpus];
        let mut routes = Vec::new();
        for (e, grp) in placement.replicas.iter().enumerate() {
            let mut remaining = fs.replica_loads[e].clone();
            for (r, &g) in grp.iter().enumerate() {
                gpu_compute[g] += remaining[r];
            }
            for src in 0..placement.num_gpus {
                let mut n = loads.get(e, src);
                if n == 0 {
                    continue;
                }
                // locality first: drain the source GPU's own replica
                for (r, &dst) in grp.iter().enumerate() {
                    if dst == src && remaining[r] > 0 && n > 0 {
                        let take = n.min(remaining[r]);
                        remaining[r] -= take;
                        n -= take;
                        routes.push(Route { expert: e, src, dst, tokens: take });
                    }
                }
                // spill the rest over replicas in placement order
                for (r, &dst) in grp.iter().enumerate() {
                    if n == 0 {
                        break;
                    }
                    if remaining[r] == 0 {
                        continue;
                    }
                    let take = n.min(remaining[r]);
                    remaining[r] -= take;
                    n -= take;
                    routes.push(Route { expert: e, src, dst, tokens: take });
                }
                debug_assert_eq!(n, 0, "flow conserves expert {e}'s load");
            }
        }
        MoeLayerPlan {
            gpu_compute,
            routes,
            sched_time: t0.elapsed().as_secs_f64(),
            sched_overlapped: overlap,
            prep_extra: 0.0,
        }
    }
}

impl Balancer for LeastLoadedInference {
    fn name(&self) -> &str {
        "Least-loaded inference (max-flow)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        assert_eq!(input.loads.len(), self.layers, "one load matrix per layer");
        let out = step_layers(input.loads, |lm| {
            Self::plan_one(&self.placement, lm, self.overlap)
        });
        self.stats.absorb(&out.stats);
        out
    }

    fn stats(&self) -> BalancerStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineMode;
    use crate::placement::cayley::cayley_graph_placement;
    use crate::rng::Rng;

    fn random_lm(seed: u64, e: usize, g: usize, n: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let mut lm = LoadMatrix::zeros(e, g);
        for _ in 0..n {
            lm.add(rng.below(e as u64) as usize, rng.below(g as u64) as usize, 1);
        }
        lm
    }

    #[test]
    fn lpp_balancer_matches_direct_schedulers() {
        let p = cayley_graph_placement(8, 16);
        let layers = 3usize;
        let mut bal =
            LppBalancer::new(p.clone(), None, SchedulerOptions::default(), layers, true);
        let mut direct: Vec<MicroEpScheduler> = (0..layers)
            .map(|_| MicroEpScheduler::new(p.clone(), None, SchedulerOptions::default()))
            .collect();
        for round in 0..3u64 {
            let loads: Vec<LoadMatrix> =
                (0..layers).map(|l| random_lm(round * 10 + l as u64, 16, 8, 900)).collect();
            let out = bal.step(&StepInput { loads: &loads });
            for (l, (plan, (s, lm))) in
                out.layers.iter().zip(direct.iter_mut().zip(&loads)).enumerate()
            {
                let want = s.schedule(lm);
                assert_eq!(plan.routes, want.routes, "round {round} layer {l}");
                assert_eq!(plan.gpu_compute, want.gpu_loads(&p), "round {round} layer {l}");
            }
        }
        let st = bal.stats();
        assert_eq!(st.steps, 3);
        assert_eq!(st.layers, 3 * layers as u64);
        assert!(st.lp_pivots > 0);
        assert!(bal.engine_stats().is_none());
    }

    #[test]
    fn engine_balancer_streams_in_layer_order() {
        let p = cayley_graph_placement(4, 8);
        let layers = 5usize;
        let opts = SchedulerOptions {
            engine: EngineMode::Pipeline { workers: 2, inflight: 2 },
            ..Default::default()
        };
        let mut bal = EngineBalancer::new(p, None, opts, layers, true).unwrap();
        let loads: Vec<LoadMatrix> =
            (0..layers).map(|l| random_lm(l as u64, 8, 4, 400)).collect();
        let mut order = Vec::new();
        let stats = bal.step_with(&StepInput { loads: &loads }, &mut |l, plan| {
            order.push(l);
            assert_eq!(plan.gpu_compute.iter().sum::<u64>(), loads[l].total());
        });
        assert_eq!(order, (0..layers).collect::<Vec<_>>());
        assert_eq!(stats.layers, layers);
        assert!(bal.engine_stats().is_some());
    }

    #[test]
    fn barrier_mode_is_a_typed_construction_error() {
        let p = cayley_graph_placement(4, 8);
        let err = EngineBalancer::new(p, None, SchedulerOptions::default(), 2, true)
            .expect_err("barrier mode has no engine");
        assert_eq!(err, EngineError::BarrierMode);
    }

    #[test]
    fn exhausted_engine_degrades_to_passthrough_plans() {
        use crate::faults::{Fault, FaultPlan};
        let p = cayley_graph_placement(4, 8);
        let layers = 2usize;
        let opts = SchedulerOptions {
            engine: EngineMode::Pipeline { workers: 1, inflight: 1 },
            // the sole worker dies on every delivery of step 0 / layer 0:
            // the pool burns its respawn budget and the balancer must
            // still cover the whole step
            faults: Some(std::sync::Arc::new(FaultPlan::with_faults(vec![(
                0,
                0,
                Fault::WorkerPanic { persistent: true },
            )]))),
            ..Default::default()
        };
        let mut bal = EngineBalancer::new(p, None, opts, layers, true).unwrap();
        let loads: Vec<LoadMatrix> =
            (0..layers).map(|l| random_lm(70 + l as u64, 8, 4, 500)).collect();
        let out = bal.step(&StepInput { loads: &loads });
        assert_eq!(out.layers.len(), layers, "every layer emitted despite engine death");
        for (l, plan) in out.layers.iter().enumerate() {
            assert_eq!(plan.gpu_compute.iter().sum::<u64>(), loads[l].total(), "layer {l}");
        }
        assert_eq!(out.stats.degradation.passthrough, layers as u64);
        assert_eq!(out.stats.degradation.total(), layers as u64);
    }

    #[test]
    fn least_loaded_inference_is_flow_optimal_and_conserves() {
        use crate::scheduler::flow::flow_schedule;
        let p = cayley_graph_placement(8, 16);
        let mut bal = LeastLoadedInference::new(p.clone(), 1, false);
        for round in 0..4u64 {
            let lm = random_lm(round, 16, 8, 1_200);
            let out = bal.step(&StepInput { loads: std::slice::from_ref(&lm) });
            let plan = &out.layers[0];
            assert_eq!(plan.gpu_compute.iter().sum::<u64>(), lm.total(), "round {round}");
            // routes realize exactly the per-GPU compute loads
            let mut from_routes = vec![0u64; 8];
            for r in &plan.routes {
                from_routes[r.dst] += r.tokens;
            }
            assert_eq!(&from_routes, &plan.gpu_compute, "round {round}");
            // the max load is the flow scheduler's exact integral optimum
            let fs = flow_schedule(&p, &lm);
            assert_eq!(
                plan.gpu_compute.iter().copied().max().unwrap(),
                fs.max_load,
                "round {round}"
            );
        }
        let st = bal.stats();
        assert_eq!(st.steps, 4);
        assert_eq!(st.lp_pivots, 0, "no LP behind the flow policy");
    }

    #[test]
    fn warm_hint_primes_without_changing_step_shape() {
        let p = cayley_graph_placement(4, 8);
        let mut bal = LppBalancer::new(p, None, SchedulerOptions::default(), 2, true);
        let loads: Vec<LoadMatrix> = (0..2).map(|l| random_lm(40 + l, 8, 4, 600)).collect();
        bal.warm_hint(&loads);
        let out = bal.step(&StepInput { loads: &loads });
        assert_eq!(out.layers.len(), 2);
        // hint already solved these exact loads: the step is warm everywhere
        assert_eq!(out.stats.warm_layers, 2);
    }
}
