//! [`MoeSession`]: the one facade every consumer drives, and the policy
//! registry that builds any [`Balancer`] from a [`PolicySpec`] name.
//!
//! Session lifecycle:
//!
//! 1. **configure** — [`MoeSession::builder`]: topology + experts (or an
//!    explicit placement), a policy (by [`PolicySpec`] or name string),
//!    the engine mode, layer count, and optional migration costing;
//! 2. **build** — the registry resolves the policy name to a concrete
//!    [`Balancer`] (constructing placement, forecasters, and the worker
//!    pool as the policy requires);
//! 3. **step** — [`MoeSession::step`] schedules every MoE layer of each
//!    micro-batch and accumulates unified [`BalancerStats`];
//! 4. **inspect** — [`MoeSession::stats`] / [`MoeSession::engine_stats`].
//!
//! Registered policies ([`registered_policies`]):
//!
//! | name | system |
//! |---|---|
//! | `micromoe` | MicroEP LPP scheduling; `options.engine` picks Barrier ([`LppBalancer`]) or Pipeline/Speculative ([`EngineBalancer`]); with [`MoeSessionBuilder::control`] the barrier arm becomes the two-timescale [`crate::control::ControlledLppBalancer`] |
//! | `micromoe-ar` | the full paper system: LPP scheduling + §6.4 adaptive replacement ([`crate::baselines::MicroMoe`]) |
//! | `vanilla-ep` | Megatron-LM fixed EP ([`crate::baselines::VanillaEp`]) |
//! | `deepspeed-pad` | DeepSpeed/GShard capacity padding ([`crate::baselines::DeepSpeedPad`]) |
//! | `smartmoe` | periodic placement re-optimization ([`crate::baselines::SmartMoe`]) |
//! | `flexmoe` | popularity-proportional replicas ([`crate::baselines::FlexMoe`]) |
//! | `least-loaded-inference` | per-batch max-flow least-loaded routing for serving ([`LeastLoadedInference`]) |

use super::policies::{EngineBalancer, LeastLoadedInference, LppBalancer};
use super::{Balancer, MoeLayerPlan, StepInput, StepOutput};
use crate::adaptive::AdaptiveConfig;
use crate::baselines::{DeepSpeedPad, FlexMoe, MicroMoe, SmartMoe, VanillaEp};
use crate::cluster::CostModel;
use crate::config::PolicySpec;
use crate::control::{ControlSpec, ControlledLppBalancer};
use crate::engine::EngineMode;
use crate::placement::cayley::symmetric_placement;
use crate::placement::Placement;
use crate::scheduler::{LoadMatrix, SchedulerOptions};
use crate::stats::{BalancerStats, EngineStats, StepStats};
use crate::topology::Topology;

/// Names the [`MoeSessionBuilder`] registry resolves (the `"micromoe"`
/// policy further fans out over [`EngineMode`] via its options).
pub fn registered_policies() -> &'static [&'static str] {
    &[
        "micromoe",
        "micromoe-ar",
        "vanilla-ep",
        "deepspeed-pad",
        "smartmoe",
        "flexmoe",
        "least-loaded-inference",
    ]
}

/// Why a session could not be built.
#[derive(Debug, thiserror::Error)]
pub enum SessionError {
    /// The policy name is not in the registry.
    #[error("unknown policy '{0}' — registered: {1:?}")]
    UnknownPolicy(String, &'static [&'static str]),
    /// A required builder input was not provided.
    #[error("session builder needs {0}")]
    Missing(&'static str),
    /// Provided inputs are inconsistent.
    #[error("invalid session config: {0}")]
    Invalid(String),
}

/// Configures and builds a [`MoeSession`] (see the module docs for the
/// lifecycle and the policy registry).
///
/// ```
/// use micromoe::balancer::MoeSession;
/// use micromoe::engine::EngineMode;
/// use micromoe::scheduler::LoadMatrix;
/// use micromoe::topology::Topology;
///
/// let mut session = MoeSession::builder()
///     .topology(Topology::new(8, 4, 2, 8))
///     .experts(16)
///     .policy_name("micromoe")
///     .engine(EngineMode::pipeline())
///     .layers(2)
///     .build()
///     .unwrap();
/// let mk = |e: usize| {
///     let mut lm = LoadMatrix::zeros(16, 8);
///     lm.add(e, 0, 100);
///     lm
/// };
/// let out = session.step(&[mk(1), mk(2)]);
/// assert_eq!(out.layers.len(), 2);
/// assert_eq!(session.stats().steps, 1);
/// ```
#[derive(Clone, Debug, Default)]
pub struct MoeSessionBuilder {
    topo: Option<Topology>,
    experts: Option<usize>,
    placement: Option<Placement>,
    spec: Option<PolicySpec>,
    layers: Option<usize>,
    overlap: Option<bool>,
    label: Option<String>,
    migration: Option<(CostModel, u64)>,
}

impl MoeSessionBuilder {
    /// Parallelism topology the session schedules over (required).
    pub fn topology(mut self, topo: Topology) -> Self {
        self.topo = Some(topo);
        self
    }

    /// Experts per MoE layer (required unless a placement is given).
    pub fn experts(mut self, experts: usize) -> Self {
        self.experts = Some(experts);
        self
    }

    /// Explicit replica placement for the policies that consume one
    /// (`micromoe`, `micromoe-ar`; symmetric Cayley by default). Rejected
    /// at build for the baselines, which derive their layout from the
    /// topology.
    pub fn placement(mut self, placement: Placement) -> Self {
        self.placement = Some(placement);
        self
    }

    /// Full policy specification (name + options + seed + cadence).
    pub fn policy(mut self, spec: PolicySpec) -> Self {
        self.spec = Some(spec);
        self
    }

    /// Select the policy by registry name, keeping other spec fields.
    pub fn policy_name(mut self, name: &str) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).name = name.to_string();
        self
    }

    /// Scheduler options (mode, warm start, solver, engine) for the policy.
    pub fn options(mut self, options: SchedulerOptions) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).options = options;
        self
    }

    /// Multi-layer execution mode for the `micromoe` policy.
    pub fn engine(mut self, engine: EngineMode) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).options.engine = engine;
        self
    }

    /// Enable structured tracing for the session's scheduling pipeline
    /// ([`crate::obs`]): builds a fresh [`crate::obs::Tracer`] on the given
    /// clock and threads it through the policy's schedulers, the engine
    /// pool, and (via [`MoeSession::serve`]) the serving tier. Read it back
    /// with [`MoeSession::tracer`]. [`crate::obs::TraceConfig::Off`] — the
    /// default — keeps the zero-cost disabled handle. Tracing observes,
    /// never steers: schedules are bit-identical either way.
    pub fn trace(mut self, cfg: crate::obs::TraceConfig) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).options.trace =
            crate::obs::Tracer::new(cfg);
        self
    }

    /// Share an existing tracer (e.g. one timeline across several
    /// sessions). Prefer [`MoeSessionBuilder::trace`] for the common
    /// single-session case.
    pub fn tracer(mut self, tracer: crate::obs::Tracer) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).options.trace = tracer;
        self
    }

    /// Attach the slow placement-control loop ([`crate::control`]): every
    /// [`ControlSpec::interval`] steps the session re-evaluates per-expert
    /// load EWMAs and, when the predicted density gain beats the migration
    /// bill, replicates/migrates experts and rebuilds the affected layers'
    /// warm scheduler bases. Only the `"micromoe"` policy on the barrier
    /// engine accepts one (rejected at build otherwise). Migration pricing
    /// defaults to [`CostModel::h100_testbed`]; override it (and the bytes
    /// moved per replica) with [`MoeSessionBuilder::migration_cost`].
    pub fn control(mut self, control: ControlSpec) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).control = Some(control);
        self
    }

    /// RNG seed for stochastic policies (FlexMoE placement, AR search,
    /// controller density search at >16 GPUs).
    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).seed = seed;
        self
    }

    /// Re-plan cadence in micro-batches for the periodic policies
    /// (SmartMoE / FlexMoE / adaptive replacement); rejected at build for
    /// policies with nothing to re-plan.
    pub fn replan_every(mut self, every: usize) -> Self {
        self.spec.get_or_insert_with(PolicySpec::default).replan_every = Some(every);
        self
    }

    /// MoE layers per step (default 1; 0 is rejected at build). The
    /// periodic plan-based policies tick their cadence per plan call and
    /// therefore only accept 1.
    pub fn layers(mut self, layers: usize) -> Self {
        self.layers = Some(layers);
        self
    }

    /// Whether scheduling overlaps the permute op (§5.4; default true).
    pub fn overlap(mut self, overlap: bool) -> Self {
        self.overlap = Some(overlap);
        self
    }

    /// Display-name override for tables and legends.
    pub fn label(mut self, label: &str) -> Self {
        self.label = Some(label.to_string());
        self
    }

    /// Charge expert migrations of the periodic policies — or of the
    /// placement controller ([`MoeSessionBuilder::control`]) — against this
    /// cost model (`bytes_per_expert` copied per moved replica).
    pub fn migration_cost(mut self, model: CostModel, bytes_per_expert: u64) -> Self {
        self.migration = Some((model, bytes_per_expert));
        self
    }

    /// Resolve the policy through the registry and build the session.
    pub fn build(self) -> Result<MoeSession, SessionError> {
        let MoeSessionBuilder {
            topo,
            experts,
            placement,
            spec,
            layers,
            overlap,
            label,
            migration,
        } = self;
        let topo = topo.ok_or(SessionError::Missing("a topology"))?;
        let spec = spec.unwrap_or_default();
        let layers = layers.unwrap_or(1);
        if layers == 0 {
            return Err(SessionError::Invalid("a session needs at least one layer".into()));
        }
        let overlap = overlap.unwrap_or(true);
        let experts = experts
            .or_else(|| placement.as_ref().map(|p| p.num_experts))
            .ok_or(SessionError::Missing("experts (or a placement)"))?;
        if let Some(p) = &placement {
            if p.num_experts != experts {
                return Err(SessionError::Invalid(format!(
                    "placement has {} experts but {experts} were requested",
                    p.num_experts
                )));
            }
        }
        let gpus = placement
            .as_ref()
            .map(|p| p.num_gpus)
            .unwrap_or_else(|| topo.microep_group_size());
        if !registered_policies().contains(&spec.name.as_str()) {
            return Err(SessionError::UnknownPolicy(spec.name.clone(), registered_policies()));
        }
        if spec.replan_every == Some(0) {
            return Err(SessionError::Invalid(
                "replan_every must be at least 1 micro-batch".into(),
            ));
        }
        // reject knobs the selected policy would silently ignore
        let periodic = matches!(spec.name.as_str(), "micromoe-ar" | "smartmoe" | "flexmoe");
        if spec.replan_every.is_some() && !periodic {
            return Err(SessionError::Invalid(format!(
                "policy '{}' has no re-plan cadence; replan_every only applies to \
                 micromoe-ar/smartmoe/flexmoe",
                spec.name
            )));
        }
        if periodic && layers > 1 {
            // these systems advance their per-micro-batch cadence and EMA
            // state once per plan_layer call; a multi-layer step would tick
            // them `layers` times per micro-batch and distort the cadence
            return Err(SessionError::Invalid(format!(
                "policy '{}' models a per-micro-batch re-plan cadence and only supports \
                 single-layer steps (layers = 1)",
                spec.name
            )));
        }
        if spec.name != "micromoe" && !spec.options.engine.is_barrier() {
            return Err(SessionError::Invalid(format!(
                "policy '{}' runs the plan-based loop; engine modes only apply to 'micromoe'",
                spec.name
            )));
        }
        if migration.is_some() && !periodic && spec.control.is_none() {
            return Err(SessionError::Invalid(format!(
                "policy '{}' never migrates experts; migration_cost only applies to \
                 micromoe-ar/smartmoe/flexmoe and controller-enabled micromoe",
                spec.name
            )));
        }
        if let Some(c) = &spec.control {
            if spec.name != "micromoe" {
                return Err(SessionError::Invalid(format!(
                    "policy '{}' has no placement controller; control only applies to \
                     'micromoe'",
                    spec.name
                )));
            }
            if !spec.options.engine.is_barrier() {
                return Err(SessionError::Invalid(
                    "the placement controller swaps per-layer placements and rebuilds \
                     their warm bases mid-run; the engine modes share one placement \
                     across a persistent pool, so control requires the barrier engine"
                        .into(),
                ));
            }
            c.validate()
                .map_err(|e| SessionError::Invalid(format!("control spec: {e}")))?;
        }
        let takes_placement =
            matches!(spec.name.as_str(), "micromoe" | "micromoe-ar" | "least-loaded-inference");
        if placement.is_some() && !takes_placement {
            return Err(SessionError::Invalid(format!(
                "policy '{}' derives its layout from the topology; an explicit placement \
                 only applies to micromoe/micromoe-ar",
                spec.name
            )));
        }

        let balancer: Box<dyn Balancer> = match spec.name.as_str() {
            "micromoe" => {
                let p = placement.unwrap_or_else(|| symmetric_placement(&topo, experts));
                match spec.options.engine {
                    EngineMode::Barrier if spec.control.is_some() => {
                        let mut cspec =
                            spec.control.clone().expect("checked control.is_some above");
                        let model = match &migration {
                            Some((m, bytes)) => {
                                cspec.bytes_per_expert = *bytes;
                                m.clone()
                            }
                            None => CostModel::h100_testbed(),
                        };
                        Box::new(ControlledLppBalancer::new(
                            p,
                            topo.clone(),
                            spec.options.clone(),
                            layers,
                            overlap,
                            cspec,
                            model,
                            spec.seed,
                        ))
                    }
                    EngineMode::Barrier => Box::new(LppBalancer::new(
                        p,
                        Some(topo.clone()),
                        spec.options.clone(),
                        layers,
                        overlap,
                    )),
                    // mode validity was checked above, but surface any
                    // engine construction failure as a typed build error
                    _ => Box::new(
                        EngineBalancer::new(
                            p,
                            Some(topo.clone()),
                            spec.options.clone(),
                            layers,
                            overlap,
                        )
                        .map_err(|e| SessionError::Invalid(e.to_string()))?,
                    ),
                }
            }
            "micromoe-ar" => {
                let p = placement.unwrap_or_else(|| symmetric_placement(&topo, experts));
                let cfg = AdaptiveConfig {
                    check_every: spec.replan_every.unwrap_or(AdaptiveConfig::default().check_every),
                    window: 8,
                    slots_per_gpu: topo.slots_per_gpu(experts).max(2),
                    ..Default::default()
                };
                let mut mm = MicroMoe::new(topo.clone(), p, spec.options.clone())
                    .with_adaptive(cfg, spec.seed);
                if let Some((model, bytes)) = migration {
                    mm = mm.with_migration_cost(model, bytes);
                }
                mm.overlap = overlap;
                Box::new(mm)
            }
            "least-loaded-inference" => {
                let p = placement.unwrap_or_else(|| symmetric_placement(&topo, experts));
                Box::new(LeastLoadedInference::new(p, layers, overlap))
            }
            "vanilla-ep" => Box::new(VanillaEp::new(topo.clone(), experts)),
            "deepspeed-pad" => Box::new(DeepSpeedPad::new(topo.clone(), experts)),
            "smartmoe" => {
                let mut s = SmartMoe::new(topo.clone(), experts);
                if let Some(every) = spec.replan_every {
                    s.replace_every = every;
                }
                if let Some((model, bytes)) = migration {
                    s = s.with_migration_cost(model, bytes);
                }
                Box::new(s)
            }
            "flexmoe" => {
                let mut f = FlexMoe::new(topo.clone(), experts, spec.seed);
                if let Some(every) = spec.replan_every {
                    f.adjust_every = every;
                }
                if let Some((model, bytes)) = migration {
                    f = f.with_migration_cost(model, bytes);
                }
                Box::new(f)
            }
            other => unreachable!("policy '{other}' was validated against the registry above"),
        };
        Ok(MoeSession {
            balancer,
            label,
            spec,
            topo,
            layers,
            gpus,
            experts,
            stats: BalancerStats::default(),
        })
    }
}

/// The facade consumers drive: owns the policy (and through it placement,
/// forecasters, and the worker pool) and steps every MoE layer of each
/// micro-batch, accumulating unified stats. Built by [`MoeSessionBuilder`].
pub struct MoeSession {
    balancer: Box<dyn Balancer>,
    label: Option<String>,
    spec: PolicySpec,
    topo: Topology,
    layers: usize,
    gpus: usize,
    experts: usize,
    stats: BalancerStats,
}

impl MoeSession {
    /// Start configuring a session.
    pub fn builder() -> MoeSessionBuilder {
        MoeSessionBuilder::default()
    }

    /// Display name (the builder label, or the policy's own name).
    pub fn name(&self) -> &str {
        self.label.as_deref().unwrap_or_else(|| self.balancer.name())
    }

    /// The policy specification this session was built from.
    pub fn policy(&self) -> &PolicySpec {
        &self.spec
    }

    /// Topology the session schedules over.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// MoE layers per step.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Experts per MoE layer.
    pub fn experts(&self) -> usize {
        self.experts
    }

    /// Source GPUs every load matrix must carry.
    pub fn gpus(&self) -> usize {
        self.gpus
    }

    /// Cumulative counters over every step driven through this session
    /// (works for any policy, unlike [`Balancer::stats`]).
    pub fn stats(&self) -> BalancerStats {
        self.stats
    }

    /// Engine counters when the policy runs the persistent scheduling
    /// engine (`micromoe` with Pipeline/Speculative); `None` otherwise.
    pub fn engine_stats(&self) -> Option<EngineStats> {
        self.balancer.engine_stats()
    }

    /// The session's tracing handle (disabled unless the builder enabled
    /// it) — a clone of the one the schedulers record into, so its event
    /// buffer is shared. Export with [`crate::obs::chrome_trace`].
    pub fn tracer(&self) -> &crate::obs::Tracer {
        &self.spec.options.trace
    }

    /// Schedule one micro-batch across every layer; `loads[l]` is layer
    /// `l`'s `input_e^g`.
    pub fn step(&mut self, loads: &[LoadMatrix]) -> StepOutput {
        self.check(loads);
        let out = self.balancer.step(&StepInput { loads });
        self.stats.absorb(&out.stats);
        out
    }

    /// Like [`MoeSession::step`], but hands each layer's plan to `sink` in
    /// layer order as soon as it is available (the engine-backed policy
    /// overlaps the sink with the remaining layers' solves).
    pub fn step_with(
        &mut self,
        loads: &[LoadMatrix],
        sink: &mut dyn FnMut(usize, MoeLayerPlan),
    ) -> StepStats {
        self.check(loads);
        let stats = self.balancer.step_with(&StepInput { loads }, sink);
        self.stats.absorb(&stats);
        stats
    }

    /// Prime the policy's predictors / warm state with expected per-layer
    /// loads (no schedule is produced). Shapes are checked like
    /// [`MoeSession::step`]'s.
    pub fn warm_hint(&mut self, expected: &[LoadMatrix]) {
        self.check(expected);
        self.balancer.warm_hint(expected);
    }

    /// Wrap this session in an open-loop batching-window server
    /// ([`crate::serving::MoeServer`]) — the serving tier's entry point.
    /// Panics if the session schedules more than one layer (serving forms
    /// single-layer decode micro-batches).
    pub fn serve(
        self,
        cfg: crate::serving::ServingConfig,
        mix: crate::workload::TopicMix,
    ) -> crate::serving::MoeServer {
        crate::serving::MoeServer::new(self, cfg, mix)
    }

    fn check(&self, loads: &[LoadMatrix]) {
        assert_eq!(loads.len(), self.layers, "one load matrix per layer");
        for (l, lm) in loads.iter().enumerate() {
            assert_eq!(lm.num_experts, self.experts, "layer {l}: expert count");
            assert_eq!(lm.num_gpus, self.gpus, "layer {l}: gpu count");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Zipf};

    fn topo() -> Topology {
        Topology::new(8, 4, 2, 8)
    }

    fn zipf_lm(experts: usize, gpus: usize, per_gpu: u64, s: f64, seed: u64) -> LoadMatrix {
        let mut rng = Rng::new(seed);
        let z = Zipf::new(experts, s);
        let mut lm = LoadMatrix::zeros(experts, gpus);
        for g in 0..gpus {
            for _ in 0..per_gpu {
                lm.add(z.sample(&mut rng), g, 1);
            }
        }
        lm
    }

    #[test]
    fn every_registered_policy_builds_and_steps() {
        for &name in registered_policies() {
            let mut session = MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name(name)
                .build()
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            for seed in 0..3 {
                let lm = zipf_lm(16, 8, 600, 1.0, seed);
                let total = lm.total();
                let out = session.step(std::slice::from_ref(&lm));
                assert_eq!(out.layers.len(), 1, "{name}");
                assert!(
                    out.layers[0].gpu_compute.iter().sum::<u64>() >= total,
                    "{name} lost tokens"
                );
            }
            assert_eq!(session.stats().steps, 3, "{name}");
            assert_eq!(session.stats().layers, 3, "{name}");
        }
    }

    #[test]
    fn engine_modes_route_to_engine_balancer() {
        for (mode, expect_engine) in [
            (EngineMode::Barrier, false),
            (EngineMode::pipeline(), true),
            (EngineMode::speculative(), true),
        ] {
            let mut session = MoeSession::builder()
                .topology(topo())
                .experts(16)
                .engine(mode)
                .layers(2)
                .build()
                .unwrap();
            let loads = vec![zipf_lm(16, 8, 500, 0.8, 1), zipf_lm(16, 8, 500, 0.8, 2)];
            let out = session.step(&loads);
            assert_eq!(out.layers.len(), 2);
            assert_eq!(session.engine_stats().is_some(), expect_engine, "{mode:?}");
        }
    }

    #[test]
    fn unknown_policy_is_rejected() {
        let err = MoeSession::builder()
            .topology(topo())
            .experts(16)
            .policy_name("nope")
            .build()
            .unwrap_err();
        assert!(matches!(err, SessionError::UnknownPolicy(..)), "{err}");
    }

    #[test]
    fn inapplicable_knobs_are_rejected() {
        // zero cadence would panic on the first modulo inside the policy
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("smartmoe")
                .replan_every(0)
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // engine modes only exist on the micromoe policy
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("micromoe-ar")
                .engine(EngineMode::speculative())
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // migration costing on a policy that never migrates
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("vanilla-ep")
                .migration_cost(crate::cluster::CostModel::h100_testbed(), 1 << 20)
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // a re-plan cadence on a policy with nothing to re-plan
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("micromoe")
                .replan_every(4)
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // periodic policies tick their cadence per plan call: multi-layer
        // steps would distort it, so the builder refuses them
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("flexmoe")
                .layers(3)
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // a controller on a policy without one
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("smartmoe")
                .control(ControlSpec::default())
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // a controller on the engine modes (it needs per-layer rebuilds,
        // which only the barrier arm supports)
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("micromoe")
                .engine(EngineMode::pipeline())
                .control(ControlSpec::default())
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // an internally inconsistent control spec
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("micromoe")
                .control(ControlSpec { hot_enter: 1.0, hot_exit: 2.0, ..Default::default() })
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // migration costing still needs a policy that migrates: micromoe
        // without a controller keeps rejecting it
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .experts(16)
                .policy_name("micromoe")
                .migration_cost(crate::cluster::CostModel::h100_testbed(), 1 << 20)
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // a placement on a policy that derives its layout from the topology
        assert!(matches!(
            MoeSession::builder()
                .topology(topo())
                .placement(crate::placement::cayley::symmetric_placement(&topo(), 16))
                .policy_name("vanilla-ep")
                .build()
                .unwrap_err(),
            SessionError::Invalid(_)
        ));
        // an explicit zero layer count
        assert!(matches!(
            MoeSession::builder().topology(topo()).experts(16).layers(0).build().unwrap_err(),
            SessionError::Invalid(_)
        ));
    }

    #[test]
    fn controller_session_ticks_and_reports_control_stats() {
        // migration_cost with a controller is accepted and overrides the
        // bytes moved per replica
        let mut session = MoeSession::builder()
            .topology(topo())
            .experts(16)
            .policy_name("micromoe")
            .layers(2)
            .seed(3)
            .control(ControlSpec { interval: 4, dwell: 2, ..Default::default() })
            .migration_cost(crate::cluster::CostModel::h100_testbed(), 1 << 22)
            .build()
            .unwrap();
        assert_eq!(session.name(), "MicroMoE (controlled)");
        // sustained skew toward one expert so the controller has work
        for step in 0..12 {
            let loads = vec![zipf_lm(16, 8, 600, 1.4, step), zipf_lm(16, 8, 600, 1.4, step)];
            let out = session.step(&loads);
            for (l, lm) in loads.iter().enumerate() {
                assert_eq!(
                    out.layers[l].gpu_compute.iter().sum::<u64>(),
                    lm.total(),
                    "step {step} layer {l}"
                );
            }
        }
        let st = session.stats();
        assert_eq!(st.control.ticks, 3, "12 steps / interval 4");
        assert!(st.control.decisions > 0, "skewed trace must trigger decisions");
        assert_eq!(st.control.bytes, st.control.moves * (1 << 22), "bytes override");
        assert!(st.prep_seconds >= st.control.downtime - 1e-12, "downtime charged");
    }

    #[test]
    fn missing_inputs_are_rejected() {
        assert!(matches!(
            MoeSession::builder().experts(16).build().unwrap_err(),
            SessionError::Missing(_)
        ));
        assert!(matches!(
            MoeSession::builder().topology(topo()).build().unwrap_err(),
            SessionError::Missing(_)
        ));
    }

    #[test]
    fn placement_supplies_experts_and_label_overrides_name() {
        use crate::placement::cayley::symmetric_placement;
        let t = topo();
        let p = symmetric_placement(&t, 16);
        let mut session = MoeSession::builder()
            .topology(t)
            .placement(p)
            .label("my arm")
            .build()
            .unwrap();
        assert_eq!(session.experts(), 16);
        assert_eq!(session.name(), "my arm");
        let lm = zipf_lm(16, 8, 400, 0.5, 9);
        let out = session.step(std::slice::from_ref(&lm));
        assert_eq!(out.layers[0].gpu_compute.iter().sum::<u64>(), lm.total());
    }

    #[test]
    fn session_stats_accumulate_for_plan_based_policies() {
        let mut session = MoeSession::builder()
            .topology(topo())
            .experts(16)
            .policy_name("vanilla-ep")
            .build()
            .unwrap();
        for seed in 0..4 {
            session.step(std::slice::from_ref(&zipf_lm(16, 8, 300, 1.0, seed)));
        }
        let st = session.stats();
        assert_eq!(st.steps, 4);
        assert_eq!(st.layers, 4);
        assert!(st.max_gpu_load > 0);
        // static policy: no LP work
        assert_eq!(st.lp_pivots, 0);
    }
}
