//! The unified load-balancing API: one step-driven [`Balancer`] trait over
//! every policy, and the [`MoeSession`] facade that drives it.
//!
//! Before this module the crate had four parallel entry points — the
//! per-layer [`crate::scheduler::MicroEpScheduler`], the barrier fan-out
//! [`crate::scheduler::schedule_layers_parallel`], the pipelined
//! [`crate::engine::ScheduleEngine`], and the `baselines` planning trait —
//! so every consumer (sim, trainer, CLI, benches) wired policies
//! differently. Now everything speaks [`Balancer`]:
//!
//! ```text
//!                 ┌──────────────── Balancer ────────────────┐
//!                 │ step(&StepInput) -> StepOutput            │
//!                 │ step_with / plan / warm_hint / stats / …  │
//!                 └──────┬──────────────┬──────────────┬──────┘
//!        LppBalancer     │  EngineBalancer             │  baselines::*
//!  (per-layer warm LPP,  │  (persistent pool,          │  (VanillaEp,
//!   barrier fan-out,     │   pipelined emission,       │   DeepSpeedPad,
//!   all ScheduleModes)   │   speculative pre-solves)   │   SmartMoe,
//!                        │                             │   FlexMoe,
//!                        │                             │   MicroMoe+AR)
//!                 ┌──────┴─────────────────────────────┴──────┐
//!                 │ MoeSession — owns placement + policy,      │
//!                 │ built from a name via the PolicySpec       │
//!                 │ registry ([`registered_policies`])         │
//!                 └────────────────────────────────────────────┘
//! ```
//!
//! A step covers **all MoE layers of one micro-batch**: `loads[l]` is layer
//! `l`'s `input_e^g` and the output carries one [`MoeLayerPlan`] per layer
//! plus unified [`StepStats`]. Single-layer consumers use the provided
//! [`Balancer::plan`] shorthand; latency-sensitive consumers use
//! [`Balancer::step_with`], which the engine-backed policy overrides to
//! hand each layer's plan over *while later layers are still solving*.

pub mod policies;
pub mod session;

use crate::scheduler::{LoadMatrix, Route, Schedule, ScheduleStats};
use crate::stats::{BalancerStats, EngineStats, StepStats};

pub use policies::{EngineBalancer, LeastLoadedInference, LppBalancer};
pub use session::{registered_policies, MoeSession, MoeSessionBuilder, SessionError};

/// What a load-balancing policy decided for one MoE layer of one
/// micro-batch (one layer of a [`Balancer`] step).
#[derive(Clone, Debug, PartialEq)]
pub struct MoeLayerPlan {
    /// tokens to compute per GPU (FFN input rows, already top-K expanded)
    pub gpu_compute: Vec<u64>,
    /// token movements (src != dst entries cost communication)
    pub routes: Vec<Route>,
    /// CPU scheduling time for this micro-batch (s); 0 for static systems
    pub sched_time: f64,
    /// whether scheduling hides under the permute op (§5.4)
    pub sched_overlapped: bool,
    /// extra prep charged to this layer (backend pre-processing,
    /// amortized migration, padding setup …)
    pub prep_extra: f64,
}

/// Input of one multi-layer scheduling step.
#[derive(Clone, Copy, Debug)]
pub struct StepInput<'a> {
    /// `loads[l]` — layer `l`'s `input_e^g` for this micro-batch.
    pub loads: &'a [LoadMatrix],
}

/// Output of one multi-layer scheduling step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// One plan per input layer, in layer order.
    pub layers: Vec<MoeLayerPlan>,
    /// Unified solve diagnostics aggregated over the step's layers.
    pub stats: StepStats,
}

/// A load-balancing policy planning every MoE layer of each micro-batch.
///
/// Implemented by the MicroEP LPP scheduler ([`LppBalancer`], all
/// [`crate::scheduler::ScheduleMode`]s), the pipelined/speculative engine
/// ([`EngineBalancer`]), and every `baselines` system — so one step loop
/// compares them all on equal footing, and new scenarios are a policy
/// registration away ([`session`]).
///
/// ```
/// use micromoe::balancer::{Balancer, StepInput};
/// use micromoe::scheduler::LoadMatrix;
/// use micromoe::topology::Topology;
///
/// // every baseline system is a Balancer; so are the LPP/engine policies
/// let mut policy = micromoe::baselines::VanillaEp::new(Topology::new(8, 4, 2, 8), 16);
/// let mut lm = LoadMatrix::zeros(16, 8);
/// lm.add(3, 1, 128);
/// let out = policy.step(&StepInput { loads: std::slice::from_ref(&lm) });
/// assert_eq!(out.layers.len(), 1);
/// assert_eq!(out.layers[0].gpu_compute.iter().sum::<u64>(), 128);
/// ```
pub trait Balancer {
    /// Display name for tables, legends, and logs.
    fn name(&self) -> &str;

    /// Schedule one micro-batch across every MoE layer.
    fn step(&mut self, input: &StepInput) -> StepOutput;

    /// Like [`Balancer::step`], but hands each layer's plan to `sink` in
    /// layer order. The engine-backed policy overrides this to emit plans
    /// *as soon as they are available*, overlapping the caller's per-layer
    /// stage with the remaining layers' solves; the default materializes
    /// the whole step first.
    fn step_with(
        &mut self,
        input: &StepInput,
        sink: &mut dyn FnMut(usize, MoeLayerPlan),
    ) -> StepStats {
        let out = self.step(input);
        for (l, plan) in out.layers.into_iter().enumerate() {
            sink(l, plan);
        }
        out.stats
    }

    /// Single-layer shorthand: a one-layer [`Balancer::step`]. Policies
    /// constructed for a fixed multi-layer shape panic on it.
    fn plan(&mut self, loads: &LoadMatrix) -> MoeLayerPlan {
        let mut out = self.step(&StepInput { loads: std::slice::from_ref(loads) });
        debug_assert_eq!(out.layers.len(), 1);
        out.layers.pop().expect("single-layer step produced one plan")
    }

    /// Prime predictors / warm-start state with per-layer loads expected in
    /// upcoming steps, without producing a schedule. Default: no-op.
    fn warm_hint(&mut self, _expected: &[LoadMatrix]) {}

    /// Cumulative counters the policy keeps internally. The LP- and
    /// engine-backed policies report real numbers; plan-based systems
    /// return the default — use [`MoeSession::stats`] for a uniform
    /// accumulator over any policy.
    fn stats(&self) -> BalancerStats {
        BalancerStats::default()
    }

    /// Speculation/pipeline counters when the policy runs the persistent
    /// scheduling engine; `None` otherwise.
    fn engine_stats(&self) -> Option<EngineStats> {
        None
    }
}

/// Drive a per-layer planner over a multi-layer step, aggregating unified
/// stats — the adapter every plan-based system uses to implement
/// [`Balancer::step`]. Layers are planned in order against the policy's
/// single internal state, exactly like the pre-trait per-micro-batch loop.
pub fn step_layers<F>(loads: &[LoadMatrix], mut plan_one: F) -> StepOutput
where
    F: FnMut(&LoadMatrix) -> MoeLayerPlan,
{
    let mut stats = StepStats::default();
    let layers: Vec<MoeLayerPlan> = loads
        .iter()
        .map(|lm| {
            let plan = plan_one(lm);
            fold_plan(&mut stats, &plan);
            plan
        })
        .collect();
    StepOutput { layers, stats }
}

/// Fold one layer plan's observable costs into a step's stats.
pub(crate) fn fold_plan(stats: &mut StepStats, plan: &MoeLayerPlan) {
    stats.layers += 1;
    stats.sched_seconds += plan.sched_time;
    stats.prep_seconds += plan.prep_extra;
    let layer_max = plan.gpu_compute.iter().copied().max().unwrap_or(0);
    stats.max_gpu_load = stats.max_gpu_load.max(layer_max);
}

/// Fold one layer's LP solve diagnostics into a step's stats.
pub(crate) fn fold_schedule(stats: &mut StepStats, s: &ScheduleStats) {
    stats.lp_pivots += s.lp_iterations as u64;
    stats.lp_dual_pivots += s.lp_dual_pivots as u64;
    stats.lp_bound_flips += s.lp_bound_flips as u64;
    stats.lp_refactors += s.lp_refactors as u64;
    if s.warm {
        stats.warm_layers += 1;
    }
    stats.degradation.record(s.rung, s.budget_exhausted, s.fallback_excess);
    if let Some(d) = s.decompose {
        stats.decompose.solves += 1;
        stats.decompose.outer_iters += d.outer_iters as u64;
        stats.decompose.subproblem_pivots += d.subproblem_pivots;
        stats.decompose.master_gap_sum += d.master_gap;
        stats.decompose.master_gap_max = stats.decompose.master_gap_max.max(d.master_gap);
        stats.decompose.blocks_degraded += d.blocks_degraded as u64;
    }
}

/// Lower a [`Schedule`] into the plan the cluster model consumes.
pub(crate) fn schedule_to_plan(
    s: Schedule,
    placement: &crate::placement::Placement,
    overlapped: bool,
) -> MoeLayerPlan {
    let gpu_compute = s.gpu_loads(placement);
    let sched_time = s.stats.solve_ns as f64 * 1e-9;
    MoeLayerPlan {
        gpu_compute,
        routes: s.routes,
        sched_time,
        sched_overlapped: overlapped,
        prep_extra: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_plan(per_gpu: u64, g: usize) -> MoeLayerPlan {
        MoeLayerPlan {
            gpu_compute: vec![per_gpu; g],
            routes: Vec::new(),
            sched_time: 1e-6,
            sched_overlapped: true,
            prep_extra: 0.5e-6,
        }
    }

    #[test]
    fn step_layers_plans_every_layer_in_order() {
        let loads: Vec<LoadMatrix> = (0..3).map(|_| LoadMatrix::zeros(2, 2)).collect();
        let mut seen = 0usize;
        let out = step_layers(&loads, |_| {
            seen += 1;
            flat_plan(seen as u64, 2)
        });
        assert_eq!(out.layers.len(), 3);
        assert_eq!(out.layers[2].gpu_compute, vec![3, 3]);
        assert_eq!(out.stats.layers, 3);
        assert_eq!(out.stats.max_gpu_load, 3);
        assert!((out.stats.sched_seconds - 3e-6).abs() < 1e-15);
        assert!((out.stats.prep_seconds - 1.5e-6).abs() < 1e-15);
    }

    #[test]
    fn fold_schedule_counts_warm_layers() {
        let mut stats = StepStats::default();
        let mut st = ScheduleStats { lp_iterations: 5, warm: true, ..Default::default() };
        fold_schedule(&mut stats, &st);
        st.warm = false;
        fold_schedule(&mut stats, &st);
        assert_eq!(stats.warm_layers, 1);
        assert_eq!(stats.lp_pivots, 10);
    }
}
