//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! `Rng` is xoshiro256** seeded via SplitMix64 — fast, high-quality, and
//! reproducible across runs, which matters for the paper reproduction: every
//! experiment records its seed, and the distributed scheduler's determinism
//! property (§5.3) is tested by re-running with identical seeds.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-device/per-layer rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index needs positive mass");
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

/// Zipfian sampler over `n` items with skew `s` (probability of rank-i item
/// ∝ i^-s, i starting at 1) — the workload generator of §7.3 / Fig. 7.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Sampler over ranks `0..n` with skew `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += (i as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Expected probability mass of rank i.
    pub fn pmf(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_skew_zero_is_uniform() {
        let z = Zipf::new(8, 0.0);
        for i in 0..8 {
            assert!((z.pmf(i) - 0.125).abs() < 1e-12);
        }
    }

    #[test]
    fn zipf_high_skew_concentrates() {
        let z = Zipf::new(32, 2.0);
        assert!(z.pmf(0) > 0.5);
        assert!(z.pmf(0) > z.pmf(1));
        assert!(z.pmf(1) > z.pmf(2));
    }

    #[test]
    fn zipf_samples_match_pmf() {
        let z = Zipf::new(8, 1.0);
        let mut r = Rng::new(9);
        let n = 200_000;
        let mut counts = [0usize; 8];
        for _ in 0..n {
            counts[z.sample(&mut r)] += 1;
        }
        for i in 0..8 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - z.pmf(i)).abs() < 0.01, "rank {i}: {emp} vs {}", z.pmf(i));
        }
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted_index(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }
}
