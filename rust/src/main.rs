//! `micromoe` CLI: inspect artifacts, run the e2e trainer, calibrate the
//! cluster model, or demo the scheduler. The figure regenerators live in
//! `cargo bench` targets; the runnable scenarios in `examples/`.
//!
//! The `info` / `train` / `calibrate` commands execute AOT artifacts over
//! PJRT and need the `xla` feature; without it they print how to enable it.

use anyhow::Result;
use micromoe::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional().first().map(String::as_str) {
        Some("info") => info(&args),
        Some("train") => train(&args),
        Some("calibrate") => calibrate(&args),
        _ => {
            println!(
                "micromoe {} — MicroMoE/MicroEP reproduction\n\n\
                 usage: micromoe <command> [--opts]\n\
                 commands:\n\
                 \x20 info                     show artifact manifest + platform\n\
                 \x20 train [--steps N] [--engine barrier|pipeline|speculative]\n\
                 \x20       [--trace spans.json]\n\
                 \x20                          run the e2e PJRT trainer (MicroEP\n\
                 \x20                          scheduling via the MoeSession facade);\n\
                 \x20                          --trace records scheduling spans and\n\
                 \x20                          exports Chrome-trace JSON\n\
                 \x20 calibrate                fit cost-model constants from PJRT timings\n\
                 figure regenerators: cargo bench (one target per paper figure)\n\
                 examples: cargo run --release --example quickstart",
                micromoe::version()
            );
            Ok(())
        }
    }
}

#[cfg(not(feature = "xla"))]
fn xla_required(cmd: &str) -> Result<()> {
    anyhow::bail!(
        "`{cmd}` executes AOT artifacts over PJRT and needs the `xla` feature: \
         rebuild with `cargo build --features xla` (requires the image's xla bindings)"
    )
}

#[cfg(not(feature = "xla"))]
fn info(_args: &Args) -> Result<()> {
    xla_required("info")
}

#[cfg(not(feature = "xla"))]
fn train(_args: &Args) -> Result<()> {
    xla_required("train")
}

#[cfg(not(feature = "xla"))]
fn calibrate(_args: &Args) -> Result<()> {
    xla_required("calibrate")
}

#[cfg(feature = "xla")]
fn info(_args: &Args) -> Result<()> {
    let rt = micromoe::runtime::Runtime::load_default()?;
    println!("platform: {}", rt.platform());
    println!("preset:   {}", rt.manifest.preset);
    println!("params:   {}", rt.manifest.num_params);
    for a in &rt.manifest.artifacts {
        println!("  {:<18} {} in -> {} out", a.name, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn train(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 64);
    let seed = args.u64_or("seed", 0);
    let spec = args.policy_spec().map_err(|e| anyhow::anyhow!(e))?;
    if spec.name != "micromoe" {
        anyhow::bail!(
            "`train` always schedules with the micromoe policy; `--policy {}` would be \
             ignored (use --engine to pick barrier|pipeline|speculative)",
            spec.name
        );
    }
    if spec.replan_every.is_some() || args.str("policy-seed").is_some() {
        anyhow::bail!(
            "`train` only consumes --engine/--workers/--inflight; \
             --replan-every/--policy-seed have no effect on it"
        );
    }
    let rt = micromoe::runtime::Runtime::load_default()?;
    let mut trainer = micromoe::train::Trainer::new(rt, seed)?;
    if args.str("engine").is_some() {
        // default stays the trainer's pipelined engine; --engine overrides
        trainer.engine_mode = spec.options.engine;
    }
    // --trace: policy_spec() armed a Wall-clock tracer on the options;
    // thread it into the trainer's session so every solve/engine span of
    // the scheduling pipeline lands on one buffer, exported after the run.
    trainer.tracer = spec.options.trace.clone();
    let log = trainer.run(steps, args.usize_or("log-every", 8))?;
    let first = log.losses.first().copied().unwrap_or(f32::NAN);
    let last = log.losses.last().copied().unwrap_or(f32::NAN);
    println!("loss: {first:.4} -> {last:.4} over {steps} steps");
    if let Some(out) = args.str("trace-out") {
        micromoe::train::Trainer::save_trace(&log, &out.into())?;
        println!("trace written to {out}");
    }
    if let Some(path) = args.trace_path() {
        let doc = micromoe::obs::chrome_trace(&trainer.tracer);
        std::fs::write(path, doc.to_string_pretty())?;
        println!(
            "chrome trace written to {path} ({} spans); open in chrome://tracing or Perfetto",
            trainer.tracer.event_count()
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn calibrate(_args: &Args) -> Result<()> {
    let mut rt = micromoe::runtime::Runtime::load_default()?;
    let (small, large) = micromoe::train::Trainer::calibrate(&mut rt)?;
    let mut model = micromoe::cluster::CostModel::h100_testbed();
    model.calibrate_compute(small, large);
    println!("measured: {small:?} {large:?}");
    println!("fitted: t_fixed = {:.3e} s, t_token = {:.3e} s/token", model.t_fixed, model.t_token);
    Ok(())
}
