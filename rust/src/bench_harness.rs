//! Mini-criterion: warmup + timed iterations + robust summary, an aligned
//! table printer for regenerating the paper's figures as text, and the
//! policy-session drivers the figure benches share (criterion is
//! unavailable offline; `cargo bench` targets use `harness = false` and
//! drive this module from `main`).

use std::time::Instant;

use crate::balancer::MoeSession;
use crate::cluster::sim::{moe_layer_time, MoeLayerBreakdown};
use crate::cluster::CostModel;
use crate::placement::random::random_placement;
use crate::rng::Rng;
use crate::scheduler::LoadMatrix;
use crate::ser::Json;
use crate::stats::{imbalance_ratio, Summary};
use crate::topology::Topology;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name (table row / JSON key).
    pub name: String,
    /// per-iteration wall time in seconds
    pub summary: Summary,
}

impl BenchResult {
    /// Median iteration time in microseconds.
    pub fn median_us(&self) -> f64 {
        self.summary.p50 * 1e6
    }
}

/// Time `f` with warmup; `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Auto-scaling: pick an iteration count so the case runs ~`budget` seconds.
pub fn bench_auto<F: FnMut()>(name: &str, budget: f64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f(); // warmup + probe
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget / probe) as usize).clamp(5, 10_000);
    bench(name, 1, iters, f)
}

/// Aligned text table (the figures-as-text output of every bench target).
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table caption (figure name).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells (each row matches `headers` in width).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given caption and columns.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with right-aligned, width-fitted columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// JSON form for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// The six standard arms of the Fig.-6-style end-to-end comparisons
/// (vanilla EP, DeepSpeed padding, SmartMoE(4), FlexMoE(4), MicroMoE,
/// MicroMoE+AR(8)), built through the policy registry — shared by the
/// fig6 bench and the cluster_sim example so the pair can't drift.
/// `migration` charges the periodic policies' expert movements against a
/// cost model (`bytes` copied per moved replica).
pub fn fig6_policy_arms(
    topo: &Topology,
    experts: usize,
    migration: Option<(&CostModel, u64)>,
) -> Vec<MoeSession> {
    // (policy name, re-plan cadence, charge migrations?)
    let arms: [(&str, Option<usize>, bool); 6] = [
        ("vanilla-ep", None, false),
        ("deepspeed-pad", None, false),
        ("smartmoe", Some(4), true),
        ("flexmoe", Some(4), true),
        ("micromoe", None, false),
        ("micromoe-ar", Some(8), true),
    ];
    arms.iter()
        .map(|&(name, replan, migrate)| {
            let mut b = MoeSession::builder()
                .topology(topo.clone())
                .experts(experts)
                .policy_name(name)
                .seed(match name {
                    "flexmoe" => 1,
                    "micromoe-ar" => 11,
                    _ => 0,
                });
            if let Some(every) = replan {
                b = b.replan_every(every);
            }
            if migrate {
                if let Some((model, bytes)) = migration {
                    b = b.migration_cost(model.clone(), bytes);
                }
            }
            b.build().expect("registered comparison arm")
        })
        .collect()
}

/// The Fig.-7 load stream at one skew: 32 experts × 8 GPUs × 2000
/// tokens/GPU Zipf(s) micro-batches from a fixed seed — shared by the
/// fig7 bench and the skew_sweep example so every arm (and both
/// consumers) sees identical loads.
pub fn fig7_zipf_stream(s: f64, batches: usize) -> Vec<LoadMatrix> {
    let mut rng = Rng::new(1);
    let zipf = crate::rng::Zipf::new(32, s);
    (0..batches)
        .map(|_| {
            let mut lm = LoadMatrix::zeros(32, 8);
            for g in 0..8 {
                for _ in 0..2000 {
                    lm.add(zipf.sample(&mut rng), g, 1);
                }
            }
            lm
        })
        .collect()
}

/// The six Fig.-7 skew-sweep arms (vanilla EP, SmartMoE(8), FlexMoE(8),
/// MicroMoE over a random placement, symmetric MicroMoE, MicroMoE+AR(4)),
/// shared by the fig7 bench and the skew_sweep example.
pub fn fig7_policy_arms(topo: &Topology, experts: usize) -> Vec<MoeSession> {
    let session = |name: &str| {
        MoeSession::builder().topology(topo.clone()).experts(experts).policy_name(name)
    };
    let mut rng = Rng::new(99);
    let random = random_placement(topo.microep_group_size(), experts, topo.d, &mut rng);
    vec![
        session("vanilla-ep").build().expect("vanilla arm"),
        session("smartmoe").replan_every(8).build().expect("smartmoe arm"),
        session("flexmoe").seed(1).replan_every(8).build().expect("flexmoe arm"),
        session("micromoe")
            .placement(random)
            .label("MicroMoE (random)")
            .build()
            .expect("random-placement arm"),
        session("micromoe").build().expect("symmetric arm"),
        session("micromoe-ar").seed(5).replan_every(4).build().expect("AR arm"),
    ]
}

/// Mean max/avg GPU-load imbalance of a policy session over a stream of
/// single-layer micro-batch steps, skipping the first `skip` batches
/// (warmup / adaptation transient) — the Fig.-7-style metric every
/// comparison bench reports.
pub fn mean_imbalance(session: &mut MoeSession, batches: &[LoadMatrix], skip: usize) -> f64 {
    assert!(batches.len() > skip, "need at least one measured batch");
    let mut acc = 0.0;
    let mut n = 0usize;
    for (i, lm) in batches.iter().enumerate() {
        let out = session.step(std::slice::from_ref(lm));
        if i >= skip {
            acc += imbalance_ratio(
                &out.layers[0].gpu_compute.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            );
            n += 1;
        }
    }
    acc / n as f64
}

/// Mean Fig.-8 layer breakdown of a policy session over single-layer
/// steps under a cost model. Migration charges (`prep_extra`) are pulled
/// out of the per-layer breakdown and returned separately as a mean
/// per-batch cost, since Fig.-6-style callers amortize them per iteration
/// instead of per layer.
pub fn mean_layer_breakdown(
    session: &mut MoeSession,
    batches: &[LoadMatrix],
    model: &CostModel,
    topo: &Topology,
) -> (MoeLayerBreakdown, f64) {
    assert!(!batches.is_empty());
    let mut acc = MoeLayerBreakdown::default();
    let mut migration = 0.0;
    for lm in batches {
        let mut out = session.step(std::slice::from_ref(lm));
        let plan = &mut out.layers[0];
        migration += plan.prep_extra;
        plan.prep_extra = 0.0;
        let bd = moe_layer_time(model, topo, plan);
        acc.prep += bd.prep;
        acc.dispatch += bd.dispatch;
        acc.compute += bd.compute;
        acc.combine += bd.combine;
    }
    let n = batches.len() as f64;
    let mean = MoeLayerBreakdown {
        prep: acc.prep / n,
        dispatch: acc.dispatch / n,
        compute: acc.compute / n,
        combine: acc.combine / n,
    };
    (mean, migration / n)
}

/// Format an a-vs-b ratio as a speedup cell (`"2.13x"`); `"-"` when the
/// denominator is degenerate. Used by the per-(pricing × factorization)
/// solver tables, where a missing baseline cell must not poison the row.
pub fn fmt_ratio(num: f64, den: f64) -> String {
    if den > 0.0 && num.is_finite() {
        format!("{:.2}x", num / den)
    } else {
        "-".to_string()
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

/// Write a bench artifact (JSON) under `target/bench-results/`.
pub fn save_json(name: &str, j: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.summary.p50 > 0.0);
        assert!(r.summary.min <= r.summary.max);
        assert_eq!(r.summary.n, 20);
    }

    #[test]
    fn auto_scales_iterations() {
        let r = bench_auto("fast", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.summary.n >= 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig X", &["system", "speedup"]);
        t.row(vec!["Megatron-LM".into(), "1.00".into()]);
        t.row(vec!["MicroMoE".into(), "1.42".into()]);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("MicroMoE"));
        // headers and rows aligned to same width
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        assert!(lines[1].len() == lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn policy_arm_helpers_build_the_standard_tables() {
        let topo = Topology::new(8, 4, 2, 8);
        assert_eq!(fig6_policy_arms(&topo, 32, None).len(), 6);
        let arms = fig7_policy_arms(&topo, 32);
        assert_eq!(arms.len(), 6);
        assert_eq!(arms[3].name(), "MicroMoE (random)");
        assert_eq!(arms[5].name(), "MicroMoE");
    }

    #[test]
    fn policy_session_drivers_measure_policies() {
        use crate::rng::{Rng, Zipf};
        let topo = Topology::new(8, 4, 2, 8);
        let mut rng = Rng::new(3);
        let z = Zipf::new(16, 1.2);
        let batches: Vec<LoadMatrix> = (0..4)
            .map(|_| {
                let mut lm = LoadMatrix::zeros(16, 8);
                for g in 0..8 {
                    for _ in 0..300 {
                        lm.add(z.sample(&mut rng), g, 1);
                    }
                }
                lm
            })
            .collect();
        let session = |name: &str| {
            MoeSession::builder()
                .topology(topo.clone())
                .experts(16)
                .policy_name(name)
                .build()
                .unwrap()
        };
        let vi = mean_imbalance(&mut session("vanilla-ep"), &batches, 1);
        let mi = mean_imbalance(&mut session("micromoe"), &batches, 1);
        assert!(mi <= vi + 1e-9, "micromoe {mi} vs vanilla {vi}");
        let model = CostModel::h100_testbed();
        let (mean, migration) =
            mean_layer_breakdown(&mut session("micromoe"), &batches, &model, &topo);
        assert!(mean.compute > 0.0 && mean.total().is_finite());
        assert_eq!(migration, 0.0, "micromoe never migrates");
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-6).contains("us"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
    }

    #[test]
    fn fmt_ratio_handles_degenerate_baselines() {
        assert_eq!(fmt_ratio(4.0, 2.0), "2.00x");
        assert_eq!(fmt_ratio(1.0, 0.0), "-");
        assert_eq!(fmt_ratio(f64::NAN, 2.0), "-");
    }
}
