//! Mini-criterion: warmup + timed iterations + robust summary, and an
//! aligned table printer for regenerating the paper's figures as text.
//! (criterion is unavailable offline; `cargo bench` targets use
//! `harness = false` and drive this module from `main`.)

use std::time::Instant;

use crate::ser::Json;
use crate::stats::Summary;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name (table row / JSON key).
    pub name: String,
    /// per-iteration wall time in seconds
    pub summary: Summary,
}

impl BenchResult {
    /// Median iteration time in microseconds.
    pub fn median_us(&self) -> f64 {
        self.summary.p50 * 1e6
    }
}

/// Time `f` with warmup; `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Auto-scaling: pick an iteration count so the case runs ~`budget` seconds.
pub fn bench_auto<F: FnMut()>(name: &str, budget: f64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f(); // warmup + probe
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget / probe) as usize).clamp(5, 10_000);
    bench(name, 1, iters, f)
}

/// Aligned text table (the figures-as-text output of every bench target).
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table caption (figure name).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells (each row matches `headers` in width).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given caption and columns.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with right-aligned, width-fitted columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// JSON form for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Format an a-vs-b ratio as a speedup cell (`"2.13x"`); `"-"` when the
/// denominator is degenerate. Used by the per-(pricing × factorization)
/// solver tables, where a missing baseline cell must not poison the row.
pub fn fmt_ratio(num: f64, den: f64) -> String {
    if den > 0.0 && num.is_finite() {
        format!("{:.2}x", num / den)
    } else {
        "-".to_string()
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

/// Write a bench artifact (JSON) under `target/bench-results/`.
pub fn save_json(name: &str, j: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/bench-results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 2, 20, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.summary.p50 > 0.0);
        assert!(r.summary.min <= r.summary.max);
        assert_eq!(r.summary.n, 20);
    }

    #[test]
    fn auto_scales_iterations() {
        let r = bench_auto("fast", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.summary.n >= 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig X", &["system", "speedup"]);
        t.row(vec!["Megatron-LM".into(), "1.00".into()]);
        t.row(vec!["MicroMoE".into(), "1.42".into()]);
        let s = t.render();
        assert!(s.contains("Fig X"));
        assert!(s.contains("MicroMoE"));
        // headers and rows aligned to same width
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        assert!(lines[1].len() == lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-6).contains("us"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
    }

    #[test]
    fn fmt_ratio_handles_degenerate_baselines() {
        assert_eq!(fmt_ratio(4.0, 2.0), "2.00x");
        assert_eq!(fmt_ratio(1.0, 0.0), "-");
        assert_eq!(fmt_ratio(f64::NAN, 2.0), "-");
    }
}
