//! Property-testing helper (proptest is unavailable offline).
//!
//! [`forall`] runs a property over many seeded random cases and reports the
//! failing case's seed so it can be replayed exactly:
//!
//! ```no_run
//! use micromoe::prop::forall;
//! forall("sum is commutative", 200, |rng, _case| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Rng;

/// Read a seed override from environment variable `var`, falling back to
/// `default` when unset or unparsable. The LP fuzz suites
/// (`tests/differential_lp.rs`, `tests/prop_lp_certificates.rs`) read
/// `LP_FUZZ_SEED` through this so a CI failure is replayable with
/// `LP_FUZZ_SEED=<seed> cargo test`; each test prints the seed it ran
/// with, which libtest surfaces exactly when the test fails.
pub fn seed_from_env(var: &str, default: u64) -> u64 {
    std::env::var(var).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// The LP fuzz suites' seed hook: `LP_FUZZ_SEED` wins over the test's
/// default, and the value used is printed so a failing run names the seed
/// that reproduces it.
pub fn fuzz_seed(default: u64) -> u64 {
    let seed = seed_from_env("LP_FUZZ_SEED", default);
    eprintln!("replay with: LP_FUZZ_SEED={seed}");
    seed
}

/// Base seed: override with `MICROMOE_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    seed_from_env("MICROMOE_PROP_SEED", 0xC0FFEE)
}

/// Run `prop` on `cases` independent seeded RNGs; panics with the seed of
/// the first failing case.
pub fn forall<F: Fn(&mut Rng, usize) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    prop: F,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng, case);
        });
        if let Err(cause) = result {
            let msg = cause
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| cause.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: MICROMOE_PROP_SEED={base}): {msg}"
            );
        }
    }
}

/// Shrinking-lite: run the property over an explicit size ladder, smallest
/// first, so the smallest failing size is reported.
pub fn forall_sizes<F>(name: &str, sizes: &[usize], cases_per_size: usize, prop: F)
where
    F: Fn(&mut Rng, usize) + std::panic::RefUnwindSafe,
{
    for &size in sizes {
        forall(&format!("{name}[size={size}]"), cases_per_size, |rng, _| {
            prop(rng, size)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("addition commutes", 50, |rng, _| {
            let a = rng.below(100);
            let b = rng.below(100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_case_and_seed() {
        let err = std::panic::catch_unwind(|| {
            forall("always fails", 3, |_rng, _| panic!("boom"));
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always fails"));
        assert!(msg.contains("MICROMOE_PROP_SEED"));
        assert!(msg.contains("boom"));
    }

    #[test]
    fn sizes_run_smallest_first() {
        let seen = std::sync::Mutex::new(Vec::new());
        forall_sizes("sizes", &[2, 8], 1, |_rng, size| {
            seen.lock().unwrap().push(size);
        });
        assert_eq!(*seen.lock().unwrap(), vec![2, 8]);
    }

    #[test]
    fn cases_get_distinct_rngs() {
        let seen = std::sync::Mutex::new(std::collections::HashSet::new());
        forall("distinct", 20, |rng, _| {
            seen.lock().unwrap().insert(rng.next_u64());
        });
        assert_eq!(seen.lock().unwrap().len(), 20);
    }
}
