//! Tiny CLI argument parser (no clap offline): `--key value`, `--flag`,
//! positional args, with typed accessors, usage errors, the
//! [`Args::policy_spec`] bridge that turns `--policy`/`--engine` flags
//! into a [`PolicySpec`] for the [`crate::balancer::MoeSession`] registry,
//! and the [`Args::arrival_process`] / [`Args::serving_config`] bridges
//! the serving tier's examples use.

use std::collections::HashMap;

use crate::config::PolicySpec;
use crate::engine::{EngineMode, ForecastConfig};
use crate::serving::{ArrivalProcess, ServingConfig};

/// Parsed command line: `--key value` / `--key=value` options, bare
/// `--flag`s, and positional arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value for `--key`.
    pub fn str(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// Option value for `--key`, or `default`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    /// `--key` parsed as usize, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as u64, or `default`.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as f64, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Whether `--key` was passed bare (or as `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.str(key) == Some("true")
    }

    /// Positional (non-`--`) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Build a [`PolicySpec`] from the standard policy flags:
    /// `--policy <name>` (registry name, default `micromoe`),
    /// `--engine barrier|pipeline|speculative` with optional `--workers N`
    /// / `--inflight N`, `--policy-seed N`, and `--replan-every N`.
    /// `--trace <path>` additionally enables the Wall-clock
    /// [`crate::obs::Tracer`] on the options — the command owning the run
    /// is expected to export the recorded spans to `<path>` when done
    /// (`micromoe train` writes Chrome-trace JSON there).
    pub fn policy_spec(&self) -> Result<PolicySpec, String> {
        let parse_count = |key: &str| -> Result<usize, String> {
            match self.str(key) {
                Some(v) => v.parse().map_err(|_| format!("--{key}: bad count '{v}'")),
                None => Ok(0),
            }
        };
        let mut spec = PolicySpec::default();
        if let Some(name) = self.str("policy") {
            spec.name = name.to_string();
        }
        if let Some(seed) = self.str("policy-seed") {
            spec.seed = seed.parse().map_err(|_| format!("--policy-seed: bad seed '{seed}'"))?;
        }
        if let Some(every) = self.str("replan-every") {
            spec.replan_every =
                Some(every.parse().map_err(|_| format!("--replan-every: bad count '{every}'"))?);
        }
        if self.trace_path().is_some() {
            spec.options.trace = crate::obs::Tracer::new(crate::obs::TraceConfig::Wall);
        }
        let sized = self.str("workers").is_some() || self.str("inflight").is_some();
        if let Some(engine) = self.str("engine") {
            let workers = parse_count("workers")?;
            let inflight = parse_count("inflight")?;
            spec.options.engine = match engine {
                "barrier" if sized => {
                    return Err(
                        "--workers/--inflight only apply to --engine pipeline|speculative".into()
                    )
                }
                "barrier" => EngineMode::Barrier,
                "pipeline" => EngineMode::Pipeline { workers, inflight },
                "speculative" => EngineMode::Speculative {
                    workers,
                    inflight,
                    forecast: ForecastConfig::default(),
                },
                other => {
                    return Err(format!(
                        "--engine: unknown mode '{other}' (barrier|pipeline|speculative)"
                    ))
                }
            };
        } else if sized {
            return Err(
                "--workers/--inflight require --engine pipeline|speculative".into(),
            );
        }
        Ok(spec)
    }

    /// Destination of `--trace <path>` (the Chrome-trace JSON output the
    /// owning command writes after its run), if tracing was requested.
    pub fn trace_path(&self) -> Option<&str> {
        self.str("trace")
    }

    /// Build an [`ArrivalProcess`] from the standard serving flags:
    /// `--arrival poisson|bursty|diurnal` (default poisson) with
    /// `--rate-hz` (poisson, default 20000),
    /// `--calm-hz`/`--burst-hz`/`--mean-calm-us`/`--mean-burst-us`
    /// (bursty), or `--base-hz`/`--amplitude`/`--period-us` (diurnal).
    pub fn arrival_process(&self) -> Result<ArrivalProcess, String> {
        match self.str_or("arrival", "poisson") {
            "poisson" => Ok(ArrivalProcess::Poisson { rate_hz: self.f64_or("rate-hz", 20_000.0) }),
            "bursty" => Ok(ArrivalProcess::Bursty {
                calm_hz: self.f64_or("calm-hz", 10_000.0),
                burst_hz: self.f64_or("burst-hz", 80_000.0),
                mean_calm_us: self.f64_or("mean-calm-us", 20_000.0),
                mean_burst_us: self.f64_or("mean-burst-us", 4_000.0),
            }),
            "diurnal" => Ok(ArrivalProcess::Diurnal {
                base_hz: self.f64_or("base-hz", 15_000.0),
                amplitude: self.f64_or("amplitude", 0.8),
                period_us: self.f64_or("period-us", 100_000.0),
            }),
            other => Err(format!("--arrival: unknown process '{other}' (poisson|bursty|diurnal)")),
        }
    }

    /// Build a [`ServingConfig`] from the batching-window flags
    /// (`--window-us`, `--max-batch`, `--slo-us`, `--shed-after-us`),
    /// keeping the default solve/dispatch cost charges.
    pub fn serving_config(&self) -> ServingConfig {
        let d = ServingConfig::default();
        ServingConfig {
            window_us: self.f64_or("window-us", d.window_us),
            max_batch: self.usize_or("max-batch", d.max_batch),
            slo_us: self.f64_or("slo-us", d.slo_us),
            shed_after_us: self.f64_or("shed-after-us", d.shed_after_us),
            ..d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse("--steps 100 --skew 1.5 --name fig7");
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("skew", 0.0), 1.5);
        assert_eq!(a.str("name"), Some("fig7"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--steps=42 --mode=comm");
        assert_eq!(a.usize_or("steps", 0), 42);
        assert_eq!(a.str("mode"), Some("comm"));
    }

    #[test]
    fn bare_flags() {
        let a = parse("--verbose --steps 5 --dry-run");
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("steps", 0), 5);
    }

    #[test]
    fn positionals_and_defaults() {
        let a = parse("run fig7 --out x.json");
        assert_eq!(a.positional(), &["run".to_string(), "fig7".to_string()]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "d"), "d");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b value");
        assert!(a.flag("a"));
        assert_eq!(a.str("b"), Some("value"));
    }

    #[test]
    fn policy_spec_defaults_to_micromoe() {
        let spec = parse("").policy_spec().unwrap();
        assert_eq!(spec, PolicySpec::default());
        assert_eq!(spec.name, "micromoe");
    }

    #[test]
    fn policy_spec_parses_engine_flags() {
        let spec = parse("--policy micromoe --engine speculative --workers 2 --inflight 3")
            .policy_spec()
            .unwrap();
        assert!(matches!(
            spec.options.engine,
            EngineMode::Speculative { workers: 2, inflight: 3, .. }
        ));
        let spec = parse("--engine barrier").policy_spec().unwrap();
        assert_eq!(spec.options.engine, EngineMode::Barrier);
    }

    #[test]
    fn policy_spec_parses_policy_knobs() {
        let spec = parse("--policy flexmoe --policy-seed 7 --replan-every 4")
            .policy_spec()
            .unwrap();
        assert_eq!(spec.name, "flexmoe");
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.replan_every, Some(4));
    }

    #[test]
    fn policy_spec_enables_tracing() {
        let args = parse("--trace out.json");
        assert_eq!(args.trace_path(), Some("out.json"));
        let spec = args.policy_spec().unwrap();
        assert!(spec.options.trace.enabled());
        assert_eq!(spec.options.trace.config(), crate::obs::TraceConfig::Wall);
        // tracing stays off (zero-cost) unless explicitly requested
        let plain = parse("--engine pipeline").policy_spec().unwrap();
        assert!(!plain.options.trace.enabled());
    }

    #[test]
    fn policy_spec_rejects_bad_engine() {
        assert!(parse("--engine warp").policy_spec().is_err());
        assert!(parse("--replan-every soon").policy_spec().is_err());
    }

    #[test]
    fn arrival_process_parses_every_regime() {
        assert!(matches!(
            parse("").arrival_process().unwrap(),
            ArrivalProcess::Poisson { rate_hz } if rate_hz == 20_000.0
        ));
        assert!(matches!(
            parse("--arrival poisson --rate-hz 5000").arrival_process().unwrap(),
            ArrivalProcess::Poisson { rate_hz } if rate_hz == 5_000.0
        ));
        assert!(matches!(
            parse("--arrival bursty --burst-hz 90000").arrival_process().unwrap(),
            ArrivalProcess::Bursty { burst_hz, .. } if burst_hz == 90_000.0
        ));
        assert!(matches!(
            parse("--arrival diurnal --amplitude 0.5").arrival_process().unwrap(),
            ArrivalProcess::Diurnal { amplitude, .. } if amplitude == 0.5
        ));
        assert!(parse("--arrival tidal").arrival_process().is_err());
    }

    #[test]
    fn serving_config_overrides_window_knobs() {
        let cfg = parse("--window-us 250 --max-batch 8 --slo-us 2000").serving_config();
        assert_eq!(cfg.window_us, 250.0);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.slo_us, 2_000.0);
        assert!(cfg.shed_after_us.is_infinite(), "default admission keeps everything");
    }

    #[test]
    fn policy_spec_rejects_orphan_sizing_flags() {
        // --workers/--inflight would be silently ignored without an engine
        assert!(parse("--workers 4").policy_spec().is_err());
        assert!(parse("--inflight 2").policy_spec().is_err());
        assert!(parse("--engine barrier --workers 4").policy_spec().is_err());
        assert!(parse("--engine pipeline --workers 4").policy_spec().is_ok());
        // unparseable counts/seeds error instead of falling back to defaults
        assert!(parse("--engine pipeline --workers sixteen").policy_spec().is_err());
        assert!(parse("--policy-seed abc").policy_spec().is_err());
    }
}
