//! Tiny CLI argument parser (no clap offline): `--key value`, `--flag`,
//! positional args, with typed accessors and usage errors.

use std::collections::HashMap;

/// Parsed command line: `--key value` / `--key=value` options, bare
/// `--flag`s, and positional arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value for `--key`.
    pub fn str(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// Option value for `--key`, or `default`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    /// `--key` parsed as usize, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as u64, or `default`.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as f64, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Whether `--key` was passed bare (or as `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.str(key) == Some("true")
    }

    /// Positional (non-`--`) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse("--steps 100 --skew 1.5 --name fig7");
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("skew", 0.0), 1.5);
        assert_eq!(a.str("name"), Some("fig7"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--steps=42 --mode=comm");
        assert_eq!(a.usize_or("steps", 0), 42);
        assert_eq!(a.str("mode"), Some("comm"));
    }

    #[test]
    fn bare_flags() {
        let a = parse("--verbose --steps 5 --dry-run");
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("steps", 0), 5);
    }

    #[test]
    fn positionals_and_defaults() {
        let a = parse("run fig7 --out x.json");
        assert_eq!(a.positional(), &["run".to_string(), "fig7".to_string()]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "d"), "d");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b value");
        assert!(a.flag("a"));
        assert_eq!(a.str("b"), Some("value"));
    }
}
