//! Hot/cold expert detection: per-expert EWMA load shares with dual
//! hysteresis state machines.
//!
//! The detector observes each layer's raw `input_e^g` totals *before*
//! scheduling, so its state — and therefore every controller decision — is
//! a pure function of the load trace, the spec, and the seed, independent
//! of how (or on how many workers) the fast loop solved the LPs.
//!
//! Hysteresis follows the classic thermostat shape: a *hot* flag turns on
//! only after the smoothed share exceeds `hot_enter / E` for `dwell`
//! consecutive observations, and turns off only after it drops below
//! `hot_exit / E` for `dwell` consecutive observations (with
//! `hot_exit < hot_enter`, so shares oscillating inside the band never
//! flap the flag). The *cold* flag is the mirror image around
//! `cold_enter / E < cold_exit / E`.

use super::ControlSpec;

/// Per-expert load EWMA plus hot/cold hysteresis state (one per layer in
/// the controller).
#[derive(Clone, Debug)]
pub struct LoadDetector {
    alpha: f64,
    hot_enter: f64,
    hot_exit: f64,
    cold_enter: f64,
    cold_exit: f64,
    dwell: usize,
    /// smoothed load *shares* (sum ≈ 1 once primed)
    ema: Vec<f64>,
    primed: bool,
    hot: Vec<bool>,
    hot_run: Vec<usize>,
    cold: Vec<bool>,
    cold_run: Vec<usize>,
    observed: usize,
}

impl LoadDetector {
    /// Fresh detector for `num_experts` experts under `spec`'s thresholds.
    /// Thresholds are stored pre-scaled by the uniform share `1/E`.
    pub fn new(num_experts: usize, spec: &ControlSpec) -> Self {
        assert!(num_experts > 0, "detector needs at least one expert");
        let uniform = 1.0 / num_experts as f64;
        LoadDetector {
            alpha: spec.ema_alpha,
            hot_enter: spec.hot_enter * uniform,
            hot_exit: spec.hot_exit * uniform,
            cold_enter: spec.cold_enter * uniform,
            cold_exit: spec.cold_exit * uniform,
            dwell: spec.dwell,
            ema: vec![0.0; num_experts],
            primed: false,
            hot: vec![false; num_experts],
            hot_run: vec![0; num_experts],
            cold: vec![false; num_experts],
            cold_run: vec![0; num_experts],
            observed: 0,
        }
    }

    /// Feed one step's per-expert token totals. An all-zero step (no MoE
    /// tokens this micro-batch) is skipped entirely — it carries no share
    /// information and must not decay the EWMA toward zero.
    pub fn observe(&mut self, loads: &[u64]) {
        assert_eq!(loads.len(), self.ema.len(), "one load per expert");
        let total: u64 = loads.iter().sum();
        if total == 0 {
            return;
        }
        let inv = 1.0 / total as f64;
        if !self.primed {
            for (m, &x) in self.ema.iter_mut().zip(loads) {
                *m = x as f64 * inv;
            }
            self.primed = true;
        } else {
            for (m, &x) in self.ema.iter_mut().zip(loads) {
                *m = self.alpha * (x as f64 * inv) + (1.0 - self.alpha) * *m;
            }
        }
        self.observed += 1;
        for e in 0..self.ema.len() {
            let m = self.ema[e];
            // hot machine
            let crossing = if self.hot[e] { m < self.hot_exit } else { m > self.hot_enter };
            if crossing {
                self.hot_run[e] += 1;
                if self.hot_run[e] >= self.dwell {
                    self.hot[e] = !self.hot[e];
                    self.hot_run[e] = 0;
                }
            } else {
                self.hot_run[e] = 0;
            }
            // cold machine (mirror image)
            let crossing = if self.cold[e] { m > self.cold_exit } else { m < self.cold_enter };
            if crossing {
                self.cold_run[e] += 1;
                if self.cold_run[e] >= self.dwell {
                    self.cold[e] = !self.cold[e];
                    self.cold_run[e] = 0;
                }
            } else {
                self.cold_run[e] = 0;
            }
        }
    }

    /// Smoothed per-expert load shares (all zero until the first non-empty
    /// observation).
    pub fn ema(&self) -> &[f64] {
        &self.ema
    }

    /// Experts currently flagged persistently hot.
    pub fn hot(&self) -> &[bool] {
        &self.hot
    }

    /// Experts currently flagged persistently cold.
    pub fn cold(&self) -> &[bool] {
        &self.cold
    }

    /// Non-empty observations folded in so far.
    pub fn observed(&self) -> usize {
        self.observed
    }

    /// Number of experts tracked.
    pub fn num_experts(&self) -> usize {
        self.ema.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ControlSpec {
        ControlSpec { dwell: 3, ..Default::default() }
    }

    /// skewed step: expert 0 takes `frac` of 1000 tokens, rest uniform
    fn skewed(e: usize, frac: f64) -> Vec<u64> {
        let hotload = (1000.0 * frac) as u64;
        let rest = (1000 - hotload) / (e as u64 - 1);
        let mut v = vec![rest; e];
        v[0] = hotload;
        v
    }

    #[test]
    fn first_observation_seeds_ema_exactly() {
        let mut d = LoadDetector::new(4, &spec());
        d.observe(&[10, 20, 30, 40]);
        assert_eq!(d.ema(), &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(d.observed(), 1);
    }

    #[test]
    fn zero_total_steps_are_skipped() {
        let mut d = LoadDetector::new(4, &spec());
        d.observe(&[10, 20, 30, 40]);
        let before = d.ema().to_vec();
        d.observe(&[0, 0, 0, 0]);
        assert_eq!(d.ema(), &before[..]);
        assert_eq!(d.observed(), 1);
    }

    #[test]
    fn dwell_blocks_single_spike() {
        let mut d = LoadDetector::new(8, &spec());
        // steady uniform, then one hot spike, then uniform again
        for _ in 0..5 {
            d.observe(&[125; 8]);
        }
        d.observe(&skewed(8, 0.9));
        assert!(!d.hot()[0], "one spike must not flip the hot flag");
    }

    #[test]
    fn sustained_heat_enters_after_dwell_and_band_prevents_flapping() {
        let mut d = LoadDetector::new(8, &spec());
        // sustained 60% share on expert 0: uniform share is 1/8, so the
        // EWMA crosses 2/8 quickly and must stay crossed `dwell` steps
        for _ in 0..10 {
            d.observe(&skewed(8, 0.6));
        }
        assert!(d.hot()[0], "sustained skew must flag hot");
        assert!(!d.cold()[0]);
        assert!(d.cold().iter().skip(1).all(|&c| c), "starved experts go cold");
        // decay into the hysteresis band (between hot_exit and hot_enter):
        // the flag must hold
        let spec_scaled_exit = 1.5 / 8.0;
        let spec_scaled_enter = 2.0 / 8.0;
        for _ in 0..100 {
            d.observe(&skewed(8, 0.23)); // share inside (1.5/8, 2/8)
            let m = d.ema()[0];
            if m < spec_scaled_enter && m > spec_scaled_exit {
                assert!(d.hot()[0], "EWMA inside the band must not exit hot");
            }
        }
        // full cooldown exits
        for _ in 0..50 {
            d.observe(&[125; 8]);
        }
        assert!(!d.hot()[0], "uniform load must eventually exit hot");
    }

    #[test]
    fn detector_state_is_independent_of_call_site() {
        // bit-determinism: two detectors fed the same trace agree exactly
        let (mut a, mut b) = (LoadDetector::new(8, &spec()), LoadDetector::new(8, &spec()));
        let mut x = 1u64;
        for _ in 0..64 {
            // cheap LCG trace
            let loads: Vec<u64> = (0..8)
                .map(|_| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    x >> 56
                })
                .collect();
            a.observe(&loads);
            b.observe(&loads);
        }
        assert_eq!(a.ema(), b.ema());
        assert_eq!(a.hot(), b.hot());
        assert_eq!(a.cold(), b.cold());
    }
}
