//! Decision execution: the two-timescale balancer.
//!
//! [`ControlledLppBalancer`] wraps the barrier LPP fan-out (the
//! [`crate::balancer::LppBalancer`] machinery) with the slow control loop:
//! every step it feeds the raw per-layer loads to the detectors, and every
//! `interval` steps it runs [`super::decide`] per layer. A committed
//! decision
//!
//! 1. emits a [`crate::obs::Span::PlacementChange`] trace span,
//! 2. swaps the layer's placement and **rebuilds that layer's scheduler
//!    only** (a fresh [`MicroEpScheduler`] starts with a cold warm-start
//!    basis, so the invalidation shows up honestly as one `cold_lp` rung
//!    in [`crate::stats::DegradationStats`]; untouched layers keep their
//!    warm bases),
//! 3. charges the migration downtime into the layer's `prep_extra` for
//!    this step (and into [`crate::stats::ControlStats`]), and
//! 4. re-plans the step against the new placement.
//!
//! Realized gain is scored one tick later: the density of the *old*
//! placement under the *new* EWMA shares minus the new placement's — the
//! honest "what did the move actually buy" number
//! ([`crate::stats::ControlStats::gain_accuracy`]).

// fold_plan / fold_schedule / schedule_to_plan are the same crate-internal
// helpers the plain policies use, so the controlled arm's accounting stays
// bit-identical to LppBalancer's outside of control ticks
use crate::balancer::{
    fold_plan, fold_schedule, schedule_to_plan, Balancer, MoeLayerPlan, StepInput, StepOutput,
};
use crate::cluster::CostModel;
use crate::obs::Span;
use crate::placement::graph::max_induced_density;
use crate::placement::Placement;
use crate::rng::Rng;
use crate::scheduler::{schedule_layers_parallel, LoadMatrix, MicroEpScheduler, SchedulerOptions};
use crate::stats::{BalancerStats, ControlStats, StepStats};
use crate::topology::Topology;

use super::{decide, ControlSpec, LoadDetector};

/// The `"micromoe"` barrier policy with the slow placement-control loop
/// attached: per-layer warm-started LPP scheduling every step, per-layer
/// replicate/evict placement adaptation every [`ControlSpec::interval`]
/// steps. Built by `MoeSession::builder().control(..)`.
pub struct ControlledLppBalancer {
    topo: Topology,
    opts: SchedulerOptions,
    model: CostModel,
    spec: ControlSpec,
    slot_budget: usize,
    overlap: bool,
    placements: Vec<Placement>,
    scheds: Vec<MicroEpScheduler>,
    detectors: Vec<LoadDetector>,
    rngs: Vec<Rng>,
    /// old placement per layer awaiting realized-gain scoring next tick
    pending: Vec<Option<Placement>>,
    step: usize,
    ticks: usize,
    stats: BalancerStats,
}

impl ControlledLppBalancer {
    /// One detector + scheduler + decision stream per layer over a shared
    /// starting placement. `seed` forks one decision rng per layer (only
    /// consumed by the approximate density evaluator, i.e. never at ≤16
    /// GPUs). The controller may deepen GPUs up to the starting
    /// placement's deepest slot count plus [`ControlSpec::slot_headroom`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        placement: Placement,
        topo: Topology,
        opts: SchedulerOptions,
        layers: usize,
        overlap: bool,
        spec: ControlSpec,
        model: CostModel,
        seed: u64,
    ) -> Self {
        assert!(layers > 0, "balancer needs at least one layer");
        spec.validate().expect("control spec must be validated by the builder");
        let g = placement.num_gpus;
        let deepest = (0..g).map(|gpu| placement.slots_used(gpu)).max().unwrap_or(1);
        let slot_budget = deepest + spec.slot_headroom;
        let scheds = (0..layers)
            .map(|l| {
                let mut s =
                    MicroEpScheduler::new(placement.clone(), Some(topo.clone()), opts.clone());
                s.set_layer(l);
                s
            })
            .collect();
        let detectors =
            (0..layers).map(|_| LoadDetector::new(placement.num_experts, &spec)).collect();
        let mut root = Rng::new(seed);
        let rngs = (0..layers).map(|l| root.fork(l as u64)).collect();
        ControlledLppBalancer {
            topo,
            opts,
            model,
            spec,
            slot_budget,
            overlap,
            placements: vec![placement; layers],
            scheds,
            detectors,
            rngs,
            pending: vec![None; layers],
            step: 0,
            ticks: 0,
            stats: BalancerStats::default(),
        }
    }

    /// MoE layers scheduled per step.
    pub fn layers(&self) -> usize {
        self.scheds.len()
    }

    /// Control ticks run so far.
    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Current per-layer placements (starts as `layers` copies of the
    /// build placement; diverges as per-layer decisions commit).
    pub fn placements(&self) -> &[Placement] {
        &self.placements
    }

    /// Layer `l`'s detector state — the replay surface the golden and
    /// determinism tests drive independently of any scheduling.
    pub fn detector(&self, layer: usize) -> &LoadDetector {
        &self.detectors[layer]
    }

    /// Run one control tick over every layer: score last tick's realized
    /// gains, then ask [`decide`] for new placements. Returns this tick's
    /// [`ControlStats`] plus the per-layer downtime to charge.
    fn control_tick(&mut self) -> (ControlStats, Vec<f64>) {
        self.ticks += 1;
        let mut control = ControlStats { ticks: 1, ..Default::default() };
        let mut charge = vec![0.0; self.scheds.len()];
        for l in 0..self.scheds.len() {
            // realized gain of the *previous* decision, under today's EWMA
            if let Some(old) = self.pending[l].take() {
                let ema: Vec<f64> = self.detectors[l].ema().to_vec();
                let d_old = max_induced_density(&old, &ema, &mut self.rngs[l]).density;
                let d_new =
                    max_induced_density(&self.placements[l], &ema, &mut self.rngs[l]).density;
                control.realized_gain += d_old - d_new;
            }
            let Some(d) = decide(
                &self.placements[l],
                &self.detectors[l],
                &self.topo,
                &self.model,
                &self.spec,
                self.slot_budget,
                &mut self.rngs[l],
            ) else {
                continue;
            };
            self.opts.trace.record(d.downtime * 1e6, Span::PlacementChange {
                step: self.step,
                tick: self.ticks,
                moves: d.moves.len(),
                bytes: d.bytes,
                predicted_gain: d.predicted_gain,
                downtime: d.downtime,
            });
            control.decisions += 1;
            control.moves += d.moves.len() as u64;
            control.bytes += d.bytes;
            control.downtime += d.downtime;
            control.predicted_gain += d.predicted_gain;
            charge[l] = d.downtime;
            // swap the placement; keep the old one for realized-gain
            // scoring at the next tick
            let old = std::mem::replace(&mut self.placements[l], d.placement);
            self.pending[l] = Some(old);
            // warm-basis invalidation, this layer only: a fresh scheduler
            // has no basis, so its next solve takes the cold_lp rung
            let mut fresh = MicroEpScheduler::new(
                self.placements[l].clone(),
                Some(self.topo.clone()),
                self.opts.clone(),
            );
            fresh.set_layer(l);
            self.scheds[l] = fresh;
        }
        (control, charge)
    }
}

impl Balancer for ControlledLppBalancer {
    fn name(&self) -> &str {
        "MicroMoE (controlled)"
    }

    fn step(&mut self, input: &StepInput) -> StepOutput {
        assert_eq!(input.loads.len(), self.scheds.len(), "one load matrix per layer");
        // detectors see the raw input loads before any scheduling — the
        // decision stream depends only on the load trace, spec, and seed
        for (det, lm) in self.detectors.iter_mut().zip(input.loads) {
            det.observe(&lm.expert_loads());
        }
        self.step += 1;
        let (control, charge) = if self.step % self.spec.interval == 0 {
            self.control_tick()
        } else {
            (ControlStats::default(), vec![0.0; self.scheds.len()])
        };
        // re-plan against the (possibly just-changed) placements
        let schedules = schedule_layers_parallel(&mut self.scheds, input.loads);
        let mut stats = StepStats::default();
        let layers: Vec<MoeLayerPlan> = schedules
            .into_iter()
            .enumerate()
            .map(|(l, s)| {
                fold_schedule(&mut stats, &s.stats);
                let mut plan = schedule_to_plan(s, &self.placements[l], self.overlap);
                plan.prep_extra += charge[l];
                fold_plan(&mut stats, &plan);
                plan
            })
            .collect();
        stats.control = control;
        self.stats.absorb(&stats);
        StepOutput { layers, stats }
    }

    fn warm_hint(&mut self, expected: &[LoadMatrix]) {
        assert_eq!(expected.len(), self.scheds.len(), "one expected load matrix per layer");
        // prime each layer's warm basis with a discarded solve; detectors
        // are NOT fed — hints are speculative, not observed traffic
        for (s, lm) in self.scheds.iter_mut().zip(expected) {
            let _ = s.schedule(lm);
        }
    }

    fn stats(&self) -> BalancerStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::migration::expert_bytes;
    use crate::placement::cayley::symmetric_placement;
    use crate::workload::{DriftingWorkload, Workload};

    fn topo() -> Topology {
        Topology::new(8, 4, 2, 8)
    }

    fn spec() -> ControlSpec {
        ControlSpec {
            interval: 4,
            dwell: 2,
            bytes_per_expert: expert_bytes(256, 1024, true),
            ..Default::default()
        }
    }

    fn controlled(layers: usize) -> ControlledLppBalancer {
        let topo = topo();
        let placement = symmetric_placement(&topo, 16);
        ControlledLppBalancer::new(
            placement,
            topo,
            SchedulerOptions::default(),
            layers,
            false,
            spec(),
            CostModel::h100_testbed(),
            42,
        )
    }

    fn drift_trace(steps: usize, layers: usize) -> Vec<Vec<LoadMatrix>> {
        let mut wl = DriftingWorkload::new(16, 8, 2048, 1.2, 8, 99);
        (0..steps).map(|_| (0..layers).map(|_| wl.next_batch()).collect()).collect()
    }

    #[test]
    fn controller_ticks_and_conserves_tokens() {
        let mut b = controlled(2);
        let trace = drift_trace(20, 2);
        for (i, loads) in trace.iter().enumerate() {
            let out = b.step(&StepInput { loads });
            assert_eq!(out.layers.len(), 2);
            for (l, plan) in out.layers.iter().enumerate() {
                assert_eq!(
                    plan.gpu_compute.iter().sum::<u64>(),
                    loads[l].total(),
                    "token conservation at step {i} layer {l}"
                );
            }
        }
        assert_eq!(b.ticks(), 5, "20 steps / interval 4");
        let st = b.stats();
        assert_eq!(st.control.ticks, 5);
        assert!(st.control.decisions > 0, "drifting Zipf must trigger decisions");
        assert!(st.control.downtime > 0.0);
        // downtime was charged into prep time
        assert!(st.prep_seconds >= st.control.downtime - 1e-12);
    }

    #[test]
    fn reruns_are_bit_identical() {
        let trace = drift_trace(24, 1);
        let run = || {
            let mut b = controlled(1);
            for loads in &trace {
                b.step(&StepInput { loads });
            }
            (b.stats(), b.placements().to_vec())
        };
        let (sa, pa) = run();
        let (sb, pb) = run();
        assert_eq!(sa.control, sb.control);
        assert_eq!(pa[0].replicas, pb[0].replicas);
        assert_eq!(sa.control.downtime.to_bits(), sb.control.downtime.to_bits());
        assert_eq!(sa.control.predicted_gain.to_bits(), sb.control.predicted_gain.to_bits());
    }

    #[test]
    fn off_tick_steps_never_touch_placement() {
        let mut b = controlled(1);
        let trace = drift_trace(3, 1); // interval 4: no tick in 3 steps
        let before = b.placements()[0].replicas.clone();
        for loads in &trace {
            let out = b.step(&StepInput { loads });
            assert_eq!(out.stats.control, ControlStats::default());
            assert_eq!(out.layers[0].prep_extra, 0.0);
        }
        assert_eq!(b.placements()[0].replicas, before);
        assert_eq!(b.ticks(), 0);
    }

    #[test]
    fn only_decided_layers_lose_their_warm_basis() {
        // layer 0 sees drifting skew (decisions), layer 1 steady uniform
        // (no decisions): layer 1 must keep warm-solving every step after
        // the first, i.e. cold_lp rung count stays at layers-with-decisions
        let mut b = controlled(2);
        let mut wl = DriftingWorkload::new(16, 8, 2048, 1.4, 6, 5);
        let uniform = {
            let mut lm = LoadMatrix::zeros(16, 8);
            for e in 0..16 {
                for g in 0..8 {
                    lm.add(e, g, 16);
                }
            }
            lm
        };
        for _ in 0..24 {
            let loads = vec![wl.next_batch(), uniform.clone()];
            b.step(&StepInput { loads: &loads });
        }
        let st = b.stats();
        // every decision costs exactly one cold re-solve (the rebuilt
        // layer); the two initial cold solves are the baseline
        assert_eq!(st.degradation.cold_lp, 2 + st.control.decisions, "per-layer invalidation");
    }
}
