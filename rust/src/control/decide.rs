//! Placement-change proposals: greedy replication of hot experts (with
//! cold-replica eviction when slots run out) scored by predicted Eq.-3
//! density gain against the migration bill.
//!
//! Each control tick calls [`decide`] with the layer's current placement
//! and detector state. The decider builds a proposal one operation at a
//! time: every operation is re-scored with the real density evaluator
//! ([`crate::placement::graph::max_induced_density`]) on the EWMA load
//! shares and re-priced with the real migration model
//! ([`crate::cluster::migration::migration_time`]) on the cumulative move
//! list — so the accepted decision's predicted gain and downtime are
//! exactly what the balancer then charges and traces.

use crate::cluster::migration::{migration_time, placement_diff, Move};
use crate::cluster::CostModel;
use crate::placement::graph::max_induced_density;
use crate::placement::Placement;
use crate::rng::Rng;
use crate::topology::Topology;

use super::{ControlSpec, LoadDetector};

/// One committed placement change: the new placement, the replica copies
/// that realize it, and the decision-time accounting the balancer charges
/// into [`crate::stats::ControlStats`].
#[derive(Clone, Debug)]
pub struct Decision {
    /// The placement to switch to (already [`Placement::validate`]d).
    pub placement: Placement,
    /// Replica copies `placement_diff(old, new)` — deterministically
    /// ordered by `(expert, src, dst)`.
    pub moves: Vec<Move>,
    /// Predicted Eq.-3 density improvement on the EWMA shares (old density
    /// minus new density; positive).
    pub predicted_gain: f64,
    /// Migration downtime for `moves`, seconds.
    pub downtime: f64,
    /// Total bytes migrated (`moves.len() × bytes_per_expert`).
    pub bytes: u64,
    /// Hot-expert replications in the proposal.
    pub replications: usize,
    /// Cold replicas evicted to make room.
    pub evictions: usize,
}

/// Proxy per-GPU load under `ema` shares: each expert's share split evenly
/// across its replicas. Cheap stand-in for ranking destination GPUs; the
/// accept/reject call is always made by the real density evaluator.
fn proxy_loads(replicas: &[Vec<usize>], ema: &[f64], num_gpus: usize) -> Vec<f64> {
    let mut proxy = vec![0.0; num_gpus];
    for (e, group) in replicas.iter().enumerate() {
        let per = ema[e] / group.len() as f64;
        for &g in group {
            proxy[g] += per;
        }
    }
    proxy
}

/// Propose a placement change for one layer, or `None` when the current
/// placement is already good enough (no hot experts, every operation over
/// budget, or total predicted gain under `min_gain × current density`).
///
/// Deterministic for a fixed `(current, detector, spec, rng)` state: hot
/// experts are visited by descending EWMA share (index ties ascending),
/// candidate GPUs by ascending proxy load (then index), eviction victims
/// by ascending EWMA share (then index). `rng` is only consumed by the
/// approximate density evaluator, i.e. never at ≤16 GPUs.
#[allow(clippy::too_many_arguments)]
pub fn decide(
    current: &Placement,
    detector: &LoadDetector,
    topo: &Topology,
    model: &CostModel,
    spec: &ControlSpec,
    slot_budget: usize,
    rng: &mut Rng,
) -> Option<Decision> {
    assert_eq!(detector.num_experts(), current.num_experts, "detector/placement shape");
    if detector.observed() == 0 {
        return None;
    }
    let g = current.num_gpus;
    let ema: Vec<f64> = detector.ema().to_vec();
    let base = max_induced_density(current, &ema, rng).density;

    // working replica groups, mutated op by op
    let mut working: Vec<Vec<usize>> = current.replicas.clone();
    let mut used: Vec<usize> = (0..g)
        .map(|gpu| working.iter().filter(|grp| grp.contains(&gpu)).count())
        .collect();

    let mut hot: Vec<usize> =
        (0..working.len()).filter(|&e| detector.hot()[e]).collect();
    hot.sort_by(|&a, &b| {
        ema[b].partial_cmp(&ema[a]).expect("EWMA shares are finite").then(a.cmp(&b))
    });

    let mut cur_density = base;
    let mut replications = 0usize;
    let mut evictions = 0usize;

    for &e in &hot {
        if working[e].len() >= g {
            continue; // already everywhere
        }
        let proxy = proxy_loads(&working, &ema, g);
        // coolest non-hosting GPU with a free slot
        let mut dst = (0..g)
            .filter(|&gpu| !working[e].contains(&gpu) && used[gpu] < slot_budget)
            .min_by(|&a, &b| {
                proxy[a].partial_cmp(&proxy[b]).expect("proxy loads are finite").then(a.cmp(&b))
            });
        // no free slot anywhere: evict the coldest cold replica on the
        // coolest non-hosting GPU that has one
        let mut evicted: Option<(usize, usize)> = None; // (victim expert, gpu)
        if dst.is_none() {
            let mut gpus: Vec<usize> =
                (0..g).filter(|&gpu| !working[e].contains(&gpu)).collect();
            gpus.sort_by(|&a, &b| {
                proxy[a].partial_cmp(&proxy[b]).expect("proxy loads are finite").then(a.cmp(&b))
            });
            'search: for gpu in gpus {
                let victim = (0..working.len())
                    .filter(|&c| {
                        c != e
                            && detector.cold()[c]
                            && !detector.hot()[c]
                            && working[c].len() > 1
                            && working[c].contains(&gpu)
                    })
                    .min_by(|&a, &b| {
                        ema[a]
                            .partial_cmp(&ema[b])
                            .expect("EWMA shares are finite")
                            .then(a.cmp(&b))
                    });
                if let Some(c) = victim {
                    working[c].retain(|&x| x != gpu);
                    used[gpu] -= 1;
                    evicted = Some((c, gpu));
                    dst = Some(gpu);
                    break 'search;
                }
            }
        }
        let Some(dst) = dst else { continue };

        // tentative op: replicate e onto dst
        working[e].push(dst);
        working[e].sort_unstable();
        used[dst] += 1;
        let tentative = Placement::from_replicas(g, working.clone());
        let moves = placement_diff(current, &tentative, topo);
        let over_budget = moves.len() > spec.max_moves
            || migration_time(&moves, spec.bytes_per_expert, model, topo, g)
                > spec.budget_seconds;
        let density =
            if over_budget { f64::INFINITY } else { max_induced_density(&tentative, &ema, rng).density };
        if !over_budget && density < cur_density - 1e-12 {
            cur_density = density;
            replications += 1;
            if evicted.is_some() {
                evictions += 1;
            }
        } else {
            // revert the op (different later ops may still fit the budget)
            working[e].retain(|&x| x != dst);
            used[dst] -= 1;
            if let Some((c, gpu)) = evicted {
                working[c].push(gpu);
                working[c].sort_unstable();
                used[gpu] += 1;
            }
        }
    }

    if replications == 0 {
        return None;
    }
    let predicted_gain = base - cur_density;
    if predicted_gain <= spec.min_gain * base {
        return None;
    }
    let placement = Placement::from_replicas(g, working);
    placement.validate().expect("controller proposed an invalid placement");
    let moves = placement_diff(current, &placement, topo);
    let downtime = migration_time(&moves, spec.bytes_per_expert, model, topo, g);
    let bytes = moves.len() as u64 * spec.bytes_per_expert;
    Some(Decision { placement, moves, predicted_gain, downtime, bytes, replications, evictions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::migration::expert_bytes;

    fn topo() -> Topology {
        Topology::new(4, 2, 2, 2)
    }

    fn spec() -> ControlSpec {
        ControlSpec {
            dwell: 2,
            // small expert so the default 0.5 s budget fits several copies
            bytes_per_expert: expert_bytes(256, 1024, true),
            ..Default::default()
        }
    }

    /// Detector driven to a steady skew: expert 0 hot, the tail cold.
    fn skewed_detector(experts: usize, spec: &ControlSpec) -> LoadDetector {
        let mut d = LoadDetector::new(experts, spec);
        let mut loads = vec![40u64; experts];
        loads[0] = 1000;
        for _ in 0..12 {
            d.observe(&loads);
        }
        assert!(d.hot()[0], "setup: expert 0 must be hot");
        d
    }

    #[test]
    fn stationary_uniform_yields_no_decision() {
        let s = spec();
        let mut d = LoadDetector::new(8, &s);
        for _ in 0..20 {
            d.observe(&[100; 8]);
        }
        let p = Placement::from_replicas(4, (0..8).map(|e| vec![e % 4]).collect());
        let mut rng = Rng::new(1);
        assert!(decide(&p, &d, &topo(), &CostModel::h100_testbed(), &s, 3, &mut rng).is_none());
    }

    #[test]
    fn hot_expert_gets_replicated_with_positive_gain() {
        let s = spec();
        let d = skewed_detector(8, &s);
        let p = Placement::from_replicas(4, (0..8).map(|e| vec![e % 4]).collect());
        let mut rng = Rng::new(1);
        let dec = decide(&p, &d, &topo(), &CostModel::h100_testbed(), &s, 3, &mut rng)
            .expect("hot skew must trigger a decision");
        assert!(dec.placement.replica_count(0) > 1, "hot expert replicated");
        assert!(dec.predicted_gain > 0.0);
        assert!(dec.downtime > 0.0);
        assert_eq!(dec.bytes, dec.moves.len() as u64 * s.bytes_per_expert);
        assert!(!dec.moves.is_empty());
        dec.placement.validate().unwrap();
        // moves reproduce the placement diff exactly
        assert_eq!(dec.moves, placement_diff(&p, &dec.placement, &topo()));
    }

    #[test]
    fn budget_below_reinit_floor_blocks_everything() {
        // migration_time has a 50 ms re-init floor; a 10 ms budget can
        // never be met, so no decision may come out
        let s = ControlSpec { budget_seconds: 0.01, ..spec() };
        let d = skewed_detector(8, &s);
        let p = Placement::from_replicas(4, (0..8).map(|e| vec![e % 4]).collect());
        let mut rng = Rng::new(1);
        assert!(decide(&p, &d, &topo(), &CostModel::h100_testbed(), &s, 3, &mut rng).is_none());
    }

    #[test]
    fn full_slots_force_cold_eviction() {
        let s = spec();
        let d = skewed_detector(8, &s);
        // every GPU fully packed at 2 slots; expert 1 double-replicated so
        // a cold victim with >1 replicas exists off expert 0's GPU
        let p = Placement::from_replicas(
            4,
            vec![
                vec![0],
                vec![1, 2],
                vec![1],
                vec![2],
                vec![3],
                vec![3],
                vec![0],
                // expert 7 keeps its single replica (never evictable)
                vec![2],
            ],
        );
        assert!((0..4).all(|g| p.slots_used(g) >= 2));
        let mut rng = Rng::new(1);
        let dec = decide(&p, &d, &topo(), &CostModel::h100_testbed(), &s, 2, &mut rng)
            .expect("eviction path must free a slot for the hot expert");
        assert!(dec.evictions >= 1, "a cold replica must have been evicted");
        assert!(dec.placement.replica_count(0) > 1);
        // single-replica experts survive: eviction never orphans an expert
        for e in 0..8 {
            assert!(dec.placement.replica_count(e) >= 1);
        }
        dec.placement.validate().unwrap();
    }

    #[test]
    fn decisions_are_deterministic() {
        let s = spec();
        let d = skewed_detector(8, &s);
        let p = Placement::from_replicas(4, (0..8).map(|e| vec![e % 4]).collect());
        let run = || {
            let mut rng = Rng::new(7);
            decide(&p, &d, &topo(), &CostModel::h100_testbed(), &s, 3, &mut rng)
        };
        let (a, b) = (run().unwrap(), run().unwrap());
        assert_eq!(a.moves, b.moves);
        assert_eq!(a.placement.replicas, b.placement.replicas);
        assert_eq!(a.predicted_gain.to_bits(), b.predicted_gain.to_bits());
        assert_eq!(a.downtime.to_bits(), b.downtime.to_bits());
    }
}
