//! Placement controller: the slow loop of a two-timescale load-balancing
//! system (ROADMAP item 3, the Pro-Prophet-style replication/migration
//! layer the paper positions LPP scheduling inside).
//!
//! The fast loop is the per-micro-batch LPP token scheduler; it rebalances
//! *tokens* against a fixed replica placement and runs every step. This
//! module adds the slow loop: every `interval` steps the controller looks
//! at smoothed per-expert load, decides whether the *placement itself* has
//! gone stale, and — when the predicted Eq.-3 density gain beats the
//! migration bill — replicates hot experts, evicting cold replicas when
//! slots run out:
//!
//! ```text
//!            per step (fast)                every N steps (slow)
//!   loads ──► LPP schedule ──► plans   loads ──► EWMA + hysteresis
//!                 ▲                               │ hot/cold experts
//!                 │ placement                     ▼
//!            ┌────┴─────┐   replicate/evict  ┌─────────┐
//!            │ Placement│ ◄─────────────────┤  decide  │
//!            └──────────┘  (budgeted moves,  └─────────┘
//!                           predicted gain
//!                           vs migration time)
//! ```
//!
//! The pieces:
//!
//! * [`detect`] — [`LoadDetector`]: per-expert EWMA of load *shares* with
//!   dual hysteresis state machines (enter/exit thresholds plus a dwell
//!   requirement) flagging persistently hot and cold experts without
//!   flapping on transient spikes.
//! * [`decide`] — [`decide::decide`]: greedy replicate/evict proposals
//!   scored by the exact/approx Eq.-3 density evaluators
//!   ([`crate::placement::graph`]) against
//!   [`crate::cluster::migration::migration_time`] under the topology's
//!   link bandwidths, subject to a per-tick downtime budget and move cap.
//! * [`apply`] — [`ControlledLppBalancer`]: a [`crate::balancer::Balancer`]
//!   that executes committed decisions through
//!   [`crate::cluster::migration::placement_diff`], charges the downtime
//!   into the step's prep time ([`crate::stats::ControlStats`]), emits
//!   [`crate::obs::Span::PlacementChange`] trace spans, rebuilds the warm
//!   scheduler bases *of the affected layers only*, and re-plans.
//!
//! Determinism: the detector observes the raw per-layer input loads
//! (before any scheduling), so for a fixed spec, seed, and load trace the
//! decision sequence is a pure function of the trace — independent of
//! scheduler threading or engine worker counts. At ≤16 GPUs the density
//! evaluator takes the exact path and never consumes randomness, which is
//! what lets `tests/golden_controller.rs` replay the Python reference
//! bit-exactly.

pub mod apply;
pub mod decide;
pub mod detect;

pub use apply::ControlledLppBalancer;
pub use decide::{decide, Decision};
pub use detect::LoadDetector;

use crate::cluster::migration::expert_bytes;

/// Tuning knobs of the slow placement-control loop. All fields are plain
/// scalars so the spec round-trips through the [`crate::config`] JSON
/// registry and compares exactly; the [`crate::cluster::CostModel`] used
/// to price migrations is supplied separately (builder override or the
/// H100 testbed default).
///
/// Thresholds are expressed as multiples of the uniform share `1/E`
/// (`E` = expert count): `hot_enter = 2.0` means "flag an expert hot once
/// its smoothed load share has exceeded twice the uniform share for
/// `dwell` consecutive steps".
#[derive(Clone, Debug, PartialEq)]
pub struct ControlSpec {
    /// Steps between control ticks (the slow-loop period).
    pub interval: usize,
    /// EWMA smoothing factor for per-expert load shares, in `(0, 1]`.
    pub ema_alpha: f64,
    /// Hot-entry threshold, × uniform share. Must exceed `hot_exit`.
    pub hot_enter: f64,
    /// Hot-exit threshold, × uniform share (the hysteresis band floor).
    pub hot_exit: f64,
    /// Cold-entry threshold, × uniform share. Must be below `cold_exit`.
    pub cold_enter: f64,
    /// Cold-exit threshold, × uniform share (the hysteresis band ceiling).
    pub cold_exit: f64,
    /// Consecutive threshold-crossing steps required to flip a state.
    pub dwell: usize,
    /// Migration-downtime budget per control tick, seconds. Decisions
    /// whose [`crate::cluster::migration::migration_time`] exceeds it are
    /// rejected (note the 50 ms re-init floor: budgets below that block
    /// every migration).
    pub budget_seconds: f64,
    /// Maximum replica copies per decision.
    pub max_moves: usize,
    /// Minimum *relative* predicted density gain (fraction of the current
    /// Eq.-3 density) below which a proposal is dropped — keeps the
    /// controller from thrashing on noise-level improvements.
    pub min_gain: f64,
    /// Bytes migrated per expert replica (params + optimizer state);
    /// defaults to the GPT-32×1.3B expert of the paper's Table 2. A
    /// session-level `migration_cost(model, bytes)` override replaces it.
    pub bytes_per_expert: u64,
    /// Extra replica slots per GPU the controller may use beyond the
    /// initial placement's deepest GPU.
    pub slot_headroom: usize,
}

impl Default for ControlSpec {
    fn default() -> Self {
        ControlSpec {
            interval: 16,
            ema_alpha: 0.25,
            hot_enter: 2.0,
            hot_exit: 1.5,
            cold_enter: 0.5,
            cold_exit: 0.75,
            dwell: 4,
            budget_seconds: 0.5,
            max_moves: 8,
            min_gain: 0.01,
            bytes_per_expert: expert_bytes(2048, 8192, true),
            slot_headroom: 1,
        }
    }
}

impl ControlSpec {
    /// Check the spec's internal consistency (threshold ordering, positive
    /// periods/budgets). Returns a human-readable reason on failure; the
    /// session builder surfaces it as
    /// [`crate::balancer::SessionError::Invalid`].
    pub fn validate(&self) -> Result<(), String> {
        if self.interval == 0 {
            return Err("control interval must be >= 1 step".into());
        }
        if !(self.ema_alpha > 0.0 && self.ema_alpha <= 1.0) {
            return Err(format!("ema_alpha {} outside (0, 1]", self.ema_alpha));
        }
        if !(self.hot_enter > self.hot_exit) {
            return Err(format!(
                "hot_enter {} must exceed hot_exit {} (hysteresis band)",
                self.hot_enter, self.hot_exit
            ));
        }
        if !(self.cold_exit > self.cold_enter) {
            return Err(format!(
                "cold_exit {} must exceed cold_enter {} (hysteresis band)",
                self.cold_exit, self.cold_enter
            ));
        }
        if !(self.cold_exit <= self.hot_exit) {
            return Err(format!(
                "cold_exit {} must not exceed hot_exit {} (an expert cannot \
                 be hot and cold at once)",
                self.cold_exit, self.hot_exit
            ));
        }
        if self.dwell == 0 {
            return Err("dwell must be >= 1 step".into());
        }
        if !(self.budget_seconds > 0.0) {
            return Err(format!("budget_seconds {} must be positive", self.budget_seconds));
        }
        if self.max_moves == 0 {
            return Err("max_moves must be >= 1".into());
        }
        if !(self.min_gain >= 0.0) {
            return Err(format!("min_gain {} must be >= 0", self.min_gain));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_valid() {
        ControlSpec::default().validate().unwrap();
    }

    #[test]
    fn validate_rejects_inverted_bands() {
        let mut s = ControlSpec { hot_enter: 1.0, ..Default::default() };
        assert!(s.validate().is_err(), "hot_enter <= hot_exit must fail");
        s = ControlSpec { cold_enter: 0.9, ..Default::default() };
        assert!(s.validate().is_err(), "cold_enter >= cold_exit must fail");
        s = ControlSpec { cold_exit: 1.6, ..Default::default() };
        assert!(s.validate().is_err(), "overlapping hot/cold bands must fail");
    }

    #[test]
    fn validate_rejects_degenerate_periods() {
        for bad in [
            ControlSpec { interval: 0, ..Default::default() },
            ControlSpec { dwell: 0, ..Default::default() },
            ControlSpec { max_moves: 0, ..Default::default() },
            ControlSpec { ema_alpha: 0.0, ..Default::default() },
            ControlSpec { ema_alpha: 1.5, ..Default::default() },
            ControlSpec { budget_seconds: 0.0, ..Default::default() },
            ControlSpec { min_gain: -0.1, ..Default::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }
}
